"""AutumnKV: an LSM-backed, content-addressed prefix cache for serving.

Prompts are split into PAGE_TOKENS-token pages; each page's KV slice is
stored in the Autumn engine under a *chain hash* (rolling hash of all tokens
up to the page end), so:

  * identical prefixes across different requests share storage (dedup),
  * a lookup probes the chain hashes longest-first — each probe is a
    bloom-filtered point read, the paper's O(sqrt(log N))-runs fast path;
    misses cost ~zero block reads thanks to the Monkey allocation,
  * recurrent/SSM state snapshots are stored in the full-prompt record, so a
    full hit restores hybrid-arch caches exactly.

v1 semantics (DESIGN.md §2): full-prompt hits skip prefill entirely; partial
hits share storage (pages dedup) but recompute — the Pallas paged_attention
kernel (repro.kernels) is the on-TPU read path for paged KV.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core import LSMConfig, make_store
from repro.core.types import splitmix64
from repro.models import model as M
from repro.models.config import ModelConfig

PAGE_TOKENS = 64
Pytree = Any


def chain_hashes(tokens: np.ndarray, page: int = PAGE_TOKENS) -> List[int]:
    """Rolling hash at each full page boundary (uint64, never 0)."""
    out = []
    h = np.uint64(0x243F6A8885A308D3)
    for i, t in enumerate(np.asarray(tokens, dtype=np.uint64)):
        h = splitmix64(np.asarray([h ^ (t + np.uint64(0x9E3779B97F4A7C15))]))[0]
        if (i + 1) % page == 0:
            # page keys live in the lower half-space; bit 63 tags state records
            out.append(int(h & ((np.uint64(1) << np.uint64(63)) -
                               np.uint64(1))) or 1)
    return out


def _kv_axis(logical: Tuple[Optional[str], ...]) -> Optional[int]:
    for i, name in enumerate(logical):
        if name == "kv_seq":
            return i
    return None


@dataclasses.dataclass
class CacheCodec:
    """Splits a decode cache pytree into per-page KV slices + a state blob."""
    cfg: ModelConfig
    batch: int
    s_max: int

    def __post_init__(self):
        self.logical = M.cache_logical_specs(self.cfg, self.batch, self.s_max)

    def leaves(self, cache: Pytree):
        flat_c = jax.tree_util.tree_flatten_with_path(cache)[0]
        flat_l = jax.tree.leaves(self.logical,
                                 is_leaf=lambda x: isinstance(x, tuple))
        return [(jax.tree_util.keystr(p), v, lg)
                for (p, v), lg in zip(flat_c, flat_l)]

    def page_bytes(self, cache: Pytree, page_idx: int,
                   page: int = PAGE_TOKENS) -> bytes:
        """Serialize every kv_seq slice [page_idx*page, (page_idx+1)*page)."""
        parts = []
        for path, leaf, lg in self.leaves(cache):
            ax = _kv_axis(lg)
            if ax is None:
                continue
            arr = np.asarray(leaf)
            lo = page_idx * page
            if lo >= arr.shape[ax]:
                continue
            sl = [slice(None)] * arr.ndim
            sl[ax] = slice(lo, min(lo + page, arr.shape[ax]))
            parts.append(np.ascontiguousarray(arr[tuple(sl)]).tobytes())
        return b"".join(parts)

    def state_bytes(self, cache: Pytree) -> bytes:
        """Serialize every non-paged leaf (recurrent states, conv tails, pos)."""
        parts = []
        for path, leaf, lg in self.leaves(cache):
            if _kv_axis(lg) is None:
                parts.append(np.asarray(leaf).tobytes())
        return b"".join(parts)

    def write_page(self, cache: Pytree, blob: bytes, page_idx: int,
                   page: int = PAGE_TOKENS) -> Pytree:
        off = 0
        flat = jax.tree_util.tree_flatten_with_path(cache)
        out = []
        flat_l = jax.tree.leaves(self.logical,
                                 is_leaf=lambda x: isinstance(x, tuple))
        for (p, leaf), lg in zip(flat[0], flat_l):
            ax = _kv_axis(lg)
            arr = np.asarray(leaf)
            if ax is not None and page_idx * page < arr.shape[ax]:
                lo = page_idx * page
                hi = min(lo + page, arr.shape[ax])
                sl = [slice(None)] * arr.ndim
                sl[ax] = slice(lo, hi)
                shape = list(arr.shape)
                shape[ax] = hi - lo
                n = int(np.prod(shape)) * arr.dtype.itemsize
                piece = np.frombuffer(blob[off:off + n], arr.dtype
                                      ).reshape(shape)
                off += n
                arr = arr.copy()
                arr[tuple(sl)] = piece
            out.append(arr)
        return jax.tree.unflatten(flat[1], out)

    def write_state(self, cache: Pytree, blob: bytes) -> Pytree:
        off = 0
        flat = jax.tree_util.tree_flatten_with_path(cache)
        out = []
        flat_l = jax.tree.leaves(self.logical,
                                 is_leaf=lambda x: isinstance(x, tuple))
        for (p, leaf), lg in zip(flat[0], flat_l):
            arr = np.asarray(leaf)
            if _kv_axis(lg) is None:
                n = arr.size * arr.dtype.itemsize
                arr = np.frombuffer(blob[off:off + n], arr.dtype
                                    ).reshape(arr.shape).copy()
                off += n
            out.append(arr)
        return jax.tree.unflatten(flat[1], out)


_STATE_TAG = np.uint64(1) << np.uint64(63)


class AutumnKVCache:
    """Content-addressed page store over the Autumn LSM engine."""

    def __init__(self, cfg: ModelConfig, batch: int, s_max: int,
                 lsm_config: Optional[LSMConfig] = None,
                 page_tokens: int = PAGE_TOKENS):
        self.cfg = cfg
        self.codec = CacheCodec(cfg, batch, s_max)
        self.page = page_tokens
        self.db = make_store(lsm_config or LSMConfig(
            policy="garnering", T=2.0, c=0.8, memtable_bytes=1 << 20,
            base_level_bytes=8 << 20, bits_per_key=10,
            bloom_allocation="monkey",
            # memory subsystem (DESIGN.md §9): hot page blocks served from
            # DRAM, L0 pinned so fresh inserts are always resident (sharded:
            # one shared budgeted cache, 1/N slices per shard)
            cache_bytes=4 << 20, pin_l0_bytes=2 << 20,
            # async scheduler (DESIGN.md §11): page-insert bursts after
            # prefill return without paying flush/compaction; lookups read
            # through the immutable-memtable window mid-churn
            async_compaction=True,
            # sharded keyspace (DESIGN.md §12): chain hashes are uniform over
            # uint64, so the default splitters balance; two shards run
            # background flush/compaction in parallel under one worker budget
            shards=2, compaction_workers=2))
        self.hits = 0
        self.misses = 0
        self.pages_written = 0
        self.pages_deduped = 0

    # ------------------------------------------------------------ interface
    def lookup(self, tokens: np.ndarray, template: Pytree) -> Optional[Pytree]:
        """Full-prompt hit: reassemble the decode cache; else None."""
        hs = chain_hashes(tokens, self.page)
        if not hs or len(tokens) % self.page != 0:
            self.misses += 1
            return None
        state_blob = self.db.get(int(np.uint64(hs[-1]) | _STATE_TAG))
        if state_blob is None:
            self.misses += 1
            return None
        cache = self.codec.write_state(template, state_blob)
        for i, h in enumerate(hs):
            page_blob = self.db.get(h)
            if page_blob is None:
                self.misses += 1
                return None
            cache = self.codec.write_page(cache, page_blob, i, self.page)
        self.hits += 1
        return cache

    def lookup_batch(self, prompts: List[np.ndarray],
                     template: Pytree) -> List[Optional[Pytree]]:
        """Batched ``lookup`` for a serving wave (DESIGN.md §3).

        Gathers every prompt's state + page chain-hash keys and resolves them
        with ONE ``LSMStore.multi_get`` — the engine probes each level's
        filters for the whole wave at once instead of walking the tree once
        per key.  Hit/miss semantics and counters match per-prompt
        ``lookup`` calls.
        """
        metas: List[Tuple[List[int], bool]] = []
        all_keys: List[int] = []
        for tokens in prompts:
            hs = chain_hashes(tokens, self.page)
            ok = bool(hs) and len(tokens) % self.page == 0
            metas.append((hs, ok))
            if ok:
                all_keys.append(int(np.uint64(hs[-1]) | _STATE_TAG))
                all_keys.extend(hs)
        blobs = self.db.multi_get(all_keys) if all_keys else []
        out: List[Optional[Pytree]] = []
        off = 0
        for hs, ok in metas:
            if not ok:
                self.misses += 1
                out.append(None)
                continue
            state_blob = blobs[off]
            page_blobs = blobs[off + 1: off + 1 + len(hs)]
            off += 1 + len(hs)
            if state_blob is None or any(b is None for b in page_blobs):
                self.misses += 1
                out.append(None)
                continue
            cache = self.codec.write_state(template, state_blob)
            for i, blob in enumerate(page_blobs):
                cache = self.codec.write_page(cache, blob, i, self.page)
            self.hits += 1
            out.append(cache)
        return out

    def insert(self, tokens: np.ndarray, cache: Pytree):
        hs = chain_hashes(tokens, self.page)
        for i, h in enumerate(hs):
            if self.db.get(h) is not None:   # content-addressed dedup
                self.pages_deduped += 1
                continue
            self.db.put(h, self.codec.page_bytes(cache, i, self.page))
            self.pages_written += 1
        if hs:
            self.db.put(int(np.uint64(hs[-1]) | _STATE_TAG),
                        self.codec.state_bytes(cache))
        self.db.flush()

    def stats(self) -> Dict[str, Any]:
        out = dict(hits=self.hits, misses=self.misses,
                   pages_written=self.pages_written,
                   pages_deduped=self.pages_deduped,
                   levels=self.db.num_levels_in_use,
                   block_cache=self.db.cache_summary(),
                   io=self.db.stats.to_dict())
        tel = self.db.telemetry
        if tel is not None:
            # per-op-class latency summaries + trace health (DESIGN.md §14);
            # attach a Telemetry via lsm_config=LSMConfig(..., telemetry=...)
            out["latency"] = tel.summary()
            out["trace_events"] = len(tel.trace)
        return out

    def close(self) -> None:
        """Drain and stop the store's background compaction workers.

        The cache keeps serving afterwards on the synchronous path; call
        this when retiring an engine so each cache instance doesn't leave a
        parked worker thread behind.
        """
        self.db.close()
