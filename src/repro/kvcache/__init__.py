from .autumnkv import PAGE_TOKENS, AutumnKVCache, CacheCodec, chain_hashes
