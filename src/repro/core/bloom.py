"""Bloom filters + the Monkey/Autumn optimal FPR allocation (paper Eq. 2, 7-10).

``BloomFilter`` is a vectorized double-hashing bloom filter over uint64 keys.
``allocate_fprs`` solves the Monkey optimization adapted to Garnering: minimize
the zero-result point-read cost R = sum_i p_i subject to the total filter
memory budget (Eq. 8).  The Lagrangian solution is p_i proportional to N_i
(capped at 1), which for Garnering capacities reproduces Eq. 9:
p_{L-i} = p_L * c^{i(i-1)/2} / T^i.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

import numpy as np

from .types import splitmix64

LN2 = math.log(2.0)
LN2_SQ = LN2 * LN2


class BloomFilter:
    """Standard bloom filter with k = round(bits_per_key * ln2) double hashes."""

    __slots__ = ("m_bits", "k", "bits", "n_keys")

    def __init__(self, keys: np.ndarray, bits_per_key: float):
        n = int(keys.size)
        self.n_keys = n
        if n == 0 or bits_per_key <= 0:
            # Degenerate filter: answers "maybe" for everything (FPR = 1).
            self.m_bits = 0
            self.k = 0
            self.bits = np.zeros(0, dtype=np.uint64)
            return
        m = max(64, int(round(bits_per_key * n)))
        self.m_bits = m
        self.k = max(1, int(round(bits_per_key * LN2)))
        self.bits = np.zeros((m + 63) // 64, dtype=np.uint64)
        h1, h2 = self._hashes(np.asarray(keys, dtype=np.uint64))
        for i in range(self.k):
            pos = (h1 + np.uint64(i) * h2) % np.uint64(m)
            np.bitwise_or.at(self.bits, (pos >> np.uint64(6)).astype(np.int64),
                             np.uint64(1) << (pos & np.uint64(63)))

    @staticmethod
    def _hashes(keys: np.ndarray):
        h1 = splitmix64(keys)
        h2 = splitmix64(h1) | np.uint64(1)  # odd => full-period double hashing
        return h1, h2

    def may_contain(self, keys: np.ndarray) -> np.ndarray:
        """Vectorized membership test. True = maybe present, False = absent."""
        keys = np.asarray(keys, dtype=np.uint64)
        if self.m_bits == 0:
            return np.ones(keys.shape, dtype=bool)
        h1, h2 = self._hashes(keys)
        out = np.ones(keys.shape, dtype=bool)
        m = np.uint64(self.m_bits)
        for i in range(self.k):
            pos = (h1 + np.uint64(i) * h2) % m
            word = self.bits[(pos >> np.uint64(6)).astype(np.int64)]
            out &= (word >> (pos & np.uint64(63))) & np.uint64(1) != 0
        return out

    @property
    def memory_bits(self) -> int:
        return self.m_bits

    def expected_fpr(self) -> float:
        if self.m_bits == 0:
            return 1.0
        return theoretical_fpr(self.m_bits / max(self.n_keys, 1))


def theoretical_fpr(bits_per_key: float) -> float:
    """Eq. 2: FPR = e^{-ln(2)^2 * M/N}."""
    if bits_per_key <= 0:
        return 1.0
    return math.exp(-LN2_SQ * bits_per_key)


def bits_for_fpr(p: float) -> float:
    """Invert Eq. 2: bits/key needed for target FPR p (p in (0, 1])."""
    if p >= 1.0:
        return 0.0
    return -math.log(p) / LN2_SQ


def allocate_fprs(level_sizes: Sequence[int], total_bits: float) -> np.ndarray:
    """Monkey/Autumn water-filling (Eq. 7-10 generalized to measured N_i).

    Minimize sum_i p_i  s.t.  sum_i (-N_i ln p_i / ln2^2) = total_bits,
    0 < p_i <= 1.  KKT => p_i = lam * N_i on the interior, p_i = 1 where the
    budget runs out (largest levels saturate first, exactly as the paper sets
    p_L = 1 in the "Filter Memory Budget" analysis).
    Returns the optimal per-level FPRs.
    """
    sizes = np.asarray([max(int(s), 0) for s in level_sizes], dtype=np.float64)
    L = sizes.size
    fprs = np.ones(L)
    if total_bits <= 0 or L == 0:
        return fprs
    active = sizes > 0
    # Saturate levels (p_i = 1) from the largest down until the remaining
    # budget supports an interior solution with p_i <= 1 for all active i.
    order = np.argsort(-sizes)  # largest first
    saturated = np.zeros(L, dtype=bool)
    for cut in range(L + 1):
        interior = active & ~saturated
        if not interior.any():
            break
        n_int = sizes[interior]
        # Interior solution: p_i = lam*N_i; budget constraint gives
        # sum(-N_i ln(lam N_i)) / ln2^2 = total_bits  =>  solve for ln lam.
        s = n_int.sum()
        ln_lam = -(total_bits * LN2_SQ + (n_int * np.log(n_int)).sum()) / s
        p = np.exp(ln_lam) * n_int
        if (p <= 1.0 + 1e-12).all():
            fprs[interior] = np.minimum(p, 1.0)
            return fprs
        # Saturate the largest not-yet-saturated level and retry.
        for idx in order:
            if active[idx] and not saturated[idx]:
                saturated[idx] = True
                break
    return fprs


def fprs_to_bits_per_key(fprs: Sequence[float]) -> np.ndarray:
    return np.asarray([bits_for_fpr(p) for p in fprs])


def garnering_theoretical_fprs(L: int, T: float, c: float, p_last: float = 1.0
                               ) -> np.ndarray:
    """Closed-form Eq. 9: p_{L-i} = p_L * c^{i(i-1)/2} / T^i (1-indexed levels)."""
    out = np.empty(L)
    for i in range(L):  # i = distance from last level
        out[L - 1 - i] = p_last * (c ** (i * (i - 1) / 2)) / (T ** i)
    return np.minimum(out, 1.0)


def zero_result_read_cost(fprs: Sequence[float]) -> float:
    """Eq. 7: expected blocks read by a point query for an absent key."""
    return float(np.sum(fprs))
