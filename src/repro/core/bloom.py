"""Bloom filters + the Monkey/Autumn optimal FPR allocation (paper Eq. 2, 7-10).

``BloomFilter`` is a vectorized double-hashing bloom filter over uint64 keys.
Bit positions are computed with the *same* 32-bit murmur-style hash family as
the Pallas batched-probe kernel (``repro.kernels.bloom_probe.hash_pair``) and
the bitset is stored as uint32 words, so the engine's batched read path can
probe the identical filter either in numpy (``may_contain``) or on the VPU
(``repro.kernels.ops.bloom_probe_filter``) and get bit-identical answers
(DESIGN.md §3).

``allocate_fprs`` solves the Monkey optimization adapted to Garnering: minimize
the zero-result point-read cost R = sum_i p_i subject to the total filter
memory budget (Eq. 8).  The Lagrangian solution is p_i proportional to N_i
(capped at 1), which for Garnering capacities reproduces Eq. 9:
p_{L-i} = p_L * c^{i(i-1)/2} / T^i.
"""
from __future__ import annotations

import math
import sys
from typing import Optional, Sequence

import numpy as np

LN2 = math.log(2.0)
LN2_SQ = LN2 * LN2


def _mix32(x: np.ndarray, c1: int, c2: int) -> np.ndarray:
    """numpy twin of kernels.bloom_probe._mix32 (must stay in lockstep)."""
    x = x.astype(np.uint32, copy=True)
    x ^= x >> np.uint32(16)
    x *= np.uint32(c1)
    x ^= x >> np.uint32(13)
    x *= np.uint32(c2)
    x ^= x >> np.uint32(16)
    return x


def hash_pair(keys: np.ndarray):
    """Two independent uint32 hashes of u64 keys — identical positions to the
    Pallas kernel's ``hash_pair`` on the (lo, hi) halves."""
    keys = np.asarray(keys, dtype=np.uint64)
    lo = (keys & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    hi = (keys >> np.uint64(32)).astype(np.uint32)
    h1 = _mix32(lo ^ _mix32(hi, 0x85EBCA6B, 0xC2B2AE35),
                0xCC9E2D51, 0x1B873593)
    h2 = _mix32(hi ^ _mix32(lo, 0x27D4EB2F, 0x165667B1),
                0x9E3779B9, 0x85EBCA77) | np.uint32(1)
    return h1, h2


def build_bits(h1: np.ndarray, h2: np.ndarray, k: int, m_bits: int
               ) -> np.ndarray:
    """Construct the uint32-word bitset from hashes in one vectorized pass.

    All ``k * n`` double-hash positions are computed at once, scattered into
    a boolean bit map (duplicate positions collapse for free), and packed
    little-endian — the exact word/bit layout ``may_contain`` and the Pallas
    probe kernel index.  Replaces the k-iteration ``np.bitwise_or.at`` loop,
    which is unbuffered and dominates compaction's filter-rebuild cost.
    """
    ks = np.arange(k, dtype=np.uint32)[:, None]
    pos = (h1[None, :] + ks * h2[None, :]) % np.uint32(m_bits)
    bitmap = np.zeros(m_bits, dtype=bool)
    bitmap[pos.ravel()] = True
    words = np.packbits(bitmap, bitorder="little").view(np.uint32)
    if sys.byteorder == "big":   # packed bytes are little-endian words
        words = words.byteswap()
    return words


class BloomFilter:
    """Standard bloom filter with k = round(bits_per_key * ln2) double hashes.

    ``bits`` is a uint32 word array with m_bits == 32 * len(bits), the exact
    layout ``bloom_probe_pallas`` consumes.
    """

    __slots__ = ("m_bits", "k", "bits", "n_keys")

    def __init__(self, keys: np.ndarray, bits_per_key: float, hash_fn=None):
        """``hash_fn(keys) -> (h1, h2)`` optionally reroutes the hash pass
        (e.g. ``kernels.ops.bloom_build_hashes``, the engine's
        ``use_pallas_bloom`` build route); it must stay in bit-lockstep with
        :func:`hash_pair` so numpy and VPU probes agree on the bitset."""
        n = int(keys.size)
        self.n_keys = n
        if n == 0 or bits_per_key <= 0:
            # Degenerate filter: answers "maybe" for everything (FPR = 1).
            self.m_bits = 0
            self.k = 0
            self.bits = np.zeros(0, dtype=np.uint32)
            return
        # Round up to whole uint32 words: the Pallas kernel derives m from the
        # word count, so numpy and VPU probes must agree on m exactly.
        m = -(-max(64, int(round(bits_per_key * n))) // 32) * 32
        self.m_bits = m
        self.k = max(1, int(round(bits_per_key * LN2)))
        h1, h2 = (hash_fn or hash_pair)(np.asarray(keys, dtype=np.uint64))
        self.bits = build_bits(np.asarray(h1, dtype=np.uint32),
                               np.asarray(h2, dtype=np.uint32), self.k, m)

    def may_contain(self, keys: np.ndarray) -> np.ndarray:
        """Vectorized membership test. True = maybe present, False = absent."""
        keys = np.asarray(keys, dtype=np.uint64)
        if self.m_bits == 0:
            return np.ones(keys.shape, dtype=bool)
        h1, h2 = hash_pair(keys)
        out = np.ones(keys.shape, dtype=bool)
        m = np.uint32(self.m_bits)
        for i in range(self.k):
            pos = (h1 + np.uint32(i) * h2) % m
            word = self.bits[(pos >> np.uint32(5)).astype(np.int64)]
            out &= (word >> (pos & np.uint32(31))) & np.uint32(1) != 0
        return out

    @property
    def memory_bits(self) -> int:
        return self.m_bits

    def expected_fpr(self) -> float:
        if self.m_bits == 0:
            return 1.0
        return theoretical_fpr(self.m_bits / max(self.n_keys, 1))


def theoretical_fpr(bits_per_key: float) -> float:
    """Eq. 2: FPR = e^{-ln(2)^2 * M/N}."""
    if bits_per_key <= 0:
        return 1.0
    return math.exp(-LN2_SQ * bits_per_key)


def bits_for_fpr(p: float) -> float:
    """Invert Eq. 2: bits/key needed for target FPR p (p in (0, 1])."""
    if p >= 1.0:
        return 0.0
    return -math.log(p) / LN2_SQ


def allocate_fprs(level_sizes: Sequence[int], total_bits: float) -> np.ndarray:
    """Monkey/Autumn water-filling (Eq. 7-10 generalized to measured N_i).

    Minimize sum_i p_i  s.t.  sum_i (-N_i ln p_i / ln2^2) = total_bits,
    0 < p_i <= 1.  KKT => p_i = lam * N_i on the interior, p_i = 1 where the
    budget runs out (largest levels saturate first, exactly as the paper sets
    p_L = 1 in the "Filter Memory Budget" analysis).
    Returns the optimal per-level FPRs.
    """
    sizes = np.asarray([max(int(s), 0) for s in level_sizes], dtype=np.float64)
    L = sizes.size
    fprs = np.ones(L)
    if total_bits <= 0 or L == 0:
        return fprs
    active = sizes > 0
    # Saturate levels (p_i = 1) from the largest down until the remaining
    # budget supports an interior solution with p_i <= 1 for all active i.
    order = np.argsort(-sizes)  # largest first
    saturated = np.zeros(L, dtype=bool)
    for cut in range(L + 1):
        interior = active & ~saturated
        if not interior.any():
            break
        n_int = sizes[interior]
        # Interior solution: p_i = lam*N_i; budget constraint gives
        # sum(-N_i ln(lam N_i)) / ln2^2 = total_bits  =>  solve for ln lam.
        s = n_int.sum()
        ln_lam = -(total_bits * LN2_SQ + (n_int * np.log(n_int)).sum()) / s
        p = np.exp(ln_lam) * n_int
        if (p <= 1.0 + 1e-12).all():
            fprs[interior] = np.minimum(p, 1.0)
            return fprs
        # Saturate the largest not-yet-saturated level and retry.
        for idx in order:
            if active[idx] and not saturated[idx]:
                saturated[idx] = True
                break
    return fprs


def fprs_to_bits_per_key(fprs: Sequence[float]) -> np.ndarray:
    return np.asarray([bits_for_fpr(p) for p in fprs])


def garnering_theoretical_fprs(L: int, T: float, c: float, p_last: float = 1.0
                               ) -> np.ndarray:
    """Closed-form Eq. 9: p_{L-i} = p_L * c^{i(i-1)/2} / T^i (1-indexed levels)."""
    out = np.empty(L)
    for i in range(L):  # i = distance from last level
        out[L - 1 - i] = p_last * (c ** (i * (i - 1) / 2)) / (T ** i)
    return np.minimum(out, 1.0)


def zero_result_read_cost(fprs: Sequence[float]) -> float:
    """Eq. 7: expected blocks read by a point query for an absent key."""
    return float(np.sum(fprs))
