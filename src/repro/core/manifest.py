"""MVCC manifest: immutable versions of the tree + a metadata log (§2.1).

Readers pin a :class:`Version`; flushes/compactions install a new version
atomically.  The metadata log mirrors RocksDB's MANIFEST: an append-only
record of version edits with an fsync watermark, so crash recovery restores
the last durable version and never observes a half-applied compaction.
"""
from __future__ import annotations

import dataclasses
import struct
import threading
from typing import Dict, List, Optional, Sequence, Tuple

from .faults import crc32c
from .run import SortedRun
from .types import IOStats


def _edit_checksum(version_id: int, levels: Tuple[Tuple[int, ...], ...],
                   max_level: int, last_seq: int) -> int:
    """CRC-32C over a version edit's canonical encoding (DESIGN.md §16.2):
    ``<QQQ>(version_id, max_level, last_seq)`` then, per level,
    ``<q>len`` followed by each run id as ``<q>``."""
    parts = [struct.pack("<QQQ", version_id, max_level, last_seq)]
    for lvl in levels:
        parts.append(struct.pack("<q", len(lvl)))
        parts.extend(struct.pack("<q", rid) for rid in lvl)
    return crc32c(b"".join(parts))


@dataclasses.dataclass(frozen=True)
class Version:
    version_id: int
    levels: Tuple[Tuple[int, ...], ...]  # run ids per level
    max_level: int
    last_seq: int
    checksum: int = -1  # CRC-32C of the edit; -1 = legacy/unchecksummed

    def verify(self) -> bool:
        """True iff the stored edit checksum matches the fields."""
        return self.checksum == _edit_checksum(
            self.version_id, self.levels, self.max_level, self.last_seq)

    def runs(self, storage: "RunStorage") -> List[List[SortedRun]]:
        return [[storage.get(rid) for rid in lvl] for lvl in self.levels]


class RunStorage:
    """Owns immutable runs by id; refcounted by manifest versions."""

    def __init__(self):
        self._runs: Dict[int, SortedRun] = {}

    def add(self, run: SortedRun) -> int:
        self._runs[run.run_id] = run
        return run.run_id

    def get(self, run_id: int) -> SortedRun:
        return self._runs[run_id]

    def ids(self) -> List[int]:
        """Ids of every run still owned (current + snapshot-pinned versions)."""
        return list(self._runs.keys())

    def gc(self, live_ids: Sequence[int]):
        live = set(live_ids)
        for rid in [r for r in self._runs if r not in live]:
            del self._runs[rid]

    def __len__(self):
        return len(self._runs)


class Manifest:
    """Thread-safety: every method takes the manifest mutex, so version
    installs (the async scheduler's worker), reader pin/unpin traffic, and
    GC interleave atomically; a pinned :class:`Version` itself is immutable
    and is read lock-free."""

    def __init__(self, storage: RunStorage):
        self.storage = storage
        self._mu = threading.RLock()
        self._log: List[Version] = []
        self._pinned: Dict[int, Version] = {}  # long-lived reader snapshots
        self._pin_refs: Dict[int, int] = {}    # version_id -> reader refcount
        self._synced_upto = 0  # number of durable versions
        self._next_id = 0
        self.commit(levels=[[]], max_level=1, last_seq=0, stats=IOStats())
        self.fsync(IOStats())

    # ------------------------------------------------------------- writes
    def commit(self, levels: Sequence[Sequence[SortedRun]], max_level: int,
               last_seq: int, stats: IOStats) -> Version:
        with self._mu:
            lv = tuple(tuple(self.storage.add(r) for r in lvl)
                       for lvl in levels)
            v = Version(self._next_id, lv, max_level, last_seq,
                        _edit_checksum(self._next_id, lv, max_level, last_seq))
            self._next_id += 1
            self._log.append(v)
            return v

    def fsync(self, stats: IOStats):
        with self._mu:
            self._synced_upto = len(self._log)
            stats.wal_fsyncs += 1
            # Old versions with no readers can be GC'd; keep the durable tail.
            if len(self._log) > 8:
                self._log = self._log[-8:]
                self._synced_upto = len(self._log)

    # -------------------------------------------------------------- reads
    def current(self) -> Version:
        with self._mu:
            return self._log[-1]

    def pin(self, v: Version) -> Version:
        """Pin a version for a long-lived reader: its runs survive GC even
        after the version leaves the manifest's durable tail.

        Pins are *refcounted*: two readers pinning the same version each hold
        a reference, and the version stays pinned until every reader unpins —
        long-lived readers can no longer leak a version by releasing a pin
        another reader still depends on.
        """
        with self._mu:
            self._pinned[v.version_id] = v
            self._pin_refs[v.version_id] = \
                self._pin_refs.get(v.version_id, 0) + 1
            return v

    def pin_current(self) -> Version:
        """Atomically read-and-pin the newest version.

        ``pin(current())`` from a reader thread races a concurrent
        flush/compaction install: the version read could age out of the
        durable tail (and lose its runs to GC) before the pin lands.  Taking
        both steps under the manifest mutex closes the window; snapshots and
        the scheduler's in-flight-compaction input retention both use this.
        """
        with self._mu:
            return self.pin(self._log[-1])

    def unpin(self, version_id: int) -> bool:
        """Drop one reader reference; the version unpins at refcount zero.

        Returns True iff this release actually unpinned the version (callers
        skip GC work while other readers still hold it)."""
        with self._mu:
            refs = self._pin_refs.get(version_id, 0) - 1
            if refs > 0:
                self._pin_refs[version_id] = refs
                return False
            self._pin_refs.pop(version_id, None)
            return self._pinned.pop(version_id, None) is not None

    def pin_count(self, version_id: int) -> int:
        with self._mu:
            return self._pin_refs.get(version_id, 0)

    def total_pin_refs(self) -> int:
        """Sum of all reader/compaction references (leak audit hook)."""
        with self._mu:
            return sum(self._pin_refs.values())

    def crash(self, faults=None):
        """Lose versions past the fsync watermark (simulated crash).

        An armed :class:`~repro.core.faults.FaultInjector` with
        ``corrupt_manifest_edit()`` damages the last surviving edit
        (garbles ``last_seq`` without updating its checksum), so recovery
        must detect the mismatch and fall back one version.
        """
        with self._mu:
            self._pinned.clear()  # reader pins are process state, not durable
            self._pin_refs.clear()
            self._log = self._log[: max(self._synced_upto, 1)]
            if faults is not None and faults.manifest_corruption \
                    and len(self._log) > 1:
                faults.manifest_corruption = False
                faults.fired["manifest_edit"] = \
                    faults.fired.get("manifest_edit", 0) + 1
                v = self._log[-1]
                self._log[-1] = dataclasses.replace(
                    v, last_seq=v.last_seq ^ (1 << 17))

    def recover_current(self) -> Tuple[Version, int]:
        """Newest checksum-valid version, popping any corrupt tail edits.

        Every popped edit was itself a durable prefix of the manifest log,
        so falling back one (or more) versions is prefix-consistent by
        construction.  Version 0 (the empty tree) is the floor.  Returns
        ``(version, n_popped)``.
        """
        with self._mu:
            popped = 0
            while len(self._log) > 1 and not self._log[-1].verify():
                self._log.pop()
                popped += 1
            self._synced_upto = min(self._synced_upto, len(self._log))
            return self._log[-1], popped

    def live_run_ids(self) -> List[int]:
        with self._mu:
            ids: List[int] = []
            for v in self._log:
                for lvl in v.levels:
                    ids.extend(lvl)
            for v in self._pinned.values():
                for lvl in v.levels:
                    ids.extend(lvl)
            return ids

    def gc(self):
        with self._mu:
            self.storage.gc(self.live_run_ids())
