"""Telemetry subsystem: latency histograms + engine event tracing (§14).

Every number the engine reported before this module was a throughput mean or
a lifetime counter in :class:`~repro.core.types.IOStats` — no way to see
*tail* latency, *when* a stall happened, or *which* background event caused
it.  The LSM survey (Luo & Carey) makes the point that stall/compaction/cache
telemetry is what separates a tunable production store from a benchmark demo,
and the planned workload-adaptive tuner ("How to Grow an LSM-tree") needs
exactly these runtime signals as its input.  Three pieces:

``LatencyHistogram``
    Log-bucketed (2 buckets per octave — bucket edges at powers of sqrt(2),
    ~±19% relative resolution) numpy-backed counts over [1 ns, ~2 minutes],
    with ``record(ns)``, ``record_many(array)``, ``percentile(p)`` and the
    same fieldwise ``__add__``/``merge`` algebra ``IOStats`` has, so
    per-thread and per-shard histograms aggregate by summation.

``EventTrace``
    A bounded ring buffer of timestamped engine lifecycle events (flush and
    compaction start/end, slowdown/stall enter/exit, view rebuilds, cache
    eviction pressure, shard snapshot retries, background failures), with
    ``dump()``/``since(cursor)`` for incremental consumption and a
    human-readable ``timeline()`` report.  End events carry ``t0``/``dur_ns``
    so consumers can rebuild intervals without pairing start/end records.

``Telemetry``
    The facade a store carries via ``LSMConfig.telemetry`` (``None`` by
    default — every instrumentation site is a single ``is None`` check when
    disabled).  Latency records go to **per-thread** histogram shards
    registered with a GIL-atomic ``list.append`` — recording on the lock-free
    read path acquires no lock and loses no increments under concurrency;
    merging happens at *read* time (``histogram``/``summary``).  Trace
    emission takes a tiny leaf lock, but is only called from lifecycle paths
    (flush/compaction/stall/rebuild/eviction), never from the lock-free read
    path.  All timestamps are ``time.perf_counter_ns()`` so histogram samples
    and trace events share one monotonic clock.

Sharded aggregation is free by construction: the facade installs one live
``LSMConfig`` on every shard, so all shards record into the same
``Telemetry`` object (events may carry a ``shard`` field where the emitter
knows it).
"""
from __future__ import annotations

import math
import threading
import time
from collections import deque
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["LatencyHistogram", "EventTrace", "TraceEvent", "Telemetry",
           "TelemetrySnapshot", "TelemetryWindow", "OP_CLASSES"]

# Per-op-class latency histograms the engine records (benchmarks may add
# their own classes; the Telemetry facade accepts any string key).
OP_CLASSES = ("get", "multi_get", "put", "put_batch", "write_batch",
              "scan", "seek", "flush", "compaction", "view_rebuild",
              "wal_fsync", "stall", "rebalance", "scrub")

_SQRT2 = math.sqrt(2.0)
# Octaves 0..42 cover 1 ns .. 2^42 ns (~73 min) at 2 buckets/octave;
# anything larger clamps into the top bucket.
_MAX_OCTAVE = 42
N_BUCKETS = 2 * (_MAX_OCTAVE + 1)
# Lower edge of bucket i: even buckets start at 2^o, odd at floor(2^o*sqrt2).
# (The first odd edge collides with its octave start for o=0 — one empty
# bucket at the bottom, harmless and kept so index math stays branch-free.)
_MID = tuple(int((1 << o) * _SQRT2) for o in range(_MAX_OCTAVE + 2))
BUCKET_EDGES = np.asarray(
    [e for o in range(_MAX_OCTAVE + 1) for e in ((1 << o), _MID[o])],
    dtype=np.int64)
# Upper edge per bucket (top bucket closes one octave up).
_UPPER = np.empty(N_BUCKETS, dtype=np.int64)
_UPPER[:-1] = BUCKET_EDGES[1:]
_UPPER[-1] = 1 << (_MAX_OCTAVE + 1)


def bucket_of(ns: int) -> int:
    """Bucket index of a duration (the single definition ``record``,
    ``record_many`` and the percentile oracle tests all share)."""
    ns = int(ns)
    if ns < 1:
        ns = 1
    o = ns.bit_length() - 1
    if o > _MAX_OCTAVE:
        return N_BUCKETS - 1
    return (o << 1) + (1 if ns >= _MID[o] else 0)


class LatencyHistogram:
    """Log-bucketed latency histogram with the IOStats merge algebra."""

    __slots__ = ("counts", "n", "sum_ns", "max_ns", "min_ns")

    def __init__(self):
        self.counts = np.zeros(N_BUCKETS, dtype=np.int64)
        self.n = 0
        self.sum_ns = 0
        self.max_ns = 0
        self.min_ns = 0       # 0 while empty

    # ------------------------------------------------------------- recording
    def record(self, ns: int) -> None:
        """One sample, O(1), no locks (callers keep per-thread instances)."""
        ns = int(ns)
        if ns < 1:
            ns = 1
        o = ns.bit_length() - 1
        if o > _MAX_OCTAVE:
            i = N_BUCKETS - 1
        else:
            i = (o << 1) + (1 if ns >= _MID[o] else 0)
        self.counts[i] += 1
        self.n += 1
        self.sum_ns += ns
        if ns > self.max_ns:
            self.max_ns = ns
        if self.min_ns == 0 or ns < self.min_ns:
            self.min_ns = ns

    def record_many(self, ns_array) -> None:
        """Vectorized ``record`` (bulk ingestion from benchmark harnesses).

        Bucket-for-bucket identical to a scalar ``record`` loop: the edge
        array is the same one ``bucket_of`` indexes.
        """
        a = np.asarray(ns_array, dtype=np.int64)
        if a.size == 0:
            return
        a = np.maximum(a, 1)
        idx = np.searchsorted(BUCKET_EDGES, a, side="right") - 1
        np.clip(idx, 0, N_BUCKETS - 1, out=idx)
        self.counts += np.bincount(idx, minlength=N_BUCKETS)
        self.n += int(a.size)
        self.sum_ns += int(a.sum())
        mx = int(a.max())
        if mx > self.max_ns:
            self.max_ns = mx
        mn = int(a.min())
        if self.min_ns == 0 or mn < self.min_ns:
            self.min_ns = mn

    # ------------------------------------------------------------- queries
    def percentile(self, p: float) -> float:
        """Nearest-rank percentile, geometrically interpolated *within* the
        bucket holding the rank-th smallest sample by the rank's position
        among that bucket's samples.  The estimate always stays inside the
        bucket (tests assert bucket equality exactly; a lone sample gets
        the geometric midpoint, as before), but unlike a fixed midpoint it
        moves smoothly as the tail mass shifts — the online tuner's
        objective (§17) needs that resolution to see a gradient between
        windows whose p99 lands in the same half-octave bucket."""
        if self.n == 0:
            return float("nan")
        rank = max(1, math.ceil(self.n * float(p) / 100.0))
        cum = np.cumsum(self.counts)
        i = int(np.searchsorted(cum, rank))
        lo = max(int(BUCKET_EDGES[i]), 1)
        hi = max(int(_UPPER[i]), lo)
        if hi <= lo:
            return float(lo)
        before = int(cum[i - 1]) if i else 0
        cnt = int(self.counts[i])
        frac = (rank - before - 0.5) / cnt if cnt else 0.5
        return lo * (hi / lo) ** frac

    def mean(self) -> float:
        return self.sum_ns / self.n if self.n else float("nan")

    def __len__(self) -> int:
        return self.n

    # -------------------------------------------------------------- algebra
    def __add__(self, other: "LatencyHistogram") -> "LatencyHistogram":
        if not isinstance(other, LatencyHistogram):
            return NotImplemented
        out = LatencyHistogram()
        out.counts = self.counts + other.counts
        out.n = self.n + other.n
        out.sum_ns = self.sum_ns + other.sum_ns
        out.max_ns = max(self.max_ns, other.max_ns)
        if self.min_ns and other.min_ns:
            out.min_ns = min(self.min_ns, other.min_ns)
        else:
            out.min_ns = self.min_ns or other.min_ns
        return out

    def __radd__(self, other):
        if other == 0:   # sum() support
            return self + LatencyHistogram()
        return self.__add__(other)

    @staticmethod
    def merge(hists: "Iterable[LatencyHistogram]") -> "LatencyHistogram":
        out = LatencyHistogram()
        for h in hists:
            out = out + h
        return out

    def diff(self, prev: "LatencyHistogram") -> "LatencyHistogram":
        """Windowed delta ``self - prev`` (counts/n/sum_ns are monotonic, so
        the subtraction is the interval's histogram — the sensing primitive
        behind :meth:`Telemetry.delta`, DESIGN.md §17).  ``max_ns``/``min_ns``
        are not subtractable; the window keeps the lifetime extremes, which
        only ever *widen* a percentile caller's view, never narrow it."""
        out = LatencyHistogram()
        out.counts = self.counts - prev.counts
        out.n = self.n - prev.n
        out.sum_ns = self.sum_ns - prev.sum_ns
        out.max_ns = self.max_ns
        out.min_ns = self.min_ns
        return out

    def to_dict(self) -> Dict[str, float]:
        """Summary row (stable key order) for JSON dumps / stats() surfaces."""
        return dict(count=self.n,
                    p50_ns=self.percentile(50),
                    p99_ns=self.percentile(99),
                    p999_ns=self.percentile(99.9),
                    max_ns=self.max_ns,
                    min_ns=self.min_ns,
                    mean_ns=self.mean())


class TraceEvent:
    """One timestamped engine lifecycle event (immutable)."""

    __slots__ = ("seq", "ts_ns", "kind", "fields")

    def __init__(self, seq: int, ts_ns: int, kind: str, fields: dict):
        self.seq = seq
        self.ts_ns = ts_ns
        self.kind = kind
        self.fields = fields

    def interval(self) -> Optional[Tuple[int, int]]:
        """(t0, t1) when the event carries one (end events with t0/dur_ns)."""
        t0 = self.fields.get("t0")
        dur = self.fields.get("dur_ns")
        if t0 is None or dur is None:
            return None
        return int(t0), int(t0) + int(dur)

    def __repr__(self):
        kv = " ".join(f"{k}={v}" for k, v in self.fields.items())
        return f"TraceEvent({self.seq} {self.kind} {kv})"


class EventTrace:
    """Bounded ring buffer of :class:`TraceEvent` (oldest dropped first).

    ``emit`` takes a small leaf mutex (it never acquires another lock, so it
    is deadlock-free inside the cache/scheduler mutexes that call it); it is
    only used on lifecycle paths, never on the lock-free read path.
    """

    def __init__(self, capacity: int = 4096):
        self.capacity = int(capacity)
        self._mu = threading.Lock()
        self._buf: deque = deque(maxlen=self.capacity)
        self._seq = 0
        self.dropped = 0

    def emit(self, kind: str, **fields) -> int:
        """Append one event; returns its seq (a cursor/token)."""
        ts = time.perf_counter_ns()
        with self._mu:
            self._seq += 1
            if len(self._buf) == self.capacity:
                self.dropped += 1
            self._buf.append(TraceEvent(self._seq, ts, kind, fields))
            return self._seq

    def __len__(self) -> int:
        return len(self._buf)

    @property
    def last_seq(self) -> int:
        return self._seq

    def dump(self) -> List[TraceEvent]:
        """All buffered events, oldest first."""
        with self._mu:
            return list(self._buf)

    def since(self, cursor: int) -> Tuple[List[TraceEvent], int]:
        """Events with ``seq > cursor`` plus the new cursor — the
        incremental-consumer API (``evs, cur = trace.since(cur)``)."""
        with self._mu:
            evs = [e for e in self._buf if e.seq > cursor]
            return evs, self._seq

    def timeline(self, limit: Optional[int] = None) -> str:
        """Human-readable timeline (ms relative to the oldest buffered
        event), newest-last.  ``limit`` keeps only the last N lines."""
        evs = self.dump()
        if limit is not None:
            evs = evs[-limit:]
        if not evs:
            return "(no events)"
        t_base = evs[0].ts_ns
        lines = []
        for e in evs:
            kv = " ".join(f"{k}={v}" for k, v in e.fields.items()
                          if k not in ("t0",))
            lines.append(f"{(e.ts_ns - t_base) / 1e6:12.3f} ms "
                         f"#{e.seq:<6d} {e.kind:<18s} {kv}")
        return "\n".join(lines)


class TelemetrySnapshot:
    """Point-in-time capture for windowed-delta sensing (DESIGN.md §17):
    the merged per-op histograms plus the trace cursor.  Pair two of these
    with :meth:`Telemetry.delta` to get an interval's histograms and events
    without re-merging full histories each tick."""

    __slots__ = ("hists", "cursor")

    def __init__(self, hists: Dict[str, LatencyHistogram], cursor: int):
        self.hists = hists
        self.cursor = cursor


class TelemetryWindow:
    """One sensing interval: per-op histogram *diffs* (only classes with
    samples in the window), the trace events emitted during it, and the
    end snapshot (pass as ``prev`` to chain the next window for free)."""

    __slots__ = ("hists", "events", "end")

    def __init__(self, hists: Dict[str, LatencyHistogram],
                 events: List[TraceEvent], end: TelemetrySnapshot):
        self.hists = hists
        self.events = events
        self.end = end

    @property
    def ops(self) -> int:
        """Total samples across the window's op classes."""
        return sum(h.n for h in self.hists.values())


class Telemetry:
    """Facade: per-op-class latency histograms + one event trace.

    Recording is lock-free: each thread gets its own dict of per-op
    histograms, registered in ``_shards`` with a single GIL-atomic
    ``list.append`` (no mutex on the read path, no lost increments — the
    same design as :class:`~repro.core.types.StatsHub`).  Reads merge the
    shards on demand; a merged histogram is a consistent-enough snapshot
    (counters are monotonic), exactly the contract ``IOStats`` reads have.
    """

    def __init__(self, trace_capacity: int = 4096):
        self.trace = EventTrace(trace_capacity)
        self._tl = threading.local()
        self._shards: List[Dict[str, LatencyHistogram]] = []

    # ------------------------------------------------------------- recording
    def _local(self) -> Dict[str, LatencyHistogram]:
        try:
            return self._tl.h
        except AttributeError:
            h: Dict[str, LatencyHistogram] = {}
            self._tl.h = h
            self._shards.append(h)   # GIL-atomic: no lock on first record
            return h

    def record(self, op: str, ns: int) -> None:
        """Record one latency sample for an op class (lock-free)."""
        h = self._local()
        hist = h.get(op)
        if hist is None:
            hist = h[op] = LatencyHistogram()
        hist.record(ns)

    def emit(self, kind: str, **fields) -> int:
        """Append one trace event; returns its seq token."""
        return self.trace.emit(kind, **fields)

    # --------------------------------------------------------------- queries
    def histogram(self, op: str) -> LatencyHistogram:
        """Merged (all threads) histogram for one op class."""
        out = LatencyHistogram()
        for shard in list(self._shards):
            h = shard.get(op)
            if h is not None:
                out = out + h
        return out

    def histograms(self) -> Dict[str, LatencyHistogram]:
        """Merged histograms for every op class any thread recorded."""
        ops: Dict[str, LatencyHistogram] = {}
        for shard in list(self._shards):
            for op, h in list(shard.items()):
                ops[op] = (ops[op] + h) if op in ops else (
                    LatencyHistogram() + h)
        return ops

    def percentile(self, op: str, p: float) -> float:
        return self.histogram(op).percentile(p)

    # ------------------------------------------------- windowed-delta API
    def snapshot(self) -> TelemetrySnapshot:
        """Capture the merged histograms + trace cursor (allocation-light:
        one small int64 array per active op class; no locks taken — the
        merge reads the same GIL-atomic shard list ``histograms`` does)."""
        return TelemetrySnapshot(self.histograms(), self.trace.last_seq)

    def delta(self, prev: TelemetrySnapshot) -> TelemetryWindow:
        """The interval since ``prev``: histogram diffs for every op class
        that recorded samples, plus ``EventTrace.since(prev.cursor)``
        events.  The online tuner and ``serve_latency``'s tail attribution
        both sense through this instead of re-merging full histories."""
        end = self.snapshot()
        hists: Dict[str, LatencyHistogram] = {}
        for op, h in end.hists.items():
            p = prev.hists.get(op)
            d = h.diff(p) if p is not None else h
            if d.n > 0:
                hists[op] = d
        events, _ = self.trace.since(prev.cursor)
        return TelemetryWindow(hists, events, end)

    def summary(self) -> Dict[str, Dict[str, float]]:
        """{op: histogram row} over every recorded op class (stable order:
        engine classes first, extras alphabetically)."""
        hs = self.histograms()
        keys = [k for k in OP_CLASSES if k in hs] + \
            sorted(k for k in hs if k not in OP_CLASSES)
        return {k: hs[k].to_dict() for k in keys}

    def report(self, trace_limit: int = 40) -> str:
        """Human-readable report: percentile table + trace timeline tail."""
        rows = ["op                 count      p50_us      p99_us     "
                "p999_us      max_us"]
        for op, d in self.summary().items():
            rows.append(f"{op:<16s}{d['count']:>8d} {d['p50_ns']/1e3:>11.1f} "
                        f"{d['p99_ns']/1e3:>11.1f} {d['p999_ns']/1e3:>11.1f} "
                        f"{d['max_ns']/1e3:>11.1f}")
        return ("\n".join(rows) + "\n\n-- trace (last "
                f"{trace_limit} events) --\n" + self.trace.timeline(
                    limit=trace_limit))
