"""Cursor-based streaming merging iterator over sorted runs + memtable.

This replaces the old scan path's seek-retry loop (re-seeking *every* run and
sort-merging ``count`` candidates per attempt, restarting with a bigger window
on truncation) with the classic LSM design (DESIGN.md §3): one cursor per run
holding a position that only moves forward, plus a merge buffer refilled
incrementally.

Each refill:
  1. takes a window of entries from every source — sources are ordered
     newest-first (memtable, then runs as ``LSMStore._runs_newest_first``
     yields them), the same resolution order the scalar ``get`` path walks;
  2. clamps every window to the *frontier* — the smallest last-key among
     truncated windows, below which every version of every key is guaranteed
     visible (numpy slice views, nothing is copied);
  3. merges the clamped keys with one stable sort, so the first occurrence of
     a key is its newest version (no sequence numbers needed);
  4. emits at most ``demand`` winners, consuming each source only up to the
     last emitted key — unconsumed entries stay put and are re-windowed by
     the next refill, so oversized windows cost views, not work;
  5. materializes winning values with one batched row-gather + ``tobytes``
     per source (tombstone winners emit ``None`` and are skipped on read).

Cursors never move backwards and nothing is re-seeked.  ``scan`` passes its
``count`` as the demand hint, so a scan usually completes in one refill;
plain ``next`` streaming starts small and doubles the demand per refill.

I/O cost model: ``seek`` charges every participating run one iterator seek
(``stats.seeks``/``runs_touched_range``); ``consume`` charges every run the
data blocks *spanned* by the prefix the merged stream actually consumed from
it, deduplicated across refills at block granularity — matching
``SortedRun.blocks_spanned`` on the consumed ranges.  With a block cache
attached (``core.cache.BlockCache``) every spanned block first consults the
cache; only misses charge ``blocks_read``.
"""
from __future__ import annotations

from bisect import bisect_right
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .memtable import Memtable
from .run import SortedRun
from .types import KEY_DTYPE, TOMBSTONE_LEN, IOStats

_FIRST_DEMAND = 16
_MAX_WINDOW = 4096


def combined_mem_items(memtables: Sequence[Memtable], key: int
                       ) -> List[Tuple[int, int, Optional[bytes]]]:
    """Newest-wins combination of the memtable rotation queue's scans.

    ``memtables`` is newest first ([active, imm_newest, ..., imm_oldest],
    the engine's ``_mem_sources`` order); the first source holding a key
    owns it, so the merge core (and the range view's scan, DESIGN.md §13)
    sees one key-sorted memtable stream.
    """
    if not memtables:
        return []
    if len(memtables) == 1:
        return memtables[0].scan(key)
    combined = {}
    for mt in memtables:
        for k, s, v in mt.scan(key):
            if k not in combined:
                combined[k] = (s, v)
    return [(k, s, v) for k, (s, v) in sorted(combined.items())]


class _RunCursor:
    """Forward-only position over one immutable run, with block accounting."""

    __slots__ = ("run", "stats", "cache", "n", "pos", "_charged")

    def __init__(self, run: SortedRun, stats: IOStats, cache=None):
        self.run = run
        self.stats = stats
        self.cache = cache
        self.n = len(run)
        self.pos = self.n
        self._charged = -1

    def seek(self, key: int) -> None:
        self.stats.seeks += 1
        self.stats.runs_touched_range += 1
        self.pos = int(self.run.keys.searchsorted(np.uint64(key)))
        self._charged = -1

    def window(self, w: int):
        """Up to ``w`` keys at the cursor: (keys_view, truncated)."""
        i = self.pos
        e = i + w
        if e >= self.n:
            return self.run.keys[i:], False
        return self.run.keys[i:e], True

    def consume(self, cnt: int) -> None:
        """Advance past ``cnt`` entries, charging the blocks they span.

        Blocks already charged by an earlier refill are not re-charged; with a
        block cache attached each newly spanned block is a hit (free) or a
        miss (charged + admitted) instead of an unconditional read.
        """
        if cnt <= 0:
            return
        i = self.pos
        bo = self.run.block_of
        b0, b1 = int(bo[i]), int(bo[i + cnt - 1])
        first_new = max(b0, self._charged + 1)
        if self.cache is None:
            self.stats.blocks_read += b1 - first_new + 1
        else:
            # span-charge the newly consumed blocks in one cache call
            self.cache.read_block_span(self.run.run_id, first_new, b1,
                                       self.run.block_bytes, self.stats)
        self._charged = b1
        self.pos = i + cnt


class MergingIterator:
    """Streaming merge of runs (newest-first order) + an optional memtable.

    Usage: ``it.seek(k)`` then ``it.next()`` until None; or ``it.scan(k, n)``;
    or iterate (``for key, value in it`` after a seek).  Entries come out in
    strictly increasing key order; tombstones and shadowed versions are
    consumed internally.
    """

    def __init__(self, runs: Sequence[SortedRun],
                 memtable: Optional[Memtable] = None,
                 stats: Optional[IOStats] = None,
                 chunk: int = _MAX_WINDOW, cache=None,
                 memtables: Optional[Sequence[Memtable]] = None):
        """``memtables`` (newest first) supersedes ``memtable`` when given:
        the async engine passes [active, imm_newest, ..., imm_oldest] so the
        immutable-memtable queue stays visible between the active memtable
        and L0 (DESIGN.md §11); duplicates resolve newest-memtable-wins at
        seek time, so the merge core still sees one memtable stream."""
        self.stats = stats if stats is not None else IOStats()
        self._cursors: List[_RunCursor] = [
            _RunCursor(r, self.stats, cache) for r in runs if len(r)]
        if memtables is None:
            memtables = [memtable] if memtable is not None else []
        self._memtables: List[Memtable] = [m for m in memtables
                                           if m is not None]
        self._mem_keys = np.zeros(0, dtype=KEY_DTYPE)
        self._mem_items: List[Tuple[int, int, Optional[bytes]]] = []
        self._mem_pos = 0
        self._max_window = max(int(chunk), _FIRST_DEMAND)
        self._demand = _FIRST_DEMAND
        self._tomb_carry = 0
        self._exhausted = True
        self._bk: List[int] = []                    # emitted keys
        self._bv: List[Optional[bytes]] = []        # emitted values (aligned)
        self._bi = 0

    # ------------------------------------------------------------ interface
    def seek(self, key: int, expected: int = 0) -> None:
        """Position every cursor at its first entry >= key.

        ``expected`` hints how many entries the caller intends to consume so
        the first refill can size itself to demand.
        """
        key = int(key)
        for cur in self._cursors:
            cur.seek(key)
        self._mem_items = combined_mem_items(self._memtables, key)
        self._mem_keys = np.fromiter((e[0] for e in self._mem_items),
                                     KEY_DTYPE, len(self._mem_items))
        self._mem_pos = 0
        self._demand = max(int(expected), _FIRST_DEMAND)
        self._tomb_carry = 0
        self._exhausted = False
        self._bk = []
        self._bv = []
        self._bi = 0

    def next(self) -> Optional[Tuple[int, bytes]]:
        """The next live entry, or None when the stream is exhausted."""
        while True:
            i = self._bi
            if i < len(self._bk):
                self._bi = i + 1
                v = self._bv[i]
                if v is None:          # tombstone winner
                    continue
                return self._bk[i], v
            if self._exhausted or not self._refill():
                return None

    def scan(self, start_key: int, count: int) -> List[Tuple[int, bytes]]:
        """First ``count`` live entries with key >= start_key."""
        self.seek(start_key, expected=count)
        out: List[Tuple[int, bytes]] = []
        while len(out) < count:
            i = self._bi
            bk, bv = self._bk, self._bv
            nb = len(bk)
            if i >= nb:
                if self._exhausted or not self._refill():
                    break
                continue
            need = count - len(out)
            while i < nb and need:
                v = bv[i]
                if v is not None:
                    out.append((bk[i], v))
                    need -= 1
                i += 1
            self._bi = i
        return out

    def __iter__(self) -> Iterator[Tuple[int, bytes]]:
        while True:
            e = self.next()
            if e is None:
                return
            yield e

    # ---------------------------------------------------------------- merge
    def _refill(self) -> bool:
        """Merge the sources' next windows into the emit buffer.

        ``demand`` — the emission cap — is the base geometric ramp plus
        *twice* the count of tombstone winners the previous refill emitted
        (``_tomb_carry``): tombstones occupy demand slots but yield no live
        entries, so without the carry a scan over a heavily-deleted range
        degrades to O(deleted / max_window) refills of mostly-dead winners.
        The 2x is what makes the growth geometric — a refill that was all
        tombstones doubles the next dead-prefix budget (carry alone would
        only add ``max_window`` per refill: O(sqrt(deleted)) refills, not
        O(log)).  The window follows demand past the ``_MAX_WINDOW`` cap
        when tombstone-driven, so the refill count stays O(log deleted).
        """
        demand = self._demand + 2 * self._tomb_carry
        self._demand = min(self._demand * 2, self._max_window)
        w = min(max(2 * demand, _FIRST_DEMAND),
                max(self._max_window, demand))
        # 1. windows, newest source first (memtable, then runs)
        parts_k: List[np.ndarray] = []
        sids: List[int] = []                        # -1 = memtable
        rows0: List[int] = []
        frontier: Optional[int] = None
        mi = self._mem_pos
        if mi < len(self._mem_keys):
            k = self._mem_keys[mi:mi + w]
            parts_k.append(k)
            sids.append(-1)
            rows0.append(mi)
            if mi + w < len(self._mem_keys):
                frontier = int(k[-1])
        for sid, cur in enumerate(self._cursors):
            k, truncated = cur.window(w)
            if not len(k):
                continue
            if truncated:
                fk = int(k[-1])
                frontier = fk if frontier is None else min(frontier, fk)
            parts_k.append(k)
            sids.append(sid)
            rows0.append(cur.pos)
        if not parts_k:
            self._exhausted = True
            return False
        # 2. clamp windows to the frontier (slice views, no copies)
        if frontier is not None:
            fb = np.uint64(frontier)
            cnts = [int(p.searchsorted(fb, side="right")) for p in parts_k]
            parts_k = [p[:c] for p, c in zip(parts_k, cnts)]
        else:
            cnts = [len(p) for p in parts_k]
        # 3. one stable sort; first occurrence of a key = newest version
        K = np.concatenate(parts_k) if len(parts_k) > 1 else parts_k[0]
        order = np.argsort(K, kind="stable")
        Ks = K[order]
        first = np.empty(Ks.size, dtype=bool)
        first[0] = True
        np.not_equal(Ks[1:], Ks[:-1], out=first[1:])
        widx = order[first]                 # concat-indices of winners
        wkeys = Ks[first]
        # 4. cap emission at demand; consume only up to the last emitted key
        if wkeys.size > demand:
            cutoff = np.uint64(wkeys[demand - 1])
            wkeys = wkeys[:demand]
            widx = widx[:demand]
            cnts = [int(p.searchsorted(cutoff, side="right"))
                    for p in parts_k]
        elif frontier is None:
            self._exhausted = True          # every source fully drained
        for sid, c in zip(sids, cnts):
            if sid < 0:
                self._mem_pos += c
            else:
                self._cursors[sid].consume(c)
        # 5. map winners back to (source, row) and batch-extract values
        starts = [0]
        for p in parts_k:
            starts.append(starts[-1] + len(p))
        nsrc = len(parts_k)
        vals: List[Optional[bytes]] = [None] * wkeys.size
        if nsrc == 1:
            groups = [(0, np.arange(wkeys.size), widx + rows0[0])]
        else:
            part_of = np.searchsorted(starts, widx, side="right") - 1
            groups = []
            for g in range(nsrc):
                sel = np.nonzero(part_of == g)[0]
                if sel.size:
                    groups.append((g, sel, widx[sel] - starts[g] + rows0[g]))
        for g, sel, rows in groups:
            sid = sids[g]
            if sid < 0:
                items = self._mem_items
                for t, r in zip(sel.tolist(), rows.tolist()):
                    vals[t] = items[r][2]
            else:
                run = self._cursors[sid].run
                vl = run.vlens[rows]
                vmax = run.vals.shape[1] if run.vals.ndim == 2 else 0
                flat = run.vals[rows].tobytes() if vmax else b""
                for o, (t, l) in enumerate(zip(sel.tolist(), vl.tolist())):
                    if l != TOMBSTONE_LEN:
                        off = o * vmax
                        vals[t] = flat[off:off + l]
        self._bk = wkeys.tolist()
        self._bv = vals
        self._bi = 0
        # tombstone winners consumed demand without yielding entries; grow
        # the next refill's demand by exactly that count (see docstring)
        self._tomb_carry = vals.count(None)
        return True
