"""Sharded keyspace: N independent LSMStores behind one facade (DESIGN.md §12).

PR 4's determinism turnstile serializes one background job per store, so a
single tree can never use more than one core of background compaction.  The
standard route to multi-core scale (the partitioning survey in Luo & Carey;
RocksDB column families / CockroachDB ranges) is to *range-partition* the key
space into N fully independent trees:

``ShardedLSMStore``
    Order-preserving splitters (``shards - 1`` ascending uint64 bounds; key k
    lives in the first shard whose splitter exceeds it) route every key to
    exactly one inner :class:`LSMStore`.  Each shard owns its WAL + memtable,
    its Manifest/RunStorage, and its own ``CompactionScheduler`` — background
    flush/compaction runs genuinely in parallel across shards, bounded by a
    *shared worker budget* (one semaphore sized ``compaction_workers``, so N
    shards never oversubscribe the machine).  The facade presents the entire
    single-store API: batched ops are split by ONE vectorized
    ``np.searchsorted`` against the splitters and fanned out per shard;
    cross-shard ``scan``/``seek`` exploit the order-preserving partition —
    shard i's keys all precede shard i+1's, so a range read is a
    shard-ordered concatenation, not a merge.

Shared memory subsystem
    All shards share one budgeted :class:`BlockCache`: each shard reads
    through a namespaced ``BlockCacheView`` with a ``cache_bytes / N`` slice
    (admission pressure evicts only the owning namespace's cold entries) and
    a ``pin_l0_bytes / N`` DRAM-resident L0 slice.  Cache keys are
    namespaced by shard id and ``retain``/repin/clear are namespace-scoped,
    so one shard's post-commit invalidation can never evict (or alias) a
    sibling's live blocks.

Differential contract
    The plain single store (or ``shards=1``) is the retained oracle: for any
    op sequence, every read (``get``/``multi_get``/``scan``/``seek``) returns
    byte-identical results, because each key's ops land on one shard in
    program order and shard ranges are disjoint.  ``shards=1`` is bit-for-bit
    the plain store (same flush boundaries, same seqs, same bloom bits).
    With ``shards>1`` the per-shard trees are smaller — sequence numbers are
    per-shard and levels are shallower (that depth reduction, plus parallel
    background work, is the speedup) — so cross-shard equality is defined on
    read *results*, not run bytes.

Concurrency
    The facade inherits the engine's single-writer/multi-reader discipline:
    one foreground thread writes (each shard still sees a single writer);
    readers are lock-free per shard.  Snapshots pin every shard's current
    version in shard order (each pin is atomic per shard via the manifest
    mutex); with the single writer idle, the pinned tuple is exactly the
    acked state.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from bisect import bisect_right
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .cache import BlockCache, BlockCacheView
from .engine import LSMConfig, LSMStore
from .manifest import Version
from .types import KEY_DTYPE, IOStats


def uniform_splitters(shards: int, key_space: int = 1 << 64
                      ) -> Tuple[int, ...]:
    """``shards - 1`` ascending bounds splitting ``[0, key_space)`` evenly.

    The default (full uint64 space) is right for hashed key schemes
    (AutumnKVCache chain hashes, YCSB's scrambled keys); dense sequential
    key ranges should pass their own ``key_space``.
    """
    return tuple(key_space * (i + 1) // shards for i in range(shards - 1))


@dataclasses.dataclass(frozen=True)
class ShardedSnapshot:
    """One pinned :class:`Version` per shard, in shard order."""
    versions: Tuple[Version, ...]


class ShardedLSMStore:
    """Range-partitioned facade over ``config.shards`` independent stores.

    Construct via :func:`make_store` (returns a plain :class:`LSMStore`
    when ``config.shards <= 1``).  All shards share the facade's *live*
    ``LSMConfig`` object, so runtime toggles (``use_pallas_bloom``,
    ``slowdown_trigger``/``stall_trigger``) keep reaching every shard with
    no per-shard plumbing; construction-time fields that must differ per
    shard (cache/pin budgets, worker counts) are overridden before the
    shared object is installed.
    """

    def __init__(self, config: Optional[LSMConfig] = None):
        self.config = config or LSMConfig(shards=2)
        n = max(1, int(self.config.shards))
        splitters = self.config.shard_splitters
        if splitters is None:
            splitters = uniform_splitters(n)
        splitters = [int(s) for s in splitters]
        if len(splitters) != n - 1:
            raise ValueError(
                f"need {n - 1} splitters for {n} shards, got {len(splitters)}")
        if splitters != sorted(set(splitters)):
            raise ValueError("splitters must be strictly ascending")
        self._splitters = np.asarray(splitters, dtype=KEY_DTYPE)
        self._splitters_list = splitters
        # Shared worker budget: at most `compaction_workers` background jobs
        # in flight across ALL shards (each shard still runs its own
        # one-job-at-a-time determinism turnstile).
        self._budget = None
        if self.config.async_compaction:
            self._budget = threading.Semaphore(
                max(1, int(self.config.compaction_workers)))
        shard_cfg = dataclasses.replace(
            self.config, shards=1, shard_splitters=None,
            cache_bytes=0, pin_l0_bytes=0,   # cache is shared, attached below
            compaction_workers=1)            # 1 worker thread per shard pool
        self.shards: List[LSMStore] = [
            LSMStore(dataclasses.replace(shard_cfg),
                     scheduler_budget=self._budget, scheduler_offset=i)
            for i in range(n)]
        # Facade write gate: serializes snapshot acquisition against
        # facade-level writes (put/delete/batch/flush).  Without it a
        # ``get_snapshot`` racing a cross-shard ``write_batch`` can pin
        # shard 0 before the batch and shard 1 after it — a *torn* snapshot
        # that no single-store snapshot could ever expose.  RLock because
        # the batch entry points nest (``put_batch`` -> ``write_batch``).
        # The single-writer discipline makes the gate uncontended in every
        # existing workload; only a concurrent snapshot taker ever waits.
        self._write_gate = threading.RLock()
        for s in self.shards:
            # Live-config sharing: runtime toggles on the facade's config
            # reach every shard.  Construction-only fields (memtable size,
            # worker count, cache budgets) were already consumed above.
            s.config = self.config
        self.block_cache: Optional[BlockCache] = None
        if self.config.cache_bytes > 0 or self.config.pin_l0_bytes > 0:
            self._build_shared_cache()

    # ------------------------------------------------------------ partition
    def _shard_of(self, key: int) -> int:
        return bisect_right(self._splitters_list, int(key))

    def _split(self, keys_arr: np.ndarray) -> np.ndarray:
        """Vectorized shard assignment: one searchsorted for the batch."""
        return np.searchsorted(self._splitters, keys_arr, side="right")

    # ---------------------------------------------------------------- cache
    def _build_shared_cache(self) -> None:
        """One budgeted BlockCache, one namespaced view + L0 slice per shard."""
        cfg = self.config
        n = len(self.shards)
        self.block_cache = BlockCache(cfg.cache_bytes, cfg.cache_policy)
        self.block_cache.telemetry = cfg.telemetry
        per_cache = cfg.cache_bytes // n
        per_pin = cfg.pin_l0_bytes // n
        for i, s in enumerate(self.shards):
            s.attach_cache(BlockCacheView(self.block_cache, i, per_cache),
                           per_pin)

    def configure_cache(self, cache_bytes: int, pin_l0_bytes: int = 0,
                        policy: Optional[str] = None) -> None:
        """(Re)build the shared memory subsystem on a live facade.

        Mirrors ``LSMStore.configure_cache``: replaces any existing cache
        (contents dropped), slices the budgets ``1/N`` per shard, and
        repins every shard's current L0 (charged).  Zeros detach.
        """
        self.config.cache_bytes = int(cache_bytes)
        self.config.pin_l0_bytes = int(pin_l0_bytes)
        if policy is not None:
            self.config.cache_policy = policy
        if cache_bytes <= 0 and pin_l0_bytes <= 0:
            self.block_cache = None
            for s in self.shards:
                s.block_cache = None
                s.pinned_l0 = None
            return
        self._build_shared_cache()

    # ------------------------------------------------------------- writes
    def put(self, key: int, value: bytes) -> None:
        with self._write_gate:
            self.shards[self._shard_of(key)].put(key, value)

    def delete(self, key: int) -> None:
        with self._write_gate:
            self.shards[self._shard_of(key)].delete(key)

    def put_batch(self, keys, values) -> None:
        """Batched puts, split per shard by one vectorized searchsorted.

        A broadcast value (one ``bytes`` for every key) splits entirely in
        numpy — no per-element Python indexing on the ingest hot path."""
        if isinstance(values, (bytes, bytearray)):
            keys_arr = np.asarray(keys, dtype=KEY_DTYPE)
            sids = self._split(keys_arr)
            val = bytes(values)
            with self._write_gate:
                for si in np.unique(sids):
                    self.shards[int(si)].put_batch(
                        keys_arr[sids == si].tolist(), val)
            return
        self.write_batch(zip(keys, values))

    def delete_batch(self, keys) -> None:
        self.write_batch((k, None) for k in keys)

    def write_batch(self, ops: Iterable[Tuple[int, Optional[bytes]]]) -> None:
        """Batched puts + deletes: one searchsorted assigns every op its
        shard; each shard then ingests its sub-batch through its own
        vectorized ``write_batch`` lane.  Per-key op order is preserved
        (the split is a stable partition), so the final state equals the
        single-store oracle's for the same sequence.
        """
        pairs = list(ops)
        if not pairs:
            return
        keys_arr = np.fromiter((int(k) for k, _ in pairs), KEY_DTYPE,
                               len(pairs))
        sids = self._split(keys_arr)
        with self._write_gate:
            for si in np.unique(sids):
                idx = np.nonzero(sids == si)[0]
                self.shards[int(si)].write_batch(pairs[int(j)] for j in idx)

    def flush(self) -> None:
        with self._write_gate:
            for s in self.shards:
                s.flush()

    def fsync_wal(self) -> None:
        """Durability barrier on every shard's active WAL."""
        for s in self.shards:
            s.fsync_wal()

    # -------------------------------------------------------------- reads
    def _shard_snap(self, snapshot: Optional[ShardedSnapshot], si: int
                    ) -> Optional[Version]:
        return None if snapshot is None else snapshot.versions[si]

    def get(self, key: int,
            snapshot: Optional[ShardedSnapshot] = None) -> Optional[bytes]:
        si = self._shard_of(key)
        return self.shards[si].get(key, snapshot=self._shard_snap(snapshot, si))

    def multi_get(self, keys: Sequence[int],
                  snapshot: Optional[ShardedSnapshot] = None
                  ) -> List[Optional[bytes]]:
        """Batched point reads: one searchsorted splits the wave, each
        shard resolves its sub-batch with its own vectorized ``multi_get``,
        and results scatter back to the callers' positions."""
        keys_arr = np.asarray(list(keys), dtype=KEY_DTYPE)
        n = int(keys_arr.size)
        results: List[Optional[bytes]] = [None] * n
        if n == 0:
            return results
        sids = self._split(keys_arr)
        for si in np.unique(sids):
            idx = np.nonzero(sids == si)[0]
            sub = self.shards[int(si)].multi_get(
                keys_arr[idx], snapshot=self._shard_snap(snapshot, int(si)))
            for j, v in zip(idx, sub):
                results[int(j)] = v
        return results

    def seek(self, key: int,
             snapshot: Optional[ShardedSnapshot] = None) -> Optional[int]:
        """First key >= key across shards: because the partition is
        order-preserving, the first shard (in range order) with any
        result holds the global minimum."""
        for si in range(self._shard_of(key), len(self.shards)):
            got = self.shards[si].seek(key,
                                       snapshot=self._shard_snap(snapshot, si))
            if got is not None:
                return got
        return None

    def scan(self, start_key: int, count: int,
             snapshot: Optional[ShardedSnapshot] = None
             ) -> List[Tuple[int, bytes]]:
        """Range read: shard-ordered concatenation of per-shard scans (no
        cross-shard merge needed — shard i's keys all precede shard i+1's).
        Byte-identical to the single-store oracle's ``scan``/``scan_scalar``.
        """
        return self._scan_impl(start_key, count, snapshot, scalar=False)

    def scan_scalar(self, start_key: int, count: int,
                    snapshot: Optional[ShardedSnapshot] = None
                    ) -> List[Tuple[int, bytes]]:
        """Reference range read through every shard's ``scan_scalar``."""
        return self._scan_impl(start_key, count, snapshot, scalar=True)

    def _scan_impl(self, start_key: int, count: int,
                   snapshot: Optional[ShardedSnapshot], scalar: bool
                   ) -> List[Tuple[int, bytes]]:
        out: List[Tuple[int, bytes]] = []
        for si in range(self._shard_of(start_key), len(self.shards)):
            need = count - len(out)
            if need <= 0:
                break
            shard = self.shards[si]
            fn = shard.scan_scalar if scalar else shard.scan
            out.extend(fn(start_key, need,
                          snapshot=self._shard_snap(snapshot, si)))
        return out[:count]

    # ----------------------------------------------------------- snapshots
    def get_snapshot(self) -> ShardedSnapshot:
        """Pin every shard's current version atomically w.r.t. facade writes.

        Two mechanisms make the pinned tuple a point-in-time cut instead of
        a torn one:

        1. The facade **write gate**: acquisition holds the same lock every
           facade write path takes, so a concurrent cross-shard
           ``write_batch``/``flush`` is either entirely before or entirely
           after the snapshot — never half-visible.  (Pinning shard 0,
           losing the CPU to a writer that lands on shards 0 *and* 1, then
           pinning shard 1 was exactly the torn interleaving.)
        2. **Pin-validate-retry** against background installs: after
           pinning all shards, each shard's current version id is re-read;
           if any shard installed a version mid-acquisition (async flush or
           compaction on a worker thread), the pins are released and the
           tuple is re-taken.  Installs are rate-limited by real merge
           work, so the seqlock-style loop settles immediately in practice.

        Remaining async-mode caveat (documented, not defended): snapshots
        see only *installed* versions, never memtables, and each shard's
        background flush runs on its own schedule — so the halves of an
        already-acked batch can *enter* snapshot visibility at different
        times.  The gate guarantees the snapshot never splits a facade
        write's acquisition; quiesce (or sync mode) before snapshotting
        when cross-shard batch atomicity of *visibility* is required.
        """
        with self._write_gate:
            while True:
                pins = tuple(s.get_snapshot() for s in self.shards)
                if all(p.version_id == s.manifest.current().version_id
                       for s, p in zip(self.shards, pins)):
                    return ShardedSnapshot(pins)
                tel = self.config.telemetry
                if tel is not None:
                    tel.emit("snapshot_retry", shards=len(self.shards))
                for s, p in zip(self.shards, pins):
                    s.release_snapshot(p)

    def release_snapshot(self, snapshot: ShardedSnapshot) -> None:
        for s, v in zip(self.shards, snapshot.versions):
            s.release_snapshot(v)

    # ------------------------------------------------------------ recovery
    def crash(self) -> None:
        """Whole-store crash: every shard aborts its background pipeline and
        loses volatile state; each shard's fsynced WAL segments + durable
        manifest survive independently."""
        for s in self.shards:
            s.crash()

    def recover(self) -> None:
        """Recover every shard (durable manifest + consolidated WAL replay),
        clearing and re-pinning its slice of the shared cache."""
        for s in self.shards:
            s.recover()

    def close(self) -> None:
        """Drain and stop every shard's background workers (each shard then
        serves on the synchronous, state-equivalent path)."""
        err = None
        for s in self.shards:
            try:
                s.close()
            except BaseException as e:   # close every shard before raising
                err = err or e
        if err is not None:
            raise err

    def wait_for_quiesce(self, timeout: Optional[float] = None) -> bool:
        """Block until every shard's background pipeline drains."""
        deadline = None if timeout is None else time.monotonic() + timeout
        ok = True
        for s in self.shards:
            left = None if deadline is None \
                else max(0.0, deadline - time.monotonic())
            ok = s.wait_for_quiesce(left) and ok
        return ok

    # ---------------------------------------------------------------- info
    @property
    def stats(self) -> IOStats:
        """Aggregated counters across shards (a fresh fieldwise-summed
        ``IOStats`` — use ``snapshot()``/``delta()`` on it as usual)."""
        return IOStats.merge(s.stats for s in self.shards)

    @property
    def telemetry(self):
        """The facade's (and, by live-config sharing, every shard's)
        Telemetry — one object aggregates all shards' histograms/events."""
        return self.config.telemetry

    @property
    def num_levels_in_use(self) -> int:
        return max(s.num_levels_in_use for s in self.shards)

    @property
    def total_entries(self) -> int:
        return sum(s.total_entries for s in self.shards)

    def total_live_entries(self) -> int:
        return sum(s.total_live_entries() for s in self.shards)

    def space_amplification(self) -> float:
        phys = logical = 0
        for s in self.shards:
            p, lg = s._space_profile()
            phys += p
            logical += lg
        return phys / logical if logical else 1.0

    def level_summary(self) -> List[dict]:
        """Per-level aggregate across shards (capacities summed)."""
        out: List[dict] = []
        for s in self.shards:
            for d in s.level_summary():
                i = d["level"]
                while len(out) <= i:
                    out.append(dict(level=len(out), runs=0, entries=0,
                                    bytes=0, capacity=None))
                out[i]["runs"] += d["runs"]
                out[i]["entries"] += d["entries"]
                out[i]["bytes"] += d["bytes"]
                if d["capacity"] is not None:
                    out[i]["capacity"] = (out[i]["capacity"] or 0) \
                        + d["capacity"]
        return out

    def cache_summary(self) -> dict:
        """Shared-cache health: one hit rate, global charged bytes, and the
        number of DRAM-resident L0 runs across all shards."""
        if self.block_cache is None:
            return dict(enabled=False, hit_rate=0.0, hits=0, misses=0,
                        evictions=0, charged_bytes=0, pinned_bytes=0,
                        pinned_l0_runs=0)
        c = self.block_cache
        return dict(enabled=True, hit_rate=c.hit_rate(), hits=c.hits,
                    misses=c.misses, evictions=c.evictions,
                    charged_bytes=c.charged_bytes,
                    pinned_bytes=c.pinned_bytes,
                    pinned_l0_runs=sum(
                        len(s.pinned_l0.pinned_run_ids) for s in self.shards
                        if s.pinned_l0 is not None))


def make_store(config: Optional[LSMConfig] = None):
    """The store factory every call site uses: a plain :class:`LSMStore`
    for ``shards <= 1`` (the retained bit-for-bit oracle path), a
    :class:`ShardedLSMStore` facade otherwise — the ``LSMConfig.shards``
    knob is the only thing a caller changes."""
    config = config or LSMConfig()
    if config.shards <= 1:
        return LSMStore(config)
    return ShardedLSMStore(config)
