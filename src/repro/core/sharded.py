"""Sharded keyspace: N independent LSMStores behind one facade (DESIGN.md §12).

PR 4's determinism turnstile serializes one background job per store, so a
single tree can never use more than one core of background compaction.  The
standard route to multi-core scale (the partitioning survey in Luo & Carey;
RocksDB column families / CockroachDB ranges) is to *range-partition* the key
space into N fully independent trees:

``ShardedLSMStore``
    Order-preserving splitters (``shards - 1`` ascending uint64 bounds; key k
    lives in the first shard whose splitter exceeds it) route every key to
    exactly one inner :class:`LSMStore`.  Each shard owns its WAL + memtable,
    its Manifest/RunStorage, and its own ``CompactionScheduler`` — background
    flush/compaction runs genuinely in parallel across shards, bounded by a
    *shared worker budget* (one semaphore sized ``compaction_workers``, so N
    shards never oversubscribe the machine).  The facade presents the entire
    single-store API: batched ops are split by ONE vectorized
    ``np.searchsorted`` against the splitters and fanned out per shard;
    cross-shard ``scan``/``seek`` exploit the order-preserving partition —
    shard i's keys all precede shard i+1's, so a range read is a
    shard-ordered concatenation, not a merge.

Dynamic rebalancing (DESIGN.md §15)
    Static splitters collapse under skew: a hotspot piles every op into one
    shard while its siblings idle.  With ``rebalance_interval_ops > 0`` the
    facade tracks per-shard routed ops in a decaying window, detects
    imbalance (max/mean share ≥ ``rebalance_ratio``) at write and
    compaction/quiesce boundaries, and re-derives the splitters as
    load-weighted key quantiles over the shards' own runs — splitting hot
    shards and merging cold neighbours in one step.  Data moves by
    **cross-shard run migration**: quiesce, export each shard's
    leaving-range slice, rebuild it as L0 runs in the destination (durably
    committed), log + publish the new routing, then strip each source to
    its new range.  Readers never block: routing lives in one immutable
    ``_Routing`` object swapped by reference; a reader captures it,
    computes, and retries iff the reference moved mid-read (seqlock
    flavor).  Snapshots carry the routing they were taken under, and their
    manifest pins keep pre-migration runs alive, so snapshot reads are
    never retried and survive any number of rebalances.

Shared memory subsystem
    All shards share one budgeted :class:`BlockCache`: each shard reads
    through a namespaced ``BlockCacheView`` with a ``cache_bytes / N`` slice
    (admission pressure evicts only the owning namespace's cold entries) and
    a ``pin_l0_bytes / N`` DRAM-resident L0 slice.  Cache keys are
    namespaced by shard id and ``retain``/repin/clear are namespace-scoped,
    so one shard's post-commit invalidation can never evict (or alias) a
    sibling's live blocks.  A rebalance re-slices the per-namespace budgets
    load-proportionally (with a 1/(4N) floor), so a merged cold shard hands
    its idle cache back to the hot half of the keyspace; namespaces are
    never renumbered — migrated runs get fresh run-ids in the destination's
    storage, so their blocks key under the destination's namespace and the
    source's strip-commit ``retain`` drops the dead ones.

Differential contract
    The plain single store (or ``shards=1``) is the retained oracle: for any
    op sequence, every read (``get``/``multi_get``/``scan``/``seek``) returns
    byte-identical results, because each key's ops land on one shard in
    program order and shard ranges are disjoint.  ``shards=1`` is bit-for-bit
    the plain store (same flush boundaries, same seqs, same bloom bits).
    With ``shards>1`` the per-shard trees are smaller — sequence numbers are
    per-shard and levels are shallower (that depth reduction, plus parallel
    background work, is the speedup) — so cross-shard equality is defined on
    read *results*, not run bytes.  Rebalancing preserves it: a migrated
    key's entire version history lives in exactly one shard before and
    after the move (imports are deduped newest-wins from the quiesced
    source; the destination owned nothing in the moved range, so dropping
    collapsed tombstones loses nothing live).

Concurrency
    The facade inherits the engine's single-writer/multi-reader discipline:
    one foreground thread writes (each shard still sees a single writer);
    readers are lock-free per shard.  Rebalancing runs on a foreground
    thread under the write gate — never on a scheduler worker, whose
    ``on_idle`` hook only *flags* imbalance (running it there would
    deadlock: the migration quiesces that very scheduler).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from bisect import bisect_left, bisect_right
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .cache import BlockCache, BlockCacheView
from .engine import LSMConfig, LSMStore
from .manifest import Version
from .run import build_run
from .scheduler import CompactJob, WorkerBudget
from .tuner import TunerStep
from .types import KEY_DTYPE, IOStats

_KEY_SPACE_END = 1 << 64
_HIST_B = 32                 # buckets per shard in the load histogram (§15)


def uniform_splitters(shards: int, key_space: int = 1 << 64
                      ) -> Tuple[int, ...]:
    """``shards - 1`` ascending bounds splitting ``[0, key_space)`` evenly.

    The default (full uint64 space) is right for hashed key schemes
    (AutumnKVCache chain hashes, YCSB's scrambled keys); dense sequential
    key ranges should pass their own ``key_space``.
    """
    return tuple(key_space * (i + 1) // shards for i in range(shards - 1))


class _Routing:
    """One immutable routing epoch: the splitters plus their derived forms.

    Readers capture a single reference, compute against it, then validate
    ``facade._routing is r`` — a mid-read migration swaps the reference
    (always to a fresh object), so a torn read (source already stripped /
    destination not yet routed) is detected and retried.  The writer swaps
    it only under the facade write gate, *after* durably logging the new
    splitters, which is what makes crash recovery unambiguous.
    """

    __slots__ = ("lst", "arr", "epoch", "n")

    def __init__(self, splitters: Sequence[int], epoch: int = 0):
        self.lst = [int(x) for x in splitters]
        self.arr = np.asarray(self.lst, dtype=KEY_DTYPE)
        self.epoch = int(epoch)
        self.n = len(self.lst) + 1

    def shard_of(self, key: int) -> int:
        return bisect_right(self.lst, int(key))

    def split(self, keys_arr: np.ndarray) -> np.ndarray:
        """Vectorized shard assignment: one searchsorted for the batch."""
        return np.searchsorted(self.arr, keys_arr, side="right")

    def bounds(self, si: int) -> Tuple[int, int]:
        """Shard ``si``'s owned key range ``[lo, hi)`` (hi may be 2**64)."""
        lo = self.lst[si - 1] if si > 0 else 0
        hi = self.lst[si] if si < self.n - 1 else _KEY_SPACE_END
        return lo, hi


@dataclasses.dataclass(frozen=True)
class ShardedSnapshot:
    """One pinned :class:`Version` per shard, in shard order, plus the
    routing epoch the pins were taken under — snapshot reads route with
    *their* splitters, and the pins keep pre-migration runs alive, so a
    snapshot survives any number of rebalances unchanged."""
    versions: Tuple[Version, ...]
    routing: Optional[_Routing] = None


class ShardedLSMStore:
    """Range-partitioned facade over ``config.shards`` independent stores.

    Construct via :func:`make_store` (returns a plain :class:`LSMStore`
    when ``config.shards <= 1``).  All shards share the facade's *live*
    ``LSMConfig`` object, so runtime toggles (``use_pallas_bloom``,
    ``slowdown_trigger``/``stall_trigger``, the rebalance knobs) keep
    reaching every shard with no per-shard plumbing; construction-time
    fields that must differ per shard (cache/pin budgets, worker counts)
    are overridden before the shared object is installed.
    """

    def __init__(self, config: Optional[LSMConfig] = None):
        self.config = config or LSMConfig(shards=2)
        n = max(1, int(self.config.shards))
        splitters = self.config.shard_splitters
        if splitters is None:
            splitters = uniform_splitters(n)
        splitters = [int(s) for s in splitters]
        if len(splitters) != n - 1:
            raise ValueError(
                f"need {n - 1} splitters for {n} shards, got {len(splitters)}")
        if splitters != sorted(set(splitters)):
            raise ValueError("splitters must be strictly ascending")
        # Routing epoch 0 + its durable log.  The log mirrors the WAL's
        # fsync discipline except routing commits sync immediately (they
        # are rare); crash() truncates to the synced watermark and
        # recover() restores the last durable epoch.
        self._routing = _Routing(splitters, epoch=0)
        self._routing_log: List[Tuple[int, ...]] = [tuple(splitters)]
        self._routing_synced = 1
        # Shared worker budget: at most `compaction_workers` background jobs
        # in flight across ALL shards (each shard still runs its own
        # one-job-at-a-time determinism turnstile).
        self._budget = None
        if self.config.async_compaction:
            # resizable: the online tuner's worker-reallocation actuator
            # retargets it at quiesce boundaries (DESIGN.md §17)
            self._budget = WorkerBudget(
                max(1, int(self.config.compaction_workers)))
        shard_cfg = dataclasses.replace(
            self.config, shards=1, shard_splitters=None,
            cache_bytes=0, pin_l0_bytes=0,   # cache is shared, attached below
            compaction_workers=1,            # 1 worker thread per shard pool
            tuner=None)                      # facade drives the one tuner;
                                             # shards must not double-drive
        self.shards: List[LSMStore] = [
            LSMStore(dataclasses.replace(shard_cfg),
                     scheduler_budget=self._budget, scheduler_offset=i)
            for i in range(n)]
        # Facade write gate: serializes snapshot acquisition against
        # facade-level writes (put/delete/batch/flush) AND rebalancing.
        # Without it a ``get_snapshot`` racing a cross-shard ``write_batch``
        # can pin shard 0 before the batch and shard 1 after it — a *torn*
        # snapshot that no single-store snapshot could ever expose.  RLock
        # because the batch entry points nest (``put_batch`` ->
        # ``write_batch``).  The single-writer discipline makes the gate
        # uncontended in every existing workload; only a concurrent
        # snapshot taker (or a rebalance) ever waits.
        self._write_gate = threading.RLock()
        # Per-shard load accounting (DESIGN.md §15).  _load is the decaying
        # trigger window (reset on rebalance, halved on each non-triggering
        # check so stale skew ages out); _load_total is cumulative for
        # reporting.  Plain-int bumps: racy under concurrent readers,
        # intentionally — load is a heuristic and the lock-free read path
        # must never take a lock.
        self._load = [0] * n
        self._load_total = [0] * n
        # Per-shard key-space histogram over the same decaying window: 32
        # buckets spanning the shard's current range.  This is the "cheap
        # per-shard load summary" that lets _derive_splitters cut at the
        # *measured* within-shard distribution — without it the derivation
        # assumes even spread and chases a concentrated hot range through
        # several geometric half-step migrations instead of one.  Reset
        # whenever the routing (and so the bucket geometry) changes.
        self._load_hist = [np.zeros(_HIST_B) for _ in range(n)]
        self._ops_since_check = 0
        self._rebalance_needed = False
        self._in_rebalance = False
        self.rebalances = 0          # completed rebalance count
        self.migrated_entries = 0    # physical entries moved across shards
        for si, s in enumerate(self.shards):
            # Live-config sharing: runtime toggles on the facade's config
            # reach every shard.  Construction-only fields (memtable size,
            # worker count, cache budgets) were already consumed above.
            s.config = self.config
            if s._scheduler is not None:
                # imbalance detection at compaction/quiesce boundaries:
                # the drained-queue hook only sets a flag (see _on_shard_idle)
                s._scheduler.on_idle = self._on_shard_idle
        self.block_cache: Optional[BlockCache] = None
        if self.config.cache_bytes > 0 or self.config.pin_l0_bytes > 0:
            self._build_shared_cache()
        # Online tuning (DESIGN.md §17): the facade is the tuner's single
        # driver (shard configs carried tuner=None at construction, so the
        # shards' own write paths never tick it); same cheap armed-counter
        # trigger shape as rebalancing.
        self._tuner = self.config.tuner
        self._tune_ops = 0
        self._tune_armed = False
        self._tune_prev_shard_stats: Optional[List[IOStats]] = None
        if self._tuner is not None:
            self._tuner.bind(self)

    # ------------------------------------------------------------ partition
    @property
    def _splitters(self) -> np.ndarray:
        return self._routing.arr

    @property
    def _splitters_list(self) -> List[int]:
        return self._routing.lst

    @property
    def splitters(self) -> Tuple[int, ...]:
        """The current routing bounds (moves when a rebalance lands)."""
        return tuple(self._routing.lst)

    def _shard_of(self, key: int) -> int:
        return self._routing.shard_of(key)

    def _split(self, keys_arr: np.ndarray) -> np.ndarray:
        """Vectorized shard assignment: one searchsorted for the batch."""
        return self._routing.split(keys_arr)

    def _note_ops(self, si: int, k: int = 1) -> None:
        self._load[si] += k
        self._load_total[si] += k
        self._ops_since_check += k

    def _note_key(self, si: int, key: int) -> None:
        """Scalar load note incl. the key-space histogram bucket."""
        self._note_ops(si)
        lo, hi = self._routing.bounds(si)
        b = int((key - lo) * _HIST_B / (hi - lo))
        h = self._load_hist[si]
        h[b if 0 <= b < _HIST_B else _HIST_B - 1] += 1.0

    def _note_keys(self, si: int, keys_arr: np.ndarray) -> None:
        """Batched load note: one bincount feeds the histogram.

        Racy-benign like the scalar counters (reads note without the
        gate); the histogram is a trigger heuristic, never a correctness
        input."""
        self._note_ops(si, int(keys_arr.size))
        lo, hi = self._routing.bounds(si)
        b = ((keys_arr.astype(np.float64) - lo)
             * (_HIST_B / float(hi - lo))).astype(np.int64)
        np.clip(b, 0, _HIST_B - 1, out=b)
        self._load_hist[si] += np.bincount(b, minlength=_HIST_B)

    # ---------------------------------------------------------------- cache
    def _build_shared_cache(self) -> None:
        """One budgeted BlockCache, one namespaced view + L0 slice per shard."""
        cfg = self.config
        n = len(self.shards)
        self.block_cache = BlockCache(cfg.cache_bytes, cfg.cache_policy)
        self.block_cache.telemetry = cfg.telemetry
        per_cache = cfg.cache_bytes // n
        per_pin = cfg.pin_l0_bytes // n
        for i, s in enumerate(self.shards):
            s.attach_cache(BlockCacheView(self.block_cache, i, per_cache),
                           per_pin)

    def configure_cache(self, cache_bytes: int, pin_l0_bytes: int = 0,
                        policy: Optional[str] = None) -> None:
        """(Re)build the shared memory subsystem on a live facade.

        Mirrors ``LSMStore.configure_cache``: replaces any existing cache
        (contents dropped), slices the budgets ``1/N`` per shard, and
        repins every shard's current L0 (charged).  Zeros detach.
        """
        self.config.cache_bytes = int(cache_bytes)
        self.config.pin_l0_bytes = int(pin_l0_bytes)
        if policy is not None:
            self.config.cache_policy = policy
        if cache_bytes <= 0 and pin_l0_bytes <= 0:
            self.block_cache = None
            for s in self.shards:
                s.block_cache = None
                s.pinned_l0 = None
            return
        self._build_shared_cache()

    # ------------------------------------------------------------- writes
    def put(self, key: int, value: bytes) -> None:
        with self._write_gate:
            si = self._routing.shard_of(key)
            self.shards[si].put(key, value)
            self._note_key(si, key)
        self._maybe_rebalance()
        if self._tuner is not None:
            self._maybe_tune(1)

    def delete(self, key: int) -> None:
        with self._write_gate:
            si = self._routing.shard_of(key)
            self.shards[si].delete(key)
            self._note_key(si, key)
        self._maybe_rebalance()
        if self._tuner is not None:
            self._maybe_tune(1)

    def put_batch(self, keys, values) -> None:
        """Batched puts, split per shard by one vectorized searchsorted.

        A broadcast value (one ``bytes`` for every key) splits entirely in
        numpy — no per-element Python indexing on the ingest hot path."""
        if isinstance(values, (bytes, bytearray)):
            keys_arr = np.asarray(keys, dtype=KEY_DTYPE)
            val = bytes(values)
            with self._write_gate:
                sids = self._routing.split(keys_arr)
                for si in np.unique(sids):
                    sel = keys_arr[sids == si]
                    self.shards[int(si)].put_batch(sel.tolist(), val)
                    self._note_keys(int(si), sel)
            self._maybe_rebalance()
            if self._tuner is not None:
                self._maybe_tune(int(keys_arr.size))
            return
        self.write_batch(zip(keys, values))

    def delete_batch(self, keys) -> None:
        self.write_batch((k, None) for k in keys)

    def write_batch(self, ops: Iterable[Tuple[int, Optional[bytes]]]) -> None:
        """Batched puts + deletes: one searchsorted assigns every op its
        shard; each shard then ingests its sub-batch through its own
        vectorized ``write_batch`` lane.  Per-key op order is preserved
        (the split is a stable partition), so the final state equals the
        single-store oracle's for the same sequence.
        """
        pairs = list(ops)
        if not pairs:
            return
        keys_arr = np.fromiter((int(k) for k, _ in pairs), KEY_DTYPE,
                               len(pairs))
        with self._write_gate:
            # split under the gate: routing must not move between
            # assignment and the per-shard writes
            sids = self._routing.split(keys_arr)
            for si in np.unique(sids):
                idx = np.nonzero(sids == si)[0]
                self.shards[int(si)].write_batch(pairs[int(j)] for j in idx)
                self._note_keys(int(si), keys_arr[idx])
        self._maybe_rebalance()
        if self._tuner is not None:
            self._maybe_tune(len(pairs))

    def flush(self) -> None:
        with self._write_gate:
            for s in self.shards:
                s.flush()
        self._maybe_rebalance()
        if self._tuner is not None:
            self._maybe_tune(0)

    def fsync_wal(self) -> None:
        """Durability barrier on every shard's active WAL."""
        for s in self.shards:
            s.fsync_wal()

    # -------------------------------------------------------------- reads
    def _shard_snap(self, snapshot: Optional[ShardedSnapshot], si: int
                    ) -> Optional[Version]:
        return None if snapshot is None else snapshot.versions[si]

    def _snap_routing(self, snapshot: ShardedSnapshot) -> _Routing:
        r = snapshot.routing
        return r if r is not None else self._routing

    def get(self, key: int,
            snapshot: Optional[ShardedSnapshot] = None) -> Optional[bytes]:
        if snapshot is not None:
            si = self._snap_routing(snapshot).shard_of(key)
            return self.shards[si].get(key, snapshot=snapshot.versions[si])
        while True:
            r = self._routing
            si = r.shard_of(key)
            out = self.shards[si].get(key)
            if self._routing is r:   # no migration landed mid-read
                self._note_key(si, key)
                return out

    def multi_get(self, keys: Sequence[int],
                  snapshot: Optional[ShardedSnapshot] = None
                  ) -> List[Optional[bytes]]:
        """Batched point reads: one searchsorted splits the wave, each
        shard resolves its sub-batch with its own vectorized ``multi_get``,
        and results scatter back to the callers' positions."""
        keys_arr = np.asarray(list(keys), dtype=KEY_DTYPE)
        if keys_arr.size == 0:
            return []
        if snapshot is not None:
            return self._multi_get_routed(self._snap_routing(snapshot),
                                          keys_arr, snapshot)
        while True:
            r = self._routing
            results = self._multi_get_routed(r, keys_arr, None)
            if self._routing is r:
                return results

    def _multi_get_routed(self, r: _Routing, keys_arr: np.ndarray,
                          snapshot: Optional[ShardedSnapshot]
                          ) -> List[Optional[bytes]]:
        results: List[Optional[bytes]] = [None] * int(keys_arr.size)
        sids = r.split(keys_arr)
        for si in np.unique(sids):
            idx = np.nonzero(sids == si)[0]
            sub = self.shards[int(si)].multi_get(
                keys_arr[idx], snapshot=self._shard_snap(snapshot, int(si)))
            for j, v in zip(idx, sub):
                results[int(j)] = v
            if snapshot is None:
                self._note_keys(int(si), keys_arr[idx])
        return results

    def seek(self, key: int,
             snapshot: Optional[ShardedSnapshot] = None) -> Optional[int]:
        """First key >= key across shards: because the partition is
        order-preserving, the first shard (in range order) with any
        in-range result holds the global minimum."""
        if snapshot is not None:
            return self._seek_routed(self._snap_routing(snapshot), key,
                                     snapshot)
        while True:
            r = self._routing
            got = self._seek_routed(r, key, None)
            if self._routing is r:
                return got

    def _seek_routed(self, r: _Routing, key: int,
                     snapshot: Optional[ShardedSnapshot]) -> Optional[int]:
        for si in range(r.shard_of(key), len(self.shards)):
            lo, hi = r.bounds(si)
            got = self.shards[si].seek(max(int(key), lo),
                                       snapshot=self._shard_snap(snapshot, si))
            if got is not None and got < hi:
                return got
        return None

    def scan(self, start_key: int, count: int,
             snapshot: Optional[ShardedSnapshot] = None
             ) -> List[Tuple[int, bytes]]:
        """Range read: shard-ordered concatenation of per-shard scans (no
        cross-shard merge needed — shard i's keys all precede shard i+1's).
        Byte-identical to the single-store oracle's ``scan``/``scan_scalar``.
        """
        return self._scan_impl(start_key, count, snapshot, scalar=False)

    def scan_scalar(self, start_key: int, count: int,
                    snapshot: Optional[ShardedSnapshot] = None
                    ) -> List[Tuple[int, bytes]]:
        """Reference range read through every shard's ``scan_scalar``."""
        return self._scan_impl(start_key, count, snapshot, scalar=True)

    def _scan_impl(self, start_key: int, count: int,
                   snapshot: Optional[ShardedSnapshot], scalar: bool
                   ) -> List[Tuple[int, bytes]]:
        if snapshot is not None:
            return self._scan_routed(self._snap_routing(snapshot), start_key,
                                     count, snapshot, scalar)
        while True:
            r = self._routing
            out = self._scan_routed(r, start_key, count, None, scalar)
            if self._routing is r:
                return out

    def _scan_routed(self, r: _Routing, start_key: int, count: int,
                     snapshot: Optional[ShardedSnapshot], scalar: bool
                     ) -> List[Tuple[int, bytes]]:
        out: List[Tuple[int, bytes]] = []
        for si in range(r.shard_of(int(start_key)), len(self.shards)):
            need = count - len(out)
            if need <= 0:
                break
            lo, hi = r.bounds(si)
            shard = self.shards[si]
            fn = shard.scan_scalar if scalar else shard.scan
            part = fn(max(int(start_key), lo), need,
                      snapshot=self._shard_snap(snapshot, si))
            if part and part[-1][0] >= hi:
                # mid-migration only: clip entries the captured routing
                # assigns to a later shard.  Results are sorted, so every
                # in-range entry precedes the clipped tail — the kept
                # prefix is complete and the next shard continues it.
                keys = [k for k, _ in part]
                part = part[:bisect_left(keys, hi)]
            out.extend(part)
        return out[:count]

    # ----------------------------------------------------------- snapshots
    def get_snapshot(self) -> ShardedSnapshot:
        """Pin every shard's current version atomically w.r.t. facade writes.

        Two mechanisms make the pinned tuple a point-in-time cut instead of
        a torn one:

        1. The facade **write gate**: acquisition holds the same lock every
           facade write path (and a rebalance) takes, so a concurrent
           cross-shard ``write_batch``/``flush``/migration is either
           entirely before or entirely after the snapshot — never
           half-visible.  (Pinning shard 0, losing the CPU to a writer that
           lands on shards 0 *and* 1, then pinning shard 1 was exactly the
           torn interleaving.)
        2. **Pin-validate-retry** against background installs: after
           pinning all shards, each shard's current version id is re-read;
           if any shard installed a version mid-acquisition (async flush or
           compaction on a worker thread), the pins are released and the
           tuple is re-taken.  Installs are rate-limited by real merge
           work, so the seqlock-style loop settles immediately in practice.

        The snapshot also captures the routing it was taken under (stable
        here — the gate excludes migrations): its reads route with those
        splitters forever, and the pins keep any since-migrated runs alive
        in their original shard.

        Remaining async-mode caveat (documented, not defended): snapshots
        see only *installed* versions, never memtables, and each shard's
        background flush runs on its own schedule — so the halves of an
        already-acked batch can *enter* snapshot visibility at different
        times.  The gate guarantees the snapshot never splits a facade
        write's acquisition; quiesce (or sync mode) before snapshotting
        when cross-shard batch atomicity of *visibility* is required.
        """
        with self._write_gate:
            while True:
                pins = tuple(s.get_snapshot() for s in self.shards)
                if all(p.version_id == s.manifest.current().version_id
                       for s, p in zip(self.shards, pins)):
                    return ShardedSnapshot(pins, self._routing)
                tel = self.config.telemetry
                if tel is not None:
                    tel.emit("snapshot_retry", shards=len(self.shards))
                for s, p in zip(self.shards, pins):
                    s.release_snapshot(p)

    def release_snapshot(self, snapshot: ShardedSnapshot) -> None:
        for s, v in zip(self.shards, snapshot.versions):
            s.release_snapshot(v)

    # ---------------------------------------------------------- rebalancing
    def _on_shard_idle(self) -> None:
        """Scheduler-worker hook at a drained-queue boundary: flag only.

        A worker thread must never *run* the rebalance — the migration
        quiesces that worker's own scheduler, which would deadlock — so the
        hook just records that the window looks skewed; the next foreground
        write (or ``wait_for_quiesce``) consumes the flag.
        """
        cfg = self.config
        iv = cfg.rebalance_interval_ops
        if iv <= 0 or self._in_rebalance or self._ops_since_check < iv:
            return
        loads = self._load
        tot = sum(loads)
        if tot and max(loads) * len(loads) >= cfg.rebalance_ratio * tot:
            self._rebalance_needed = True

    def _maybe_rebalance(self) -> bool:
        """Write-boundary trigger: cheap flag/counter test, full check at
        most every ``rebalance_interval_ops`` routed ops."""
        cfg = self.config
        if cfg.rebalance_interval_ops <= 0 or self._in_rebalance:
            return False
        if not self._rebalance_needed \
                and self._ops_since_check < cfg.rebalance_interval_ops:
            return False
        return self.rebalance_now()

    def arm_rebalancing(self, interval_ops: int,
                        ratio: Optional[float] = None) -> None:
        """Enable (or retune) automatic rebalancing on a live facade.

        Resets the load window.  The intended use is bulk-load-then-serve:
        a sequential preload looks maximally skewed to the windowed tracker
        (every sorted wave lands in one shard), so load with
        ``rebalance_interval_ops=0`` and arm once the serving phase starts.
        """
        with self._write_gate:
            self.config.rebalance_interval_ops = int(interval_ops)
            if ratio is not None:
                self.config.rebalance_ratio = float(ratio)
            self._load = [0] * len(self.shards)
            self._load_hist = [np.zeros(_HIST_B)
                               for _ in range(len(self.shards))]
            self._ops_since_check = 0
            self._rebalance_needed = False

    def rebalance_now(self, force: bool = False) -> bool:
        """Evaluate the load window and rebalance if it is skewed (or
        ``force``).  Returns True iff a migration landed."""
        return self._rebalance(None, force)

    def rebalance_to(self, splitters: Sequence[int]) -> bool:
        """Migrate to an explicit splitter vector (tests / operators).

        Same protocol as the automatic path, skipping derivation."""
        lst = [int(x) for x in splitters]
        if len(lst) != len(self.shards) - 1:
            raise ValueError(
                f"need {len(self.shards) - 1} splitters, got {len(lst)}")
        if lst != sorted(set(lst)):
            raise ValueError("splitters must be strictly ascending")
        return self._rebalance(lst, True)

    def _rebalance(self, target: Optional[List[int]], force: bool) -> bool:
        if self._in_rebalance:       # reentrancy (quiesce inside migration)
            return False
        with self._write_gate:
            if self._in_rebalance:
                return False
            self._in_rebalance = True
            try:
                self._rebalance_needed = False
                self._ops_since_check = 0
                loads = list(self._load)
                tot = sum(loads)
                n = len(self.shards)
                ratio = (max(loads) * n / tot) if tot else 1.0
                if not force and ratio < self.config.rebalance_ratio:
                    # decay the window so stale skew ages out
                    self._load = [v // 2 for v in loads]
                    self._load_hist = [h * 0.5 for h in self._load_hist]
                    return False
                return self._rebalance_to(target, loads, ratio)
            finally:
                self._in_rebalance = False

    def _rebalance_to(self, target: Optional[List[int]],
                      loads: List[int], ratio: float) -> bool:
        """The migration protocol (gate held, ``_in_rebalance`` set).

        Order is the crash-safety argument (DESIGN.md §15): (1) quiesce —
        memtables become runs, schedulers drain; (2) build + durably commit
        import runs in every destination; (3) append the new splitters to
        the durable routing log, then publish the reader-visible routing
        swap; (4) strip each source to its new range (durable per shard).
        A crash before (3) recovers the old routing and the recovery clip
        drops the committed imports — exact pre-migration state; a crash
        after (3) recovers the new routing and the clip finishes the
        source cleanup — exact post-migration state.
        """
        t0 = time.perf_counter_ns()
        n = len(self.shards)
        # (1) quiesce: the migration operates on a settled, run-only tree
        for s in self.shards:
            s.flush()
        for s in self.shards:
            if not s.wait_for_quiesce(timeout=120.0):
                return False     # nothing mutated yet: clean abort
        old = self._routing
        new_lst = target if target is not None \
            else self._derive_splitters(loads)
        if new_lst is None or list(new_lst) == old.lst:
            self._load = [v // 2 for v in loads]
            self._load_hist = [h * 0.5 for h in self._load_hist]
            return False
        new = _Routing(new_lst, old.epoch + 1)
        tel = self.config.telemetry
        if tel is not None:
            tel.emit("rebalance_start", epoch=new.epoch,
                     imbalance=round(ratio, 3), window_ops=int(sum(loads)))
        if self._budget is not None:
            self._budget.acquire()   # migration rides the worker budget —
        try:                         # acquired AFTER quiesce (a drained
            # pipeline holds no permit; permit-then-quiesce deadlocks
            # at budget=1)
            moves, moved = self._install_imports(old, new)      # (2)
            self._commit_routing(new)                           # (3)
            self._cleanup_sources(new)                          # (4)
        finally:
            if self._budget is not None:
                self._budget.release()
        if tel is not None:
            for si in range(n):
                ol, oh = old.bounds(si)
                nl, nh = new.bounds(si)
                if (nl, nh) == (ol, oh):
                    continue
                if nl >= ol and nh <= oh:
                    tel.emit("shard_split", shard=si, lo=nl, hi=nh)
                elif nl <= ol and nh >= oh:
                    tel.emit("shard_merge", shard=si, lo=nl, hi=nh)
                else:                # slid sideways: shrank one side,
                    tel.emit("shard_shift", shard=si, lo=nl, hi=nh)  # grew the other
        self._reassign_cache_budgets(loads)
        # the moved data lands as L0 runs and the stripped sources may be
        # under-shaped: reshape in the background (no-op when shaped; sync
        # mode compacts inline to stay the deterministic oracle)
        for s in self.shards:
            if s._scheduler is not None:
                s._scheduler.submit(CompactJob())
            else:
                s._compact_until_quiet()
        self.rebalances += 1
        self._load = [0] * n
        self._load_hist = [np.zeros(_HIST_B) for _ in range(n)]
        dur = time.perf_counter_ns() - t0
        if tel is not None:
            tel.record("rebalance", dur)
            tel.emit("rebalance_end", epoch=new.epoch, moves=moves,
                     entries=moved, t0=t0, dur_ns=dur)
        return True

    def _derive_splitters(self, loads: List[int]) -> Optional[List[int]]:
        """Load-weighted key quantiles over the shards' stored keys.

        Each shard's unique key set (stride-subsampled when huge) carries
        its window load distributed by the shard's key-space histogram —
        keys in hot buckets weigh more, so a concentrated hot range is cut
        at its *measured* median in one step instead of being chased
        through several even-spread half-migrations.  The global cumsum is
        cut at i/n of total weight, which simultaneously splits hot shards
        and merges cold neighbours.  Returns None when there is no data
        (or no usable cut).
        """
        n = len(self.shards)
        routing = self._routing
        keys_parts: List[np.ndarray] = []
        w_parts: List[np.ndarray] = []
        for si, s in enumerate(self.shards):
            runs = [r for lvl in s._levels for r in lvl if len(r)]
            if not runs:
                continue
            if len(runs) == 1:
                k = runs[0].keys
            else:
                k = np.unique(np.concatenate([r.keys for r in runs]))
            stride = max(1, k.size // 65536)
            if stride > 1:
                k = k[::stride]
            keys_parts.append(k)
            # bucket each sampled key, spread the bucket's observed load
            # over its keys; smooth with 1/8 of a uniform mass so buckets
            # the window never touched still get a floor (and a shard with
            # an empty histogram degrades to the even-spread weighting)
            lo, hi = routing.bounds(si)
            h = self._load_hist[si]
            b = ((k.astype(np.float64) - lo)
                 * (_HIST_B / float(hi - lo))).astype(np.int64)
            np.clip(b, 0, _HIST_B - 1, out=b)
            wb = h + max(float(h.sum()), 1.0) / (_HIST_B * 8.0)
            wb *= (loads[si] + 1.0) / wb.sum()
            cnt = np.maximum(np.bincount(b, minlength=_HIST_B), 1)
            w_parts.append(wb[b] / cnt[b])
        if not keys_parts:
            return None
        K = np.concatenate(keys_parts)   # sorted: shard ranges are disjoint
        W = np.concatenate(w_parts)
        cum = np.cumsum(W)
        targets = float(cum[-1]) * np.arange(1, n) / n
        idx = np.minimum(np.searchsorted(cum, targets), K.size - 1)
        out: List[int] = []
        prev = -1
        for c in K[idx]:
            c = int(c)
            if c <= prev:            # enforce strictly ascending
                c = prev + 1
            out.append(c)
            prev = c
        if out[-1] >= _KEY_SPACE_END:
            return None              # fix-up ran off the key space
        return out

    def _install_imports(self, old: _Routing, new: _Routing
                         ) -> Tuple[int, int]:
        """Step (2): durably commit every leaving-range slice into its new
        owner as a fresh L0 run (deduped newest-wins; whole-key tombstones
        collapse — the destination owned nothing in the moved range, so
        nothing live is shadowed)."""
        tel = self.config.telemetry
        moves = moved = 0
        for si, s in enumerate(self.shards):
            ol, oh = old.bounds(si)
            nl, nh = new.bounds(si)
            # what shard si gives away = its old range minus its new range:
            # at most a low-side and a high-side interval
            for lo, hi in ((ol, min(oh, nl)), (max(ol, nh), oh)):
                if lo >= hi:
                    continue
                cols = s.export_range(lo, hi)
                if cols is None:
                    continue
                k, sq, vl, vv = cols
                dest_ids = new.split(k)
                for dj in np.unique(dest_ids):
                    dj = int(dj)
                    mask = dest_ids == dj
                    dst = self.shards[dj]
                    run = build_run(k[mask], sq[mask], vl[mask], vv[mask],
                                    bits_per_key=dst._bits_for_level(0),
                                    drop_tombstones=True,
                                    block_size=self.config.block_size,
                                    key_bytes=self.config.key_bytes,
                                    hash_fn=dst._bloom_hash_fn())
                    if len(run) == 0:
                        continue     # the slice was all tombstones
                    dst.import_migrated_run(run)
                    moves += 1
                    moved += len(run)
                    if tel is not None:
                        tel.emit("run_migrate", src=si, dst=dj,
                                 entries=len(run), bytes=run.data_bytes)
        self.migrated_entries += moved
        return moves, moved

    def _commit_routing(self, new: _Routing) -> None:
        """Step (3): durable intent first (the log append is fsynced
        immediately — routing changes are rare), then the reader-visible
        reference swap.  Everything written after this point routes — and
        is WAL-logged — under the new splitters, which is the invariant
        recovery's range clip relies on."""
        self._routing_log.append(tuple(new.lst))
        self._routing_synced = len(self._routing_log)
        self._routing = new

    def _cleanup_sources(self, new: _Routing) -> None:
        """Step (4): drop each shard's moved-away entries (durable per
        shard; a crash part-way is finished by recovery's clip)."""
        for si, s in enumerate(self.shards):
            lo, hi = new.bounds(si)
            s.strip_to_range(lo, hi)

    def _reassign_cache_budgets(self, loads: List[int]) -> None:
        """Re-slice the shared cache load-proportionally (1/(4N) floor).

        A merged cold shard hands its idle budget back to the hot range;
        namespaces never renumber, so no entries are invalidated — only
        the admission budgets move."""
        if self.block_cache is None or self.config.cache_bytes <= 0:
            return
        total = self.config.cache_bytes
        n = len(self.shards)
        base = (sum(loads) + n) // (3 * n) + 1   # floor ≈ 1/(4N) share
        w = [ld + base for ld in loads]
        wsum = sum(w)
        budgets = [total * wi // wsum for wi in w]
        budgets[max(range(n), key=lambda i: w[i])] += total - sum(budgets)
        for i, s in enumerate(self.shards):
            if s.block_cache is not None:
                s.block_cache.budget_bytes = budgets[i]
            self.block_cache.set_ns_budget(i, budgets[i])

    # --------------------------------------------- online tuning (§17)
    def _shards_idle(self) -> bool:
        """True at a facade-wide compaction-chain boundary (sync shards
        are always at one)."""
        return all(s._scheduler is None or s._scheduler.idle()
                   for s in self.shards)

    def _maybe_tune(self, k: int = 0) -> None:
        """Write-boundary tuning trigger (the ``_maybe_rebalance`` shape):
        count routed ops, arm at ``interval_ops``, fire at the first
        all-shards-idle boundary."""
        tun = self._tuner
        self._tune_ops += k
        if not self._tune_armed:
            if self._tune_ops < tun.interval_ops:
                return
            self._tune_armed = True
        if not self._shards_idle():
            return
        self._tune_ops = 0
        self._tune_armed = False
        with self._write_gate:
            tun.tick(self)

    def apply_tuning(self) -> Optional[TunerStep]:
        """Run one tuner tick now iff every shard is at a boundary — the
        facade twin of ``LSMStore.apply_tuning`` (DESIGN.md §17).  Taken
        under the write gate so a concurrent snapshot can never observe a
        half-applied actuation."""
        tun = self._tuner
        if tun is None or not self._shards_idle():
            return None
        self._tune_ops = 0
        self._tune_armed = False
        with self._write_gate:
            return tun.tick(self)

    def compact_to_shape(self, timeout: Optional[float] = 600.0) -> int:
        """Maintenance reshape across shards (``LSMStore.compact_to_shape``):
        drain every shard, then fold each shard's tree to its (re)tuned
        policy's predicted level count.  Foreground, under the write gate —
        the explicit maintenance window after a policy retune widened the
        capacity schedule.  Returns total maintenance merges."""
        with self._write_gate:
            if not self.wait_for_quiesce(timeout):
                return 0
            return sum(s.compact_to_shape() for s in self.shards)

    def retune_policy(self, *, T: Optional[float] = None,
                      c: Optional[float] = None) -> None:
        """Swap every shard's policy to a same-family one with new knobs
        (tuner actuator); future compaction targets only, trees never
        rewritten."""
        cfg = self.config
        if T is not None:
            cfg.T = float(T)
        if c is not None:
            cfg.c = float(c)
        for s in self.shards:
            s.policy = s.policy.retuned(T=cfg.T, c=cfg.c)

    def resize_worker_budget(self, n: int) -> bool:
        """Retarget the shared worker-budget semaphore (tuner actuator).
        Shrinks only land when the permits are free — apply_tuning calls
        this at an all-idle boundary, where they are."""
        if self._budget is None:
            return False
        ok = self._budget.resize(n)
        if ok:
            self.config.compaction_workers = self._budget.size
        return ok

    def set_cache_split(self, pin_l0_bytes: int) -> None:
        """Facade twin of ``LSMStore.set_cache_split``: move budget between
        the shared cache and the per-shard pinned-L0 slices at constant
        total memory.  Gentle — the shared cache evicts down in place and
        each namespace budget rescales proportionally (preserving any
        miss-weighted skew the budget rule has built up); no contents are
        dropped wholesale."""
        if self.block_cache is None:
            return
        cfg = self.config
        total = cfg.cache_bytes + cfg.pin_l0_bytes
        pin = max(0, min(int(pin_l0_bytes), total))
        cache = total - pin
        scale = cache / cfg.cache_bytes if cfg.cache_bytes > 0 else 0.0
        cfg.cache_bytes = cache
        cfg.pin_l0_bytes = pin
        self.block_cache.resize(cache)
        n = len(self.shards)
        per_pin = pin // n
        for s in self.shards:
            v = s.block_cache
            if v is not None:
                v.resize(int(v.budget_bytes * scale) if scale > 0
                         else cache // n)
            if s.pinned_l0 is not None:
                s.pinned_l0.pin_l0_bytes = per_pin
                with s._maint_lock:
                    s.pinned_l0.repin(s._levels[0], stats=s._stats.local())

    def _get_pin_frac(self) -> float:
        total = self.config.cache_bytes + self.config.pin_l0_bytes
        return self.config.pin_l0_bytes / total if total else 0.0

    def _set_pin_frac(self, v: float) -> None:
        total = self.config.cache_bytes + self.config.pin_l0_bytes
        self.set_cache_split(int(total * float(v)))

    def _tuning_actuators(self):
        """Facade knob set: level ratios fan out to every shard; pressure
        and worker knobs act on the shared config/budget."""
        acts = {
            "c": (lambda: self.shards[0].policy.c,
                  lambda v: self.retune_policy(c=v)),
            "T": (lambda: self.shards[0].policy.T,
                  lambda v: self.retune_policy(T=v)),
        }
        if self.config.async_compaction:
            acts["slowdown_trigger"] = (
                lambda: self.config.slowdown_trigger,
                lambda v: setattr(self.config, "slowdown_trigger", int(v)))
        if self._budget is not None:
            acts["compaction_workers"] = (lambda: self._budget.size,
                                          self.resize_worker_budget)
        if self.block_cache is not None and self.config.cache_bytes \
                + self.config.pin_l0_bytes > 0:
            acts["pin_frac"] = (self._get_pin_frac, self._set_pin_frac)
        return acts

    def _tuning_rules(self, window, stats_delta) -> None:
        """Rule-based actuation the tuner runs every tick (no hill-climb):
        shift shared-cache namespace budgets toward hit-rate-starved
        shards.  Same floor/weighting shape as the rebalance-time
        ``_reassign_cache_budgets``, but weighted by each shard's *window*
        cache misses (the starvation signal) instead of routed ops."""
        if self.block_cache is None or self.config.cache_bytes <= 0:
            return
        cur = [s.stats for s in self.shards]
        prev = self._tune_prev_shard_stats
        self._tune_prev_shard_stats = cur
        if prev is None:
            return
        misses = [c.delta(p).cache_miss_blocks
                  for c, p in zip(cur, prev)]
        if sum(misses) <= 0:
            return
        total = self.config.cache_bytes
        n = len(self.shards)
        base = (sum(misses) + n) // (3 * n) + 1   # floor ≈ 1/(4N) share
        w = [m + base for m in misses]
        wsum = sum(w)
        budgets = [total * wi // wsum for wi in w]
        budgets[max(range(n), key=lambda i: w[i])] += total - sum(budgets)
        for i, s in enumerate(self.shards):
            if s.block_cache is not None:
                s.block_cache.resize(budgets[i])
            else:
                self.block_cache.set_ns_budget(i, budgets[i])

    # ------------------------------------------------------------ recovery
    def crash(self) -> None:
        """Whole-store crash: every shard aborts its background pipeline and
        loses volatile state; each shard's fsynced WAL segments + durable
        manifest survive independently, as does the synced prefix of the
        routing log."""
        for s in self.shards:
            s.crash()
        del self._routing_log[self._routing_synced:]

    def recover(self) -> None:
        """Recover every shard (durable manifest + consolidated WAL replay),
        restore the last durable routing, and clip each shard to its routed
        range — which atomically resolves a crash mid-migration to either
        the exact pre-migration state (routing commit didn't land: the
        clip drops the already-committed import copies) or the exact
        post-migration state (it did: the clip finishes the source
        cleanup).  Replayed WAL/memtable contents are always in-range
        w.r.t. the recovered routing, because writes only ever route under
        a routing that was durably logged first."""
        routing = _Routing(self._routing_log[-1],
                           epoch=len(self._routing_log) - 1)
        self._routing = routing
        for si, s in enumerate(self.shards):
            s.recover()
            lo, hi = routing.bounds(si)
            s.strip_to_range(lo, hi)
        self._load = [0] * len(self.shards)
        self._load_hist = [np.zeros(_HIST_B) for _ in range(len(self.shards))]
        self._ops_since_check = 0
        self._rebalance_needed = False

    def close(self) -> None:
        """Drain and stop every shard's background workers (each shard then
        serves on the synchronous, state-equivalent path)."""
        err = None
        for s in self.shards:
            try:
                s.close()
            except BaseException as e:   # close every shard before raising
                err = err or e
        if err is not None:
            raise err

    def wait_for_quiesce(self, timeout: Optional[float] = None) -> bool:
        """Block until every shard's background pipeline drains.

        A quiesce is also a rebalance boundary: if the drained window is
        skewed past the trigger, the migration runs here (foreground
        thread, gate taken inside) and its reshaping jobs are drained
        within the same deadline — after a True return the facade is both
        settled *and* balanced w.r.t. the closed window."""
        deadline = None if timeout is None else time.monotonic() + timeout
        ok = self._drain_shards(deadline)
        if ok and not self._in_rebalance and self._maybe_rebalance():
            ok = self._drain_shards(deadline)
        if ok and self._tuner is not None and self._tune_armed:
            self.apply_tuning()
        return ok

    def _drain_shards(self, deadline: Optional[float]) -> bool:
        ok = True
        for s in self.shards:
            left = None if deadline is None \
                else max(0.0, deadline - time.monotonic())
            ok = s.wait_for_quiesce(left) and ok
        return ok

    # ------------------------------------------------- integrity (§16)
    @property
    def degraded(self) -> bool:
        """True when any shard is read-only after persistent background
        failure.  Degradation is per-shard: writes routed to a degraded
        shard raise ``StoreDegradedError`` while every other shard keeps
        accepting writes, and reads keep serving everywhere."""
        return any(s.degraded for s in self.shards)

    def degraded_shards(self) -> List[int]:
        """Indices of read-only shards (empty list == fully writable)."""
        return [si for si, s in enumerate(self.shards) if s.degraded]

    def scrub(self) -> List[dict]:
        """Verify block checksums across every shard's runs; per-run report
        dicts (shard-tagged) in shard order — the facade twin of
        ``LSMStore.scrub``."""
        report: List[dict] = []
        for si, s in enumerate(self.shards):
            for r in s.scrub():
                r["shard"] = si
                report.append(r)
        return report

    # ---------------------------------------------------------------- info
    @property
    def stats(self) -> IOStats:
        """Aggregated counters across shards (a fresh fieldwise-summed
        ``IOStats`` — use ``snapshot()``/``delta()`` on it as usual)."""
        return IOStats.merge(s.stats for s in self.shards)

    @property
    def shard_stats(self) -> List[dict]:
        """Per-shard ``IOStats.to_dict()``, in shard order — the raw
        per-shard sensor block behind ``shard_load_summary``."""
        return [s.stats.to_dict() for s in self.shards]

    def shard_load_ops(self) -> List[int]:
        """Cumulative facade ops (reads + writes) routed per shard.
        Benchmarks diff two calls to get a window's imbalance."""
        return list(self._load_total)

    def shard_load_summary(self) -> List[dict]:
        """Cheap per-shard load/pressure summary: routed-op share, live
        bytes, and the stall/write counters rebalancing decisions read."""
        n = len(self.shards)
        tot = sum(self._load_total) or 1
        out = []
        for si, s in enumerate(self.shards):
            lo, hi = self._routing.bounds(si)
            st = s.stats
            phys, _ = s._space_profile()
            out.append(dict(shard=si, lo=lo, hi=hi,
                            ops=self._load_total[si],
                            op_share=self._load_total[si] / tot,
                            window_ops=self._load[si],
                            live_bytes=phys,
                            entries=s.total_entries,
                            wal_appends=st.wal_appends,
                            point_reads=st.point_reads,
                            range_reads=st.range_reads,
                            stall_ns=st.stall_ns))
        return out

    @property
    def telemetry(self):
        """The facade's (and, by live-config sharing, every shard's)
        Telemetry — one object aggregates all shards' histograms/events."""
        return self.config.telemetry

    @property
    def num_levels_in_use(self) -> int:
        return max(s.num_levels_in_use for s in self.shards)

    @property
    def total_entries(self) -> int:
        return sum(s.total_entries for s in self.shards)

    def total_live_entries(self) -> int:
        return sum(s.total_live_entries() for s in self.shards)

    def space_amplification(self) -> float:
        phys = logical = 0
        for s in self.shards:
            p, lg = s._space_profile()
            phys += p
            logical += lg
        return phys / logical if logical else 1.0

    def level_summary(self) -> List[dict]:
        """Per-level aggregate across shards (capacities summed)."""
        out: List[dict] = []
        for s in self.shards:
            for d in s.level_summary():
                i = d["level"]
                while len(out) <= i:
                    out.append(dict(level=len(out), runs=0, entries=0,
                                    bytes=0, capacity=None))
                out[i]["runs"] += d["runs"]
                out[i]["entries"] += d["entries"]
                out[i]["bytes"] += d["bytes"]
                if d["capacity"] is not None:
                    out[i]["capacity"] = (out[i]["capacity"] or 0) \
                        + d["capacity"]
        return out

    def cache_summary(self) -> dict:
        """Shared-cache health: one hit rate, global charged bytes, and the
        number of DRAM-resident L0 runs across all shards."""
        if self.block_cache is None:
            return dict(enabled=False, hit_rate=0.0, hits=0, misses=0,
                        evictions=0, charged_bytes=0, pinned_bytes=0,
                        pinned_l0_runs=0)
        c = self.block_cache
        return dict(enabled=True, hit_rate=c.hit_rate(), hits=c.hits,
                    misses=c.misses, evictions=c.evictions,
                    charged_bytes=c.charged_bytes,
                    pinned_bytes=c.pinned_bytes,
                    pinned_l0_runs=sum(
                        len(s.pinned_l0.pinned_run_ids) for s in self.shards
                        if s.pinned_l0 is not None))


def make_store(config: Optional[LSMConfig] = None):
    """The store factory every call site uses: a plain :class:`LSMStore`
    for ``shards <= 1`` (the retained bit-for-bit oracle path), a
    :class:`ShardedLSMStore` facade otherwise — the ``LSMConfig.shards``
    knob is the only thing a caller changes."""
    config = config or LSMConfig()
    if config.shards <= 1:
        return LSMStore(config)
    return ShardedLSMStore(config)
