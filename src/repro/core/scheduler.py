"""Background compaction scheduler: flush/compaction off the write path.

Production LSM stores decouple compaction from the foreground write path —
synchronous merges tax every write burst exactly when the merge policy is
most active (LevelDB's single background thread; the scheduling analysis in
the Luo & Carey LSM survey).  This module is that subsystem for the Autumn
engine (DESIGN.md §11):

``CompactionScheduler``
    Owns the job queue and ``compaction_workers`` daemon worker threads.
    Foreground ``put``/``put_batch``/``flush`` only *rotate* the full
    memtable into the immutable queue and submit a :class:`FlushJob`; the
    worker turns it into an L0 run, installs the new version, and chains
    :class:`CompactJob` continuations until the tree is shaped — exactly the
    sequence the synchronous engine runs inline, which is what makes the
    sync store a bit-for-bit differential oracle after ``wait_for_quiesce``.

Determinism contract
    Jobs execute strictly one at a time in queue order (a turnstile: a
    worker only pops when no job is in flight), and a job's compaction
    continuations are pushed to the *front* of the queue — so the apply
    order for any op sequence is flush₁, its compactions, flush₂, … —
    byte-identical to the synchronous engine's trajectory.  Extra workers
    are hot standbys today (the job pipeline is inherently sequential:
    each plan depends on the previous apply); the knob exists for the
    sharding follow-on, where per-shard schedulers drain independent trees.

Safety
    The worker is the only thread that mutates levels (copy-on-write list
    swaps; readers are lock-free on the captured reference), every version
    installs through the mutex-guarded ``Manifest``, and each in-flight
    compaction pins its input version (``Manifest.pin_current``) so
    concurrent snapshot release / GC can never free the runs mid-merge.
    ``abort_and_drain`` (crash path) stops the in-flight job at its next
    safe point, clears the queue, and returns only when nothing is running
    — pins and cache entries are released before the engine wipes volatile
    state, so a crash mid-compaction leaks neither.
"""
from __future__ import annotations

import os
import sys
import threading
import time
import weakref
from collections import deque
from typing import Callable, Deque, Optional

from .memtable import ImmutableMemtable


def _pin_worker_to_spare_core(offset: int = 0) -> None:
    """Best-effort: move the calling worker thread onto one of the trailing
    cores of the process affinity set, leaving the first core to the
    foreground.

    Production stores give background compaction pools dedicated cores for
    exactly this reason (RocksDB's background-thread affinity): without it
    the OS migrates the write-path thread onto the worker's core mid-burst
    and the two ping-pong.  ``offset`` spreads per-shard schedulers'
    workers round-robin from the last core downwards (DESIGN.md §12) —
    offset 0 is the last core, exactly the pre-sharding behavior; with
    more shards than spare cores the wrap reaches the foreground's core,
    which is the right trade once the foreground finishes and the drain
    phase would otherwise leave that core idle.  On Linux
    ``sched_setaffinity(0, ...)`` scopes to the calling *thread*; no-ops
    (with the full mask kept) on single-core affinities and on platforms
    without the syscall.
    """
    try:
        aff = sorted(os.sched_getaffinity(0))
        if len(aff) > 1:
            os.sched_setaffinity(0, {aff[-1 - (offset % len(aff))]})
    except (AttributeError, OSError):
        pass
    try:
        # Background work must lose scheduling ties against the foreground
        # writer (RocksDB runs its compaction pool at low priority for the
        # same reason): with several shards' workers runnable at once, an
        # equal-priority pool would take a proportional share of the
        # writer's core/GIL time mid-burst.  Linux-only on purpose: there
        # ``who=0`` scopes setpriority to the calling *thread* (the kernel
        # takes a TID); on other POSIX systems the same call would renice
        # the whole process — writer included — irreversibly.
        if sys.platform.startswith("linux"):
            os.setpriority(os.PRIO_PROCESS, 0, 10)
    except (AttributeError, OSError):
        pass


class WorkerBudget:
    """Resizable counting semaphore for the cross-shard worker budget.

    Drop-in for the plain ``threading.Semaphore`` the sharded facade hands
    its per-shard schedulers (DESIGN.md §12) — workers ``acquire``/``release``
    around each job exactly as before — plus :meth:`resize`, the online
    tuner's worker-reallocation actuator (§17).  Grow is always safe
    (permits are minted).  Shrink only retires *free* permits, non-blocking:
    the caller invokes it at a quiesce/idle boundary where every permit is
    home; if a straggler still holds one, the shrink aborts cleanly (False)
    rather than blocking the foreground or stranding a worker.
    """

    def __init__(self, n: int):
        self._size = max(1, int(n))
        self._sem = threading.Semaphore(self._size)
        self._mu = threading.Lock()

    @property
    def size(self) -> int:
        return self._size

    def acquire(self, *args, **kwargs):
        return self._sem.acquire(*args, **kwargs)

    def release(self) -> None:
        self._sem.release()

    # `with budget:` — same protocol as threading.Semaphore
    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def resize(self, n: int) -> bool:
        """Retarget the budget to ``n`` permits; True iff it landed."""
        n = max(1, int(n))
        with self._mu:
            delta = n - self._size
            if delta > 0:
                for _ in range(delta):
                    self._sem.release()
            elif delta < 0:
                got = 0
                for _ in range(-delta):
                    if not self._sem.acquire(blocking=False):
                        for _ in range(got):   # roll back: all-or-nothing
                            self._sem.release()
                        return False
                    got += 1
            self._size = n
            return True


class FlushJob:
    """Turn one immutable memtable into an L0 run + version install."""

    __slots__ = ("imm", "retries")

    def __init__(self, imm: ImmutableMemtable):
        self.imm = imm
        self.retries = 0

    def run(self, store) -> Optional["CompactJob"]:
        return store._bg_flush(self.imm)

    def __repr__(self):
        return f"FlushJob(entries={len(self.imm.memtable)})"


class CompactJob:
    """Plan-and-apply one compaction task against the *current* tree.

    Generation is decoupled from apply (``policy.plan`` runs when the job
    executes, never earlier), so a task can never go stale; the planned
    task's captured ``src_run_ids`` are still validated by ``_apply`` as the
    discipline check.  Returns another CompactJob while the tree is
    unshaped — the scheduler front-queues it, keeping all compactions of a
    flush ahead of the next flush.
    """

    __slots__ = ("last_task", "retries")

    def __init__(self):
        self.last_task = None
        self.retries = 0

    def run(self, store) -> Optional["CompactJob"]:
        task = store._bg_compact_one()
        self.last_task = task
        if task is not None:
            return CompactJob()
        # Tree is shaped: this worker just paid for the sort work a range
        # view reuses, so refresh the view here (DESIGN.md §13) — the
        # foreground write path never rebuilds.  No-op unless the store has
        # ``use_range_views`` set.
        store._bg_refresh_view()
        return None

    def __repr__(self):
        return f"CompactJob(last={self.last_task})"


class CompactionScheduler:
    def __init__(self, store, workers: int = 1,
                 budget: Optional[threading.Semaphore] = None,
                 worker_offset: int = 0):
        # Weak reference only: the parked worker threads must not root the
        # store.  An async store whose owner drops every reference (without
        # calling close()) stays collectable — the workers notice the dead
        # ref on their idle-wait heartbeat and exit, unrooting the
        # scheduler itself.
        #
        # ``budget`` (sharded facade, DESIGN.md §12): a semaphore shared by
        # N sibling schedulers bounding how many background jobs run
        # concurrently across the whole facade — each shard keeps its own
        # determinism turnstile (one in-flight job per shard, queue order),
        # while the shared budget caps total background CPU at
        # ``compaction_workers``.  ``worker_offset`` spreads the pools over
        # the spare cores.
        self._store = weakref.ref(store)
        self._budget = budget
        self._worker_offset = int(worker_offset)
        self.workers = max(1, int(workers))
        self._mu = threading.Lock()
        self._cv = threading.Condition(self._mu)
        self._queue: Deque = deque()
        self._inflight = 0
        self._paused = False
        self._abort = False
        self._stop = False
        self._failure: Optional[BaseException] = None
        # Optional facade hook (DESIGN.md §15): called by the worker that
        # just drained the queue (outside the condition lock).  The sharded
        # facade points this at its imbalance check so rebalancing is
        # *detected* at compaction/quiesce boundaries; the hook must only
        # set flags — the actual rebalance runs on a foreground thread
        # (running it here would deadlock: a rebalance quiesces this very
        # scheduler from its only worker).
        self.on_idle: Optional[Callable[[], None]] = None
        self._threads = []
        for i in range(self.workers):
            t = threading.Thread(target=self._loop, daemon=True,
                                 name=f"autumn-compaction-{i}")
            t.start()
            self._threads.append(t)

    # ------------------------------------------------------------ submission
    @property
    def lock(self) -> threading.Condition:
        """The scheduler condition: guards the queue AND the engine's
        immutable-memtable list (rotation appends and flush-install pops are
        both read-modify-write on ``store._imm``, so they share this lock;
        readers still see the list lock-free via reference capture)."""
        return self._cv

    def submit(self, job) -> None:
        with self._cv:
            if self._stop:
                raise RuntimeError("scheduler is shut down")
            if self._failure is not None:
                raise RuntimeError(
                    "background compaction failed; the store's durable "
                    "state is intact — crash()+recover() to resume"
                ) from self._failure
            self._queue.append(job)
            self._cv.notify_all()

    # --------------------------------------------------------------- workers
    def _loop(self) -> None:
        _pin_worker_to_spare_core(self._worker_offset)
        while True:
            with self._cv:
                # turnstile: strict one-job-at-a-time in FIFO order is the
                # determinism contract (see module docstring)
                while (not self._queue or self._inflight or self._paused) \
                        and not self._stop:
                    # timed wait = GC heartbeat: a store dropped without
                    # close() must not be kept alive by its parked workers
                    self._cv.wait(timeout=1.0)
                    if self._store() is None:
                        return
                if self._stop:
                    return
                job = self._queue.popleft()
                self._inflight += 1
            store = self._store()
            cont = None
            try:
                if not self._abort and store is not None:
                    if self._budget is None:
                        cont = job.run(store)
                    else:
                        # Shared worker budget: at most `budget` jobs run
                        # at once across all sibling shards' schedulers.
                        # Acquired outside the condition (no lock held), so
                        # a waiting shard never blocks another's turnstile;
                        # abort is re-checked after the wait.
                        with self._budget:
                            if not self._abort:
                                cont = job.run(store)
            except BaseException as e:    # worker must survive a failed job:
                cfg = store.config if store is not None else None
                tel = cfg.telemetry if cfg is not None else None
                job.retries += 1
                if cfg is not None and job.retries <= cfg.bg_max_retries \
                        and not self._abort and not self._stop:
                    # graceful degradation, stage 1 (§16.3): transient
                    # failures get bounded exponential backoff, then the
                    # same job re-runs from the front of the queue (its
                    # turnstile slot — determinism order is preserved)
                    store._stats.local().bg_retries += 1
                    if tel is not None:
                        tel.emit("bg_retry", job=type(job).__name__,
                                 attempt=job.retries, error=repr(e))
                    time.sleep(min(0.001 * (1 << (job.retries - 1)), 0.05))
                    with self._cv:
                        self._queue.appendleft(job)
                else:
                    # stage 2: retry budget exhausted — poison the pipeline
                    # and flip the store read-only (writes raise
                    # StoreDegradedError; reads keep serving)
                    if tel is not None:
                        tel.emit("bg_failure", job=type(job).__name__,
                                 error=repr(e), retries=job.retries - 1)
                    if store is not None:
                        store._stats.local().bg_gave_up += 1
                        # Degrade BEFORE publishing the failure: a writer
                        # that passed the store's _degraded check must not
                        # be the first to find the dead pipeline — submit()
                        # can only start refusing after the degraded flag
                        # is visible, and _rotate translates the residual
                        # window into the same StoreDegradedError.
                        store._enter_degraded(e)
                    with self._cv:        # a dead consumer would deadlock
                        if self._failure is None:   # writers at the stall
                            self._failure = e       # trigger escape
                        self._queue.clear()  # nothing will drain; idle()
                                             # goes True
            finally:
                store = None   # don't root the store across the idle wait
                with self._cv:
                    self._inflight -= 1
                    if cont is not None and not self._abort \
                            and self._failure is None:
                        self._queue.appendleft(cont)
                    drained = not self._queue and self._inflight == 0
                    self._cv.notify_all()
                hook = self.on_idle
                if drained and hook is not None and not self._abort:
                    try:
                        hook()     # flag-setting only; outside the condition
                    except Exception:
                        pass       # a broken hook must not kill the worker

    # ------------------------------------------------------------- lifecycle
    @property
    def aborting(self) -> bool:
        """Checked by jobs between pipeline stages (plan/merge/install)."""
        return self._abort

    def pending(self) -> int:
        with self._cv:
            return len(self._queue) + self._inflight

    def idle(self) -> bool:
        """Queue empty and nothing in flight (or the pipeline is dead).

        Lock-free peek — exact when the caller already holds the scheduler
        condition, which is the case inside ``wait_until`` predicates (the
        mutex is non-reentrant, so predicates must not call the locking
        accessors).  A failed pipeline reports idle so stalled writers
        escape instead of deadlocking; the failure surfaces on the next
        ``submit``/``wait_for_quiesce``.
        """
        return self._failure is not None or \
            (not self._queue and self._inflight == 0)

    def wait_until(self, pred: Callable[[], bool],
                   timeout: Optional[float] = None) -> bool:
        """Block the calling (foreground) thread until ``pred()`` holds;
        re-evaluated after every job completion (write-stall control)."""
        with self._cv:
            return self._cv.wait_for(pred, timeout)

    def wait_for_quiesce(self, timeout: Optional[float] = None) -> bool:
        """Block until the queue is drained and nothing is in flight.

        After a True return the store's levels are exactly what the
        synchronous engine would hold for the same op sequence (modulo any
        still-unrotated active memtable, which quiesce never flushes).
        Raises RuntimeError if a background job failed — a quiesce after a
        dead pipeline must be loud, not a plausible-looking settled tree.
        """
        with self._cv:
            ok = self._cv.wait_for(
                lambda: self._failure is not None
                or (not self._queue and self._inflight == 0), timeout)
            if self._failure is not None:
                raise RuntimeError(
                    "background compaction failed; the store's durable "
                    "state is intact — crash()+recover() to resume"
                ) from self._failure
            return ok

    def pause(self) -> None:
        """Stop popping new jobs (in-flight job finishes).  Holds the
        immutable-memtable read window open — used by tests to make the
        rotation pipeline observable deterministically.  A paused scheduler
        with queued work is not ``idle()``, so writes that hit the hard
        stall trigger will block until ``resume``; pause with the triggers
        disabled (tests do) or resume from another thread."""
        with self._cv:
            self._paused = True

    def resume(self) -> None:
        with self._cv:
            self._paused = False
            self._cv.notify_all()

    def abort_and_drain(self) -> None:
        """Crash path: discard all queued work and wait out the in-flight job.

        The abort flag makes the running job bail at its next safe point
        (its cleanup releases any input-version pin); queued jobs are
        dropped un-run.  Returns with the scheduler idle and reusable —
        ``recover()`` just starts submitting again.
        """
        store = self._store()
        tel = store.config.telemetry if store is not None else None
        if tel is not None:
            tel.emit("bg_abort", dropped=len(self._queue))
        with self._cv:
            self._abort = True
            self._queue.clear()
            self._cv.notify_all()
            self._cv.wait_for(lambda: self._inflight == 0)
            self._queue.clear()   # a bailing job may have pushed its cont
            self._abort = False
            self._failure = None  # crash wipes volatile state; the pipeline
                                  # is reusable after recover()

    def shutdown(self) -> None:
        """Stop the worker threads (final; the scheduler is not reusable)."""
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        for t in self._threads:
            t.join(timeout=5.0)
