"""REMIX-style cross-run range views (DESIGN.md §13).

The ``MergingIterator`` pays a per-source frontier merge on every scan
refill: window every run, clamp to the frontier, stable-sort the concat,
dedup.  REMIX (Zhong et al., FAST'21) observes that the sort work a scan
repeats on every refill was already paid once — at compaction time — so a
*globally-sorted view* across the runs can be maintained out of band and a
range read collapses to one binary search plus one sequential sweep.

:class:`RangeView` is that structure for one tree (one per store; the
sharded facade gets one per shard): four parallel columns over every entry
of every run, sorted by key with exactly one row per key (the newest
version wins, exactly the merge resolution order):

  ``keys``  uint64, strictly increasing — the global sorted key index
  ``src``   int32 index into ``runs`` (the view's newest-first run list)
  ``rows``  int64 row of the winning version inside its run
  ``live``  bool, False where the winning version is a tombstone

A scan binary-searches ``keys`` once, sweeps ``live`` forward until it has
``count`` set bits (growing the sweep window geometrically, so
tombstone-dense ranges cost O(log deleted) sweeps, not O(deleted/window)),
then materializes values with one batched row-gather per touched run —
no per-refill multi-way merge, no per-entry Python in the common path.
Entries still in memtables are merged in on top (they are newer than every
run by construction); with the memtables empty the sweep is pure.

Rebuilds are *incremental at compaction boundaries*: per-level sorted
columns are cached by the level's run-id tuple, so an install that touched
levels src/dst recomputes only those levels' columns (a flush resorts only
L0) before one radix argsort re-merges the level streams.  The engine's
copy-on-write level lists make invalidation free: a view remembers the
exact ``_levels`` list object it was built from (``levels_ref``), and any
install swaps that reference — ``levels_ref is store._levels`` is the
entire freshness check.  Runs referenced by a stale view are immutable and
held alive by the view itself, so a racing install can never tear a scan.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .run import SortedRun
from .types import KEY_DTYPE, TOMBSTONE_LEN, IOStats

# Per-level sorted columns: (keys, src_local, rows, live).
LevelColumns = Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]


def _level_columns(runs_newest_first: Sequence[SortedRun]) -> LevelColumns:
    """Sorted newest-wins columns across one level's runs.

    Single-run levels (every level >= 1 after a leveled merge) are free:
    the columns are views/aranges over the run's own arrays.  Multi-run
    levels (L0 tiering) pay one stable argsort + first-occurrence dedup.
    """
    if len(runs_newest_first) == 1:
        r = runs_newest_first[0]
        n = len(r)
        return (r.keys, np.zeros(n, np.int32),
                np.arange(n, dtype=np.int64), r.vlens != TOMBSTONE_LEN)
    K = np.concatenate([r.keys for r in runs_newest_first])
    src = np.concatenate([np.full(len(r), i, np.int32)
                          for i, r in enumerate(runs_newest_first)])
    rows = np.concatenate([np.arange(len(r), dtype=np.int64)
                           for r in runs_newest_first])
    vl = np.concatenate([r.vlens for r in runs_newest_first])
    order = np.argsort(K, kind="stable")
    Ks = K[order]
    first = np.empty(Ks.size, dtype=bool)
    first[0] = True
    np.not_equal(Ks[1:], Ks[:-1], out=first[1:])
    sel = order[first]
    return Ks[first], src[sel], rows[sel], vl[sel] != TOMBSTONE_LEN


def build_range_view(levels: Sequence[Sequence[SortedRun]],
                     level_cache: Optional[Dict[Tuple[int, ...],
                                                LevelColumns]] = None,
                     telemetry=None) -> "RangeView":
    """Build the global view from a captured (copy-on-write) level list.

    ``level_cache`` maps a level's run-id tuple to its sorted columns;
    levels untouched since the last rebuild reuse their cached columns
    (the incremental half of the rebuild), and entries for retired run
    sets are pruned so the cache never roots dead runs.

    ``telemetry`` (DESIGN.md §14): when set, every rebuild emits a
    ``view_rebuild`` trace event carrying entry/run counts and the build
    duration (the engine separately records the latency histogram).
    """
    t0 = time.perf_counter_ns() if telemetry is not None else 0
    view = _build_range_view(levels, level_cache)
    if telemetry is not None:
        dur = time.perf_counter_ns() - t0
        telemetry.emit("view_rebuild", entries=len(view),
                       runs=len(view.runs), t0=t0, dur_ns=dur)
    return view


def _build_range_view(levels, level_cache):
    runs: List[SortedRun] = []
    parts_k: List[np.ndarray] = []
    parts_src: List[np.ndarray] = []
    parts_rows: List[np.ndarray] = []
    parts_live: List[np.ndarray] = []
    live_keys = set()
    for lvl in levels:
        rr = [r for r in reversed(lvl) if len(r)]  # newest first within level
        if not rr:
            continue
        ck = tuple(r.run_id for r in rr)
        live_keys.add(ck)
        cols = level_cache.get(ck) if level_cache is not None else None
        if cols is None:
            cols = _level_columns(rr)
            if level_cache is not None:
                level_cache[ck] = cols
        off = len(runs)
        runs.extend(rr)
        k, s, rw, lv = cols
        parts_k.append(k)
        parts_src.append(s if off == 0 else s + np.int32(off))
        parts_rows.append(rw)
        parts_live.append(lv)
    if level_cache is not None:
        for stale in [k for k in level_cache if k not in live_keys]:
            del level_cache[stale]
    if not parts_k:
        z = np.zeros(0, dtype=KEY_DTYPE)
        return RangeView(levels, [], z, np.zeros(0, np.int32),
                         np.zeros(0, np.int64), np.zeros(0, bool))
    if len(parts_k) == 1:
        return RangeView(levels, runs, parts_k[0], parts_src[0],
                         parts_rows[0], parts_live[0])
    # Level streams concatenated newest-level-first + one stable (radix)
    # argsort: the first occurrence of each key is its newest version —
    # the same resolution the point-read path walks run by run.
    K = np.concatenate(parts_k)
    order = np.argsort(K, kind="stable")
    Ks = K[order]
    first = np.empty(Ks.size, dtype=bool)
    first[0] = True
    np.not_equal(Ks[1:], Ks[:-1], out=first[1:])
    sel = order[first]
    return RangeView(levels, runs,
                     Ks[first],
                     np.concatenate(parts_src)[sel],
                     np.concatenate(parts_rows)[sel],
                     np.concatenate(parts_live)[sel])


class RangeView:
    """One immutable globally-sorted view of one tree (see module doc)."""

    __slots__ = ("levels_ref", "runs", "keys", "src", "rows", "live",
                 "all_live")

    def __init__(self, levels_ref, runs: List[SortedRun], keys: np.ndarray,
                 src: np.ndarray, rows: np.ndarray, live: np.ndarray):
        self.levels_ref = levels_ref   # identity token: the exact COW list
        self.runs = runs               # newest-first, holds the runs alive
        self.keys = keys
        self.src = src
        self.rows = rows
        self.live = live
        # paid once per rebuild: a tombstone-free view sweeps without ever
        # touching the liveness bitmap (the overwhelmingly common shape)
        self.all_live = bool(live.all())

    def __len__(self) -> int:
        return int(self.keys.size)

    # ------------------------------------------------------------------ reads
    def seek(self, key: int, stats: Optional[IOStats] = None,
             cache=None) -> Optional[int]:
        """First indexed key >= ``key`` (tombstone winners included — the
        same approximate-liveness contract as ``LSMStore.seek``'s run walk,
        which doesn't liveness-filter run entries either).  Cost: one
        binary search + one block touch, against one seek + one block per
        run on the merging path."""
        i = int(self.keys.searchsorted(np.uint64(int(key))))
        if i >= self.keys.size:
            return None
        if stats is not None:
            stats.seeks += 1
            stats.runs_touched_range += 1
            run = self.runs[int(self.src[i])]
            run._charge_block(run.block_of[int(self.rows[i])], stats, cache)
        return int(self.keys[i])

    def scan(self, start_key: int, count: int,
             mem_items: Sequence[Tuple[int, int, Optional[bytes]]] = (),
             stats: Optional[IOStats] = None,
             cache=None) -> List[Tuple[int, bytes]]:
        """First ``count`` live entries with key >= start_key.

        ``mem_items`` is the newest-wins-combined, key-sorted memtable
        stream from ``start_key`` (``iterator.combined_mem_items``); its
        entries shadow same-key view entries (memtables are newer than
        every run).  Empty memtables take the pure-sweep fast path.
        """
        if count <= 0:
            return []
        i0 = int(self.keys.searchsorted(np.uint64(int(start_key))))
        if not mem_items:
            return self._scan_sweep(i0, count, stats, cache)
        return self._scan_with_mem(i0, count, mem_items, stats, cache)

    def _scan_sweep(self, i0: int, count: int, stats, cache
                    ) -> List[Tuple[int, bytes]]:
        n = self.keys.size
        if self.all_live:
            sl = slice(i0, min(i0 + count, n))
            vals = self._materialize(sl, stats, cache)
            return list(zip(self.keys[sl].tolist(), vals))
        sel: List[int] = []
        i = i0
        w = max(2 * count, 32)
        while len(sel) < count and i < n:
            hits = np.nonzero(self.live[i:i + w])[0]
            if hits.size:
                take = hits[:count - len(sel)]
                sel.extend((i + take).tolist())
            i += w
            w *= 2   # tombstone-dense ranges: O(log deleted) sweeps
        idx = np.asarray(sel, dtype=np.int64)
        vals = self._materialize(idx, stats, cache)
        return list(zip(self.keys[idx].tolist(), vals))

    def _scan_with_mem(self, i0: int, count: int, mem_items, stats, cache
                       ) -> List[Tuple[int, bytes]]:
        """Two-source merge: the (small, fully materialized) memtable
        stream against growing view windows; memtable wins duplicates.
        Winners accumulate in key order until ``count`` live ones exist,
        then view winners' values gather in one batch per run."""
        n = self.keys.size
        mk = np.fromiter((e[0] for e in mem_items), KEY_DTYPE,
                         len(mem_items))
        mem_live = np.fromiter((e[2] is not None for e in mem_items),
                               bool, len(mem_items))
        acc_keys: List[int] = []
        acc_live: List[bool] = []
        acc_mem: List[int] = []    # memtable row, or -1 for a view winner
        acc_view: List[int] = []   # view index, or -1 for a memtable winner
        got = 0
        mi = 0
        i = i0
        w = max(2 * count, 32)
        while got < count and (i < n or mi < mk.size):
            vk = self.keys[i:i + w]
            truncated = i + w < n
            mrem = mk[mi:]
            cat = np.concatenate([mrem, vk])
            if cat.size == 0:
                break
            order = np.argsort(cat, kind="stable")  # mem first => mem wins
            cs = cat[order]
            first = np.empty(cs.size, dtype=bool)
            first[0] = True
            np.not_equal(cs[1:], cs[:-1], out=first[1:])
            widx = order[first]
            wkeys = cs[first]
            if truncated:
                # keys beyond the view window's frontier may still be
                # preceded by unseen view keys — defer them
                frontier = np.uint64(vk[-1])
                cut = int(wkeys.searchsorted(frontier, side="right"))
                widx, wkeys = widx[:cut], wkeys[:cut]
                mem_consumed = int(mrem.searchsorted(frontier, side="right"))
            else:
                mem_consumed = int(mrem.size)
            is_mem = widx < mrem.size
            liv = np.empty(widx.size, dtype=bool)
            liv[is_mem] = mem_live[mi + widx[is_mem]]
            vsel = i + (widx[~is_mem] - mrem.size)
            liv[~is_mem] = self.live[vsel]
            for t in range(widx.size):
                acc_keys.append(int(wkeys[t]))
                acc_live.append(bool(liv[t]))
                if is_mem[t]:
                    acc_mem.append(mi + int(widx[t]))
                    acc_view.append(-1)
                else:
                    acc_mem.append(-1)
                    acc_view.append(i + int(widx[t]) - int(mrem.size))
            got += int(np.count_nonzero(liv))
            mi += mem_consumed
            i += int(vk.size)
            w *= 2   # tombstone-dense growth, same law as the pure sweep
        # Take the first `count` live winners in key order; view winners'
        # values materialize in one batched gather pass.
        take: List[int] = []
        for t in range(len(acc_keys)):
            if acc_live[t]:
                take.append(t)
                if len(take) == count:
                    break
        view_slots = [t for t in take if acc_view[t] >= 0]
        vvals = self._materialize(
            np.asarray([acc_view[t] for t in view_slots], dtype=np.int64),
            stats, cache)
        by_slot = dict(zip(view_slots, vvals))
        out: List[Tuple[int, bytes]] = []
        for t in take:
            if acc_view[t] >= 0:
                out.append((acc_keys[t], by_slot[t]))
            else:
                out.append((acc_keys[t], mem_items[acc_mem[t]][2]))
        return out

    # ------------------------------------------------------------- gathering
    def _materialize(self, idx: np.ndarray, stats, cache
                     ) -> List[Optional[bytes]]:
        """Values for view indices ``idx`` (all expected live), one batched
        row-gather + block charge per touched run.

        ``idx`` arrives ascending (scan order), so within each run the
        gathered rows — and their block ids — are already sorted: group
        membership and block dedup are boundary scans, never re-sorts.
        """
        # ``idx`` may be a slice (contiguous all-live sweep — the column
        # "gathers" are then zero-copy views) or an int64 index array
        src = self.src[idx]
        rows = self.rows[idx]
        n = int(src.size)
        out: List[Optional[bytes]] = [None] * n
        if n == 0:
            return out
        order = np.argsort(src, kind="stable")
        ssrc = src[order]
        cut = np.nonzero(ssrc[1:] != ssrc[:-1])[0] + 1
        starts = [0] + cut.tolist()
        ends = cut.tolist() + [n]
        for a, b in zip(starts, ends):
            m = order[a:b]
            run = self.runs[int(ssrc[a])]
            rs = rows[m]
            if stats is not None:
                bids = run.block_of[rs]       # ascending: rs is ascending
                if cache is None:
                    nb = 1 if bids.size <= 1 else \
                        1 + int(np.count_nonzero(bids[1:] != bids[:-1]))
                    stats.blocks_read += nb
                else:
                    if bids.size > 1:
                        keep = np.empty(bids.size, dtype=bool)
                        keep[0] = True
                        np.not_equal(bids[1:], bids[:-1], out=keep[1:])
                        bids = bids[keep]
                    cache.read_blocks(run.run_id, bids.tolist(),
                                      run.block_bytes, stats)
            vals = run.values_at(rs)
            for t, v in zip(m.tolist(), vals):
                out[t] = v
        return out
