"""Shared types and cost accounting for the Autumn LSM engine.

The paper's analysis is written in units of *disk block I/Os*.  This engine is
host-memory resident (DESIGN.md §2, §8): a "block" is a BLOCK_SIZE-byte unit of
a sorted run, and every block touch is counted by :class:`IOStats`.  Wall-clock
latencies reported by the benchmarks therefore measure the same thing db_bench
measures — relative policy cost — while the block counters validate the
complexity table (Table 2) exactly.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Iterable, List, Optional

import numpy as np

# Paper/db_bench defaults: 4 KiB blocks, 16-byte keys (8-byte user key is
# stored as uint64; the extra 8 bytes model seq/metadata overhead per entry).
BLOCK_SIZE = 4096
KEY_BYTES = 16

KEY_DTYPE = np.uint64
SEQ_DTYPE = np.uint64

# Sentinel length marking a tombstone entry inside a run.
TOMBSTONE_LEN = -1


@dataclasses.dataclass
class IOStats:
    """Counters for the disk-I/O cost model plus engine health stats."""

    blocks_read: int = 0          # data blocks touched by reads
    blocks_written: int = 0       # data blocks written by flush/compaction
    cache_hit_blocks: int = 0     # block reads served by the BlockCache
    cache_miss_blocks: int = 0    # block reads that missed the cache (charged)
    seeks: int = 0                # iterator seek operations (1 per run touched)
    bloom_probes: int = 0         # CPU cost proxy (paper §3.1 CPU Optimization)
    bloom_negatives: int = 0      # probes answered "definitely absent"
    false_positives: int = 0      # bloom said maybe, block read found nothing
    runs_touched_point: int = 0   # runs examined across all point reads
    runs_touched_range: int = 0   # runs examined across all range reads
    point_reads: int = 0
    range_reads: int = 0
    entries_flushed: int = 0      # entries written from memtable to level 0/1
    bytes_flushed: int = 0
    entries_compacted: int = 0    # entries rewritten by compactions
    bytes_compacted: int = 0
    compactions: int = 0
    delayed_last_level_compactions: int = 0  # paper §3.1 "Delayed ... Compaction"
    write_stalls: int = 0
    write_slowdowns: int = 0      # soft write-pressure events (async scheduler)
    stall_ns: int = 0             # foreground ns spent stalled/slowed on
                                  # write pressure (async scheduler)
    bg_flushes: int = 0           # memtable flushes applied by a worker thread
    bg_compactions: int = 0       # compaction tasks applied by a worker thread
    wal_appends: int = 0
    wal_fsyncs: int = 0
    view_rebuilds: int = 0        # cross-run range-view rebuilds (§13)
    bg_view_rebuilds: int = 0     # rebuilds run by a scheduler worker
    view_entries_built: int = 0   # entries indexed across all rebuilds
    view_rebuild_ns: int = 0      # wall time spent rebuilding views
    view_scans: int = 0           # range reads served by a range view
    view_fallbacks: int = 0       # view-eligible reads served by the
                                  # merging iterator (view stale mid-churn)
    bg_retries: int = 0           # background jobs re-run after a failure
                                  # (bounded exponential backoff, §16.3)
    bg_gave_up: int = 0           # background jobs abandoned after the
                                  # retry budget — store degrades read-only

    def write_amplification(self) -> float:
        """Average number of times each flushed byte was rewritten."""
        if self.bytes_flushed == 0:
            return 0.0
        return (self.bytes_flushed + self.bytes_compacted) / self.bytes_flushed

    def snapshot(self) -> "IOStats":
        return dataclasses.replace(self)

    def delta(self, since: "IOStats") -> "IOStats":
        out = IOStats()
        for f in dataclasses.fields(IOStats):
            setattr(out, f.name, getattr(self, f.name) - getattr(since, f.name))
        return out

    def __add__(self, other: "IOStats") -> "IOStats":
        """Fieldwise sum over *every* counter (cache hit/miss, stall_ns,
        bg_* included automatically — new fields join the sum by being
        declared, the single place aggregation is defined)."""
        if not isinstance(other, IOStats):
            return NotImplemented
        out = IOStats()
        for f in dataclasses.fields(IOStats):
            setattr(out, f.name, getattr(self, f.name) + getattr(other, f.name))
        return out

    def __radd__(self, other):
        # sum() support: sum(shard.stats for shard in shards)
        if other == 0:
            return self.snapshot()
        return self.__add__(other)

    @staticmethod
    def merge(stats: "Iterable[IOStats]") -> "IOStats":
        """Aggregate many stores' counters into one (the sharded facade's
        ``stats`` view).  Returns a fresh IOStats; inputs are not mutated."""
        out = IOStats()
        for s in stats:
            out = out + s
        return out

    def to_dict(self) -> "Dict[str, float]":
        """Counters as a dict in declaration order (the stable key order the
        CSV/JSON surfaces rely on), plus the derived ``write_amp`` — the one
        dump used by ``AutumnKVCache.stats()`` and the benchmarks instead of
        ad-hoc field reaching."""
        out = {f.name: getattr(self, f.name)
               for f in dataclasses.fields(IOStats)}
        out["write_amp"] = self.write_amplification()
        return out


class StatsHub:
    """Lossless concurrent :class:`IOStats` accumulation.

    Scheduler workers and foreground threads used to ``+=`` the *same*
    ``IOStats`` fields — a non-atomic read-modify-write that silently lost
    increments under contention (e.g. ``stall_ns`` charged by a stalled
    writer while a worker merged compaction counters).  The hub gives every
    thread its own private ``IOStats`` shard via :meth:`local`; shards are
    registered with a GIL-atomic ``list.append`` so neither registration nor
    the hot ``+=`` on a shard ever takes a lock, and no two threads ever
    mutate the same field.  :meth:`merged` folds the shards together at read
    time with the fieldwise ``IOStats.__add__`` algebra.

    Reads are monotonic-consistent (a concurrent snapshot may split an
    in-flight operation's counters across fields — the exact guarantee the
    single shared IOStats gave, minus the lost updates).  Shards of finished
    threads stay registered so their counts are never dropped; the engine
    uses a bounded worker pool, so the shard list stays small.
    """

    __slots__ = ("_tl", "_shards")

    def __init__(self):
        self._tl = threading.local()
        self._shards: List[IOStats] = []

    def local(self) -> IOStats:
        """The calling thread's private shard (create+register on first use)."""
        try:
            return self._tl.s
        except AttributeError:
            s = IOStats()
            self._tl.s = s
            self._shards.append(s)   # list.append is GIL-atomic: no lock
            return s

    def merged(self) -> IOStats:
        """Fieldwise sum of all shards (a fresh IOStats; shards unmutated)."""
        return IOStats.merge(list(self._shards))

    # ------------------------------------------------- windowed-delta API
    def snapshot(self) -> IOStats:
        """A fresh merged capture — the pair of :meth:`delta`, mirroring
        ``Telemetry.snapshot()``/``delta()`` so interval consumers (the
        online tuner, DESIGN.md §17) sense both sources the same way."""
        return self.merged()

    def delta(self, prev: IOStats) -> IOStats:
        """Counter diffs accumulated since ``prev`` (a :meth:`snapshot`)."""
        return self.merged().delta(prev)


def entry_bytes(val_len: int, key_bytes: int = KEY_BYTES) -> int:
    """Physical size of one entry (tombstones carry only the key)."""
    return key_bytes + max(val_len, 0)


def blocks_for_bytes(nbytes: int, block_size: int = BLOCK_SIZE) -> int:
    return max(1, -(-nbytes // block_size)) if nbytes > 0 else 0


def splitmix64(x: np.ndarray) -> np.ndarray:
    """Vectorized splitmix64 — the hash family used for bloom filters."""
    x = x.astype(np.uint64, copy=True)
    x += np.uint64(0x9E3779B97F4A7C15)
    z = x
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    z = z ^ (z >> np.uint64(31))
    return z
