"""Fault injection and end-to-end integrity primitives (DESIGN.md §16).

Three things live here because everything else imports them:

* **CRC32C** (Castagnoli) — the checksum used by every integrity frame in
  the store: WAL record frames, ``SortedRun`` block checksums, and manifest
  edit checksums.  ``crc32c`` is the scalar byte-loop oracle;
  ``crc32c_rows`` is the vectorized twin (column-lockstep over byte
  positions with active-length masks) used by the batched WAL append and
  the run builder.  The two are property-tested bit-for-bit equal.
  ``zlib.crc32`` is the *wrong* polynomial (CRC-32/ISO-HDLC), so the table
  is built here from the reflected Castagnoli polynomial — no new deps.

* **Typed failure exceptions** — :class:`InjectedFault` (a deliberately
  injected I/O error), :class:`CorruptionError` (a checksum mismatch,
  carrying ``run_id``/``block_id``), and :class:`StoreDegradedError`
  (writes rejected because the store is in read-only degraded mode).

* **FaultInjector** — the LevelDB ``fault_injection_test`` / mock-env
  shape adapted to the in-memory durability model.  Attached via
  ``LSMConfig.faults``; every durability/IO site calls
  ``faults.check("<site>")`` (guarded by ``if faults is not None`` so the
  ``faults=None`` default adds zero overhead).  Trigger modes: one-shot /
  n-shot (``fail``), every-Nth (``fail_every``), probabilistic with a
  seeded RNG (``fail_prob``).  Corruption modes arm state consumed at
  ``crash()`` time (WAL tail, manifest last edit) or act immediately on a
  sampled run block (``corrupt_run_block``).
"""
from __future__ import annotations

import random
from typing import Dict, Optional

import numpy as np

__all__ = [
    "FAULT_SITES",
    "CorruptionError",
    "FaultInjector",
    "InjectedFault",
    "StoreDegradedError",
    "crc32c",
    "crc32c_rows",
]

# ---------------------------------------------------------------------------
# CRC32C (Castagnoli, reflected polynomial 0x82F63B78)
# ---------------------------------------------------------------------------

def _build_table() -> np.ndarray:
    poly = 0x82F63B78
    table = np.empty(256, dtype=np.uint32)
    for i in range(256):
        crc = i
        for _ in range(8):
            crc = (crc >> 1) ^ poly if crc & 1 else crc >> 1
        table[i] = crc
    return table


_TABLE = _build_table()
_TABLE_LIST = [int(x) for x in _TABLE]  # plain ints: no numpy boxing in the scalar loop


def crc32c(data: bytes) -> int:
    """Scalar CRC-32C over ``data`` — the oracle for :func:`crc32c_rows`."""
    crc = 0xFFFFFFFF
    tab = _TABLE_LIST
    for b in data:
        crc = (crc >> 8) ^ tab[(crc ^ b) & 0xFF]
    return crc ^ 0xFFFFFFFF


def crc32c_rows(mat: np.ndarray, lens: np.ndarray) -> np.ndarray:
    """Vectorized CRC-32C over the rows of a padded byte matrix.

    ``mat`` is ``(n, L) uint8``; row ``i``'s message is ``mat[i, :lens[i]]``
    (padding bytes beyond ``lens[i]`` never touch the checksum).  All rows
    advance one byte position per pass, masked by their remaining length —
    bit-for-bit equal to calling :func:`crc32c` per row.
    """
    mat = np.ascontiguousarray(mat, dtype=np.uint8)
    n = mat.shape[0]
    lens = np.asarray(lens, dtype=np.int64)
    crc = np.full(n, 0xFFFFFFFF, dtype=np.uint32)
    if n:
        for j in range(mat.shape[1]):
            active = lens > j
            if not active.any():
                break
            step = (crc >> np.uint32(8)) ^ _TABLE[(crc ^ mat[:, j]) & np.uint32(0xFF)]
            crc = np.where(active, step, crc)
    return crc ^ np.uint32(0xFFFFFFFF)


# ---------------------------------------------------------------------------
# Typed failures
# ---------------------------------------------------------------------------

class InjectedFault(IOError):
    """A deliberately injected fault at a named durability/IO site."""

    def __init__(self, site: str):
        super().__init__(f"injected fault at {site!r}")
        self.site = site


class CorruptionError(RuntimeError):
    """A checksum mismatch detected on read, scrub, or recovery.

    ``run_id``/``block_id`` locate a bad sorted-run block; WAL/manifest
    corruption uses ``run_id=-1`` with a descriptive ``where``.
    """

    def __init__(self, run_id: int, block_id: int, where: str = "block"):
        super().__init__(
            f"corruption detected in {where} (run_id={run_id}, block_id={block_id})"
        )
        self.run_id = run_id
        self.block_id = block_id
        self.where = where


class StoreDegradedError(RuntimeError):
    """Writes rejected: the store is read-only after persistent background
    failure.  Reads keep serving the committed tree; ``crash()`` +
    ``recover()`` restores write service."""


# ---------------------------------------------------------------------------
# FaultInjector
# ---------------------------------------------------------------------------

#: Every instrumented durability/IO site (the crash-point matrix iterates this).
FAULT_SITES = (
    "wal_append",
    "wal_fsync",
    "manifest_fsync",
    "block_read",
    "compaction_merge",
    "flush_write",
    "migration_import",
    "migration_strip",
)


class FaultInjector:
    """Trigger injected failures and corruption at named sites.

    Failure triggers (``check(site)`` raises :class:`InjectedFault`):

    * ``fail(site, times=1)``   — fire on the next ``times`` hits (one-shot
      by default; ``times=-1`` fires forever).
    * ``fail_every(site, n)``   — fire on every Nth hit of the site.
    * ``fail_prob(site, p)``    — fire with probability ``p`` per hit,
      from the injector's seeded RNG (deterministic per seed).

    Corruption arming (consumed by ``crash()`` paths):

    * ``corrupt_wal_tail(mode)``      — ``"torn"`` keeps a random prefix of
      the unsynced tail instead of dropping it all; ``"bitflip"`` /
      ``"garbage"`` damage the *synced* buffer's last frame region so
      recovery must checksum its way to the first bad frame.
    * ``corrupt_manifest_edit()``     — damage the last manifest edit so
      its checksum fails and recovery falls back one version.
    * ``corrupt_run_block(run)``      — immediate: flip bytes inside a
      sampled block of ``run`` and return its block id.

    ``fired`` counts every triggered failure/corruption by site for test
    assertions.  All randomness comes from one seeded RNG.
    """

    def __init__(self, seed: int = 0):
        self.rng = random.Random(seed)
        self._times: Dict[str, int] = {}
        self._every: Dict[str, int] = {}
        self._hits: Dict[str, int] = {}
        self._prob: Dict[str, float] = {}
        self.fired: Dict[str, int] = {}
        self.wal_tail_mode: Optional[str] = None   # None | torn | bitflip | garbage
        self.manifest_corruption: bool = False

    # -- arming -------------------------------------------------------------

    def fail(self, site: str, times: int = 1) -> "FaultInjector":
        self._times[site] = times
        return self

    def fail_every(self, site: str, n: int) -> "FaultInjector":
        if n < 1:
            raise ValueError("fail_every needs n >= 1")
        self._every[site] = n
        return self

    def fail_prob(self, site: str, p: float) -> "FaultInjector":
        self._prob[site] = float(p)
        return self

    def clear(self, site: Optional[str] = None) -> None:
        if site is None:
            self._times.clear()
            self._every.clear()
            self._prob.clear()
        else:
            self._times.pop(site, None)
            self._every.pop(site, None)
            self._prob.pop(site, None)

    def corrupt_wal_tail(self, mode: str = "bitflip") -> "FaultInjector":
        if mode not in ("torn", "bitflip", "garbage"):
            raise ValueError(f"unknown WAL tail corruption mode {mode!r}")
        self.wal_tail_mode = mode
        return self

    def corrupt_manifest_edit(self) -> "FaultInjector":
        self.manifest_corruption = True
        return self

    # -- firing -------------------------------------------------------------

    def _fired(self, site: str) -> None:
        self.fired[site] = self.fired.get(site, 0) + 1
        raise InjectedFault(site)

    def check(self, site: str) -> None:
        """Raise :class:`InjectedFault` if a trigger for ``site`` fires."""
        t = self._times.get(site)
        if t is not None and t != 0:
            if t > 0:
                self._times[site] = t - 1
            self._fired(site)
        n = self._every.get(site)
        if n is not None:
            h = self._hits.get(site, 0) + 1
            self._hits[site] = h
            if h % n == 0:
                self._fired(site)
        p = self._prob.get(site)
        if p is not None and self.rng.random() < p:
            self._fired(site)

    # -- corruption helpers (called by crash()/tests) -----------------------

    def mangle_wal_tail(self, buf: bytearray, synced_upto: int) -> int:
        """Apply the armed WAL tail corruption to ``buf`` and return the
        new buffer length to keep.  Consumes the armed mode."""
        mode, self.wal_tail_mode = self.wal_tail_mode, None
        if mode is None:
            return synced_upto
        self.fired["wal_tail:" + mode] = self.fired.get("wal_tail:" + mode, 0) + 1
        if mode == "torn":
            # a torn write: some prefix of the unsynced tail made it out
            extra = len(buf) - synced_upto
            return synced_upto + (self.rng.randrange(extra + 1) if extra > 0 else 0)
        # bitflip / garbage damage bytes *within* the synced region's tail,
        # so recovery cannot trust the length watermark and must checksum.
        if synced_upto == 0:
            return 0
        lo = max(0, synced_upto - 32)
        if mode == "bitflip":
            pos = self.rng.randrange(lo, synced_upto)
            buf[pos] ^= 1 << self.rng.randrange(8)
        else:  # garbage
            pos = self.rng.randrange(lo, synced_upto)
            end = min(synced_upto, pos + 8)
            for i in range(pos, end):
                buf[i] = self.rng.randrange(256)
        return synced_upto

    def corrupt_run_block(self, run) -> int:
        """Flip bytes inside a sampled block of ``run``; return the block id.

        Prefers a value byte (payload corruption); for blocks holding only
        tombstones / empty values, flips a sequence-number bit instead —
        either way the per-block checksum stops matching.
        """
        if run.n_blocks == 0 or len(run) == 0:
            raise ValueError("cannot corrupt an empty run")
        bid = self.rng.randrange(run.n_blocks)
        idx = np.nonzero(run.block_of == bid)[0]
        if idx.size == 0:  # block spanned by a giant neighbouring entry
            bid = int(run.block_of[self.rng.randrange(len(run))])
            idx = np.nonzero(run.block_of == bid)[0]
        e = int(idx[self.rng.randrange(idx.size)])
        vlen = int(run.vlens[e])
        if vlen > 0 and run.vals.ndim == 2 and run.vals.shape[1] > 0:
            col = self.rng.randrange(vlen)
            run.vals[e, col] ^= np.uint8(1 << self.rng.randrange(8))
        else:
            run.seqs[e] ^= np.uint64(1 << self.rng.randrange(40))
        self.fired["corrupt_block"] = self.fired.get("corrupt_block", 0) + 1
        return bid
