"""Memtable + write-ahead log.

The memtable buffers updates in insertion order keyed by uint64 user key
(newest write to a key wins, as in a skiplist memtable).  The WAL is an
append-only in-memory byte log with an explicit fsync barrier counter so
durability/recovery logic is real and testable without a filesystem
(DESIGN.md §8.2).
"""
from __future__ import annotations

import struct
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from .run import SortedRun, build_run
from .types import KEY_BYTES, KEY_DTYPE, SEQ_DTYPE, TOMBSTONE_LEN, IOStats

_PUT, _DEL = 0, 1
_HDR = struct.Struct("<BQQI")  # op, key, seq, vlen


class WriteAheadLog:
    """Append-only log; ``records()`` replays committed entries on recovery."""

    def __init__(self):
        self._buf = bytearray()
        self._synced_upto = 0

    def append(self, op: int, key: int, seq: int, value: bytes, stats: IOStats):
        self._buf += _HDR.pack(op, key, seq, len(value))
        self._buf += value
        stats.wal_appends += 1

    def fsync(self, stats: IOStats):
        self._synced_upto = len(self._buf)
        stats.wal_fsyncs += 1

    def truncate(self):
        """Called after a successful flush: the flushed prefix is durable."""
        self._buf = bytearray()
        self._synced_upto = 0

    def crash(self):
        """Simulate a crash: unsynced suffix is lost."""
        self._buf = self._buf[: self._synced_upto]

    def records(self) -> Iterator[Tuple[int, int, int, bytes]]:
        off, buf = 0, bytes(self._buf)
        while off + _HDR.size <= len(buf):
            op, key, seq, vlen = _HDR.unpack_from(buf, off)
            off += _HDR.size
            if off + vlen > len(buf):
                break  # torn tail write
            yield op, key, seq, buf[off:off + vlen]
            off += vlen

    def __len__(self):
        return len(self._buf)


class Memtable:
    """Insertion buffer. Size accounting matches the run entry-size model."""

    def __init__(self, capacity_bytes: int, key_bytes: int = KEY_BYTES):
        self.capacity_bytes = capacity_bytes
        self.key_bytes = key_bytes
        self._data: Dict[int, Tuple[int, Optional[bytes]]] = {}
        self._bytes = 0

    def put(self, key: int, seq: int, value: Optional[bytes]):
        """value=None is a tombstone."""
        prev = self._data.get(key)
        if prev is not None:
            self._bytes -= self.key_bytes + (len(prev[1]) if prev[1] is not None else 0)
        self._data[key] = (seq, value)
        self._bytes += self.key_bytes + (len(value) if value is not None else 0)

    def get(self, key: int) -> Optional[Tuple[int, Optional[bytes]]]:
        return self._data.get(key)

    def scan(self, start_key: int) -> List[Tuple[int, int, Optional[bytes]]]:
        items = [(k, s, v) for k, (s, v) in self._data.items() if k >= start_key]
        items.sort()
        return items

    @property
    def size_bytes(self) -> int:
        return self._bytes

    def __len__(self):
        return len(self._data)

    def is_full(self) -> bool:
        return self._bytes >= self.capacity_bytes

    def to_run(self, bits_per_key: float, stats: IOStats) -> SortedRun:
        n = len(self._data)
        keys = np.fromiter(self._data.keys(), dtype=KEY_DTYPE, count=n)
        seqs = np.empty(n, dtype=SEQ_DTYPE)
        vmax = 0
        for i, (s, v) in enumerate(self._data.values()):
            seqs[i] = s
            if v is not None and len(v) > vmax:
                vmax = len(v)
        vlens = np.empty(n, dtype=np.int32)
        vals = np.zeros((n, vmax), dtype=np.uint8)
        for i, (s, v) in enumerate(self._data.values()):
            if v is None:
                vlens[i] = TOMBSTONE_LEN
            else:
                vlens[i] = len(v)
                vals[i, :len(v)] = np.frombuffer(v, dtype=np.uint8)
        run = build_run(keys, seqs, vlens, vals, bits_per_key=bits_per_key)
        stats.entries_flushed += len(run)
        stats.bytes_flushed += run.data_bytes
        stats.blocks_written += run.n_blocks
        return run

    def clear(self):
        self._data.clear()
        self._bytes = 0
