"""Memtable + write-ahead log.

The memtable buffers updates in insertion order keyed by uint64 user key
(newest write to a key wins, as in a skiplist memtable).  The WAL is an
append-only in-memory byte log with an explicit fsync barrier counter so
durability/recovery logic is real and testable without a filesystem
(DESIGN.md §8.2).
"""
from __future__ import annotations

import struct
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .faults import crc32c, crc32c_rows
from .run import SortedRun, build_run
from .types import (BLOCK_SIZE, KEY_BYTES, KEY_DTYPE, SEQ_DTYPE,
                    TOMBSTONE_LEN, IOStats)

_PUT, _DEL = 0, 1
# WAL record frame (DESIGN.md §16.2): crc32c(4) | body(21) | payload(vlen)
# where the checksum covers body+payload.  Recovery verifies every frame and
# replays up to the first bad one — length fields are never trusted alone.
_CRC = struct.Struct("<I")
_HDR = struct.Struct("<BQQI")  # frame body: op, key, seq, vlen
FRAME_OVERHEAD = _CRC.size + _HDR.size  # 25 bytes per record before payload
# numpy twin of _HDR for vectorized batch appends (packed little-endian)
_HDR_DTYPE = np.dtype([("op", "u1"), ("key", "<u8"),
                       ("seq", "<u8"), ("vlen", "<u4")])
assert _HDR_DTYPE.itemsize == _HDR.size

# Cap on the transient padded scratch matrix the vectorized CRC passes
# allocate: a batch (or WAL replay) mixing many small records with one
# outlier-length value must not allocate n*max bytes at once (100k records
# next to a single 4KB value would be ~400MB of padding — and the replay
# gather's int64 index intermediate is 8x that again).  Per-span scratch is
# ~10x this cap; spans stay large enough that the vectorized pass keeps its
# throughput.
_CRC_PAD_BUDGET = 1 << 20


def _pad_spans(vlens: np.ndarray, hsz: int):
    """Row spans ``(i, j)`` for a bounded-memory padded CRC pass.

    Each span keeps ``(j-i) * (hsz + vlens[i:j].max())`` under
    :data:`_CRC_PAD_BUDGET` (a record wider than the whole budget gets a
    span of its own — that width is the record itself, not padding).  The
    width is taken over a bounded lookahead window, so uniform stretches
    keep large vectorized spans and an outlier only shrinks the spans that
    actually contain it.
    """
    n = len(vlens)
    i = 0
    while i < n:
        look = min(n, i + 65536)
        width = hsz + int(vlens[i:look].max())
        j = min(look, i + max(1, _CRC_PAD_BUDGET // width))
        yield i, j
        i = j


class WriteAheadLog:
    """Append-only log; ``records()`` replays committed entries on recovery."""

    def __init__(self):
        self._buf = bytearray()
        self._synced_upto = 0

    def append(self, op: int, key: int, seq: int, value: bytes, stats: IOStats):
        body = _HDR.pack(op, key, seq, len(value))
        self._buf += _CRC.pack(crc32c(body + value))
        self._buf += body
        self._buf += value
        stats.wal_appends += 1

    def append_batch(self, items: Sequence[Tuple[int, Optional[bytes]]],
                     first_seq: int, stats: IOStats) -> None:
        """Append one batch of records in a single vectorized pass.

        ``items`` are (key, value-or-None-for-delete) pairs; record ``i``
        gets sequence ``first_seq + i``.  The byte layout is identical to
        ``len(items)`` scalar :meth:`append` calls (one header + payload per
        record), so :meth:`records` replays a batch — including a torn tail,
        where the fsync watermark cuts mid-record — exactly as it replays
        scalar appends.
        """
        n = len(items)
        if n == 0:
            return
        values = [v for _, v in items]
        self.append_batch_cols(
            values,
            np.fromiter((k for k, _ in items), np.uint64, n),
            np.fromiter((_DEL if v is None else _PUT for v in values),
                        np.uint8, n),
            np.fromiter((len(v) if v is not None else 0 for v in values),
                        np.int64, n),
            first_seq, stats)

    def append_batch_cols(self, values: Sequence[Optional[bytes]],
                          keys_arr: np.ndarray, ops_arr: np.ndarray,
                          vlens_arr: np.ndarray, first_seq: int,
                          stats: IOStats) -> None:
        """Column-form :meth:`append_batch` (the engine's fast path, which
        precomputes the header columns once per batch and passes per-chunk
        views).  Headers are packed with one structured-dtype write;
        uniform-length batches interleave header and payload with a single
        2-D column copy, ragged ones with two index scatters — never a
        per-record ``struct.pack``.
        """
        n = len(values)
        if n == 0:
            return
        hdr = np.empty(n, dtype=_HDR_DTYPE)
        hdr["op"] = ops_arr
        hdr["key"] = keys_arr
        hdr["seq"] = np.arange(first_seq, first_seq + n, dtype=np.uint64)
        hdr["vlen"] = vlens_arr
        fo, hsz = _CRC.size, _HDR.size
        fsz = fo + hsz
        hview = hdr.view(np.uint8).reshape(n, hsz)
        payload = b"".join(v for v in values if v is not None)
        v0 = int(vlens_arr[0])
        if int(vlens_arr.min()) == v0 == int(vlens_arr.max()):
            # uniform record size: interleave with one 2-D column copy, then
            # checksum every frame body in one vectorized pass
            out = np.empty((n, fsz + v0), dtype=np.uint8)
            out[:, fo:fsz] = hview
            if v0:
                out[:, fsz:] = np.frombuffer(payload, np.uint8).reshape(n, v0)
            crcs = crc32c_rows(out[:, fo:], np.full(n, hsz + v0, np.int64))
            out[:, :fo] = crcs.astype("<u4").view(np.uint8).reshape(n, fo)
        else:
            vl = np.asarray(vlens_arr, np.int64)
            cum = np.cumsum(vl, dtype=np.int64)
            pstarts = cum - vl
            flat = np.frombuffer(payload, dtype=np.uint8)
            # checksum pass over padded (body | payload) matrices, masked to
            # each record's true frame-body length; _pad_spans bounds the
            # padded scratch so one outlier-length record never inflates
            # the transient allocation to n*max bytes
            crcs = np.empty(n, np.uint32)
            for i, j in _pad_spans(vl, hsz):
                w = int(vl[i:j].max())
                body = np.zeros((j - i, hsz + w), dtype=np.uint8)
                body[:, :hsz] = hview[i:j]
                if w:
                    mask = np.arange(w)[None, :] < vl[i:j, None]
                    body[:, hsz:][mask] = flat[pstarts[i]:cum[j - 1]]
                crcs[i:j] = crc32c_rows(body, hsz + vl[i:j])
            crcb = crcs.astype("<u4").view(np.uint8).reshape(n, fo)
            starts = np.arange(n, dtype=np.int64) * fsz + pstarts
            out = np.empty(n * fsz + int(cum[-1]), dtype=np.uint8)
            out[(starts[:, None] + np.arange(fo)).ravel()] = crcb.ravel()
            out[(starts[:, None] + fo + np.arange(hsz)).ravel()] = hview.ravel()
            if payload:
                intra = np.arange(flat.size, dtype=np.int64) \
                    - np.repeat(pstarts, vl)
                out[np.repeat(starts + fsz, vl) + intra] = flat
        self._buf += out.tobytes()
        stats.wal_appends += n

    def fsync(self, stats: IOStats):
        self._synced_upto = len(self._buf)
        stats.wal_fsyncs += 1

    def truncate(self):
        """Called after a successful flush: the flushed prefix is durable."""
        self._buf = bytearray()
        self._synced_upto = 0

    def crash(self, faults=None):
        """Simulate a crash: unsynced suffix is lost.

        With an armed :class:`~repro.core.faults.FaultInjector` the loss is
        dirtier: ``torn`` keeps a random prefix of the unsynced tail (a torn
        write that partially reached the device), ``bitflip``/``garbage``
        damage bytes near the end of the *synced* region — recovery must
        checksum its way to the first bad frame instead of trusting the
        watermark.
        """
        keep = (self._synced_upto if faults is None
                else faults.mangle_wal_tail(self._buf, self._synced_upto))
        self._buf = self._buf[:keep]
        self._synced_upto = min(self._synced_upto, len(self._buf))

    def _scan_frames(self):
        """Parse + verify frames: (metas, frame_offsets, good_end_offset).

        ``metas[i]`` is (op, key, seq, vlen) for the i-th *checksum-valid*
        frame; ``good_end_offset`` is the byte offset just past the last
        valid frame (everything beyond is a torn tail or corruption).
        Verification is one vectorized :func:`crc32c_rows` pass over a
        padded frame-body matrix, not a per-record byte loop.
        """
        buf = bytes(self._buf)
        fo, hsz = _CRC.size, _HDR.size
        fsz = fo + hsz
        n = len(buf)
        metas, offs, stored = [], [], []
        off = 0
        while off + fsz <= n:
            (crc,) = _CRC.unpack_from(buf, off)
            op, key, seq, vlen = _HDR.unpack_from(buf, off + fo)
            end = off + fsz + vlen
            if end > n:
                break  # torn tail (or a corrupt length running past the end)
            metas.append((op, key, seq, vlen))
            offs.append(off)
            stored.append(crc)
            off = end
        if not metas:
            return [], [], 0
        vlens = np.fromiter((m[3] for m in metas), np.int64, len(metas))
        arr = np.frombuffer(buf, np.uint8)
        starts = np.fromiter(offs, np.int64, len(offs)) + fo
        lens = hsz + vlens
        stored_a = np.fromiter(stored, np.uint32, len(stored))
        ok = np.empty(len(metas), bool)
        # spans bound the padded gather matrix (see _pad_spans): replaying a
        # WAL mixing small records with one huge value must not allocate
        # n*max bytes of padding
        for i, j in _pad_spans(vlens, hsz):
            cols = np.arange(int(lens[i:j].max()), dtype=np.int64)
            mask = cols[None, :] < lens[i:j, None]
            mat = np.zeros((j - i, cols.size), np.uint8)
            mat[mask] = arr[(starts[i:j, None] + cols)[mask]]
            ok[i:j] = crc32c_rows(mat, lens[i:j]) == stored_a[i:j]
        good = len(metas) if bool(ok.all()) else int(np.argmin(ok))
        end = (offs[good - 1] + fsz + metas[good - 1][3]) if good else 0
        return metas[:good], offs[:good], end

    def repair(self) -> int:
        """Drop everything past the last checksum-valid frame (recovery
        path); returns the number of bytes discarded."""
        _, _, good_end = self._scan_frames()
        dropped = len(self._buf) - good_end
        if dropped:
            self._buf = self._buf[:good_end]
            self._synced_upto = min(self._synced_upto, good_end)
        return dropped

    def records(self) -> Iterator[Tuple[int, int, int, bytes]]:
        """Replay checksum-valid records; stops at the first bad frame, so a
        corrupt length field can never smuggle garbage past replay."""
        metas, offs, _ = self._scan_frames()
        buf, fsz = bytes(self._buf), _CRC.size + _HDR.size
        for (op, key, seq, vlen), off in zip(metas, offs):
            p = off + fsz
            yield op, key, seq, buf[p:p + vlen]

    def __len__(self):
        return len(self._buf)


class Memtable:
    """Insertion buffer. Size accounting matches the run entry-size model."""

    def __init__(self, capacity_bytes: int, key_bytes: int = KEY_BYTES,
                 block_size: int = BLOCK_SIZE):
        self.capacity_bytes = capacity_bytes
        self.key_bytes = key_bytes
        self.block_size = block_size
        self.frozen = False
        self._data: Dict[int, Tuple[int, Optional[bytes]]] = {}
        self._bytes = 0

    def freeze(self) -> "Memtable":
        """Mark immutable (async rotation): reads stay valid from any thread
        because the dict is never touched again; writes become errors."""
        self.frozen = True
        return self

    def put(self, key: int, seq: int, value: Optional[bytes]):
        """value=None is a tombstone."""
        if self.frozen:
            raise RuntimeError("write to a frozen (rotated) memtable")
        prev = self._data.get(key)
        if prev is not None:
            self._bytes -= self.key_bytes + (len(prev[1]) if prev[1] is not None else 0)
        self._data[key] = (seq, value)
        self._bytes += self.key_bytes + (len(value) if value is not None else 0)

    def put_batch(self, keys: Sequence[int],
                  values: Sequence[Optional[bytes]], first_seq: int,
                  added: Optional[int] = None) -> None:
        """Bulk insert: ``keys[i]`` gets sequence ``first_seq + i``.

        The last occurrence of a duplicate key wins with its own sequence
        number, exactly as a scalar put loop would leave it.  The dict is
        built and merged with C-level ``zip``/``update``; byte accounting
        refunds overwritten entries from one ``map(get)`` pass instead of a
        per-entry probe.  ``added`` optionally supplies the precomputed byte
        total of the batch (valid only without in-batch duplicates — the
        engine passes its chunk-sizing cumsum; ignored when duplicates
        collapse entries).
        """
        if self.frozen:
            raise RuntimeError("write to a frozen (rotated) memtable")
        data = self._data
        kb = self.key_bytes
        n = len(keys)
        incoming = dict(zip(keys, zip(range(first_seq, first_seq + n),
                                      values)))
        if added is None or len(incoming) != n:
            added = sum(kb + len(v) if v is not None else kb
                        for _, v in incoming.values())
        if data:
            removed = sum(
                kb + len(pv[1]) if pv[1] is not None else kb
                for pv in map(data.get, incoming) if pv is not None)
        else:
            removed = 0
        data.update(incoming)
        self._bytes += added - removed

    def get(self, key: int) -> Optional[Tuple[int, Optional[bytes]]]:
        return self._data.get(key)

    def snapshot_items(self, start_key: Optional[int] = None
                       ) -> List[Tuple[int, int, Optional[bytes]]]:
        """Lock-free point-in-time copy of (key, seq, value) triples.

        A reader thread iterating the *active* memtable can race the single
        writer ('dictionary changed size during iteration'); ``dict.copy``
        is one C-level call that holds the GIL throughout (int keys, no
        user ``__hash__``/``__eq__`` re-entry), so copying first gives a
        consistent snapshot with no lock on the hot write path and no
        retry.  ``start_key`` filters during the single extraction pass.
        """
        data = self._data.copy()
        if start_key is None:
            return [(k, s, v) for k, (s, v) in data.items()]
        return [(k, s, v) for k, (s, v) in data.items() if k >= start_key]

    def scan(self, start_key: int) -> List[Tuple[int, int, Optional[bytes]]]:
        items = self.snapshot_items(start_key)
        items.sort()
        return items

    @property
    def size_bytes(self) -> int:
        return self._bytes

    def __len__(self):
        return len(self._data)

    def is_full(self) -> bool:
        return self._bytes >= self.capacity_bytes

    def to_run(self, bits_per_key: float, stats: IOStats,
               hash_fn=None) -> SortedRun:
        """Freeze into a sorted run (one python pass + vectorized packing).

        Values are joined into one flat byte buffer and scattered into the
        padded value matrix with a single fancy-index write; the run
        inherits this memtable's ``block_size``/``key_bytes``.  ``hash_fn``
        reroutes the bloom build's hash pass (engine's Pallas route).
        """
        n = len(self._data)
        keys = np.fromiter(self._data.keys(), dtype=KEY_DTYPE, count=n)
        if n:
            seq_t, val_t = zip(*self._data.values())   # two C-level passes
            seqs = np.fromiter(seq_t, dtype=SEQ_DTYPE, count=n)
            vlens = np.fromiter(
                (TOMBSTONE_LEN if v is None else len(v) for v in val_t),
                dtype=np.int32, count=n)
        else:
            val_t = ()
            seqs = np.empty(0, dtype=SEQ_DTYPE)
            vlens = np.empty(0, dtype=np.int32)
        lens = np.maximum(vlens, 0).astype(np.int64)
        vmax = int(lens.max()) if n else 0
        if vmax and int(vlens.min()) == vmax:
            # uniform value size, no tombstones: the joined payload IS the
            # row-major matrix
            flat = np.frombuffer(b"".join(val_t), dtype=np.uint8)
            vals = flat.reshape(n, vmax).copy()
        elif vmax:
            vals = np.zeros((n, vmax), dtype=np.uint8)
            flat = np.frombuffer(
                b"".join(v for v in val_t if v is not None), dtype=np.uint8)
            if flat.size:
                # row-major boolean scatter: C-order assignment walks rows
                # left-to-right, exactly the joined payload's layout
                mask = np.arange(vmax)[None, :] < lens[:, None]
                vals[mask] = flat
        else:
            vals = np.zeros((n, 0), dtype=np.uint8)
        run = build_run(keys, seqs, vlens, vals, bits_per_key=bits_per_key,
                        block_size=self.block_size, key_bytes=self.key_bytes,
                        hash_fn=hash_fn)
        stats.entries_flushed += len(run)
        stats.bytes_flushed += run.data_bytes
        stats.blocks_written += run.n_blocks
        return run

    def clear(self):
        if self.frozen:
            raise RuntimeError("clear of a frozen (rotated) memtable")
        self._data.clear()
        self._bytes = 0


class ImmutableMemtable:
    """A frozen memtable queued for background flush, plus its WAL segment.

    Rotation (async mode, DESIGN.md §11) freezes the active memtable and
    hands it here together with the WAL that logged exactly its records; the
    pair stays readable on every read path (between the active memtable and
    L0, newest-first) until the background flush installs the run, and the
    WAL segment — fully fsynced at rotation — is the durable twin replayed
    by recovery if a crash beats the flush.
    """

    __slots__ = ("memtable", "wal")

    def __init__(self, memtable: Memtable, wal: WriteAheadLog):
        self.memtable = memtable.freeze()
        self.wal = wal
