"""The Autumn LSM storage engine.

Composes memtable + WAL, immutable sorted runs, a pluggable merge policy
(Garnering by default), MVCC manifest, Monkey/Autumn bloom allocation, and a
RocksDB-style L0 rate limiter.  All reads/writes are accounted in the block
I/O cost model (types.IOStats) so the paper's Table 2 complexities can be
validated empirically.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .bloom import allocate_fprs, bits_for_fpr
from .manifest import Manifest, RunStorage, Version
from .memtable import Memtable, WriteAheadLog
from .policy import CompactionTask, MergePolicy, make_policy
from .run import SortedRun, build_run, merge_runs
from .types import (BLOCK_SIZE, KEY_BYTES, KEY_DTYPE, SEQ_DTYPE,
                    TOMBSTONE_LEN, IOStats)


@dataclasses.dataclass
class LSMConfig:
    policy: str = "garnering"
    T: float = 2.0
    c: float = 0.8                      # Garnering scaling factor (c=1 => Leveling)
    memtable_bytes: int = 1 << 20       # 1 MiB write buffer
    base_level_bytes: int = 10 << 20    # max_bytes_for_level_base (OptimizeForSmallDb)
    l0_compaction_trigger: int = 4
    l0_stop_writes_trigger: int = 12    # rate limiter (level0_stop_writes_trigger)
    bits_per_key: float = 0.0           # 0 => no bloom filters
    bloom_allocation: str = "uniform"   # "uniform" | "monkey"
    wal_fsync_every_write: bool = False # False => fsync at flush (db default)
    block_size: int = BLOCK_SIZE
    key_bytes: int = KEY_BYTES


class LSMStore:
    def __init__(self, config: Optional[LSMConfig] = None):
        self.config = config or LSMConfig()
        self.policy: MergePolicy = make_policy(
            self.config.policy, T=self.config.T, c=self.config.c,
            l0_trigger=self.config.l0_compaction_trigger)
        self.stats = IOStats()
        self.storage = RunStorage()
        self.manifest = Manifest(self.storage)
        self.memtable = Memtable(self.config.memtable_bytes, self.config.key_bytes)
        self.wal = WriteAheadLog()
        self._levels: List[List[SortedRun]] = [[]]
        self._max_level = 1
        self._seq = 0

    # ------------------------------------------------------------- writes
    def put(self, key: int, value: bytes):
        self._write(key, value)

    def delete(self, key: int):
        self._write(key, None)

    def _write(self, key: int, value: Optional[bytes]):
        self._seq += 1
        self.wal.append(1 if value is None else 0, key, self._seq,
                        value or b"", self.stats)
        if self.config.wal_fsync_every_write:
            self.wal.fsync(self.stats)
        self.memtable.put(int(key), self._seq, value)
        if self.memtable.is_full():
            self.flush()

    def flush(self):
        """Freeze the memtable into an L0 run (no merge — §3.2 L0 tiering)."""
        if len(self.memtable) == 0:
            return
        # Rate limiter: too many L0 runs => write stall until compaction.
        if len(self._levels[0]) >= self.config.l0_stop_writes_trigger:
            self.stats.write_stalls += 1
            self._compact_until_quiet()
        self.wal.fsync(self.stats)
        run = self.memtable.to_run(self._bits_for_level(0), self.stats)
        self.memtable.clear()
        self.wal.truncate()
        if len(run):
            self._levels[0].append(run)  # newest last
            self._commit()
        self._compact_until_quiet()

    # -------------------------------------------------------- compactions
    def _compact_until_quiet(self):
        sizes = [[r.data_bytes for r in lvl] for lvl in self._levels]
        while True:
            new_L, task, delayed = self.policy.plan(
                sizes, self._max_level, self.config.base_level_bytes)
            if delayed:
                self.stats.delayed_last_level_compactions += delayed
            self._max_level = max(self._max_level, new_L)
            if task is None:
                return
            self._apply(task)
            sizes = [[r.data_bytes for r in lvl] for lvl in self._levels]

    def _apply(self, task: CompactionTask):
        while len(self._levels) <= task.dst_level:
            self._levels.append([])
        srcs = self._levels[task.src_level]
        dsts = self._levels[task.dst_level] if task.include_dst else []
        deepest = self._deepest_nonempty()
        drop_tombs = task.include_dst and task.dst_level >= deepest
        merged = merge_runs(srcs + dsts, self._bits_for_level(task.dst_level),
                            self.stats, drop_tombstones=drop_tombs)
        self._levels[task.src_level] = []
        if task.include_dst:
            self._levels[task.dst_level] = [merged] if len(merged) else []
        elif len(merged):
            self._levels[task.dst_level].append(merged)
        self._max_level = max(self._max_level, task.dst_level)
        self._commit()

    def _deepest_nonempty(self) -> int:
        deepest = 1
        for i in range(len(self._levels) - 1, 0, -1):
            if self._levels[i]:
                deepest = i
                break
        return deepest

    def _commit(self):
        self.manifest.commit(self._levels, self._max_level, self._seq, self.stats)
        self.manifest.fsync(self.stats)
        self.manifest.gc()

    # -------------------------------------------------------------- bloom
    def _bits_for_level(self, level: int) -> float:
        cfg = self.config
        if cfg.bits_per_key <= 0:
            return 0.0
        if cfg.bloom_allocation == "uniform":
            return cfg.bits_per_key
        # Monkey/Autumn allocation (Eq. 8-10): optimal FPR per level given the
        # total budget of bits_per_key * total_entries.
        counts = [sum(len(r) for r in lvl) for lvl in self._levels]
        while len(counts) <= level:
            counts.append(0)
        total = sum(counts)
        if total == 0:
            return cfg.bits_per_key
        # The level being (re)built will hold roughly the entries being merged
        # into it; use current counts as the Monkey size profile.
        fprs = allocate_fprs(counts, cfg.bits_per_key * total)
        return bits_for_fpr(float(fprs[level])) if counts[level] > 0 else cfg.bits_per_key

    # -------------------------------------------------------------- reads
    def _read_state(self, snapshot: Optional[Version] = None
                    ) -> List[List[SortedRun]]:
        if snapshot is None:
            return self._levels
        return snapshot.runs(self.storage)

    def _runs_newest_first(self, levels: List[List[SortedRun]]):
        for r in reversed(levels[0]):
            yield r
        for lvl in levels[1:]:
            for r in reversed(lvl):
                yield r

    def get(self, key: int, snapshot: Optional[Version] = None) -> Optional[bytes]:
        self.stats.point_reads += 1
        if snapshot is None:
            hit = self.memtable.get(int(key))
            if hit is not None:
                return hit[1]
        use_bloom = self.config.bits_per_key > 0
        for run in self._runs_newest_first(self._read_state(snapshot)):
            if len(run) == 0:
                continue
            self.stats.runs_touched_point += 1
            found, value, _ = run.point_get(int(key), self.stats, use_bloom)
            if found:
                return value
        return None

    def seek(self, key: int, snapshot: Optional[Version] = None) -> Optional[int]:
        """Position a merging iterator at the first key >= key (db_bench Seek).

        Cost: one seek + one block read per run with a valid position."""
        self.stats.range_reads += 1
        best: Optional[int] = None
        for run in self._runs_newest_first(self._read_state(snapshot)):
            if len(run) == 0:
                continue
            self.stats.runs_touched_range += 1
            self.stats.seeks += 1
            i = run.seek_idx(int(key))
            if i < len(run):
                self.stats.blocks_read += 1
                k = int(run.keys[i])
                if best is None or k < best:
                    best = k
        if snapshot is None:
            for k, s, v in self.memtable.scan(int(key))[:1]:
                if v is not None and (best is None or k < best):
                    best = k
        return best

    def scan(self, start_key: int, count: int,
             snapshot: Optional[Version] = None) -> List[Tuple[int, bytes]]:
        """Range read: first ``count`` live entries with key >= start_key.

        Implements a merging iterator over all runs + memtable; I/O accounting
        charges each run one seek block plus the blocks spanned by the entries
        the merged iterator actually consumed from that run.
        """
        self.stats.range_reads += 1
        levels = self._read_state(snapshot)
        runs = [r for r in self._runs_newest_first(levels) if len(r)]
        per_run_take = max(count, 1)
        while True:
            cand_k: List[np.ndarray] = []
            cand_s: List[np.ndarray] = []
            cand_v: List[List[Optional[bytes]]] = []
            # Results are only valid up to the smallest last-key among
            # truncated run slices (a run whose window ended may still hold
            # keys below another run's contributions).
            frontier: Optional[int] = None
            seek_positions = []
            for run in runs:
                i = run.seek_idx(int(start_key))
                seek_positions.append(i)
                k, s, l, v = run.slice_from(i, per_run_take)
                if i + per_run_take < len(run) and len(k):
                    fk = int(k[-1])
                    frontier = fk if frontier is None else min(frontier, fk)
                cand_k.append(k)
                cand_s.append(s)
                cand_v.append([None if l[j] == TOMBSTONE_LEN else bytes(v[j, :l[j]])
                               for j in range(len(k))])
            mem_items = (self.memtable.scan(int(start_key))
                         if snapshot is None else [])
            merged = self._merge_candidates(cand_k, cand_s, cand_v, mem_items)
            live = [(k, v) for k, v in merged if v is not None and
                    (frontier is None or k <= frontier)][:count]
            if len(live) >= count or frontier is None:
                # Account I/O for the final pass only (the retry loop models
                # an iterator that would have kept reading anyway).
                end_key = live[-1][0] if live else None
                for run, i in zip(runs, seek_positions):
                    self.stats.runs_touched_range += 1
                    self.stats.seeks += 1
                    if i >= len(run):
                        continue
                    if end_key is None:
                        consumed_end = i + 1
                    else:
                        consumed_end = int(np.searchsorted(
                            run.keys, np.uint64(end_key), side="right"))
                        consumed_end = max(consumed_end, i + 1)
                    self.stats.blocks_read += run.blocks_spanned(i, consumed_end)
                return live
            per_run_take *= 4

    @staticmethod
    def _merge_candidates(cand_k, cand_s, cand_v, mem_items):
        ks: List[int] = []
        ss: List[int] = []
        vs: List[Optional[bytes]] = []
        for k_arr, s_arr, v_list in zip(cand_k, cand_s, cand_v):
            ks.extend(int(x) for x in k_arr)
            ss.extend(int(x) for x in s_arr)
            vs.extend(v_list)
        for k, s, v in mem_items:
            ks.append(k)
            ss.append(s)
            vs.append(v)
        order = sorted(range(len(ks)), key=lambda i: (ks[i], -ss[i]))
        out: List[Tuple[int, Optional[bytes]]] = []
        last_key = None
        for i in order:
            if ks[i] != last_key:
                out.append((ks[i], vs[i]))
                last_key = ks[i]
        return out

    # ----------------------------------------------------------- snapshots
    def get_snapshot(self) -> Version:
        return self.manifest.current()

    # ------------------------------------------------------------ recovery
    def crash(self):
        """Simulate process crash: volatile state is lost."""
        self.wal.crash()
        self.manifest.crash()
        self.memtable.clear()

    def recover(self):
        """Rebuild volatile state from the durable manifest + WAL."""
        v = self.manifest.current()
        self._levels = v.runs(self.storage)
        self._max_level = v.max_level
        self._seq = v.last_seq
        self.memtable.clear()
        for op, key, seq, value in self.wal.records():
            self._seq = max(self._seq, seq)
            self.memtable.put(key, seq, None if op == 1 else value)

    # ---------------------------------------------------------------- info
    def level_summary(self) -> List[dict]:
        out = []
        for i, lvl in enumerate(self._levels):
            cap = (self.policy.capacity(i, self._max_level,
                                        self.config.base_level_bytes)
                   if i >= 1 else None)
            out.append(dict(level=i, runs=len(lvl),
                            entries=sum(len(r) for r in lvl),
                            bytes=sum(r.data_bytes for r in lvl),
                            capacity=cap))
        return out

    @property
    def num_levels_in_use(self) -> int:
        return self._max_level

    @property
    def total_entries(self) -> int:
        return sum(len(r) for lvl in self._levels for r in lvl) + len(self.memtable)

    def total_live_entries(self) -> int:
        """Logical entry count (newest versions only, tombstones excluded)."""
        seen: set = set()
        live = 0
        for k, (s, v) in self.memtable._data.items():
            seen.add(k)
            if v is not None:
                live += 1
        for run in self._runs_newest_first(self._levels):
            mask = ~np.isin(run.keys, np.fromiter(seen, dtype=KEY_DTYPE, count=len(seen))) \
                if seen else np.ones(len(run), bool)
            newk = run.keys[mask]
            live += int(np.count_nonzero(run.vlens[mask] != TOMBSTONE_LEN))
            seen.update(int(x) for x in newk)
        return live

    def space_amplification(self) -> float:
        phys = sum(r.data_bytes for lvl in self._levels for r in lvl)
        live = self.total_live_entries()
        if live == 0:
            return 1.0
        # logical bytes: approximate with average entry size of physical data
        total = sum(len(r) for lvl in self._levels for r in lvl)
        if total == 0:
            return 1.0
        return phys / (phys * live / total)
