"""The Autumn LSM storage engine.

Composes memtable + WAL, immutable sorted runs, a pluggable merge policy
(Garnering by default), MVCC manifest, Monkey/Autumn bloom allocation, and a
RocksDB-style L0 rate limiter.  All reads/writes are accounted in the block
I/O cost model (types.IOStats) so the paper's Table 2 complexities can be
validated empirically.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .bloom import allocate_fprs, bits_for_fpr
from .cache import BlockCache, PinnedLevelManager
from .iterator import MergingIterator
from .manifest import Manifest, RunStorage, Version
from .memtable import Memtable, WriteAheadLog
from .policy import CompactionTask, MergePolicy, make_policy
from .run import SortedRun, build_run, merge_runs
from .types import (BLOCK_SIZE, KEY_BYTES, KEY_DTYPE, SEQ_DTYPE,
                    TOMBSTONE_LEN, IOStats)

_UNSET = object()


@dataclasses.dataclass
class LSMConfig:
    policy: str = "garnering"
    T: float = 2.0
    c: float = 0.8                      # Garnering scaling factor (c=1 => Leveling)
    memtable_bytes: int = 1 << 20       # 1 MiB write buffer
    base_level_bytes: int = 10 << 20    # max_bytes_for_level_base (OptimizeForSmallDb)
    l0_compaction_trigger: int = 4
    l0_stop_writes_trigger: int = 12    # rate limiter (level0_stop_writes_trigger)
    bits_per_key: float = 0.0           # 0 => no bloom filters
    bloom_allocation: str = "uniform"   # "uniform" | "monkey"
    wal_fsync_every_write: bool = False # False => fsync at flush (db default)
    block_size: int = BLOCK_SIZE
    key_bytes: int = KEY_BYTES
    use_pallas_bloom: bool = False      # route multi_get probes AND filter
                                        # rebuilds through the Pallas hash
                                        # family (numpy when unavailable)
    use_pallas_merge: bool = False      # route compaction's pairwise merges
                                        # through the bitonic merge-path
                                        # kernel (numpy when unavailable)
    cache_bytes: int = 0                # block cache budget; 0 => no cache
    pin_l0_bytes: int = 0               # DRAM-resident L0 budget (paper's
                                        # "bounded space of DRAM"); 0 => none
    cache_policy: str = "clock"         # "clock" (second-chance) | "lru"


class LSMStore:
    def __init__(self, config: Optional[LSMConfig] = None):
        self.config = config or LSMConfig()
        self.policy: MergePolicy = make_policy(
            self.config.policy, T=self.config.T, c=self.config.c,
            l0_trigger=self.config.l0_compaction_trigger)
        self.stats = IOStats()
        self.storage = RunStorage()
        self.manifest = Manifest(self.storage)
        self.memtable = Memtable(self.config.memtable_bytes,
                                 self.config.key_bytes,
                                 self.config.block_size)
        self.wal = WriteAheadLog()
        self._levels: List[List[SortedRun]] = [[]]
        self._max_level = 1
        self._seq = 0
        self._pallas_probe_fn = _UNSET  # lazy: resolved on first multi_get
        self._pallas_hash_fn = _UNSET   # lazy: resolved on first filter build
        self._pallas_merge_fn = _UNSET  # lazy: resolved on first compaction
        self.block_cache: Optional[BlockCache] = None
        self.pinned_l0: Optional[PinnedLevelManager] = None
        if self.config.cache_bytes > 0 or self.config.pin_l0_bytes > 0:
            self.configure_cache(self.config.cache_bytes,
                                 self.config.pin_l0_bytes,
                                 self.config.cache_policy)

    def configure_cache(self, cache_bytes: int, pin_l0_bytes: int = 0,
                        policy: Optional[str] = None) -> None:
        """(Re)build the memory subsystem on a live store.

        Replaces any existing cache (contents are dropped) and immediately
        repins the current L0 within the new budget.  Passing zeros detaches
        the cache and reverts every read path to raw block accounting.
        ``policy=None`` keeps the store's configured ``cache_policy``.
        """
        self.config.cache_bytes = int(cache_bytes)
        self.config.pin_l0_bytes = int(pin_l0_bytes)
        if policy is not None:
            self.config.cache_policy = policy
        policy = self.config.cache_policy
        if cache_bytes <= 0 and pin_l0_bytes <= 0:
            self.block_cache = None
            self.pinned_l0 = None
            return
        self.block_cache = BlockCache(cache_bytes, policy)
        self.pinned_l0 = PinnedLevelManager(self.block_cache, pin_l0_bytes)
        # attaching mid-life: resident L0 blocks must be loaded (charged)
        self.pinned_l0.repin(self._levels[0], stats=self.stats)

    # ------------------------------------------------------------- writes
    def put(self, key: int, value: bytes):
        self._write(key, value)

    def delete(self, key: int):
        self._write(key, None)

    def _write(self, key: int, value: Optional[bytes]):
        self._seq += 1
        self.wal.append(1 if value is None else 0, key, self._seq,
                        value or b"", self.stats)
        if self.config.wal_fsync_every_write:
            self.wal.fsync(self.stats)
        self.memtable.put(int(key), self._seq, value)
        if self.memtable.is_full():
            self.flush()

    # ------------------------------------------------------- batched writes
    def put_batch(self, keys, values) -> None:
        """Batched puts: semantically ``[put(k, v) for k, v in zip(...)]``.

        ``values`` is either a sequence aligned with ``keys`` or a single
        ``bytes`` broadcast to every key.  See :meth:`write_batch`.
        """
        if isinstance(values, (bytes, bytearray)):
            values = [bytes(values)] * len(keys)
        self.write_batch(zip(keys, values))

    def delete_batch(self, keys) -> None:
        """Batched deletes: semantically ``[delete(k) for k in keys]``."""
        self.write_batch((k, None) for k in keys)

    def write_batch(self, ops: Iterable[Tuple[int, Optional[bytes]]]) -> None:
        """Batched puts + deletes (value=None), the vectorized ingest lane.

        Bit-for-bit equivalent to the scalar write loop — same WAL bytes,
        same sequence numbers, same memtable state, and same flush
        boundaries, hence identical IOStats — but the work is amortized:
        each chunk appends one vectorized WAL batch record, bulk-inserts
        into the memtable, and checks the flush trigger once.  Chunks are
        sized so no *intermediate* insert could have filled the memtable
        (entry sizes only shrink when an overwrite refunds bytes, so the
        running upper bound is safe); a chunk degenerates to one entry only
        when that single entry might fill it — exactly where the scalar
        loop would flush.  With ``wal_fsync_every_write`` the batch fsyncs
        once per chunk (group commit) instead of once per record; that is
        the only accounting difference from the scalar loop.
        """
        pairs = list(ops)
        n = len(pairs)
        if n == 0:
            return
        keys_l, vals_l = zip(*pairs)
        keys_l = list(map(int, keys_l))
        # one pass of column prep for the whole batch; chunks take views
        keys_arr = np.fromiter(keys_l, np.uint64, n)
        vlens = np.fromiter(
            (len(v) if v is not None else 0 for v in vals_l), np.int64, n)
        ops_arr = np.fromiter((v is None for v in vals_l), np.uint8, n)
        kb = self.memtable.key_bytes
        cum = np.cumsum(vlens + kb)
        i = 0
        while i < n:
            room = self.memtable.capacity_bytes - self.memtable.size_bytes
            base = int(cum[i - 1]) if i else 0
            # first index whose running total reaches the bound — O(log n)
            # on the uncut cumsum, no per-chunk array copy
            j = max(i + 1,
                    int(np.searchsorted(cum, base + room, side="left")))
            chunk_vals = vals_l[i:j]
            first_seq = self._seq + 1
            self._seq += j - i
            self.wal.append_batch_cols(
                chunk_vals, keys_arr[i:j], ops_arr[i:j], vlens[i:j],
                first_seq, self.stats)
            if self.config.wal_fsync_every_write:
                self.wal.fsync(self.stats)
            self.memtable.put_batch(keys_l[i:j], chunk_vals, first_seq,
                                    added=int(cum[j - 1] - base))
            if self.memtable.is_full():
                self.flush()
            i = j

    def flush(self):
        """Freeze the memtable into an L0 run (no merge — §3.2 L0 tiering)."""
        if len(self.memtable) == 0:
            return
        # Rate limiter: too many L0 runs => write stall until compaction.
        if len(self._levels[0]) >= self.config.l0_stop_writes_trigger:
            self.stats.write_stalls += 1
            self._compact_until_quiet()
        self.wal.fsync(self.stats)
        run = self.memtable.to_run(self._bits_for_level(0), self.stats,
                                   hash_fn=self._bloom_hash_fn())
        self.memtable.clear()
        self.wal.truncate()
        if len(run):
            self._levels[0].append(run)  # newest last
            self._commit()
        self._compact_until_quiet()

    # -------------------------------------------------------- compactions
    def _compact_until_quiet(self):
        sizes = [[r.data_bytes for r in lvl] for lvl in self._levels]
        while True:
            new_L, task, delayed = self.policy.plan(
                sizes, self._max_level, self.config.base_level_bytes)
            if delayed:
                self.stats.delayed_last_level_compactions += delayed
            self._max_level = max(self._max_level, new_L)
            if task is None:
                return
            self._apply(task)
            sizes = [[r.data_bytes for r in lvl] for lvl in self._levels]

    def _apply(self, task: CompactionTask):
        while len(self._levels) <= task.dst_level:
            self._levels.append([])
        srcs = self._levels[task.src_level]
        dsts = self._levels[task.dst_level] if task.include_dst else []
        deepest = self._deepest_nonempty()
        drop_tombs = task.include_dst and task.dst_level >= deepest
        merged = merge_runs(srcs + dsts, self._bits_for_level(task.dst_level),
                            self.stats, drop_tombstones=drop_tombs,
                            block_size=self.config.block_size,
                            key_bytes=self.config.key_bytes,
                            pair_merge=self._pair_merge_fn(),
                            bloom_hash=self._bloom_hash_fn())
        self._levels[task.src_level] = []
        if task.include_dst:
            self._levels[task.dst_level] = [merged] if len(merged) else []
        elif len(merged):
            self._levels[task.dst_level].append(merged)
        self._max_level = max(self._max_level, task.dst_level)
        self._commit()

    def _deepest_nonempty(self) -> int:
        deepest = 1
        for i in range(len(self._levels) - 1, 0, -1):
            if self._levels[i]:
                deepest = i
                break
        return deepest

    def _commit(self):
        self.manifest.commit(self._levels, self._max_level, self._seq, self.stats)
        self.manifest.fsync(self.stats)
        self.manifest.gc()
        if self.block_cache is not None:
            # Invalidation protocol (DESIGN.md §9): drop blocks of runs that
            # compaction retired (snapshot-pinned runs stay live in storage),
            # then re-derive the DRAM-resident L0 from the new version.
            self.block_cache.retain(self.storage.ids())
            self.pinned_l0.repin(self._levels[0])

    # -------------------------------------------------------------- bloom
    def _bits_for_level(self, level: int) -> float:
        cfg = self.config
        if cfg.bits_per_key <= 0:
            return 0.0
        if cfg.bloom_allocation == "uniform":
            return cfg.bits_per_key
        # Monkey/Autumn allocation (Eq. 8-10): optimal FPR per level given the
        # total budget of bits_per_key * total_entries.
        counts = [sum(len(r) for r in lvl) for lvl in self._levels]
        while len(counts) <= level:
            counts.append(0)
        total = sum(counts)
        if total == 0:
            return cfg.bits_per_key
        # The level being (re)built will hold roughly the entries being merged
        # into it; use current counts as the Monkey size profile.
        fprs = allocate_fprs(counts, cfg.bits_per_key * total)
        return bits_for_fpr(float(fprs[level])) if counts[level] > 0 else cfg.bits_per_key

    # -------------------------------------------------------------- reads
    def _read_state(self, snapshot: Optional[Version] = None
                    ) -> List[List[SortedRun]]:
        if snapshot is None:
            return self._levels
        return snapshot.runs(self.storage)

    def _runs_newest_first(self, levels: List[List[SortedRun]]):
        for r in reversed(levels[0]):
            yield r
        for lvl in levels[1:]:
            for r in reversed(lvl):
                yield r

    def get(self, key: int, snapshot: Optional[Version] = None) -> Optional[bytes]:
        self.stats.point_reads += 1
        if snapshot is None:
            hit = self.memtable.get(int(key))
            if hit is not None:
                return hit[1]
        use_bloom = self.config.bits_per_key > 0
        for run in self._runs_newest_first(self._read_state(snapshot)):
            if len(run) == 0:
                continue
            self.stats.runs_touched_point += 1
            found, value, _ = run.point_get(int(key), self.stats, use_bloom,
                                            cache=self.block_cache)
            if found:
                return value
        return None

    def _bloom_probe_fn(self):
        """Resolve the Pallas batched-probe route (numpy fallback).

        The config flag is re-read every call so toggling
        ``use_pallas_bloom`` on a live store takes effect; only the import
        result is cached.
        """
        if not self.config.use_pallas_bloom:
            return None
        if self._pallas_probe_fn is _UNSET:
            try:
                from repro.kernels.ops import bloom_probe_filter
                self._pallas_probe_fn = bloom_probe_filter
            except Exception:       # jax/pallas unavailable: stay on numpy
                self._pallas_probe_fn = None
        return self._pallas_probe_fn

    def _bloom_hash_fn(self):
        """Resolve the Pallas filter-*build* hash route (numpy fallback).

        Shares the ``use_pallas_bloom`` toggle with the probe route: when
        on, flush and compaction rebuild output filters from one device-side
        hash pass (``kernels.ops.bloom_build_hashes``) that is bit-identical
        to the numpy family, so either backend may probe the result.
        """
        if not self.config.use_pallas_bloom:
            return None
        if self._pallas_hash_fn is _UNSET:
            try:
                from repro.kernels.ops import bloom_build_hashes
                self._pallas_hash_fn = bloom_build_hashes
            except Exception:       # jax/pallas unavailable: stay on numpy
                self._pallas_hash_fn = None
        return self._pallas_hash_fn

    def _pair_merge_fn(self):
        """Resolve the Pallas merge-path compaction lane (numpy fallback).

        When ``use_pallas_merge`` is on, every pairwise merge of the
        compaction ladder routes through ``kernels.ops.merge_runs_tiled``
        (merge-path partition + bitonic network; interpret mode on CPU, the
        same BlockSpecs lower via Mosaic on TPU).  Differentially tested
        bit-for-bit against the numpy ladder and ``merge_runs_scalar``.
        """
        if not self.config.use_pallas_merge:
            return None
        if self._pallas_merge_fn is _UNSET:
            try:
                from repro.kernels.ops import merge_runs_tiled
                self._pallas_merge_fn = merge_runs_tiled
            except Exception:       # jax/pallas unavailable: stay on numpy
                self._pallas_merge_fn = None
        return self._pallas_merge_fn

    def multi_get(self, keys: Sequence[int],
                  snapshot: Optional[Version] = None) -> List[Optional[bytes]]:
        """Batched point reads: semantically ``[get(k) for k in keys]``.

        The batch is resolved level by level: every still-pending key is
        bloom-probed against a run in one vectorized pass (optionally through
        the Pallas kernel, DESIGN.md §3) and located with one searchsorted
        over the run's fence-pointed key array.  Aggregate IOStats accounting
        is identical to the equivalent sequence of scalar ``get`` calls.
        """
        keys_arr = np.asarray(list(keys), dtype=KEY_DTYPE)
        n = int(keys_arr.size)
        self.stats.point_reads += n
        results: List[Optional[bytes]] = [None] * n
        if n == 0:
            return results
        if snapshot is None and len(self.memtable):
            keep = []
            for j in range(n):
                hit = self.memtable.get(int(keys_arr[j]))
                if hit is not None:
                    results[j] = hit[1]    # value, or None for a tombstone
                else:
                    keep.append(j)
            pending = np.asarray(keep, dtype=np.int64)
        else:
            pending = np.arange(n, dtype=np.int64)
        use_bloom = self.config.bits_per_key > 0
        probe_fn = self._bloom_probe_fn()
        for run in self._runs_newest_first(self._read_state(snapshot)):
            if pending.size == 0:
                break
            if len(run) == 0:
                continue
            self.stats.runs_touched_point += int(pending.size)
            found, values = run.point_get_batch(
                keys_arr[pending], self.stats, use_bloom, probe_fn,
                cache=self.block_cache)
            if found.any():
                for p in np.nonzero(found)[0]:
                    results[int(pending[p])] = values[int(p)]
                pending = pending[~found]
        return results

    def seek(self, key: int, snapshot: Optional[Version] = None) -> Optional[int]:
        """Position a merging iterator at the first key >= key (db_bench Seek).

        Cost: one seek + one block read per run with a valid position."""
        self.stats.range_reads += 1
        best: Optional[int] = None
        for run in self._runs_newest_first(self._read_state(snapshot)):
            if len(run) == 0:
                continue
            self.stats.runs_touched_range += 1
            self.stats.seeks += 1
            i = run.seek_idx(int(key))
            if i < len(run):
                run._charge_block(run.block_of[i], self.stats,
                                  self.block_cache)
                k = int(run.keys[i])
                if best is None or k < best:
                    best = k
        if snapshot is None:
            for k, s, v in self.memtable.scan(int(key))[:1]:
                if v is not None and (best is None or k < best):
                    best = k
        return best

    def iterator(self, snapshot: Optional[Version] = None,
                 chunk: int = 512) -> MergingIterator:
        """A streaming merging iterator over the current (or snapshot) state.

        Holds one cursor per run + the memtable; see core.iterator for the
        merge and I/O-accounting semantics (DESIGN.md §3).  The iterator reads
        a frozen set of runs — writes/compactions after creation are not seen
        by run cursors (memtable updates may be, as in RocksDB iterators pin
        SSTs but here the memtable is shared; take a snapshot for isolation).
        """
        levels = self._read_state(snapshot)
        runs = [r for r in self._runs_newest_first(levels) if len(r)]
        mem = self.memtable if snapshot is None else None
        return MergingIterator(runs, memtable=mem, stats=self.stats,
                               chunk=chunk, cache=self.block_cache)

    def scan(self, start_key: int, count: int,
             snapshot: Optional[Version] = None) -> List[Tuple[int, bytes]]:
        """Range read: first ``count`` live entries with key >= start_key.

        One seek per run positions a cursor; the merged stream then refills
        incrementally per run (no restart loop), charging each run the blocks
        it actually contributed — see core.iterator.
        """
        self.stats.range_reads += 1
        it = self.iterator(snapshot)
        return it.scan(int(start_key), count)

    def scan_scalar(self, start_key: int, count: int,
                    snapshot: Optional[Version] = None
                    ) -> List[Tuple[int, bytes]]:
        """Reference range read (the pre-iterator seek-retry implementation).

        Kept as the differential-test oracle and the benchmarks' scalar
        baseline: slices ``count`` candidates from every run, sort-merges the
        python lists, and retries with a 4x larger window when a truncated
        run could still hide smaller keys.
        """
        self.stats.range_reads += 1
        levels = self._read_state(snapshot)
        runs = [r for r in self._runs_newest_first(levels) if len(r)]
        per_run_take = max(count, 1)
        while True:
            cand_k: List[np.ndarray] = []
            cand_s: List[np.ndarray] = []
            cand_v: List[List[Optional[bytes]]] = []
            # Results are only valid up to the smallest last-key among
            # truncated run slices (a run whose window ended may still hold
            # keys below another run's contributions).
            frontier: Optional[int] = None
            seek_positions = []
            for run in runs:
                i = run.seek_idx(int(start_key))
                seek_positions.append(i)
                k, s, l, v = run.slice_from(i, per_run_take)
                if i + per_run_take < len(run) and len(k):
                    fk = int(k[-1])
                    frontier = fk if frontier is None else min(frontier, fk)
                cand_k.append(k)
                cand_s.append(s)
                cand_v.append([None if l[j] == TOMBSTONE_LEN else bytes(v[j, :l[j]])
                               for j in range(len(k))])
            mem_items = (self.memtable.scan(int(start_key))
                         if snapshot is None else [])
            merged = self._merge_candidates(cand_k, cand_s, cand_v, mem_items)
            live = [(k, v) for k, v in merged if v is not None and
                    (frontier is None or k <= frontier)][:count]
            if len(live) >= count or frontier is None:
                # Account I/O for the final pass only (the retry loop models
                # an iterator that would have kept reading anyway).
                end_key = live[-1][0] if live else None
                for run, i in zip(runs, seek_positions):
                    self.stats.runs_touched_range += 1
                    self.stats.seeks += 1
                    if i >= len(run):
                        continue
                    if end_key is None:
                        consumed_end = i + 1
                    else:
                        consumed_end = int(np.searchsorted(
                            run.keys, np.uint64(end_key), side="right"))
                        consumed_end = max(consumed_end, i + 1)
                    self.stats.blocks_read += run.blocks_spanned(i, consumed_end)
                return live
            per_run_take *= 4

    @staticmethod
    def _merge_candidates(cand_k, cand_s, cand_v, mem_items):
        ks: List[int] = []
        ss: List[int] = []
        vs: List[Optional[bytes]] = []
        for k_arr, s_arr, v_list in zip(cand_k, cand_s, cand_v):
            ks.extend(int(x) for x in k_arr)
            ss.extend(int(x) for x in s_arr)
            vs.extend(v_list)
        for k, s, v in mem_items:
            ks.append(k)
            ss.append(s)
            vs.append(v)
        order = sorted(range(len(ks)), key=lambda i: (ks[i], -ss[i]))
        out: List[Tuple[int, Optional[bytes]]] = []
        last_key = None
        for i in order:
            if ks[i] != last_key:
                out.append((ks[i], vs[i]))
                last_key = ks[i]
        return out

    # ----------------------------------------------------------- snapshots
    def get_snapshot(self) -> Version:
        """Acquire a reader reference on the current version.

        Thin wrapper over the manifest's *refcounted* pins: snapshot reads
        stay valid across any number of later flushes/compactions until the
        matching ``release_snapshot``; if several readers snapshot the same
        version, it stays pinned until the last one releases.
        """
        return self.manifest.pin(self.manifest.current())

    def release_snapshot(self, snapshot: Version) -> None:
        """Drop one reader reference (see ``get_snapshot``)."""
        if not self.manifest.unpin(snapshot.version_id):
            return  # other readers still hold the version: nothing can free
        self.manifest.gc()
        if self.block_cache is not None:
            # Runs kept alive only by the released snapshot may be gone now.
            self.block_cache.retain(self.storage.ids())

    # ------------------------------------------------------------ recovery
    def crash(self):
        """Simulate process crash: volatile state is lost."""
        self.wal.crash()
        self.manifest.crash()
        self.memtable.clear()

    def recover(self):
        """Rebuild volatile state from the durable manifest + WAL."""
        v = self.manifest.current()
        self._levels = v.runs(self.storage)
        self._max_level = v.max_level
        self._seq = v.last_seq
        if self.block_cache is not None:
            # DRAM contents did not survive the crash; reload the pin set
            # from the recovered L0 (charged — these are real device reads)
            # while the unpinned cache refills on demand.
            self.block_cache.clear()
            self.pinned_l0.repin(self._levels[0], stats=self.stats)
        self.memtable.clear()
        for op, key, seq, value in self.wal.records():
            self._seq = max(self._seq, seq)
            self.memtable.put(key, seq, None if op == 1 else value)

    # ---------------------------------------------------------------- info
    def cache_summary(self) -> dict:
        """Memory-subsystem health: hit rate, charged bytes, residency."""
        if self.block_cache is None:
            return dict(enabled=False, hit_rate=0.0, hits=0, misses=0,
                        evictions=0, charged_bytes=0, pinned_bytes=0,
                        pinned_l0_runs=0)
        c = self.block_cache
        return dict(enabled=True, hit_rate=c.hit_rate(), hits=c.hits,
                    misses=c.misses, evictions=c.evictions,
                    charged_bytes=c.charged_bytes,
                    pinned_bytes=c.pinned_bytes,
                    pinned_l0_runs=len(self.pinned_l0.pinned_run_ids))

    def level_summary(self) -> List[dict]:
        out = []
        for i, lvl in enumerate(self._levels):
            cap = (self.policy.capacity(i, self._max_level,
                                        self.config.base_level_bytes)
                   if i >= 1 else None)
            out.append(dict(level=i, runs=len(lvl),
                            entries=sum(len(r) for r in lvl),
                            bytes=sum(r.data_bytes for r in lvl),
                            capacity=cap))
        return out

    @property
    def num_levels_in_use(self) -> int:
        return self._max_level

    @property
    def total_entries(self) -> int:
        return sum(len(r) for lvl in self._levels for r in lvl) + len(self.memtable)

    def _live_profile(self) -> Tuple[int, int]:
        """(live entry count, live logical bytes) of the newest versions.

        One vectorized pass: concatenate every source's keys newest-first
        (memtable, then runs in read order), stable-argsort, and keep the
        first occurrence of each key — the newest version, since stable
        sorting preserves concatenation order within equal keys.  Replaces
        the per-run ``np.isin`` against an ever-growing seen-set (quadratic
        in the number of runs x entries).
        """
        parts_k: List[np.ndarray] = []
        parts_vl: List[np.ndarray] = []
        mem = self.memtable._data
        if mem:
            parts_k.append(np.fromiter(mem.keys(), KEY_DTYPE, len(mem)))
            parts_vl.append(np.fromiter(
                (TOMBSTONE_LEN if v is None else len(v)
                 for _, v in mem.values()), np.int64, len(mem)))
        for run in self._runs_newest_first(self._levels):
            if len(run):
                parts_k.append(run.keys)
                parts_vl.append(run.vlens.astype(np.int64))
        if not parts_k:
            return 0, 0
        K = np.concatenate(parts_k)
        VL = np.concatenate(parts_vl)
        order = np.argsort(K, kind="stable")
        Ks = K[order]
        first = np.empty(Ks.size, dtype=bool)
        first[0] = True
        np.not_equal(Ks[1:], Ks[:-1], out=first[1:])
        win_vl = VL[order[first]]
        live = win_vl != TOMBSTONE_LEN
        n_live = int(np.count_nonzero(live))
        logical = int(np.sum(win_vl[live])) + n_live * self.config.key_bytes
        return n_live, logical

    def total_live_entries(self) -> int:
        """Logical entry count (newest versions only, tombstones excluded)."""
        return self._live_profile()[0]

    def space_amplification(self) -> float:
        """Physical bytes stored / logical bytes of the live newest versions
        (RocksDB's definition; 1.0 when nothing is live)."""
        phys = sum(r.data_bytes for lvl in self._levels for r in lvl) \
            + self.memtable.size_bytes
        logical = self._live_profile()[1]
        if logical == 0:
            return 1.0
        return phys / logical
