"""The Autumn LSM storage engine.

Composes memtable + WAL, immutable sorted runs, a pluggable merge policy
(Garnering by default), MVCC manifest, Monkey/Autumn bloom allocation, and a
RocksDB-style L0 rate limiter.  All reads/writes are accounted in the block
I/O cost model (types.IOStats) so the paper's Table 2 complexities can be
validated empirically.

With ``LSMConfig.async_compaction`` the flush/compaction pipeline moves off
the write path onto a background ``CompactionScheduler`` (DESIGN.md §11):
full memtables rotate into a readable immutable queue, workers install
versions in the exact synchronous order (sync mode stays the bit-for-bit
differential oracle after ``wait_for_quiesce``), and write pressure is
governed by ``slowdown_trigger``/``stall_trigger``.  The engine is
single-writer multi-reader: one thread writes; readers are lock-free on
copy-on-write level/queue references and immutable runs.  IOStats counters
are accumulated **losslessly** through a :class:`~repro.core.types.StatsHub`:
every thread mutates its own private shard (no lock, no lost ``+=``
read-modify-writes between scheduler workers and foreground threads) and
``store.stats`` merges the shards fieldwise at read time.

Optional telemetry (DESIGN.md §14): ``LSMConfig.telemetry`` carries a
:class:`~repro.core.telemetry.Telemetry` facade.  When ``None`` (default)
every instrumentation site is a single attribute load + ``is None`` test;
when set, public ops record per-op-class latency into per-thread histograms
(no locks on the read path) and lifecycle paths (flush/compaction/stall/
view-rebuild) emit trace events.
"""
from __future__ import annotations

import dataclasses
import math
import threading
import time
from typing import Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .bloom import allocate_fprs, bits_for_fpr
from .cache import BlockCache, PinnedLevelManager
from .faults import CorruptionError, FaultInjector, StoreDegradedError
from .iterator import MergingIterator, combined_mem_items
from .manifest import Manifest, RunStorage, Version
from .memtable import ImmutableMemtable, Memtable, WriteAheadLog
from .policy import CompactionTask, MergePolicy, make_policy
from .run import SortedRun, build_run, merge_runs
from .scheduler import CompactJob, CompactionScheduler, FlushJob
from .telemetry import Telemetry
from .tuner import OnlineTuner, TunerStep
from .types import (BLOCK_SIZE, KEY_BYTES, KEY_DTYPE, SEQ_DTYPE,
                    TOMBSTONE_LEN, IOStats, StatsHub)
from .view import RangeView, build_range_view

_UNSET = object()
# Soft write-pressure delay.  LevelDB sleeps 1 ms here, but its pressure unit
# is a 4 MB L0 file; ours is a ~32 KB memtable whose whole fill takes well
# under 1 ms — and on coarse-tick kernels (CONFIG_HZ=100) any nonzero sleep
# rounds up to a full 1-10 ms scheduler tick.  sleep(0) instead *yields* the
# GIL and the CPU slice to the compaction workers, which is the actual goal
# of the soft trigger; the hard stall_trigger remains the memory backstop.
_SLOWDOWN_SLEEP_S = 0.0


@dataclasses.dataclass
class LSMConfig:
    policy: str = "garnering"
    T: float = 2.0
    c: float = 0.8                      # Garnering scaling factor (c=1 => Leveling)
    memtable_bytes: int = 1 << 20       # 1 MiB write buffer
    base_level_bytes: int = 10 << 20    # max_bytes_for_level_base (OptimizeForSmallDb)
    l0_compaction_trigger: int = 4
    l0_stop_writes_trigger: int = 12    # rate limiter (level0_stop_writes_trigger)
    bits_per_key: float = 0.0           # 0 => no bloom filters
    bloom_allocation: str = "uniform"   # "uniform" | "monkey"
    wal_fsync_every_write: bool = False # False => fsync at flush (db default)
    block_size: int = BLOCK_SIZE
    key_bytes: int = KEY_BYTES
    use_pallas_bloom: bool = False      # route multi_get probes AND filter
                                        # rebuilds through the Pallas hash
                                        # family (numpy when unavailable)
    use_pallas_merge: bool = False      # route compaction's pairwise merges
                                        # through the bitonic merge-path
                                        # kernel (numpy when unavailable)
    cache_bytes: int = 0                # block cache budget; 0 => no cache
    pin_l0_bytes: int = 0               # DRAM-resident L0 budget (paper's
                                        # "bounded space of DRAM"); 0 => none
    cache_policy: str = "clock"         # "clock" (second-chance) | "lru"
    async_compaction: bool = False      # pipeline flush+compaction onto
                                        # background workers (DESIGN.md §11);
                                        # False == today's synchronous engine,
                                        # the differential oracle
    compaction_workers: int = 1         # background worker threads
    slowdown_trigger: int = 64          # queued L0 runs + immutable memtables
                                        # beyond which each rotation yields
                                        # its CPU slice to the workers (soft
                                        # pressure); <=0 disables.  Triggers
                                        # count ~memtable_bytes units, so 64
                                        # = ~2 MiB of deferred flushes at the
                                        # default write buffer
    stall_trigger: int = 256            # ... beyond which rotation blocks
                                        # until the backlog drains below the
                                        # trigger or the workers go idle
                                        # (hard pressure, ~8 MiB memory
                                        # backstop); <=0 disables
    shards: int = 1                     # >1: `make_store` builds a
                                        # ShardedLSMStore — N independent
                                        # range-partitioned LSMStores behind
                                        # one facade with parallel per-shard
                                        # schedulers and a shared budgeted
                                        # BlockCache (DESIGN.md §12).  Plain
                                        # LSMStore ignores this field.
    use_range_views: bool = False       # REMIX-style cross-run range views
                                        # (DESIGN.md §13): a globally-sorted
                                        # key index over every run, rebuilt
                                        # off the write path (scheduler
                                        # workers in async mode, lazily by
                                        # the first reader in sync mode), so
                                        # scan/seek cost one binary search +
                                        # one sequential sweep instead of a
                                        # per-refill multi-way merge.  The
                                        # MergingIterator remains both the
                                        # stale-view fallback and (with
                                        # scan_scalar) the differential
                                        # oracle.
    shard_splitters: Optional[Tuple[int, ...]] = None
                                        # order-preserving range splitters
                                        # (shards-1 ascending uint64 bounds;
                                        # key k lives in the first shard
                                        # with k < splitter).  None =>
                                        # uniform split of the full uint64
                                        # space (right for hashed keys —
                                        # kvcache/checkpoint; pass explicit
                                        # splitters for dense key ranges)
    telemetry: Optional[Telemetry] = None
                                        # latency histograms + event trace
                                        # (DESIGN.md §14).  None (default)
                                        # disables all instrumentation — the
                                        # only residual cost is an `is None`
                                        # test per public op.  The sharded
                                        # facade hands its live config to
                                        # every shard, so one Telemetry
                                        # aggregates across shards for free.
    rebalance_interval_ops: int = 0     # sharded facade only (DESIGN.md §15):
                                        # re-check per-shard load imbalance
                                        # every N routed ops (and at
                                        # scheduler-idle boundaries).  0
                                        # (default) disables rebalancing —
                                        # static splitters, bit-for-bit the
                                        # PR-5 behavior.  Plain LSMStore
                                        # ignores this field.
    rebalance_ratio: float = 2.0        # imbalance trigger: rebalance when
                                        # max/mean per-shard op share over
                                        # the current window exceeds this
                                        # (1.0 = perfectly balanced, N =
                                        # fully skewed into one shard)
    paranoid_checks: bool = False       # verify per-block checksums on every
                                        # point-read/seek block touch
                                        # (DESIGN.md §16.2); a mismatch
                                        # raises CorruptionError.  Recovery
                                        # scrubs regardless of this flag.
    faults: Optional["FaultInjector"] = None
                                        # fault-injection hooks (§16.1).
                                        # None (default) disables every
                                        # site at the cost of one `is None`
                                        # test — the same zero-overhead
                                        # contract as `telemetry`.
    bg_max_retries: int = 2             # background flush/compaction retry
                                        # budget (bounded exponential
                                        # backoff, §16.3); past it the job
                                        # is abandoned and the store
                                        # degrades read-only
    tuner: Optional[OnlineTuner] = None
                                        # online workload-adaptive tuner
                                        # (DESIGN.md §17): senses windowed
                                        # IOStats/Telemetry deltas and
                                        # hill-climbs c/T, the cache↔pin
                                        # split, slowdown_trigger, and the
                                        # facade's worker budget — applied
                                        # only at compaction-chain/quiesce
                                        # boundaries via apply_tuning().
                                        # None (default): zero overhead
                                        # beyond one `is None` test per
                                        # write, same contract as telemetry.
                                        # Needs `telemetry` to sense; inert
                                        # without it.


class LSMStore:
    def __init__(self, config: Optional[LSMConfig] = None, *,
                 scheduler_budget=None, scheduler_offset: int = 0):
        # scheduler_budget / scheduler_offset: sharded-facade wiring (a
        # shared worker-budget semaphore and a core-spreading offset handed
        # to this store's CompactionScheduler, DESIGN.md §12).  Plain
        # single-store use leaves both at their defaults.
        self.config = config or LSMConfig()
        self.policy: MergePolicy = make_policy(
            self.config.policy, T=self.config.T, c=self.config.c,
            l0_trigger=self.config.l0_compaction_trigger)
        self._stats = StatsHub()
        self.storage = RunStorage()
        self.manifest = Manifest(self.storage)
        self.memtable = Memtable(self.config.memtable_bytes,
                                 self.config.key_bytes,
                                 self.config.block_size)
        self.wal = WriteAheadLog()
        self._levels: List[List[SortedRun]] = [[]]
        self._max_level = 1
        self._seq = 0
        # Graceful degradation (DESIGN.md §16.3): set to the root failure
        # when the background pipeline exhausts its retry budget.  Writes
        # then raise StoreDegradedError; reads keep serving the committed
        # tree (no lock — a single attribute test on the write path).
        self._degraded: Optional[BaseException] = None
        # Set once the root pipeline failure has been surfaced to a caller
        # through wait_for_quiesce (close() raises via the same call);
        # close() afterwards is an idempotent, loss-free no-raise cleanup
        # instead of a second raise.  Write-path StoreDegradedError is a
        # *rejection*, not the surfacing — it can fire many times without
        # consuming the one loud raise of the underlying failure.
        self._bg_failure_surfaced = False
        self._pallas_probe_fn = _UNSET  # lazy: resolved on first multi_get
        self._pallas_hash_fn = _UNSET   # lazy: resolved on first filter build
        self._pallas_merge_fn = _UNSET  # lazy: resolved on first compaction
        # Async compaction (DESIGN.md §11): rotated memtables queue here
        # (oldest first) and stay readable until their background flush
        # installs; the maintenance lock serializes the gc+retain+repin
        # triplet between worker installs and snapshot releases.
        self._imm: List[ImmutableMemtable] = []
        self._maint_lock = threading.Lock()
        # Online tuning (DESIGN.md §17).  The tuner is cached on the store
        # so the per-write check is one attribute + `is None` test (the
        # telemetry zero-overhead contract); bind() makes this store the
        # single driver — sharded facades hand shards tuner=None configs
        # and bind the facade instead.
        self._tuner = self.config.tuner
        self._tune_ops = 0
        self._tune_armed = False
        if self._tuner is not None:
            self._tuner.bind(self)
        # REMIX-style cross-run range view (DESIGN.md §13).  The view is a
        # snapshot of one published ``self._levels`` object; freshness is a
        # pointer compare (copy-on-write installs swap the list object), so
        # invalidation is free.  ``_view_cache`` memoizes per-level sorted
        # columns keyed by run-id tuple so rebuilds only re-merge levels
        # whose membership actually changed.
        self._range_view: Optional[RangeView] = None
        self._view_cache: dict = {}
        self._scheduler: Optional[CompactionScheduler] = None
        if self.config.async_compaction:
            self._scheduler = CompactionScheduler(
                self, self.config.compaction_workers,
                budget=scheduler_budget, worker_offset=scheduler_offset)
        self.block_cache: Optional[BlockCache] = None
        self.pinned_l0: Optional[PinnedLevelManager] = None
        if self.config.cache_bytes > 0 or self.config.pin_l0_bytes > 0:
            self.configure_cache(self.config.cache_bytes,
                                 self.config.pin_l0_bytes,
                                 self.config.cache_policy)

    @property
    def stats(self) -> IOStats:
        """Merged view of every thread's counter shard (a fresh IOStats —
        ``.snapshot()``/``.delta()``/field reads all behave as before; the
        lossless-accumulation design is :class:`~repro.core.types.StatsHub`).
        Internal mutation sites never touch this property — they charge the
        calling thread's shard via ``self._stats.local()``."""
        return self._stats.merged()

    @property
    def telemetry(self) -> Optional[Telemetry]:
        return self.config.telemetry

    # ------------------------------------------------------ degraded mode
    @property
    def degraded(self) -> bool:
        """True when persistent background failure flipped the store
        read-only (§16.3); cleared by ``crash()`` + ``recover()``."""
        return self._degraded is not None

    def _enter_degraded(self, exc: BaseException) -> None:
        """Flip read-only (idempotent; called by the scheduler worker when
        a background job exhausts its retry budget)."""
        if self._degraded is None:
            self._degraded = exc
            tel = self.config.telemetry
            if tel is not None:
                tel.emit("degraded", error=repr(exc))

    def _raise_degraded(self) -> None:
        raise StoreDegradedError(
            "store is read-only after persistent background failure; "
            "reads keep serving — crash()+recover() to restore writes"
        ) from self._degraded

    def _wal_fsync(self, st: IOStats) -> None:
        """fsync the active WAL, charging ``st`` and (when telemetry is on)
        recording the fsync latency — the single helper every durability
        point uses so the ``wal_fsync`` histogram sees all of them."""
        f = self.config.faults
        if f is not None:
            f.check("wal_fsync")
        tel = self.config.telemetry
        if tel is None:
            self.wal.fsync(st)
            return
        t0 = time.perf_counter_ns()
        self.wal.fsync(st)
        tel.record("wal_fsync", time.perf_counter_ns() - t0)

    def configure_cache(self, cache_bytes: int, pin_l0_bytes: int = 0,
                        policy: Optional[str] = None) -> None:
        """(Re)build the memory subsystem on a live store.

        Replaces any existing cache (contents are dropped) and immediately
        repins the current L0 within the new budget.  Passing zeros detaches
        the cache and reverts every read path to raw block accounting.
        ``policy=None`` keeps the store's configured ``cache_policy``.
        """
        self.config.cache_bytes = int(cache_bytes)
        self.config.pin_l0_bytes = int(pin_l0_bytes)
        if policy is not None:
            self.config.cache_policy = policy
        policy = self.config.cache_policy
        if cache_bytes <= 0 and pin_l0_bytes <= 0:
            self.block_cache = None
            self.pinned_l0 = None
            return
        self.block_cache = BlockCache(cache_bytes, policy)
        self.block_cache.telemetry = self.config.telemetry
        self.pinned_l0 = PinnedLevelManager(self.block_cache, pin_l0_bytes)
        # attaching mid-life: resident L0 blocks must be loaded (charged)
        with self._maint_lock:
            self.pinned_l0.repin(self._levels[0], stats=self._stats.local())

    def attach_cache(self, cache, pin_l0_bytes: int = 0) -> None:
        """Attach an externally owned cache object (the sharded facade's
        namespaced ``BlockCacheView`` of the shared ``BlockCache``,
        DESIGN.md §12) instead of building a private one.

        The object must speak the BlockCache read/retain/pin protocol;
        every read path and the commit-time invalidation triplet use it
        exactly as they use a private cache.  Pins the current L0 within
        ``pin_l0_bytes`` immediately (charged: a mid-life attach's resident
        blocks are real reads, same as :meth:`configure_cache`).
        """
        self.block_cache = cache
        self.pinned_l0 = PinnedLevelManager(cache, pin_l0_bytes)
        with self._maint_lock:
            self.pinned_l0.repin(self._levels[0], stats=self._stats.local())

    # ------------------------------------------------------------- writes
    def put(self, key: int, value: bytes):
        tel = self.config.telemetry
        if tel is None:
            self._write(key, value)
        else:
            t0 = time.perf_counter_ns()
            self._write(key, value)
            tel.record("put", time.perf_counter_ns() - t0)
        if self._tuner is not None:
            self._maybe_tune(1)

    def delete(self, key: int):
        tel = self.config.telemetry
        if tel is None:
            self._write(key, None)
        else:
            t0 = time.perf_counter_ns()
            self._write(key, None)
            tel.record("put", time.perf_counter_ns() - t0)
        if self._tuner is not None:
            self._maybe_tune(1)

    def _write(self, key: int, value: Optional[bytes]):
        if self._degraded is not None:
            self._raise_degraded()
        f = self.config.faults
        if f is not None:
            f.check("wal_append")  # before any mutation: a failed append
                                   # leaves no partial record anywhere
        st = self._stats.local()
        self._seq += 1
        self.wal.append(1 if value is None else 0, key, self._seq,
                        value or b"", st)
        if self.config.wal_fsync_every_write:
            self._wal_fsync(st)
        self.memtable.put(int(key), self._seq, value)
        if self.memtable.is_full():
            self._on_memtable_full()

    # ------------------------------------------------------- batched writes
    def put_batch(self, keys, values) -> None:
        """Batched puts: semantically ``[put(k, v) for k, v in zip(...)]``.

        ``values`` is either a sequence aligned with ``keys`` or a single
        ``bytes`` broadcast to every key.  See :meth:`write_batch`.
        """
        if isinstance(values, (bytes, bytearray)):
            values = [bytes(values)] * len(keys)
        tel = self.config.telemetry
        if tel is None:
            self._write_batch(zip(keys, values))
        else:
            t0 = time.perf_counter_ns()
            self._write_batch(zip(keys, values))
            tel.record("put_batch", time.perf_counter_ns() - t0)
        if self._tuner is not None:
            self._maybe_tune(len(keys))

    def delete_batch(self, keys) -> None:
        """Batched deletes: semantically ``[delete(k) for k in keys]``."""
        self.write_batch((k, None) for k in keys)

    def write_batch(self, ops: Iterable[Tuple[int, Optional[bytes]]]) -> None:
        tel = self.config.telemetry
        if tel is None:
            self._write_batch(ops)
        else:
            t0 = time.perf_counter_ns()
            self._write_batch(ops)
            tel.record("write_batch", time.perf_counter_ns() - t0)
        if self._tuner is not None:
            self._maybe_tune(1)

    def _write_batch(self, ops: Iterable[Tuple[int, Optional[bytes]]]) -> None:
        """Batched puts + deletes (value=None), the vectorized ingest lane.

        Bit-for-bit equivalent to the scalar write loop — same WAL bytes,
        same sequence numbers, same memtable state, and same flush
        boundaries, hence identical IOStats — but the work is amortized:
        each chunk appends one vectorized WAL batch record, bulk-inserts
        into the memtable, and checks the flush trigger once.  Chunks are
        sized so no *intermediate* insert could have filled the memtable
        (entry sizes only shrink when an overwrite refunds bytes, so the
        running upper bound is safe); a chunk degenerates to one entry only
        when that single entry might fill it — exactly where the scalar
        loop would flush.  With ``wal_fsync_every_write`` the batch fsyncs
        once per chunk (group commit) instead of once per record; that is
        the only accounting difference from the scalar loop.
        """
        pairs = list(ops)
        n = len(pairs)
        if n == 0:
            return
        if self._degraded is not None:
            self._raise_degraded()
        faults = self.config.faults
        st = self._stats.local()
        keys_l, vals_l = zip(*pairs)
        keys_l = list(map(int, keys_l))
        # one pass of column prep for the whole batch; chunks take views
        keys_arr = np.fromiter(keys_l, np.uint64, n)
        vlens = np.fromiter(
            (len(v) if v is not None else 0 for v in vals_l), np.int64, n)
        ops_arr = np.fromiter((v is None for v in vals_l), np.uint8, n)
        kb = self.memtable.key_bytes
        cum = np.cumsum(vlens + kb)
        i = 0
        while i < n:
            room = self.memtable.capacity_bytes - self.memtable.size_bytes
            base = int(cum[i - 1]) if i else 0
            # first index whose running total reaches the bound — O(log n)
            # on the uncut cumsum, no per-chunk array copy
            j = max(i + 1,
                    int(np.searchsorted(cum, base + room, side="left")))
            chunk_vals = vals_l[i:j]
            if faults is not None:
                faults.check("wal_append")  # per chunk, before mutation
            first_seq = self._seq + 1
            self._seq += j - i
            self.wal.append_batch_cols(
                chunk_vals, keys_arr[i:j], ops_arr[i:j], vlens[i:j],
                first_seq, st)
            if self.config.wal_fsync_every_write:
                self._wal_fsync(st)
            self.memtable.put_batch(keys_l[i:j], chunk_vals, first_seq,
                                    added=int(cum[j - 1] - base))
            if self.memtable.is_full():
                self._on_memtable_full()
            i = j

    def fsync_wal(self) -> None:
        """Explicit durability barrier on the active WAL (group commit for
        callers that batch writes and fsync once, e.g. the checkpoint
        store's save path)."""
        self._wal_fsync(self._stats.local())

    def _on_memtable_full(self):
        """Full write buffer: flush inline (sync) or rotate + enqueue (async).

        Rotation happens at exactly the point the synchronous engine would
        flush, so the memtable contents handed to the background worker are
        identical to what the sync path freezes — the root of the
        differential-oracle guarantee (DESIGN.md §11).
        """
        if self._scheduler is None:
            self.flush()
        else:
            self._rotate()

    def flush(self):
        """Freeze the memtable into an L0 run (no merge — §3.2 L0 tiering).

        Async mode (``LSMConfig.async_compaction``): the call only rotates
        the memtable into the immutable queue and returns — the run build,
        version install, and any triggered compactions all happen on the
        scheduler's workers.  ``wait_for_quiesce`` blocks until that
        background pipeline drains.
        """
        if self._scheduler is not None:
            self._rotate()
            return
        if len(self.memtable) == 0:
            return
        st = self._stats.local()
        # Rate limiter: too many L0 runs => write stall until compaction.
        if len(self._levels[0]) >= self.config.l0_stop_writes_trigger:
            st.write_stalls += 1
            self._compact_until_quiet()
        tel = self.config.telemetry
        t0 = tok = 0
        if tel is not None:
            t0 = time.perf_counter_ns()
            tok = tel.emit("flush_start", entries=len(self.memtable))
        self._wal_fsync(st)
        f = self.config.faults
        if f is not None:
            f.check("flush_write")
        run = self.memtable.to_run(self._bits_for_level(0), st,
                                   hash_fn=self._bloom_hash_fn())
        if len(run):
            levels = [list(lvl) for lvl in self._levels]
            levels[0].append(run)  # newest last
            self._levels = levels  # atomic swap: readers never see a torn L0
            self._commit()
        # The WAL/memtable are released only *after* the manifest fsync in
        # _commit(): if that fsync fails, the flushed records are still in
        # the (fsynced) WAL and crash()+recover() replays them — releasing
        # first would turn a manifest fault into silent data loss.
        self.memtable.clear()
        self.wal.truncate()
        if tel is not None:
            dur = time.perf_counter_ns() - t0
            tel.record("flush", dur)
            tel.emit("flush_end", token=tok, entries=len(run),
                     t0=t0, dur_ns=dur)
        self._compact_until_quiet()

    # ------------------------------------------------- async rotation path
    def _rotate(self):
        """Foreground half of a pipelined flush (async mode).

        Applies write-pressure control, fsyncs the WAL (the rotated
        segment's durability point — same one-fsync-per-flush cadence as the
        sync path), freezes the memtable + WAL pair into the immutable
        queue where it stays readable, and enqueues the background
        :class:`FlushJob`.  The engine is single-writer: only the foreground
        thread rotates, only scheduler workers install.
        """
        if len(self.memtable) == 0:
            return
        self._throttle()
        self._wal_fsync(self._stats.local())
        imm = ImmutableMemtable(self.memtable, self.wal)
        with self._scheduler.lock:
            self._imm = self._imm + [imm]   # copy-on-write: readers hold refs
        self.memtable = Memtable(self.config.memtable_bytes,
                                 self.config.key_bytes,
                                 self.config.block_size)
        self.wal = WriteAheadLog()
        try:
            self._scheduler.submit(FlushJob(imm))
        except RuntimeError as exc:
            # Raced the worker poisoning the pipeline: this rotation's write
            # passed the _degraded check an instant before the failure was
            # published.  The write is ACCEPTED, not rejected — its record
            # is already in the rotated segment (appended + fsynced above)
            # and stays readable from the immutable queue; the flush will
            # never run, but close() folds the queue back into the sync
            # path and crash()+recover() replays the fsynced WAL, so
            # nothing acknowledged is lost.  Raising here would reject a
            # write that is already durable state.  The *next* write gets
            # the clean StoreDegradedError from the _degraded fast check:
            # the worker sets that flag before publishing the failure
            # submit() just saw, so it is guaranteed visible by now.  A
            # cause-less RuntimeError is "scheduler is shut down" — a
            # lifecycle error, not degradation — and propagates unchanged.
            if exc.__cause__ is None:
                raise
            self._enter_degraded(exc.__cause__)

    def _throttle(self):
        """LevelDB-style write-pressure control at rotation points.

        Pressure = queued L0 runs + immutable memtables.  At
        ``slowdown_trigger`` each rotation yields its CPU slice to the
        workers (see ``_SLOWDOWN_SLEEP_S``); at ``stall_trigger`` the
        rotation blocks until the scheduler drains below the trigger (or
        goes idle — steady-state L0 pressure cannot drain further).  Both
        charge ``IOStats.stall_ns`` so benchmarks can report the foreground
        time actually lost to pressure (``stall_pct``).
        """
        cfg = self.config
        st = self._stats.local()
        tel = cfg.telemetry
        depth = len(self._imm) + len(self._levels[0])
        t0 = time.perf_counter_ns()
        if cfg.stall_trigger > 0 and depth >= cfg.stall_trigger:
            st.write_stalls += 1
            tok = tel.emit("stall_enter", depth=depth) if tel is not None \
                else 0
            # A stall only waits while the background can still shrink the
            # backlog; once the scheduler is idle the pressure is the tree's
            # steady state (e.g. L0 legitimately holds l0_trigger-1 runs)
            # and waiting longer would deadlock the writer.
            sched = self._scheduler
            sched.wait_until(
                lambda: sched.idle()
                or (len(self._imm) + len(self._levels[0]))
                < cfg.stall_trigger)
            dt = time.perf_counter_ns() - t0
            if tel is not None:
                tel.record("stall", dt)
                tel.emit("stall_exit", token=tok, depth=depth,
                         t0=t0, dur_ns=dt)
        elif cfg.slowdown_trigger > 0 and depth >= cfg.slowdown_trigger:
            st.write_slowdowns += 1
            time.sleep(_SLOWDOWN_SLEEP_S)
            dt = time.perf_counter_ns() - t0
            if tel is not None:
                tel.record("stall", dt)
                tel.emit("slowdown", depth=depth, t0=t0, dur_ns=dt)
        else:
            return
        st.stall_ns += time.perf_counter_ns() - t0

    def wait_for_quiesce(self, timeout: Optional[float] = None) -> bool:
        """Block until all background flush/compaction work has drained.

        After a True return the tree (levels, keys, seqs, values) is
        state-identical to the synchronous engine's for the same op
        sequence — the async-vs-sync differential contract.  The active
        (unrotated) memtable is *not* flushed; call ``flush()`` first to
        rotate it.  Sync mode returns True immediately.
        """
        if self._scheduler is None:
            return True
        try:
            ok = self._scheduler.wait_for_quiesce(timeout)
        except RuntimeError:
            # the pipeline failure has now been surfaced to the caller;
            # close() afterwards is an idempotent no-raise cleanup
            self._bg_failure_surfaced = True
            raise
        if ok and self._tuner is not None and self._tune_armed:
            # a drained pipeline is a tuning boundary too (§17)
            self.apply_tuning()
        return ok

    # --------------------------------------------- online tuning (§17)
    def _maybe_tune(self, k: int = 1) -> None:
        """Cheap write-boundary tuning trigger (the facade's
        ``_maybe_rebalance`` shape): count ops; once ``interval_ops``
        elapse, arm, and fire at the first compaction-chain boundary —
        immediately in sync mode (every inter-op point is one), at the
        next scheduler-idle check in async mode."""
        tun = self._tuner
        self._tune_ops += k
        if not self._tune_armed:
            if self._tune_ops < tun.interval_ops:
                return
            self._tune_armed = True
        sched = self._scheduler
        if sched is not None and not sched.idle():
            return
        self._tune_ops = 0
        self._tune_armed = False
        tun.tick(self)

    def apply_tuning(self) -> Optional[TunerStep]:
        """Run one tuner tick now iff the store is at a boundary.

        The single actuation entry point (DESIGN.md §17): changes land only
        here — with the scheduler idle (sync mode always is, between ops) —
        so COW readers and the bit-for-bit oracles are never perturbed
        mid-op.  Returns the decision, or None when not at a boundary, the
        tuner is absent/unbound, or the window was too small to decide.
        """
        tun = self._tuner
        if tun is None:
            return None
        if self._scheduler is not None and not self._scheduler.idle():
            return None
        self._tune_ops = 0
        self._tune_armed = False
        return tun.tick(self)

    def retune_policy(self, *, T: Optional[float] = None,
                      c: Optional[float] = None) -> None:
        """Swap in a same-family policy with new knobs (tuner actuator).

        Only *future* ``plan()`` calls see the new capacities — the
        installed tree is never rewritten; overflow against the new
        schedule resolves through normal compaction churn.  The swap is a
        single reference assignment; call at a boundary (``apply_tuning``
        does) so no planned-but-unapplied task straddles the change."""
        cfg = self.config
        if T is not None:
            cfg.T = float(T)
        if c is not None:
            cfg.c = float(c)
        self.policy = self.policy.retuned(T=cfg.T, c=cfg.c)

    def set_cache_split(self, pin_l0_bytes: int) -> None:
        """Move budget between the block cache and the pinned-L0 slice at
        constant total memory (tuner actuator).  Gentle, unlike
        ``configure_cache``: the cache resizes in place (a shrink sheds
        only its coldest bytes) and the L0 repins under the new budget."""
        if self.block_cache is None or self.pinned_l0 is None:
            return
        cfg = self.config
        total = cfg.cache_bytes + cfg.pin_l0_bytes
        pin = max(0, min(int(pin_l0_bytes), total))
        cfg.pin_l0_bytes = pin
        cfg.cache_bytes = total - pin
        self.block_cache.resize(cfg.cache_bytes)
        self.pinned_l0.pin_l0_bytes = pin
        with self._maint_lock:
            self.pinned_l0.repin(self._levels[0], stats=self._stats.local())

    def compact_to_shape(self, max_merges: int = 64) -> int:
        """Maintenance compaction: fold the tree to the policy's shape.

        ``retune_policy`` deliberately never rewrites the installed tree —
        but when a retune *widens* the capacity schedule (larger ``T``,
        smaller ``c``) every level of the old, deeper shape sits under its
        new cap, so no organic compaction ever fires and reads keep paying
        the old shape's per-level cost indefinitely.  This is the explicit
        maintenance window (RocksDB's manual ``CompactRange`` shape): merge
        the shallowest populated deep level into the next one until the
        populated-level count matches ``policy.predicted_levels`` for the
        current data size, then let the normal planner settle any overflow
        the folding introduced.  L0 is left to its own trigger (it is the
        flush buffer, usually DRAM-pinned).  Runs through the same
        ``_apply`` as every other compaction, so COW publication, cache
        retention, and view invalidation all hold.  Call at a quiesce
        boundary (async callers drain first; returns 0 when not idle).
        Returns the number of maintenance merges performed.
        """
        if self._scheduler is not None and not self._scheduler.idle():
            return 0
        self._compact_until_quiet()     # settle organic triggers first
        pred = getattr(self.policy, "predicted_levels", None)
        merges = 0
        while merges < max_merges:
            deep = [i for i, lvl in enumerate(self._levels) if lvl and i >= 1]
            if len(deep) < 2 or pred is None:
                break
            total = sum(r.data_bytes
                        for lvl in self._levels for r in lvl)
            target = max(1, int(math.ceil(
                pred(total, self.config.base_level_bytes))))
            if len(deep) <= target:
                break
            src, dst = deep[0], deep[1]
            task = CompactionTask(
                src, dst, True, "reshape",
                src_run_ids=tuple(r.run_id for r in self._levels[src]))
            if not self._apply(task):
                break       # tree changed under us: stop, planner recovers
            merges += 1
        if merges:
            # the folds changed level sizes; re-settle, then drop the
            # monotone level-count watermark to the real new depth so
            # future capacity schedules price the reshaped tree
            self._compact_until_quiet()
            self._max_level = max(
                (i for i, lvl in enumerate(self._levels) if lvl), default=1)
            tel = self.config.telemetry
            if tel is not None:
                tel.emit("reshape", merges=merges,
                         levels=len([l for l in self._levels if l]))
        return merges

    def _tuning_actuators(self):
        """Knob accessors the tuner hill-climbs: {name: (get, set)}.

        Only knobs that exist on this store are offered — no ``pin_frac``
        without a memory subsystem, no ``slowdown_trigger`` without the
        async pressure path (sync mode never throttles).  The facade
        overrides this with its shard-wide twin."""
        acts = {
            "c": (lambda: self.policy.c,
                  lambda v: self.retune_policy(c=v)),
            "T": (lambda: self.policy.T,
                  lambda v: self.retune_policy(T=v)),
        }
        if self._scheduler is not None:
            acts["slowdown_trigger"] = (
                lambda: self.config.slowdown_trigger,
                lambda v: setattr(self.config, "slowdown_trigger", int(v)))
        if self.block_cache is not None and self.pinned_l0 is not None:
            acts["pin_frac"] = (self._get_pin_frac, self._set_pin_frac)
        return acts

    def _get_pin_frac(self) -> float:
        total = self.config.cache_bytes + self.config.pin_l0_bytes
        return self.config.pin_l0_bytes / total if total else 0.0

    def _set_pin_frac(self, v: float) -> None:
        total = self.config.cache_bytes + self.config.pin_l0_bytes
        self.set_cache_split(int(total * float(v)))

    def close(self) -> None:
        """Drain and stop the background workers (async mode).

        The store stays fully usable afterwards — it simply reverts to the
        synchronous flush/compaction path, which is state-equivalent.  Used
        by tests and benchmarks so short-lived stores don't accumulate
        parked worker threads.  No-op in sync mode.

        On a failed/degraded pipeline, close() raises the background
        failure the *first* time it is surfaced — but always completes the
        full cleanup (worker shutdown + stranded-rotation fold-back) before
        raising, and every subsequent close() is an idempotent no-raise
        no-op (§16.3): the failure must be loud exactly once, never lost,
        and never doubled.
        """
        sched = self._scheduler
        if sched is None:
            return
        surfaced = self._bg_failure_surfaced
        try:
            sched.wait_for_quiesce()   # raises on a dead pipeline
        except BaseException:
            self._bg_failure_surfaced = True
            if not surfaced:
                raise                  # finally still completes the cleanup
        finally:
            # shutdown() joins the workers, so by the time the fold-back
            # below runs no job can race the immutable queue — the failed
            # job's error can never resurface from _consolidate_imm_wal
            # with the scheduler already aborted.
            sched.shutdown()
            self._scheduler = None
            if self._imm:
                # A dead pipeline left rotated memtables stranded (the
                # exception fired before their flush installed).  The sync
                # path never reads the immutable queue, so fold them back
                # into the active WAL + memtable — durability and readable
                # state unchanged.
                self._consolidate_imm_wal()
            # With the workers gone and every rotation folded back the
            # store is loss-free on the synchronous path — degraded mode
            # (a property of the dead background pipeline) ends here.
            self._degraded = None

    def _consolidate_imm_wal(self) -> int:
        """Fold the immutable queue's WAL segments into one active log.

        Segment concatenation (oldest first, active last) is record
        concatenation, so replay order equals write order; the rotated
        segments were fully fsynced at rotation, so the consolidated synced
        watermark is their total length plus the active WAL's own
        watermark.  The memtable is rebuilt by replaying every record
        (including the unsynced tail — that is live process state, exactly
        what the active memtable held).  Shared by ``recover`` and the
        failed-pipeline ``close`` fold-back so the durability bookkeeping
        cannot drift between them.  Returns the number of records replayed.
        """
        wal = WriteAheadLog()
        buf = bytearray()
        synced = 0
        for imm in self._imm:
            buf += imm.wal._buf
            synced += len(imm.wal._buf)       # fully fsynced at rotation
        synced += self.wal._synced_upto
        buf += self.wal._buf
        wal._buf = buf
        wal._synced_upto = synced
        self.wal = wal
        self._imm = []
        self.memtable = Memtable(self.config.memtable_bytes,
                                 self.config.key_bytes,
                                 self.config.block_size)
        n = 0
        for op, key, seq, value in self.wal.records():
            n += 1
            self._seq = max(self._seq, seq)
            self.memtable.put(key, seq, None if op == 1 else value)
        return n

    # --------------------------------------------------- background applies
    def _bg_flush(self, imm: ImmutableMemtable) -> Optional[CompactJob]:
        """Worker-thread half of a pipelined flush.

        Replicates the synchronous ``flush`` body step for step (rate
        limiter before the run build, install, then compaction planning) so
        the level trajectory is bit-for-bit the sync engine's.  Returns the
        compaction continuation job; the scheduler front-queues it ahead of
        any later flushes.
        """
        sched = self._scheduler
        st = self._stats.local()
        if len(self._levels[0]) >= self.config.l0_stop_writes_trigger:
            st.write_stalls += 1
            self._compact_until_quiet()
        if sched.aborting:
            return None     # crash in progress: imm stays queued for replay
        f = self.config.faults
        if f is not None:
            f.check("flush_write")
        tel = self.config.telemetry
        t0 = tok = 0
        if tel is not None:
            t0 = time.perf_counter_ns()
            tok = tel.emit("flush_start", entries=len(imm.memtable), bg=1)
        run = imm.memtable.to_run(self._bits_for_level(0), st,
                                  hash_fn=self._bloom_hash_fn())
        if len(run):
            levels = [list(lvl) for lvl in self._levels]
            levels[0].append(run)  # newest last
            self._levels = levels
            self._commit()
        # Only now drop the readable immutable memtable: between install and
        # pop a reader may see the entries twice (same seq, same value) but
        # never zero times.  The WAL segment retires with it — the data is
        # durable in the manifest as of _commit's fsync.
        with sched.lock:
            self._imm = [m for m in self._imm if m is not imm]
            sched.lock.notify_all()     # wake write-pressure waiters
        st.bg_flushes += 1
        if tel is not None:
            dur = time.perf_counter_ns() - t0
            tel.record("flush", dur)
            tel.emit("flush_end", token=tok, entries=len(run), bg=1,
                     t0=t0, dur_ns=dur)
        return CompactJob()

    def _bg_compact_one(self) -> Optional[CompactionTask]:
        """Plan + apply one compaction task (worker thread).

        The input version is pinned for the duration of the merge — exactly
        the retention ``_commit``'s cache-invalidation protocol assumes —
        so concurrent snapshot releases can never GC the input runs
        mid-merge; the pin is released (and GC + cache retention re-run)
        whether the apply succeeds, goes stale, or aborts.
        """
        if self._scheduler.aborting:
            return None
        pinned = self.manifest.pin_current()
        try:
            task = self._plan_one()
            if task is None or not self._apply(task):
                return None
            self._stats.local().bg_compactions += 1
            return task
        finally:
            if self.manifest.unpin(pinned.version_id):
                with self._maint_lock:
                    self.manifest.gc()
                    if self.block_cache is not None:
                        self.block_cache.retain(self.storage.ids())

    # -------------------------------------------------------- compactions
    def _plan_one(self) -> Optional[CompactionTask]:
        """Generate the next compaction task against the current tree.

        Task generation is decoupled from apply (DESIGN.md §11): the
        returned task captures its source level's run ids so a (stale)
        apply against a changed tree is refused rather than silently
        merging the wrong runs.  The synchronous loop and the scheduler's
        CompactJob both plan immediately before applying, so staleness is a
        discipline check, not an expected path.
        """
        sizes = [[r.data_bytes for r in lvl] for lvl in self._levels]
        new_L, task, delayed = self.policy.plan(
            sizes, self._max_level, self.config.base_level_bytes)
        if delayed:
            self._stats.local().delayed_last_level_compactions += delayed
        self._max_level = max(self._max_level, new_L)
        if task is None:
            return None
        srcs = (self._levels[task.src_level]
                if task.src_level < len(self._levels) else [])
        return dataclasses.replace(
            task, src_run_ids=tuple(r.run_id for r in srcs))

    def _compact_until_quiet(self):
        while True:
            if self._scheduler is not None and self._scheduler.aborting:
                return      # crash in progress: bail at the task boundary
            task = self._plan_one()
            if task is None:
                return
            self._apply(task)

    def _apply(self, task: CompactionTask) -> bool:
        """Merge the task's inputs and install the result as a new version.

        The merged level lists are built copy-on-write and published with
        one reference assignment, so concurrent readers either see the old
        version or the new one — never a torn intermediate (async mode's
        lock-free read contract).  Returns False without mutating anything
        if the task's captured inputs no longer match the tree.
        """
        levels = [list(lvl) for lvl in self._levels]
        while len(levels) <= task.dst_level:
            levels.append([])
        srcs = levels[task.src_level]
        if not task.matches(srcs):
            return False
        dsts = levels[task.dst_level] if task.include_dst else []
        st = self._stats.local()
        tel = self.config.telemetry
        t0 = tok = 0
        if tel is not None:
            t0 = time.perf_counter_ns()
            tok = tel.emit("compaction_start", src=task.src_level,
                           dst=task.dst_level, runs=len(srcs) + len(dsts))
        deepest = self._deepest_nonempty()
        drop_tombs = task.include_dst and task.dst_level >= deepest
        f = self.config.faults
        if f is not None:
            f.check("compaction_merge")
        merged = merge_runs(srcs + dsts, self._bits_for_level(task.dst_level),
                            st, drop_tombstones=drop_tombs,
                            block_size=self.config.block_size,
                            key_bytes=self.config.key_bytes,
                            pair_merge=self._pair_merge_fn(),
                            bloom_hash=self._bloom_hash_fn())
        levels[task.src_level] = []
        if task.include_dst:
            levels[task.dst_level] = [merged] if len(merged) else []
        elif len(merged):
            levels[task.dst_level].append(merged)
        self._levels = levels
        self._max_level = max(self._max_level, task.dst_level)
        self._commit()
        if tel is not None:
            dur = time.perf_counter_ns() - t0
            tel.record("compaction", dur)
            tel.emit("compaction_end", token=tok, src=task.src_level,
                     dst=task.dst_level, entries=len(merged),
                     t0=t0, dur_ns=dur)
        return True

    def _deepest_nonempty(self) -> int:
        deepest = 1
        for i in range(len(self._levels) - 1, 0, -1):
            if self._levels[i]:
                deepest = i
                break
        return deepest

    def _commit(self):
        st = self._stats.local()
        self.manifest.commit(self._levels, self._max_level, self._seq, st)
        f = self.config.faults
        if f is not None:
            # after the in-memory commit, before durability: the edit is
            # appended but not synced — exactly the window a real fsync
            # failure leaves behind
            f.check("manifest_fsync")
        self.manifest.fsync(st)
        with self._maint_lock:
            # The gc + retain + repin triplet must not interleave with a
            # concurrent snapshot release (or another install): a retain
            # computed from a stale id set could drop blocks the newer
            # version just pinned.
            self.manifest.gc()
            if self.block_cache is not None:
                # Invalidation protocol (DESIGN.md §9): drop blocks of runs
                # that compaction retired (snapshot-pinned runs stay live in
                # storage), then re-derive the DRAM-resident L0 from the new
                # version.
                self.block_cache.retain(self.storage.ids())
                self.pinned_l0.repin(self._levels[0])

    # -------------------------------------------------------------- bloom
    def _bits_for_level(self, level: int) -> float:
        cfg = self.config
        if cfg.bits_per_key <= 0:
            return 0.0
        if cfg.bloom_allocation == "uniform":
            return cfg.bits_per_key
        # Monkey/Autumn allocation (Eq. 8-10): optimal FPR per level given the
        # total budget of bits_per_key * total_entries.
        counts = [sum(len(r) for r in lvl) for lvl in self._levels]
        while len(counts) <= level:
            counts.append(0)
        total = sum(counts)
        if total == 0:
            return cfg.bits_per_key
        # The level being (re)built will hold roughly the entries being merged
        # into it; use current counts as the Monkey size profile.
        fprs = allocate_fprs(counts, cfg.bits_per_key * total)
        return bits_for_fpr(float(fprs[level])) if counts[level] > 0 else cfg.bits_per_key

    # -------------------------------------------------------------- reads
    def _read_state(self, snapshot: Optional[Version] = None
                    ) -> List[List[SortedRun]]:
        if snapshot is None:
            return self._levels
        return snapshot.runs(self.storage)

    def _mem_sources(self) -> List[Memtable]:
        """Memtables in resolution order: active, then immutables newest
        first (the rotation queue's read window, DESIGN.md §11).  The lists
        are copy-on-write, so capturing the reference is a consistent view;
        in sync mode this is always just the active memtable.

        Capture order matters: the active memtable must be read *before*
        the immutable list — rotation publishes in the opposite order
        (append to the queue, then swap the active) — so a racing reader's
        worst case is seeing the rotated memtable twice (benign: identical
        entries, newest-first dedup), never zero times."""
        active = self.memtable
        imm = self._imm
        if not imm:
            return [active]
        return [active] + [m.memtable for m in reversed(imm)]

    def _runs_newest_first(self, levels: List[List[SortedRun]]):
        for r in reversed(levels[0]):
            yield r
        for lvl in levels[1:]:
            for r in reversed(lvl):
                yield r

    # ------------------------------------------------- range views (§13)
    def _view_fresh(self) -> Optional[RangeView]:
        """The current range view iff it indexes the *published* level
        list.  Copy-on-write installs swap ``self._levels``, so one pointer
        compare is the entire staleness check — no locks, no epochs."""
        v = self._range_view
        if v is not None and v.levels_ref is self._levels:
            return v
        return None

    def refresh_range_view(self, background: bool = False
                           ) -> Optional[RangeView]:
        """(Re)build the cross-run range view from the published levels.

        Incremental: per-level sorted columns are cached by run-id tuple
        (``self._view_cache``), so only levels whose membership changed
        since the last rebuild are re-sorted.  Called by a scheduler worker
        once the tree is shaped (``background=True``) or lazily by the
        first view-eligible read in sync mode — never by the write path.
        """
        if not self.config.use_range_views:
            return None
        levels = self._levels
        v = self._range_view
        if v is not None and v.levels_ref is levels:
            return v
        t0 = time.perf_counter_ns()
        view = build_range_view(levels, self._view_cache,
                                telemetry=self.config.telemetry)
        dt = time.perf_counter_ns() - t0
        st = self._stats.local()
        st.view_rebuilds += 1
        if background:
            st.bg_view_rebuilds += 1
        st.view_entries_built += len(view)
        st.view_rebuild_ns += dt
        tel = self.config.telemetry
        if tel is not None:
            tel.record("view_rebuild", dt)
        self._range_view = view
        return view

    def _bg_refresh_view(self) -> None:
        """Scheduler hook: piggyback a view rebuild on the worker that just
        found the tree quiet (CompactJob with no task to run).  The rebuild
        re-uses the sort work that compaction already paid; foreground
        writes never rebuild."""
        if not self.config.use_range_views:
            return
        if self._scheduler is not None and self._scheduler.aborting:
            return
        self.refresh_range_view(background=True)

    def get(self, key: int, snapshot: Optional[Version] = None) -> Optional[bytes]:
        tel = self.config.telemetry
        if tel is None:
            return self._get_impl(key, snapshot)
        t0 = time.perf_counter_ns()
        try:
            out = self._get_impl(key, snapshot)
        except CorruptionError as e:
            tel.emit("corruption", run_id=e.run_id, block_id=e.block_id,
                     where="get")
            raise
        # thread-local histogram record: no locks on the lock-free read path
        tel.record("get", time.perf_counter_ns() - t0)
        return out

    def _get_impl(self, key: int, snapshot: Optional[Version] = None
                  ) -> Optional[bytes]:
        st = self._stats.local()
        st.point_reads += 1
        if snapshot is None:
            # active captured BEFORE the imm check (the rotation publish
            # order makes this safe — see _mem_sources); the empty-queue
            # fast path keeps the sync hot read loop allocation-free
            active = self.memtable
            if not self._imm:
                hit = active.get(int(key))
                if hit is not None:
                    return hit[1]
            else:
                for mt in self._mem_sources():
                    hit = mt.get(int(key))
                    if hit is not None:
                        return hit[1]
        cfg = self.config
        use_bloom = cfg.bits_per_key > 0
        paranoid = cfg.paranoid_checks
        faults = cfg.faults
        for run in self._runs_newest_first(self._read_state(snapshot)):
            if len(run) == 0:
                continue
            st.runs_touched_point += 1
            found, value, _ = run.point_get(int(key), st, use_bloom,
                                            cache=self.block_cache,
                                            paranoid=paranoid, faults=faults)
            if found:
                return value
        return None

    def _bloom_probe_fn(self):
        """Resolve the Pallas batched-probe route (numpy fallback).

        The config flag is re-read every call so toggling
        ``use_pallas_bloom`` on a live store takes effect; only the import
        result is cached.
        """
        if not self.config.use_pallas_bloom:
            return None
        if self._pallas_probe_fn is _UNSET:
            try:
                from repro.kernels.ops import bloom_probe_filter
                self._pallas_probe_fn = bloom_probe_filter
            except Exception:       # jax/pallas unavailable: stay on numpy
                self._pallas_probe_fn = None
        return self._pallas_probe_fn

    def _bloom_hash_fn(self):
        """Resolve the Pallas filter-*build* hash route (numpy fallback).

        Shares the ``use_pallas_bloom`` toggle with the probe route: when
        on, flush and compaction rebuild output filters from one device-side
        hash pass (``kernels.ops.bloom_build_hashes``) that is bit-identical
        to the numpy family, so either backend may probe the result.
        """
        if not self.config.use_pallas_bloom:
            return None
        if self._pallas_hash_fn is _UNSET:
            try:
                from repro.kernels.ops import bloom_build_hashes
                self._pallas_hash_fn = bloom_build_hashes
            except Exception:       # jax/pallas unavailable: stay on numpy
                self._pallas_hash_fn = None
        return self._pallas_hash_fn

    def _pair_merge_fn(self):
        """Resolve the Pallas merge-path compaction lane (numpy fallback).

        When ``use_pallas_merge`` is on, every pairwise merge of the
        compaction ladder routes through ``kernels.ops.merge_runs_tiled``
        (merge-path partition + bitonic network; interpret mode on CPU, the
        same BlockSpecs lower via Mosaic on TPU).  Differentially tested
        bit-for-bit against the numpy ladder and ``merge_runs_scalar``.
        """
        if not self.config.use_pallas_merge:
            return None
        if self._pallas_merge_fn is _UNSET:
            try:
                from repro.kernels.ops import merge_runs_tiled
                self._pallas_merge_fn = merge_runs_tiled
            except Exception:       # jax/pallas unavailable: stay on numpy
                self._pallas_merge_fn = None
        return self._pallas_merge_fn

    def multi_get(self, keys: Sequence[int],
                  snapshot: Optional[Version] = None) -> List[Optional[bytes]]:
        """Batched point reads: semantically ``[get(k) for k in keys]``.

        The batch is resolved level by level: every still-pending key is
        bloom-probed against a run in one vectorized pass (optionally through
        the Pallas kernel, DESIGN.md §3) and located with one searchsorted
        over the run's fence-pointed key array.  Aggregate IOStats accounting
        is identical to the equivalent sequence of scalar ``get`` calls.
        """
        tel = self.config.telemetry
        if tel is None:
            return self._multi_get_impl(keys, snapshot)
        t0 = time.perf_counter_ns()
        try:
            out = self._multi_get_impl(keys, snapshot)
        except CorruptionError as e:
            tel.emit("corruption", run_id=e.run_id, block_id=e.block_id,
                     where="multi_get")
            raise
        tel.record("multi_get", time.perf_counter_ns() - t0)
        return out

    def _multi_get_impl(self, keys: Sequence[int],
                        snapshot: Optional[Version] = None
                        ) -> List[Optional[bytes]]:
        st = self._stats.local()
        keys_arr = np.asarray(list(keys), dtype=KEY_DTYPE)
        n = int(keys_arr.size)
        st.point_reads += n
        results: List[Optional[bytes]] = [None] * n
        if n == 0:
            return results
        pending = np.arange(n, dtype=np.int64)
        if snapshot is None:
            for mt in self._mem_sources():
                if len(mt) == 0 or pending.size == 0:
                    continue
                keep = []
                for j in pending:
                    hit = mt.get(int(keys_arr[j]))
                    if hit is not None:
                        results[int(j)] = hit[1]   # value, or None: tombstone
                    else:
                        keep.append(int(j))
                pending = np.asarray(keep, dtype=np.int64)
        cfg = self.config
        use_bloom = cfg.bits_per_key > 0
        paranoid = cfg.paranoid_checks
        faults = cfg.faults
        probe_fn = self._bloom_probe_fn()
        for run in self._runs_newest_first(self._read_state(snapshot)):
            if pending.size == 0:
                break
            if len(run) == 0:
                continue
            st.runs_touched_point += int(pending.size)
            found, values = run.point_get_batch(
                keys_arr[pending], st, use_bloom, probe_fn,
                cache=self.block_cache, paranoid=paranoid, faults=faults)
            if found.any():
                for p in np.nonzero(found)[0]:
                    results[int(pending[p])] = values[int(p)]
                pending = pending[~found]
        return results

    def seek(self, key: int, snapshot: Optional[Version] = None) -> Optional[int]:
        """Position a merging iterator at the first key >= key (db_bench Seek).

        Cost: one seek + one block read per run with a valid position.

        Tombstone handling is approximate (a cost probe, not a correctness
        surface — ``scan`` is): memtable entries are liveness-filtered but
        run entries are not, so a deleted key stops shadowing once its
        tombstone flushes.  In async mode that transition happens on the
        background worker's schedule rather than at an explicit ``flush``
        call; use ``scan``/``iterator`` where exact liveness matters."""
        tel = self.config.telemetry
        if tel is None:
            return self._seek_impl(key, snapshot)
        t0 = time.perf_counter_ns()
        out = self._seek_impl(key, snapshot)
        tel.record("seek", time.perf_counter_ns() - t0)
        return out

    def _seek_impl(self, key: int, snapshot: Optional[Version] = None
                   ) -> Optional[int]:
        st = self._stats.local()
        st.range_reads += 1
        best: Optional[int] = None
        # memtables BEFORE levels: the install protocol publishes the L0 run
        # first and pops the immutable memtable second, so this capture order
        # makes the race a benign duplicate, never a lost read (_mem_sources)
        mems = self._mem_sources() if snapshot is None else []
        if snapshot is None and self.config.use_range_views:
            view = self._view_fresh()
            if view is None and self._scheduler is None:
                view = self.refresh_range_view()
            if view is not None:
                st.view_scans += 1
                best = view.seek(int(key), st, self.block_cache)
                # same approximate-liveness memtable probe as the run walk
                for mt in mems:
                    for k, s, v in mt.scan(int(key))[:1]:
                        if v is not None and (best is None or k < best):
                            best = k
                return best
            st.view_fallbacks += 1
        for run in self._runs_newest_first(self._read_state(snapshot)):
            if len(run) == 0:
                continue
            st.runs_touched_range += 1
            st.seeks += 1
            i = run.seek_idx(int(key))
            if i < len(run):
                run._charge_block(run.block_of[i], st,
                                  self.block_cache,
                                  paranoid=self.config.paranoid_checks,
                                  faults=self.config.faults)
                k = int(run.keys[i])
                if best is None or k < best:
                    best = k
        for mt in mems:
            for k, s, v in mt.scan(int(key))[:1]:
                if v is not None and (best is None or k < best):
                    best = k
        return best

    def iterator(self, snapshot: Optional[Version] = None,
                 chunk: int = 512) -> MergingIterator:
        """A streaming merging iterator over the current (or snapshot) state.

        Holds one cursor per run + the memtable; see core.iterator for the
        merge and I/O-accounting semantics (DESIGN.md §3).  The iterator reads
        a frozen set of runs — writes/compactions after creation are not seen
        by run cursors (memtable updates may be, as in RocksDB iterators pin
        SSTs but here the memtable is shared; take a snapshot for isolation).
        """
        # memtables BEFORE levels (see seek): worst case a duplicate entry
        # with the same seq/value, never a lost read
        mems = self._mem_sources() if snapshot is None else None
        levels = self._read_state(snapshot)
        runs = [r for r in self._runs_newest_first(levels) if len(r)]
        return MergingIterator(runs, memtables=mems,
                               stats=self._stats.local(),
                               chunk=chunk, cache=self.block_cache)

    def scan(self, start_key: int, count: int,
             snapshot: Optional[Version] = None) -> List[Tuple[int, bytes]]:
        """Range read: first ``count`` live entries with key >= start_key.

        One seek per run positions a cursor; the merged stream then refills
        incrementally per run (no restart loop), charging each run the blocks
        it actually contributed — see core.iterator.

        With ``use_range_views`` (DESIGN.md §13) a live, *fresh* range view
        replaces all of that with one binary search + one sequential sweep
        + one batched gather per touched run.  A stale view (async churn
        between the last background rebuild and now) falls back to the
        merging iterator and counts ``view_fallbacks`` — the result is
        identical either way, only the cost differs.
        """
        tel = self.config.telemetry
        if tel is None:
            return self._scan_impl(start_key, count, snapshot)
        t0 = time.perf_counter_ns()
        out = self._scan_impl(start_key, count, snapshot)
        tel.record("scan", time.perf_counter_ns() - t0)
        return out

    def _scan_impl(self, start_key: int, count: int,
                   snapshot: Optional[Version] = None
                   ) -> List[Tuple[int, bytes]]:
        st = self._stats.local()
        st.range_reads += 1
        if snapshot is None and self.config.use_range_views:
            # memtables BEFORE the view/levels capture (see seek): a racing
            # install contributes a benign duplicate, never a lost read
            mems = self._mem_sources()
            view = self._view_fresh()
            if view is None and self._scheduler is None:
                view = self.refresh_range_view()  # lazy in sync mode
            if view is not None:
                st.view_scans += 1
                mems = [m for m in mems if len(m)]   # empty => pure sweep
                mem_items = (combined_mem_items(mems, int(start_key))
                             if mems else [])
                return view.scan(int(start_key), count, mem_items,
                                 st, self.block_cache)
            st.view_fallbacks += 1
        it = self.iterator(snapshot)
        return it.scan(int(start_key), count)

    def scan_scalar(self, start_key: int, count: int,
                    snapshot: Optional[Version] = None
                    ) -> List[Tuple[int, bytes]]:
        """Reference range read (the pre-iterator seek-retry implementation).

        Kept as the differential-test oracle and the benchmarks' scalar
        baseline: slices ``count`` candidates from every run, sort-merges the
        python lists, and retries with a 4x larger window when a truncated
        run could still hide smaller keys.
        """
        st = self._stats.local()
        st.range_reads += 1
        # memtables BEFORE levels (see seek): a flush racing this capture
        # contributes a duplicate (same seq, same value — the (key, -seq)
        # merge keeps one), never a lost read
        mems = self._mem_sources() if snapshot is None else []
        levels = self._read_state(snapshot)
        runs = [r for r in self._runs_newest_first(levels) if len(r)]
        per_run_take = max(count, 1)
        while True:
            cand_k: List[np.ndarray] = []
            cand_s: List[np.ndarray] = []
            cand_v: List[List[Optional[bytes]]] = []
            # Results are only valid up to the smallest last-key among
            # truncated run slices (a run whose window ended may still hold
            # keys below another run's contributions).
            frontier: Optional[int] = None
            seek_positions = []
            for run in runs:
                i = run.seek_idx(int(start_key))
                seek_positions.append(i)
                k, s, l, v = run.slice_from(i, per_run_take)
                if i + per_run_take < len(run) and len(k):
                    fk = int(k[-1])
                    frontier = fk if frontier is None else min(frontier, fk)
                cand_k.append(k)
                cand_s.append(s)
                cand_v.append([None if l[j] == TOMBSTONE_LEN else bytes(v[j, :l[j]])
                               for j in range(len(k))])
            mem_items: List[Tuple[int, int, Optional[bytes]]] = []
            for mt in mems:
                # seq numbers resolve duplicates across the rotation queue
                # inside _merge_candidates' (key, -seq) sort
                mem_items.extend(mt.scan(int(start_key)))
            merged = self._merge_candidates(cand_k, cand_s, cand_v, mem_items)
            live = [(k, v) for k, v in merged if v is not None and
                    (frontier is None or k <= frontier)][:count]
            if len(live) >= count or frontier is None:
                # Account I/O for the final pass only (the retry loop models
                # an iterator that would have kept reading anyway).
                end_key = live[-1][0] if live else None
                for run, i in zip(runs, seek_positions):
                    st.runs_touched_range += 1
                    st.seeks += 1
                    if i >= len(run):
                        continue
                    if end_key is None:
                        consumed_end = i + 1
                    else:
                        consumed_end = int(np.searchsorted(
                            run.keys, np.uint64(end_key), side="right"))
                        consumed_end = max(consumed_end, i + 1)
                    st.blocks_read += run.blocks_spanned(i, consumed_end)
                return live
            per_run_take *= 4

    @staticmethod
    def _merge_candidates(cand_k, cand_s, cand_v, mem_items):
        ks: List[int] = []
        ss: List[int] = []
        vs: List[Optional[bytes]] = []
        for k_arr, s_arr, v_list in zip(cand_k, cand_s, cand_v):
            ks.extend(int(x) for x in k_arr)
            ss.extend(int(x) for x in s_arr)
            vs.extend(v_list)
        for k, s, v in mem_items:
            ks.append(k)
            ss.append(s)
            vs.append(v)
        order = sorted(range(len(ks)), key=lambda i: (ks[i], -ss[i]))
        out: List[Tuple[int, Optional[bytes]]] = []
        last_key = None
        for i in order:
            if ks[i] != last_key:
                out.append((ks[i], vs[i]))
                last_key = ks[i]
        return out

    # ----------------------------------------------------------- snapshots
    def get_snapshot(self) -> Version:
        """Acquire a reader reference on the current version.

        Thin wrapper over the manifest's *refcounted* pins: snapshot reads
        stay valid across any number of later flushes/compactions until the
        matching ``release_snapshot``; if several readers snapshot the same
        version, it stays pinned until the last one releases.  The
        read-and-pin is atomic under the manifest mutex, so snapshots taken
        while background compaction churns can never pin a version whose
        runs a concurrent GC already freed.
        """
        return self.manifest.pin_current()

    def release_snapshot(self, snapshot: Version) -> None:
        """Drop one reader reference (see ``get_snapshot``)."""
        if not self.manifest.unpin(snapshot.version_id):
            return  # other readers still hold the version: nothing can free
        with self._maint_lock:
            self.manifest.gc()
            if self.block_cache is not None:
                # Runs kept alive only by the released snapshot may be gone.
                self.block_cache.retain(self.storage.ids())

    # ------------------------------------------------------------ recovery
    def crash(self):
        """Simulate process crash: volatile state is lost.

        Async mode: the scheduler aborts the in-flight job at its next safe
        point and drops all queued work *before* the volatile wipe, so no
        half-applied compaction, pinned input version, or orphaned cache
        entry survives (see ``CompactionScheduler.abort_and_drain``).  The
        immutable-memtable queue's WAL segments are durable (fully fsynced
        at rotation) and stay for ``recover`` to replay; the memtable dicts
        themselves are process state and are rebuilt from those segments.
        """
        if self._scheduler is not None:
            self._scheduler.abort_and_drain()
        f = self.config.faults
        self.wal.crash(f)
        for imm in self._imm:
            imm.wal.crash()   # fully synced at rotation: keeps every byte
        self.manifest.crash(f)
        self.memtable.clear()

    def recover(self):
        """Rebuild volatile state from the durable manifest + WAL(s).

        Async mode adds the rotated-but-unflushed WAL segments: they are
        consolidated (oldest first) ahead of the active WAL into one log —
        segment concatenation is record concatenation — so replay order
        equals write order and a *second* crash before the next rotation
        still recovers everything.  The scheduler survives recovery idle
        (its queue was drained by ``crash``) and resumes on the next
        rotation.

        Integrity (DESIGN.md §16.2): the manifest tail is checksum-verified
        (corrupt edits are popped back to the last good version — each was
        itself a durable prefix), WAL replay stops at the first bad frame
        and the log is truncated there, and every recovered run is scrubbed
        *regardless of* ``paranoid_checks`` — a bad block raises
        :class:`CorruptionError` so corruption is never served silently.
        Recovery also clears degraded mode: the failed pipeline's state was
        volatile.
        """
        tel = self.config.telemetry
        v, popped = self.manifest.recover_current()
        if popped and tel is not None:
            tel.emit("corruption", run_id=-1, block_id=-1, where="manifest",
                     popped_versions=popped)
        self._levels = v.runs(self.storage)
        self._max_level = v.max_level
        self._seq = v.last_seq
        self._degraded = None
        self._bg_failure_surfaced = False
        if self.block_cache is not None:
            # DRAM contents did not survive the crash; reload the pin set
            # from the recovered L0 (charged — these are real device reads)
            # while the unpinned cache refills on demand.
            self.block_cache.clear()
            with self._maint_lock:
                self.pinned_l0.repin(self._levels[0],
                                     stats=self._stats.local())
        # Drop bytes past the last checksum-valid WAL frame before replay:
        # a corrupt frame must not linger in the live log (new appends
        # would land after it and be unreachable to the next replay).
        wal_dropped = self.wal.repair()
        if wal_dropped and tel is not None:
            tel.emit("corruption", run_id=-1, block_id=-1, where="wal",
                     dropped_bytes=wal_dropped)
        # Post-crash every surviving WAL byte is durable (crash truncated
        # each segment to its watermark), so consolidation + replay rebuilds
        # the memtable and advances _seq; with an empty immutable queue this
        # is exactly the old single-WAL replay.
        replayed = self._consolidate_imm_wal()
        if tel is not None:
            tel.emit("wal_replay", records=replayed,
                     bytes=len(self.wal._buf), dropped_bytes=wal_dropped)
        report = self.scrub()
        for r in report:
            if r["bad_blocks"]:
                raise CorruptionError(r["run_id"], r["bad_blocks"][0],
                                      where="recovery scrub")

    def scrub(self) -> List[dict]:
        """Verify every run's block checksums; one report dict per run.

        Each entry carries ``run_id``, ``level``, ``entries``, ``blocks``
        and ``bad_blocks`` (empty list == clean).  Emits a ``scrub``
        telemetry event (plus one ``corruption`` event per dirty run) but
        does not raise — callers decide (recovery raises, operators may
        quarantine).
        """
        tel = self.config.telemetry
        t0 = time.perf_counter_ns() if tel is not None else 0
        report: List[dict] = []
        levels = self._levels
        for li, lvl in enumerate(levels):
            for run in lvl:
                bad = run.verify()
                report.append({"run_id": run.run_id, "level": li,
                               "entries": len(run), "blocks": run.n_blocks,
                               "bad_blocks": bad})
                if bad and tel is not None:
                    tel.emit("corruption", run_id=run.run_id,
                             block_id=int(bad[0]), where="scrub",
                             bad_blocks=len(bad))
        if tel is not None:
            tel.record("scrub", time.perf_counter_ns() - t0)
            tel.emit("scrub", runs=len(report),
                     bad_runs=sum(1 for r in report if r["bad_blocks"]))
        return report

    # ------------------------------------- cross-shard migration (§15)
    # Three primitives used by ShardedLSMStore rebalancing.  All of them
    # assume the caller holds the facade write gate and has quiesced this
    # store (no foreground writers, scheduler drained) — except
    # strip_to_range, which recovery also calls with a replayed (in-range
    # by invariant) memtable.

    def export_range(self, lo: int, hi: int):
        """Columns of every stored entry with ``lo <= key < hi``.

        Returns ``(keys, seqs, vlens, vals)`` with duplicates *retained*
        (one row per surviving physical entry, any level) so the importer's
        ``build_run`` dedup keeps exactly the newest version per key, or
        ``None`` when the range holds nothing.  Requires an empty memtable
        (the facade flushes before migrating) so runs are the whole store.
        """
        assert len(self.memtable) == 0 and not self._imm, \
            "export_range requires a flushed, quiesced store"
        lo64 = np.uint64(lo)
        ks, ss, ls, vs, vmax = [], [], [], [], 0
        for run in self._runs_newest_first(self._levels):
            if len(run) == 0:
                continue
            i0 = int(np.searchsorted(run.keys, lo64, side="left"))
            i1 = (len(run) if hi >= 1 << 64 else
                  int(np.searchsorted(run.keys, np.uint64(hi), side="left")))
            if i0 >= i1:
                continue
            k, s, l, v = run.slice_from(i0, i1 - i0)
            v2 = v if v.ndim == 2 else v.reshape(len(k), 0)
            ks.append(k); ss.append(s); ls.append(l); vs.append(v2)
            vmax = max(vmax, v2.shape[1])
        if not ks:
            return None
        vs = [v if v.shape[1] == vmax
              else np.pad(v, ((0, 0), (0, vmax - v.shape[1])))
              for v in vs]
        return (np.concatenate(ks), np.concatenate(ss),
                np.concatenate(ls), np.concatenate(vs))

    def import_migrated_run(self, run: SortedRun) -> None:
        """Install a migrated run as newest-L0 and commit it durably.

        The facade guarantees the run's key range is disjoint from
        everything this store currently holds (it is becoming the owner),
        so L0 placement cannot shadow or be shadowed incorrectly; the seq
        max-bump keeps every *future* local write newer than the imports.
        """
        if len(run) == 0:
            return
        f = self.config.faults
        if f is not None:
            f.check("migration_import")  # before any mutation: a failed
                                         # import leaves this store untouched
        self._seq = max(self._seq, int(run.seqs.max()))
        levels = [list(lvl) for lvl in self._levels]
        levels[0].append(run)          # newest-last, like flush
        self._levels = levels          # COW publish
        st = self._stats.local()
        st.blocks_written += -(-run.data_bytes // self.config.block_size)
        self._commit()

    def strip_to_range(self, lo: int, hi: int) -> int:
        """Drop every stored entry outside ``[lo, hi)``; return the count.

        Runs wholly outside are dropped; straddling runs are rebuilt from
        their in-range slice (already unique+sorted).  Commits only when
        something changed, so post-recovery clipping of an untouched store
        is a no-op.  The memtable is left alone: the facade only writes
        in-range keys under the routing that is durably logged *before* it
        becomes visible, so replayed memtable contents are in-range by
        invariant.
        """
        f = self.config.faults
        if f is not None:
            f.check("migration_strip")   # before any mutation: the donor
                                         # keeps its (already-copied) range
        lo64 = np.uint64(lo)
        dropped = 0
        changed = False
        levels: List[List[SortedRun]] = []
        for li, lvl in enumerate(self._levels):
            out = []
            for run in lvl:
                if len(run) == 0:
                    out.append(run)
                    continue
                i0 = int(np.searchsorted(run.keys, lo64, side="left"))
                i1 = (len(run) if hi >= 1 << 64 else
                      int(np.searchsorted(run.keys, np.uint64(hi),
                                          side="left")))
                if i0 == 0 and i1 == len(run):
                    out.append(run)
                    continue
                changed = True
                dropped += len(run) - (i1 - i0)
                if i0 >= i1:
                    continue                      # wholly outside: drop
                k, s, l, v = run.slice_from(i0, i1 - i0)
                st = self._stats.local()
                nr = build_run(k, s, l, v,
                               bits_per_key=self._bits_for_level(li),
                               assume_unique_sorted=True,
                               block_size=self.config.block_size,
                               key_bytes=self.config.key_bytes,
                               hash_fn=self._bloom_hash_fn())
                st.blocks_written += -(-nr.data_bytes
                                       // self.config.block_size)
                out.append(nr)
            levels.append(out)
        if changed:
            self._levels = levels          # COW publish: stale range views
            self._commit()                 # self-invalidate on levels_ref
        return dropped

    # ---------------------------------------------------------------- info
    def cache_summary(self) -> dict:
        """Memory-subsystem health: hit rate, charged bytes, residency."""
        if self.block_cache is None:
            return dict(enabled=False, hit_rate=0.0, hits=0, misses=0,
                        evictions=0, charged_bytes=0, pinned_bytes=0,
                        pinned_l0_runs=0)
        c = self.block_cache
        return dict(enabled=True, hit_rate=c.hit_rate(), hits=c.hits,
                    misses=c.misses, evictions=c.evictions,
                    charged_bytes=c.charged_bytes,
                    pinned_bytes=c.pinned_bytes,
                    pinned_l0_runs=len(self.pinned_l0.pinned_run_ids))

    def level_summary(self) -> List[dict]:
        out = []
        for i, lvl in enumerate(self._levels):
            cap = (self.policy.capacity(i, self._max_level,
                                        self.config.base_level_bytes)
                   if i >= 1 else None)
            out.append(dict(level=i, runs=len(lvl),
                            entries=sum(len(r) for r in lvl),
                            bytes=sum(r.data_bytes for r in lvl),
                            capacity=cap))
        return out

    @property
    def num_levels_in_use(self) -> int:
        return self._max_level

    @property
    def total_entries(self) -> int:
        # memtables BEFORE levels (see _mem_sources): a racing install can
        # double-count an in-flight flush, never drop it
        mems = self._mem_sources()
        levels = self._levels
        return sum(len(r) for lvl in levels for r in lvl) \
            + sum(len(mt) for mt in mems)

    def _live_profile(self) -> Tuple[int, int]:
        """(live entry count, live logical bytes) of the newest versions.

        One vectorized pass: concatenate every source's keys newest-first
        (memtable, then runs in read order), stable-argsort, and keep the
        first occurrence of each key — the newest version, since stable
        sorting preserves concatenation order within equal keys.  Replaces
        the per-run ``np.isin`` against an ever-growing seen-set (quadratic
        in the number of runs x entries).
        """
        parts_k: List[np.ndarray] = []
        parts_vl: List[np.ndarray] = []
        for mt in self._mem_sources():   # active, then immutables newest 1st
            # consistent point-in-time copy (the active memtable may be
            # racing the writer thread; see Memtable.snapshot_items)
            items = mt.snapshot_items()
            if items:
                parts_k.append(np.fromiter((k for k, _, _ in items),
                                           KEY_DTYPE, len(items)))
                parts_vl.append(np.fromiter(
                    (TOMBSTONE_LEN if v is None else len(v)
                     for _, _, v in items), np.int64, len(items)))
        for run in self._runs_newest_first(self._levels):
            if len(run):
                parts_k.append(run.keys)
                parts_vl.append(run.vlens.astype(np.int64))
        if not parts_k:
            return 0, 0
        K = np.concatenate(parts_k)
        VL = np.concatenate(parts_vl)
        order = np.argsort(K, kind="stable")
        Ks = K[order]
        first = np.empty(Ks.size, dtype=bool)
        first[0] = True
        np.not_equal(Ks[1:], Ks[:-1], out=first[1:])
        win_vl = VL[order[first]]
        live = win_vl != TOMBSTONE_LEN
        n_live = int(np.count_nonzero(live))
        logical = int(np.sum(win_vl[live])) + n_live * self.config.key_bytes
        return n_live, logical

    def total_live_entries(self) -> int:
        """Logical entry count (newest versions only, tombstones excluded)."""
        return self._live_profile()[0]

    def _space_profile(self) -> Tuple[int, int]:
        """(physical bytes stored, logical live bytes) — the two terms of
        space amplification, exposed separately so the sharded facade can
        sum shards before dividing (a mean of per-shard ratios is wrong
        when shard sizes differ)."""
        mems = self._mem_sources()      # memtables BEFORE levels, as above
        phys = sum(r.data_bytes for lvl in self._levels for r in lvl) \
            + sum(mt.size_bytes for mt in mems)
        return phys, self._live_profile()[1]

    def space_amplification(self) -> float:
        """Physical bytes stored / logical bytes of the live newest versions
        (RocksDB's definition; 1.0 when nothing is live)."""
        phys, logical = self._space_profile()
        if logical == 0:
            return 1.0
        return phys / logical
