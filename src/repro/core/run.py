"""Immutable sorted runs (the engine's SST analog).

A run stores its entries as parallel numpy arrays sorted by key:
  keys  : uint64 (strictly increasing — duplicates are resolved at build time,
          newest sequence number wins, matching LSM merge semantics)
  seqs  : uint64 sequence numbers (MVCC ordering across runs)
  vlens : int32 value lengths; TOMBSTONE_LEN marks a delete marker
  vals  : uint8 (n, Vmax) padded value payload

Entries are packed into BLOCK_SIZE blocks; ``block_of`` maps each entry to its
block id and the *fence pointers* (first key per block, kept in host memory —
"main memory" in the paper) let a reader locate the single candidate block of
any key with zero block touches, exactly the paper's fence-pointer model.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .bloom import BloomFilter
from .faults import CorruptionError, crc32c_rows
from .types import (BLOCK_SIZE, KEY_BYTES, KEY_DTYPE, SEQ_DTYPE,
                    TOMBSTONE_LEN, IOStats)

_run_ids = itertools.count()


def _entry_crcs(keys: np.ndarray, seqs: np.ndarray, vlens: np.ndarray,
                vals: np.ndarray) -> np.ndarray:
    """CRC-32C per entry over its canonical bytes (DESIGN.md §16.2):
    key(8 LE) | seq(8 LE) | vlen(4 LE, signed — tombstones included) |
    value[:max(vlen,0)].  One vectorized pass over a padded byte matrix."""
    n = int(keys.size)
    if n == 0:
        return np.zeros(0, dtype=np.uint32)
    vmax = vals.shape[1] if vals.ndim == 2 else 0
    mat = np.zeros((n, 20 + vmax), dtype=np.uint8)
    mat[:, 0:8] = keys.astype("<u8").view(np.uint8).reshape(n, 8)
    mat[:, 8:16] = seqs.astype("<u8").view(np.uint8).reshape(n, 8)
    mat[:, 16:20] = vlens.astype("<i4").view(np.uint8).reshape(n, 4)
    if vmax:
        mat[:, 20:] = vals
    lens = 20 + np.maximum(vlens, 0).astype(np.int64)
    return crc32c_rows(mat, lens)


class SortedRun:
    __slots__ = ("run_id", "keys", "seqs", "vlens", "vals", "block_of",
                 "fence_keys", "n_blocks", "data_bytes", "block_size",
                 "bloom", "level_hint", "block_crcs", "_uniform_vals")

    def __init__(self, keys: np.ndarray, seqs: np.ndarray, vlens: np.ndarray,
                 vals: np.ndarray, bits_per_key: float = 0.0,
                 block_size: int = BLOCK_SIZE, key_bytes: int = KEY_BYTES,
                 hash_fn=None):
        assert keys.ndim == 1
        self.block_size = block_size
        self.run_id = next(_run_ids)
        self.keys = np.ascontiguousarray(keys, dtype=KEY_DTYPE)
        self.seqs = np.ascontiguousarray(seqs, dtype=SEQ_DTYPE)
        self.vlens = np.ascontiguousarray(vlens, dtype=np.int32)
        self.vals = np.ascontiguousarray(vals, dtype=np.uint8)
        n = self.keys.size
        entry_sizes = key_bytes + np.maximum(self.vlens, 0).astype(np.int64)
        cum = np.cumsum(entry_sizes)
        self.data_bytes = int(cum[-1]) if n else 0
        # Entry i lives in the block containing its *starting* byte.
        starts = cum - entry_sizes
        self.block_of = (starts // block_size).astype(np.int64)
        self.n_blocks = int(self.block_of[-1]) + 1 if n else 0
        # Fence pointer = first key of each block (in-memory index).
        if n:
            first_idx = np.searchsorted(self.block_of,
                                        np.arange(self.n_blocks), side="left")
            self.fence_keys = self.keys[first_idx]
            # Per-block checksum = XOR of member-entry CRC-32Cs (§16.2):
            # order-independent, so verification can recompute any single
            # block without materializing its byte stream.
            self.block_crcs = self._block_crcs_from(
                _entry_crcs(self.keys, self.seqs, self.vlens, self.vals))
        else:
            self.fence_keys = np.zeros(0, dtype=KEY_DTYPE)
            self.block_crcs = np.zeros(0, dtype=np.uint32)
        self.bloom = BloomFilter(self.keys, bits_per_key, hash_fn=hash_fn)
        self.level_hint = -1  # set by the manifest; informational
        self._uniform_vals = None  # lazy: every value full-width, no tombs?

    # ------------------------------------------------------------------ size
    def __len__(self) -> int:
        return int(self.keys.size)

    @property
    def min_key(self) -> int:
        return int(self.keys[0]) if len(self) else 0

    @property
    def max_key(self) -> int:
        return int(self.keys[-1]) if len(self) else 0

    def bit_equal(self, other: "SortedRun") -> bool:
        """Bit-for-bit payload equality: keys/seqs/vlens/vals/bloom bits.

        The single definition of run equality used by every async-vs-sync
        differential oracle (tests and the micro_dbbench inline assert), so
        a future run field is added to the contract in exactly one place.
        """
        return bool(
            np.array_equal(self.keys, other.keys)
            and np.array_equal(self.seqs, other.seqs)
            and np.array_equal(self.vlens, other.vlens)
            and np.array_equal(self.vals, other.vals)
            and np.array_equal(self.bloom.bits, other.bloom.bits))

    def block_bytes(self, block_id: int) -> int:
        """Physical bytes stored in one block (the last block may be short)."""
        if block_id < 0 or block_id >= self.n_blocks:
            return 0
        if block_id == self.n_blocks - 1:
            return self.data_bytes - block_id * self.block_size
        return self.block_size

    # ------------------------------------------------------------- integrity
    def _block_crcs_from(self, entry_crcs: np.ndarray) -> np.ndarray:
        """Fold per-entry CRCs into per-block checksums (XOR-reduce at each
        block's first entry).  A block spanned entirely by a giant
        neighbouring entry has no member entries; its checksum is 0."""
        bounds = np.searchsorted(self.block_of, np.arange(self.n_blocks),
                                 side="left")
        crcs = np.bitwise_xor.reduceat(entry_crcs, bounds)
        # reduceat yields entry_crcs[bounds[i]] for empty segments — fix up
        nxt = np.append(bounds[1:], entry_crcs.size)
        crcs[bounds == nxt] = 0
        return crcs.astype(np.uint32)

    def verify_block(self, block_id: int) -> bool:
        """Recompute one block's checksum from its entries; True iff clean."""
        sel = np.nonzero(self.block_of == block_id)[0]
        if sel.size == 0:
            return int(self.block_crcs[block_id]) == 0
        fresh = _entry_crcs(self.keys[sel], self.seqs[sel],
                            self.vlens[sel], self.vals[sel])
        return int(np.bitwise_xor.reduce(fresh)) == int(self.block_crcs[block_id])

    def verify(self) -> List[int]:
        """Recompute every block checksum; returns the bad block ids
        (empty list == run is clean).  Used by ``scrub()`` and recovery."""
        if len(self) == 0:
            return []
        fresh = self._block_crcs_from(
            _entry_crcs(self.keys, self.seqs, self.vlens, self.vals))
        return np.nonzero(fresh != self.block_crcs)[0].tolist()

    def _charge_block(self, block_id: int, stats: IOStats, cache,
                      paranoid: bool = False, faults=None) -> None:
        """One block touch: through the cache when present, else raw I/O.

        ``faults`` fires the ``block_read`` injection site; ``paranoid``
        re-verifies the block's checksum after the read and raises
        :class:`CorruptionError` on a mismatch (``LSMConfig.paranoid_checks``).
        """
        if faults is not None:
            faults.check("block_read")
        if cache is None:
            stats.blocks_read += 1
        else:
            cache.read_block(self.run_id, int(block_id),
                             self.block_bytes(int(block_id)), stats)
        if paranoid and not self.verify_block(int(block_id)):
            raise CorruptionError(self.run_id, int(block_id))

    # ----------------------------------------------------------------- reads
    def point_get(self, key: int, stats: IOStats,
                  use_bloom: bool = True, cache=None,
                  paranoid: bool = False,
                  faults=None) -> Tuple[bool, Optional[bytes], int]:
        """Returns (found, value_or_None_if_tombstone, seq).

        Cost model: one bloom probe (CPU), then one block read iff the bloom
        says maybe (fence pointers locate the block for free; the read goes
        through ``cache`` when one is attached — hits charge no block I/O).
        """
        k = np.uint64(key)
        if use_bloom and self.bloom.k > 0:
            stats.bloom_probes += 1
            if not bool(self.bloom.may_contain(np.asarray([k]))[0]):
                stats.bloom_negatives += 1
                return False, None, -1
        if len(self) == 0:
            return False, None, -1  # no blocks to read
        i = int(np.searchsorted(self.keys, k))
        # fence pointers give the unique candidate block
        self._charge_block(self.block_of[min(i, len(self) - 1)], stats, cache,
                           paranoid=paranoid, faults=faults)
        if i < len(self) and self.keys[i] == k:
            vlen = int(self.vlens[i])
            if vlen == TOMBSTONE_LEN:
                return True, None, int(self.seqs[i])
            return True, bytes(self.vals[i, :vlen]), int(self.seqs[i])
        stats.false_positives += 1
        return False, None, -1

    def point_get_batch(self, keys: np.ndarray, stats: IOStats,
                        use_bloom: bool = True, probe_fn=None, cache=None,
                        paranoid: bool = False, faults=None
                        ) -> Tuple[np.ndarray, List[Optional[bytes]]]:
        """Vectorized ``point_get`` over a batch of keys.

        Returns ``(found, values)``: found[i] True means key i's newest
        version lives in this run (values[i] is its bytes, or None for a
        tombstone).  One bloom pass + one searchsorted over the whole batch;
        aggregate IOStats accounting is identical to len(keys) scalar
        ``point_get`` calls.  ``probe_fn(bloom, keys) -> bool mask`` optionally
        reroutes the filter probe (e.g. through the Pallas kernel); ``cache``
        routes the candidate block reads through the block cache, in batch
        order (so two candidates sharing a block cost one miss + one hit).
        """
        keys = np.ascontiguousarray(keys, dtype=KEY_DTYPE)
        n = keys.size
        found = np.zeros(n, dtype=bool)
        values: List[Optional[bytes]] = [None] * n
        if len(self) == 0:
            return found, values  # no blocks to read
        if use_bloom and self.bloom.k > 0:
            stats.bloom_probes += n
            if probe_fn is not None:
                maybe = np.asarray(probe_fn(self.bloom, keys), dtype=bool)
            else:
                maybe = self.bloom.may_contain(keys)
            stats.bloom_negatives += int(n - np.count_nonzero(maybe))
            cand = np.nonzero(maybe)[0]
        else:
            cand = np.arange(n)
        if cand.size == 0:
            return found, values
        # Fence pointers give each candidate its unique block: 1 read apiece.
        idx = np.searchsorted(self.keys, keys[cand])
        blocks = self.block_of[np.minimum(idx, len(self) - 1)]
        if faults is not None:
            for _ in range(int(cand.size)):  # one injection check per read
                faults.check("block_read")
        if cache is None:
            stats.blocks_read += int(cand.size)
        else:
            cache.read_blocks(self.run_id, blocks.tolist(),
                              self.block_bytes, stats)
        if paranoid:
            for b in np.unique(blocks):
                if not self.verify_block(int(b)):
                    raise CorruptionError(self.run_id, int(b))
        inb = idx < len(self)
        hit = np.zeros(cand.size, dtype=bool)
        hit[inb] = self.keys[idx[inb]] == keys[cand][inb]
        stats.false_positives += int(cand.size - np.count_nonzero(hit))
        for p in np.nonzero(hit)[0]:
            i = int(idx[p])
            j = int(cand[p])
            found[j] = True
            vlen = int(self.vlens[i])
            if vlen != TOMBSTONE_LEN:
                values[j] = bytes(self.vals[i, :vlen])
        return found, values

    def values_at(self, rows: np.ndarray) -> List[Optional[bytes]]:
        """Batched value extraction for the given rows: one row-gather +
        one ``tobytes`` for the whole batch (the same idiom the merging
        iterator uses per refill), ``None`` at tombstone rows.  Used by the
        range-view scan's per-run materialization (DESIGN.md §13)."""
        vmax = self.vals.shape[1] if self.vals.ndim == 2 else 0
        if vmax == 0:
            return [None if l == TOMBSTONE_LEN else b""
                    for l in self.vlens[rows].tolist()]
        if self._uniform_vals is None:
            # runs are immutable: pay the whole-run check once, then every
            # fixed-value_size workload splits at a fixed stride with no
            # per-row length gather at all
            self._uniform_vals = bool((self.vlens == vmax).all())
        if self._uniform_vals:
            flat = self.vals[rows].tobytes()
            return [flat[o:o + vmax] for o in range(0, len(flat), vmax)]
        lens = self.vlens[rows].tolist()
        flat = self.vals[rows].tobytes()
        out: List[Optional[bytes]] = []
        for o, l in enumerate(lens):
            if l == TOMBSTONE_LEN:
                out.append(None)
            else:
                off = o * vmax
                out.append(flat[off:off + l])
        return out

    def seek_idx(self, key: int) -> int:
        return int(np.searchsorted(self.keys, np.uint64(key), side="left"))

    def slice_from(self, start_idx: int, count: int):
        """Entries [start_idx, start_idx+count) as (keys, seqs, vlens, vals)."""
        e = min(start_idx + count, len(self))
        return (self.keys[start_idx:e], self.seqs[start_idx:e],
                self.vlens[start_idx:e], self.vals[start_idx:e])

    def blocks_spanned(self, start_idx: int, end_idx: int) -> int:
        """Number of blocks touched to read entries [start_idx, end_idx)."""
        if end_idx <= start_idx or start_idx >= len(self):
            return 0
        end_idx = min(end_idx, len(self))
        return int(self.block_of[end_idx - 1] - self.block_of[start_idx]) + 1


def levels_bit_equal(levels_a: Sequence[Sequence[SortedRun]],
                     levels_b: Sequence[Sequence[SortedRun]]) -> bool:
    """Bit-for-bit tree equality: same level count, same runs per level,
    every run pair :meth:`SortedRun.bit_equal`.

    The one definition of the async-vs-sync differential oracle's tree
    comparison, shared by the property tests and the micro_dbbench inline
    assert so the contract cannot drift between them.
    """
    if len(levels_a) != len(levels_b):
        return False
    for la, lb in zip(levels_a, levels_b):
        if len(la) != len(lb):
            return False
        for ra, rb in zip(la, lb):
            if not ra.bit_equal(rb):
                return False
    return True


# --------------------------------------------------------------------- build
def build_run(keys: np.ndarray, seqs: np.ndarray, vlens: np.ndarray,
              vals: np.ndarray, bits_per_key: float = 0.0,
              assume_unique_sorted: bool = False,
              drop_tombstones: bool = False,
              block_size: int = BLOCK_SIZE, key_bytes: int = KEY_BYTES,
              hash_fn=None) -> SortedRun:
    """Sort by key, deduplicate keeping the newest seq, optionally GC deletes.

    ``block_size``/``key_bytes`` shape the constructed run's block layout
    (threaded from ``LSMConfig`` by the engine); ``hash_fn`` optionally
    reroutes the bloom build's hash pass (e.g. through the Pallas kernel
    family — see ``core.bloom.BloomFilter``).
    """
    keys = np.asarray(keys, dtype=KEY_DTYPE)
    seqs = np.asarray(seqs, dtype=SEQ_DTYPE)
    vlens = np.asarray(vlens, dtype=np.int32)
    vals = np.asarray(vals, dtype=np.uint8)
    if vals.ndim == 1:
        vals = vals.reshape(len(keys), -1) if len(keys) else vals.reshape(0, 0)
    if not assume_unique_sorted and len(keys):
        # Stable sort by (key, -seq): newest version of each key comes first.
        order = np.lexsort((np.iinfo(np.uint64).max - seqs, keys))
        keys, seqs, vlens, vals = keys[order], seqs[order], vlens[order], vals[order]
        keep = np.ones(len(keys), dtype=bool)
        keep[1:] = keys[1:] != keys[:-1]
        keys, seqs, vlens, vals = keys[keep], seqs[keep], vlens[keep], vals[keep]
    if drop_tombstones and len(keys):
        live = vlens != TOMBSTONE_LEN
        keys, seqs, vlens, vals = keys[live], seqs[live], vlens[live], vals[live]
    return SortedRun(keys, seqs, vlens, vals, bits_per_key=bits_per_key,
                     block_size=block_size, key_bytes=key_bytes,
                     hash_fn=hash_fn)


def _account_merge_output(out: SortedRun, stats: IOStats) -> SortedRun:
    """Write-side cost model, shared by every merge path (paper §2.2)."""
    stats.blocks_written += out.n_blocks
    stats.entries_compacted += len(out)
    stats.bytes_compacted += out.data_bytes
    stats.compactions += 1
    return out


# A pair merge gallops (searchsorted) only when one side is much smaller;
# balanced pairs fall back to one stable (radix) argsort over the concat,
# which is faster than per-element binary search on balanced inputs.
_GALLOP_RATIO = 8
# Below this many total input entries the fully vectorized path's fixed
# numpy-call overhead exceeds its per-entry win over concat+lexsort.
_VECTOR_MIN_ENTRIES = 8192


def _merge_pair(a, b, seqs_cat: np.ndarray, pair_merge=None):
    """Merge two (keys, gid) nodes of the ladder into one.

    Inputs have strictly increasing keys; the output does too (the newer
    sequence number wins each duplicate).  Nodes carry only the key column
    and a *global index* into the concatenated inputs — sequence numbers are
    gathered from ``seqs_cat`` only at the (few) duplicate positions, so
    each ladder round moves two columns instead of four.

    Backend selection (all three produce identical output):
      * skewed pair — gallop: one ``np.searchsorted`` of the smaller side
        into the larger (each element's output slot is its own index plus
        its rank in the other run), then two scatters; O(small·log(large))
        lookups instead of sorting ``large`` again;
      * balanced pair — one stable argsort of the concatenated keys
        (radix for integer keys, so no comparison sort either);
      * ``pair_merge(keys_a, keys_b) -> (merged_keys, src_idx)`` reroutes
        the interleave through an accelerator
        (``kernels.ops.merge_runs_tiled``: merge-path partition + bitonic
        network), ``src_idx`` uint32 with bit 31 flagging ``b`` entries.

    Entries with equal key AND equal seq resolve arbitrarily between the
    backends (the engine's sequence numbers are unique).
    """
    ka, ga = a
    kb, gb = b
    na, nb = ka.size, kb.size
    if na == 0:
        return b
    if nb == 0:
        return a
    n = na + nb
    if pair_merge is not None:
        keys, sidx = pair_merge(ka, kb)
        keys = np.asarray(keys)
        sidx = np.asarray(sidx)
        from_b = (sidx & np.uint32(1 << 31)) != 0
        r = (sidx & np.uint32(0x7FFFFFFF)).astype(np.int64)
        gid = np.empty(n, dtype=np.int64)
        in_a = ~from_b
        gid[in_a] = ga[r[in_a]]
        gid[from_b] = gb[r[from_b]]
    elif min(na, nb) * _GALLOP_RATIO <= n:
        if na <= nb:
            small_k, small_g, big_k, big_g, side = ka, ga, kb, gb, "left"
        else:
            small_k, small_g, big_k, big_g, side = kb, gb, ka, ga, "right"
        # 'left'/'right' keep equal keys a-first, matching the argsort path
        pos = np.arange(small_k.size, dtype=np.int64) \
            + np.searchsorted(big_k, small_k, side)
        in_big = np.ones(n, dtype=bool)
        in_big[pos] = False
        keys = np.empty(n, dtype=ka.dtype)
        keys[pos] = small_k
        keys[in_big] = big_k     # boolean fill preserves sorted order
        gid = np.empty(n, dtype=np.int64)
        gid[pos] = small_g
        gid[in_big] = big_g
    else:
        keys = np.concatenate([ka, kb])
        order = np.argsort(keys, kind="stable")  # radix; a-first on ties
        keys = keys[order]
        gid = np.concatenate([ga, gb])[order]
    # Dedup: a key occurs at most twice and duplicates are adjacent; the
    # newer seq wins (equal-seq ties keep the first occurrence, matching
    # the scalar path's stable lexsort).
    dup = np.nonzero(keys[1:] == keys[:-1])[0]
    if dup.size == 0:
        return keys, gid
    keep = np.ones(n, dtype=bool)
    second_newer = seqs_cat[gid[dup + 1]] > seqs_cat[gid[dup]]
    keep[np.where(second_newer, dup, dup + 1)] = False
    return keys[keep], gid[keep]


def merge_runs(runs: Sequence[SortedRun], bits_per_key: float,
               stats: IOStats, drop_tombstones: bool = False,
               block_size: int = BLOCK_SIZE, key_bytes: int = KEY_BYTES,
               pair_merge=None, bloom_hash=None) -> SortedRun:
    """K-way compaction merge exploiting input sortedness (DESIGN.md §10).

    A balanced tournament of pairwise merges over (key, global-index)
    columns: each round interleaves sorted pairs with ``np.searchsorted``
    (or the Pallas merge-path lane via ``pair_merge``) and drops shadowed
    duplicates immediately, so seqs/vlens/values are each moved exactly once
    — one gather per column at the end, against the scalar oracle's
    pad + concat + full lexsort + permute + mask of every column.
    Bit-for-bit identical output and IOStats to the retained
    ``merge_runs_scalar`` oracle (differentially tested).

    Cost model: every input block is read, every output block written; the
    entry/byte counters feed write-amplification (paper §2.2).
    """
    if not runs:
        return build_run(np.zeros(0, KEY_DTYPE), np.zeros(0, SEQ_DTYPE),
                         np.zeros(0, np.int32), np.zeros((0, 0), np.uint8),
                         bits_per_key, block_size=block_size,
                         key_bytes=key_bytes, hash_fn=bloom_hash)
    if pair_merge is None and sum(len(r) for r in runs) < _VECTOR_MIN_ENTRIES:
        # tiny merges: the concat+lexsort core has the smaller constant
        # factor (identical output either way); the Pallas lane is never
        # shortcut so the kernel route stays exercised end to end
        return merge_runs_scalar(runs, bits_per_key, stats,
                                 drop_tombstones=drop_tombstones,
                                 block_size=block_size, key_bytes=key_bytes,
                                 bloom_hash=bloom_hash)
    for r in runs:
        stats.blocks_read += r.n_blocks
    lens = [len(r) for r in runs]
    offs = np.zeros(len(runs) + 1, dtype=np.int64)
    np.cumsum(lens, out=offs[1:])
    seqs_cat = runs[0].seqs if len(runs) == 1 else \
        np.concatenate([r.seqs for r in runs])
    # Huffman-ordered tournament: always merge the two smallest nodes, so a
    # dominant run (the usual dst level) joins only the final merges and
    # total element moves stay near the entropy bound.
    heap = [(len(r), i, (r.keys, np.arange(offs[i], offs[i + 1],
                                           dtype=np.int64)))
            for i, r in enumerate(runs)]
    heapq.heapify(heap)
    tick = len(runs)
    while len(heap) > 1:
        _, ia, a = heapq.heappop(heap)
        _, ib, b = heapq.heappop(heap)
        if ib < ia:          # keep earlier-run-first orientation for ties
            a, b = b, a
        merged = _merge_pair(a, b, seqs_cat, pair_merge)
        heapq.heappush(heap, (merged[0].size, tick, merged))
        tick += 1
    keys, gid = heap[0][2]
    vlens_cat = runs[0].vlens if len(runs) == 1 else \
        np.concatenate([r.vlens for r in runs])
    vlens = vlens_cat[gid]
    if drop_tombstones and keys.size:
        live = vlens != TOMBSTONE_LEN
        keys, vlens, gid = keys[live], vlens[live], gid[live]
    seqs = seqs_cat[gid]
    # Winner values move in two bulk passes (concat + one row gather),
    # against the scalar oracle's concat + full permute + keep-mask three;
    # only sources narrower than vmax need padding first.
    vmax = max((r.vals.shape[1] if r.vals.ndim == 2 else 0) for r in runs)
    if vmax == 0:
        vals = np.zeros((keys.size, 0), dtype=np.uint8)
    else:
        mats = []
        for r in runs:
            v = r.vals if r.vals.ndim == 2 else r.vals.reshape(len(r), 0)
            if v.shape[1] < vmax:
                v = np.pad(v, ((0, 0), (0, vmax - v.shape[1])))
            mats.append(v)
        vals_cat = mats[0] if len(mats) == 1 else np.concatenate(mats)
        vals = vals_cat[gid]
    out = SortedRun(keys, seqs, vlens, vals, bits_per_key=bits_per_key,
                    block_size=block_size, key_bytes=key_bytes,
                    hash_fn=bloom_hash)
    return _account_merge_output(out, stats)


def merge_runs_scalar(runs: Sequence[SortedRun], bits_per_key: float,
                      stats: IOStats, drop_tombstones: bool = False,
                      block_size: int = BLOCK_SIZE,
                      key_bytes: int = KEY_BYTES,
                      bloom_hash=None) -> SortedRun:
    """Reference compaction merge (concat + re-lexsort from scratch).

    The pre-vectorization implementation, kept as the differential-test
    oracle and the benchmarks' scalar baseline: it ignores that its inputs
    are already sorted.  Identical output and IOStats to ``merge_runs``.
    """
    if not runs:
        return build_run(np.zeros(0, KEY_DTYPE), np.zeros(0, SEQ_DTYPE),
                         np.zeros(0, np.int32), np.zeros((0, 0), np.uint8),
                         bits_per_key, block_size=block_size,
                         key_bytes=key_bytes, hash_fn=bloom_hash)
    vmax = max((r.vals.shape[1] if r.vals.ndim == 2 else 0) for r in runs)
    ks, ss, ls, vs = [], [], [], []
    for r in runs:
        stats.blocks_read += r.n_blocks
        ks.append(r.keys)
        ss.append(r.seqs)
        ls.append(r.vlens)
        v = r.vals if r.vals.ndim == 2 else r.vals.reshape(len(r), 0)
        if v.shape[1] < vmax:
            v = np.pad(v, ((0, 0), (0, vmax - v.shape[1])))
        vs.append(v)
    out = build_run(np.concatenate(ks), np.concatenate(ss),
                    np.concatenate(ls),
                    np.concatenate(vs) if vmax else np.zeros((sum(map(len, runs)), 0), np.uint8),
                    bits_per_key=bits_per_key, drop_tombstones=drop_tombstones,
                    block_size=block_size, key_bytes=key_bytes,
                    hash_fn=bloom_hash)
    return _account_merge_output(out, stats)
