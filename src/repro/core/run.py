"""Immutable sorted runs (the engine's SST analog).

A run stores its entries as parallel numpy arrays sorted by key:
  keys  : uint64 (strictly increasing — duplicates are resolved at build time,
          newest sequence number wins, matching LSM merge semantics)
  seqs  : uint64 sequence numbers (MVCC ordering across runs)
  vlens : int32 value lengths; TOMBSTONE_LEN marks a delete marker
  vals  : uint8 (n, Vmax) padded value payload

Entries are packed into BLOCK_SIZE blocks; ``block_of`` maps each entry to its
block id and the *fence pointers* (first key per block, kept in host memory —
"main memory" in the paper) let a reader locate the single candidate block of
any key with zero block touches, exactly the paper's fence-pointer model.
"""
from __future__ import annotations

import itertools
from typing import Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .bloom import BloomFilter
from .types import (BLOCK_SIZE, KEY_BYTES, KEY_DTYPE, SEQ_DTYPE,
                    TOMBSTONE_LEN, IOStats)

_run_ids = itertools.count()


class SortedRun:
    __slots__ = ("run_id", "keys", "seqs", "vlens", "vals", "block_of",
                 "fence_keys", "n_blocks", "data_bytes", "block_size",
                 "bloom", "level_hint")

    def __init__(self, keys: np.ndarray, seqs: np.ndarray, vlens: np.ndarray,
                 vals: np.ndarray, bits_per_key: float = 0.0,
                 block_size: int = BLOCK_SIZE, key_bytes: int = KEY_BYTES):
        assert keys.ndim == 1
        self.block_size = block_size
        self.run_id = next(_run_ids)
        self.keys = np.ascontiguousarray(keys, dtype=KEY_DTYPE)
        self.seqs = np.ascontiguousarray(seqs, dtype=SEQ_DTYPE)
        self.vlens = np.ascontiguousarray(vlens, dtype=np.int32)
        self.vals = np.ascontiguousarray(vals, dtype=np.uint8)
        n = self.keys.size
        entry_sizes = key_bytes + np.maximum(self.vlens, 0).astype(np.int64)
        cum = np.cumsum(entry_sizes)
        self.data_bytes = int(cum[-1]) if n else 0
        # Entry i lives in the block containing its *starting* byte.
        starts = cum - entry_sizes
        self.block_of = (starts // block_size).astype(np.int64)
        self.n_blocks = int(self.block_of[-1]) + 1 if n else 0
        # Fence pointer = first key of each block (in-memory index).
        if n:
            first_idx = np.searchsorted(self.block_of,
                                        np.arange(self.n_blocks), side="left")
            self.fence_keys = self.keys[first_idx]
        else:
            self.fence_keys = np.zeros(0, dtype=KEY_DTYPE)
        self.bloom = BloomFilter(self.keys, bits_per_key)
        self.level_hint = -1  # set by the manifest; informational

    # ------------------------------------------------------------------ size
    def __len__(self) -> int:
        return int(self.keys.size)

    @property
    def min_key(self) -> int:
        return int(self.keys[0]) if len(self) else 0

    @property
    def max_key(self) -> int:
        return int(self.keys[-1]) if len(self) else 0

    def block_bytes(self, block_id: int) -> int:
        """Physical bytes stored in one block (the last block may be short)."""
        if block_id < 0 or block_id >= self.n_blocks:
            return 0
        if block_id == self.n_blocks - 1:
            return self.data_bytes - block_id * self.block_size
        return self.block_size

    def _charge_block(self, block_id: int, stats: IOStats, cache) -> None:
        """One block touch: through the cache when present, else raw I/O."""
        if cache is None:
            stats.blocks_read += 1
        else:
            cache.read_block(self.run_id, int(block_id),
                             self.block_bytes(int(block_id)), stats)

    # ----------------------------------------------------------------- reads
    def point_get(self, key: int, stats: IOStats,
                  use_bloom: bool = True,
                  cache=None) -> Tuple[bool, Optional[bytes], int]:
        """Returns (found, value_or_None_if_tombstone, seq).

        Cost model: one bloom probe (CPU), then one block read iff the bloom
        says maybe (fence pointers locate the block for free; the read goes
        through ``cache`` when one is attached — hits charge no block I/O).
        """
        k = np.uint64(key)
        if use_bloom and self.bloom.k > 0:
            stats.bloom_probes += 1
            if not bool(self.bloom.may_contain(np.asarray([k]))[0]):
                stats.bloom_negatives += 1
                return False, None, -1
        if len(self) == 0:
            return False, None, -1  # no blocks to read
        i = int(np.searchsorted(self.keys, k))
        # fence pointers give the unique candidate block
        self._charge_block(self.block_of[min(i, len(self) - 1)], stats, cache)
        if i < len(self) and self.keys[i] == k:
            vlen = int(self.vlens[i])
            if vlen == TOMBSTONE_LEN:
                return True, None, int(self.seqs[i])
            return True, bytes(self.vals[i, :vlen]), int(self.seqs[i])
        stats.false_positives += 1
        return False, None, -1

    def point_get_batch(self, keys: np.ndarray, stats: IOStats,
                        use_bloom: bool = True, probe_fn=None, cache=None
                        ) -> Tuple[np.ndarray, List[Optional[bytes]]]:
        """Vectorized ``point_get`` over a batch of keys.

        Returns ``(found, values)``: found[i] True means key i's newest
        version lives in this run (values[i] is its bytes, or None for a
        tombstone).  One bloom pass + one searchsorted over the whole batch;
        aggregate IOStats accounting is identical to len(keys) scalar
        ``point_get`` calls.  ``probe_fn(bloom, keys) -> bool mask`` optionally
        reroutes the filter probe (e.g. through the Pallas kernel); ``cache``
        routes the candidate block reads through the block cache, in batch
        order (so two candidates sharing a block cost one miss + one hit).
        """
        keys = np.ascontiguousarray(keys, dtype=KEY_DTYPE)
        n = keys.size
        found = np.zeros(n, dtype=bool)
        values: List[Optional[bytes]] = [None] * n
        if len(self) == 0:
            return found, values  # no blocks to read
        if use_bloom and self.bloom.k > 0:
            stats.bloom_probes += n
            if probe_fn is not None:
                maybe = np.asarray(probe_fn(self.bloom, keys), dtype=bool)
            else:
                maybe = self.bloom.may_contain(keys)
            stats.bloom_negatives += int(n - np.count_nonzero(maybe))
            cand = np.nonzero(maybe)[0]
        else:
            cand = np.arange(n)
        if cand.size == 0:
            return found, values
        # Fence pointers give each candidate its unique block: 1 read apiece.
        idx = np.searchsorted(self.keys, keys[cand])
        if cache is None:
            stats.blocks_read += int(cand.size)
        else:
            for bid in self.block_of[np.minimum(idx, len(self) - 1)]:
                self._charge_block(bid, stats, cache)
        inb = idx < len(self)
        hit = np.zeros(cand.size, dtype=bool)
        hit[inb] = self.keys[idx[inb]] == keys[cand][inb]
        stats.false_positives += int(cand.size - np.count_nonzero(hit))
        for p in np.nonzero(hit)[0]:
            i = int(idx[p])
            j = int(cand[p])
            found[j] = True
            vlen = int(self.vlens[i])
            if vlen != TOMBSTONE_LEN:
                values[j] = bytes(self.vals[i, :vlen])
        return found, values

    def seek_idx(self, key: int) -> int:
        return int(np.searchsorted(self.keys, np.uint64(key), side="left"))

    def slice_from(self, start_idx: int, count: int):
        """Entries [start_idx, start_idx+count) as (keys, seqs, vlens, vals)."""
        e = min(start_idx + count, len(self))
        return (self.keys[start_idx:e], self.seqs[start_idx:e],
                self.vlens[start_idx:e], self.vals[start_idx:e])

    def blocks_spanned(self, start_idx: int, end_idx: int) -> int:
        """Number of blocks touched to read entries [start_idx, end_idx)."""
        if end_idx <= start_idx or start_idx >= len(self):
            return 0
        end_idx = min(end_idx, len(self))
        return int(self.block_of[end_idx - 1] - self.block_of[start_idx]) + 1


# --------------------------------------------------------------------- build
def build_run(keys: np.ndarray, seqs: np.ndarray, vlens: np.ndarray,
              vals: np.ndarray, bits_per_key: float = 0.0,
              assume_unique_sorted: bool = False,
              drop_tombstones: bool = False) -> SortedRun:
    """Sort by key, deduplicate keeping the newest seq, optionally GC deletes."""
    keys = np.asarray(keys, dtype=KEY_DTYPE)
    seqs = np.asarray(seqs, dtype=SEQ_DTYPE)
    vlens = np.asarray(vlens, dtype=np.int32)
    vals = np.asarray(vals, dtype=np.uint8)
    if vals.ndim == 1:
        vals = vals.reshape(len(keys), -1) if len(keys) else vals.reshape(0, 0)
    if not assume_unique_sorted and len(keys):
        # Stable sort by (key, -seq): newest version of each key comes first.
        order = np.lexsort((np.iinfo(np.uint64).max - seqs, keys))
        keys, seqs, vlens, vals = keys[order], seqs[order], vlens[order], vals[order]
        keep = np.ones(len(keys), dtype=bool)
        keep[1:] = keys[1:] != keys[:-1]
        keys, seqs, vlens, vals = keys[keep], seqs[keep], vlens[keep], vals[keep]
    if drop_tombstones and len(keys):
        live = vlens != TOMBSTONE_LEN
        keys, seqs, vlens, vals = keys[live], seqs[live], vlens[live], vals[live]
    return SortedRun(keys, seqs, vlens, vals, bits_per_key=bits_per_key)


def merge_runs(runs: Sequence[SortedRun], bits_per_key: float,
               stats: IOStats, drop_tombstones: bool = False) -> SortedRun:
    """K-way sort-merge (compaction). Newest seq wins on duplicate keys.

    Cost model: every input block is read, every output block written; the
    entry/byte counters feed write-amplification (paper §2.2).
    """
    if not runs:
        return build_run(np.zeros(0, KEY_DTYPE), np.zeros(0, SEQ_DTYPE),
                         np.zeros(0, np.int32), np.zeros((0, 0), np.uint8),
                         bits_per_key)
    vmax = max((r.vals.shape[1] if r.vals.ndim == 2 else 0) for r in runs)
    ks, ss, ls, vs = [], [], [], []
    for r in runs:
        stats.blocks_read += r.n_blocks
        ks.append(r.keys)
        ss.append(r.seqs)
        ls.append(r.vlens)
        v = r.vals if r.vals.ndim == 2 else r.vals.reshape(len(r), 0)
        if v.shape[1] < vmax:
            v = np.pad(v, ((0, 0), (0, vmax - v.shape[1])))
        vs.append(v)
    out = build_run(np.concatenate(ks), np.concatenate(ss),
                    np.concatenate(ls),
                    np.concatenate(vs) if vmax else np.zeros((sum(map(len, runs)), 0), np.uint8),
                    bits_per_key=bits_per_key, drop_tombstones=drop_tombstones)
    stats.blocks_written += out.n_blocks
    stats.entries_compacted += len(out)
    stats.bytes_compacted += out.data_bytes
    stats.compactions += 1
    return out
