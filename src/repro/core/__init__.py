"""Autumn LSM core: the paper's contribution (Garnering merge policy) plus the
baseline policies it is compared against, in a block-I/O-accounted engine.

Public API:
    LSMStore, LSMConfig           — the storage engine
    make_policy, Garnering, ...   — merge policies (paper §2.3/§3.1)
    BloomFilter, allocate_fprs    — Monkey/Autumn filter allocation (Eq. 7-10)
    BlockCache, PinnedLevelManager— memory subsystem: block cache + DRAM L0
    IOStats, StatsHub             — block-I/O cost accounting (lossless
                                    per-thread accumulation)
    Telemetry, LatencyHistogram,
    EventTrace                    — latency histograms + event trace (§14)
    FaultInjector, crc32c, ...    — fault injection + end-to-end checksums
                                    (§16): CorruptionError / InjectedFault /
                                    StoreDegradedError typed failures
    OnlineTuner, KNOB_BOUNDS,
    tuning_objective              — online workload-adaptive tuning (§17)
"""
from .bloom import (BloomFilter, allocate_fprs, bits_for_fpr,
                    garnering_theoretical_fprs, theoretical_fpr,
                    zero_result_read_cost)
from .cache import BlockCache, BlockCacheView, PinnedLevelManager
from .engine import LSMConfig, LSMStore
from .faults import (FAULT_SITES, CorruptionError, FaultInjector,
                     InjectedFault, StoreDegradedError, crc32c, crc32c_rows)
from .iterator import MergingIterator
from .manifest import Manifest, RunStorage, Version
from .memtable import ImmutableMemtable, Memtable, WriteAheadLog
from .policy import (POLICIES, CompactionTask, Garnering, LazyLeveling,
                     Leveling, MergePolicy, QLSMBush, Tiering, make_policy)
from .run import SortedRun, build_run, merge_runs, merge_runs_scalar
from .scheduler import CompactionScheduler
from .sharded import (ShardedLSMStore, ShardedSnapshot, make_store,
                      uniform_splitters)
from .telemetry import (EventTrace, LatencyHistogram, Telemetry,
                        TelemetrySnapshot, TelemetryWindow, TraceEvent)
from .tuner import (KNOB_BOUNDS, FOREGROUND_OPS, OnlineTuner, TunerStep,
                    tuning_objective)
from .types import BLOCK_SIZE, KEY_BYTES, IOStats, StatsHub
from .view import RangeView, build_range_view

__all__ = [
    "LSMStore", "LSMConfig", "IOStats", "BlockCache", "BlockCacheView",
    "PinnedLevelManager",
    "ShardedLSMStore", "ShardedSnapshot", "make_store", "uniform_splitters",
    "BloomFilter", "allocate_fprs",
    "bits_for_fpr", "theoretical_fpr", "garnering_theoretical_fprs",
    "zero_result_read_cost", "MergingIterator", "Manifest", "RunStorage",
    "Version", "Memtable", "ImmutableMemtable", "CompactionScheduler",
    "WriteAheadLog", "POLICIES", "CompactionTask", "Garnering", "LazyLeveling",
    "Leveling", "MergePolicy", "QLSMBush", "Tiering", "make_policy",
    "SortedRun", "build_run", "merge_runs", "merge_runs_scalar",
    "RangeView", "build_range_view",
    "Telemetry", "LatencyHistogram", "EventTrace", "TraceEvent", "StatsHub",
    "TelemetrySnapshot", "TelemetryWindow",
    "OnlineTuner", "TunerStep", "KNOB_BOUNDS", "FOREGROUND_OPS",
    "tuning_objective",
    "FAULT_SITES", "FaultInjector", "InjectedFault", "CorruptionError",
    "StoreDegradedError", "crc32c", "crc32c_rows",
    "BLOCK_SIZE", "KEY_BYTES",
]
