"""Merge policies: Leveling, Tiering, Lazy-Leveling, QLSM-Bush, and Garnering.

A policy answers two questions given the current tree state:
  * ``capacity(i, L, B)`` — byte capacity of level i (1-indexed; level 0 is
    the tiered flush level, capped by run count not bytes).
  * ``plan(...)`` — the next compaction task, or None when the tree is shaped.

Garnering (the paper's contribution, §3.1) implements:
  Eq. 4   C_i / C_{i-1} = T / c^{L-i}
  Eq. 5   C_i = B * T^i / c^{(2L-1-i) i / 2}
  Delayed last-level compaction — when level L overflows, grow L instead of
  compacting (every capacity grows with L, so the overflow resolves itself),
  counting ``delayed_last_level_compactions``.
  L0 tiering (§3.2) — level 0 holds a constant number of runs and flush never
  merges; this is shared by all policies here, as in RocksDB/LevelDB.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class CompactionTask:
    src_level: int
    dst_level: int
    include_dst: bool  # True => sort-merge with dst runs (leveled landing)
    reason: str
    # Input freshness guard for decoupled generation/apply (async scheduler):
    # the planner captures the source level's run ids at plan time; apply
    # refuses a task whose inputs no longer match the tree (the scheduler
    # then replans against current state).  None (the policies' own tasks)
    # means "apply against whatever is there now" — the synchronous
    # plan-then-apply loop never goes stale.
    src_run_ids: Optional[Tuple[int, ...]] = None

    def matches(self, src_runs: Sequence) -> bool:
        """True iff the task's captured inputs are still the level's runs."""
        if self.src_run_ids is None:
            return True
        return tuple(r.run_id for r in src_runs) == self.src_run_ids


LevelSizes = Sequence[Sequence[int]]  # [level][run] -> bytes


def _level_bytes(levels: LevelSizes, i: int) -> int:
    return sum(levels[i]) if i < len(levels) else 0


def _run_count(levels: LevelSizes, i: int) -> int:
    return len(levels[i]) if i < len(levels) else 0


class MergePolicy:
    name = "base"

    def __init__(self, T: float = 2.0, c: float = 1.0, l0_trigger: int = 4):
        assert T > 1, "size ratio T must exceed 1"
        assert 0 < c <= 1.0, "Garnering scaling factor c must be in (0, 1]"
        self.T = float(T)
        self.c = float(c)
        self.l0_trigger = int(l0_trigger)

    def retuned(self, *, T: Optional[float] = None,
                c: Optional[float] = None) -> "MergePolicy":
        """A fresh policy of the same family with adjusted knobs — the
        online tuner's level-ratio actuator (DESIGN.md §17).  The caller
        swaps it in at a compaction-chain boundary; only *future* ``plan``
        calls see the new capacities, so the installed tree is never
        rewritten (Garnering's capacities are pure functions of (i, L, B),
        no state carries over)."""
        return type(self)(T=self.T if T is None else T,
                          c=self.c if c is None else c,
                          l0_trigger=self.l0_trigger)

    # -- shape -----------------------------------------------------------
    def capacity(self, i: int, L: int, B: int) -> float:
        raise NotImplementedError

    def runs_allowed(self, i: int, L: int) -> int:
        return 1

    # -- planning --------------------------------------------------------
    def plan(self, levels: LevelSizes, L: int, B: int
             ) -> Tuple[int, Optional[CompactionTask], int]:
        """Returns (new_L, task_or_None, delayed_compactions_added)."""
        raise NotImplementedError

    # shared L0 handling: flush-only level, run-count trigger
    def _l0_task(self, levels: LevelSizes) -> Optional[CompactionTask]:
        if _run_count(levels, 0) >= self.l0_trigger:
            return CompactionTask(0, 1, True, "l0-run-count")
        return None


class Leveling(MergePolicy):
    """Classic leveled LSM: C_i = B * T^i, one run per level (§2.3.1)."""

    name = "leveling"

    def capacity(self, i: int, L: int, B: int) -> float:
        return B * self.T ** i

    def plan(self, levels, L, B):
        L = max(L, _deepest(levels))
        t = self._l0_task(levels)
        if t:
            return L, t, 0
        for i in range(1, len(levels)):
            if _level_bytes(levels, i) > self.capacity(i, L, B):
                return max(L, i + 1), CompactionTask(i, i + 1, True, "over-capacity"), 0
        return L, None, 0


class Tiering(MergePolicy):
    """Tiered LSM: level i holds up to T runs of size ~B*T^(i-1) (§2.3.1)."""

    name = "tiering"

    def capacity(self, i: int, L: int, B: int) -> float:
        return B * self.T ** i

    def runs_allowed(self, i: int, L: int) -> int:
        return max(2, int(math.ceil(self.T)))

    def plan(self, levels, L, B):
        L = max(L, _deepest(levels))
        if _run_count(levels, 0) >= self.l0_trigger:
            return L, CompactionTask(0, 1, False, "l0-run-count"), 0
        for i in range(1, len(levels)):
            if _run_count(levels, i) >= self.runs_allowed(i, L):
                return max(L, i + 1), CompactionTask(i, i + 1, False, "run-count"), 0
        return L, None, 0


class LazyLeveling(MergePolicy):
    """Dostoevsky's lazy leveling: tiered at levels 1..L-1, leveled last."""

    name = "lazy-leveling"

    def capacity(self, i: int, L: int, B: int) -> float:
        return B * self.T ** i

    def runs_allowed(self, i: int, L: int) -> int:
        return 1 if i >= L else max(2, int(math.ceil(self.T)))

    def plan(self, levels, L, B):
        L = max(L, _deepest(levels), 1)
        t = self._l0_task(levels)
        if t and L == 1:
            return L, CompactionTask(0, 1, True, "l0-run-count"), 0
        if _run_count(levels, 0) >= self.l0_trigger:
            return L, CompactionTask(0, 1, False, "l0-run-count"), 0
        for i in range(1, len(levels)):
            if i < L and _run_count(levels, i) >= self.runs_allowed(i, L):
                grow = i + 1 > L
                return max(L, i + 1), CompactionTask(i, i + 1, i + 1 >= L and not grow,
                                                     "run-count"), 0
            if i == L and _level_bytes(levels, i) > self.capacity(i, L, B):
                return L + 1, CompactionTask(i, i + 1, True, "last-over-capacity"), 0
        return L, None, 0


class QLSMBush(MergePolicy):
    """LSM-Bush approximation: doubly-exponential gaps, C_i = B*T^(2^i - 1).

    Level i (i < L) holds up to C_i/C_{i-1} = T^(2^(i-1)) runs; the last level
    is one run.  Used only as a Table-2/Fig-1 baseline (DESIGN.md §1).
    """

    name = "qlsm-bush"

    def capacity(self, i: int, L: int, B: int) -> float:
        return B * self.T ** (2 ** i - 1)

    def runs_allowed(self, i: int, L: int) -> int:
        if i >= L:
            return 1
        return max(2, int(math.ceil(self.T ** (2 ** (i - 1)))))

    def plan(self, levels, L, B):
        L = max(L, _deepest(levels), 1)
        if _run_count(levels, 0) >= self.l0_trigger:
            return L, CompactionTask(0, 1, L == 1, "l0-run-count"), 0
        for i in range(1, len(levels)):
            if i < L and _run_count(levels, i) >= self.runs_allowed(i, L):
                return max(L, i + 1), CompactionTask(i, i + 1, False, "run-count"), 0
            if i == L and _level_bytes(levels, i) > self.capacity(i, L, B):
                return L + 1, CompactionTask(i, i + 1, True, "last-over-capacity"), 0
        return L, None, 0


class Garnering(MergePolicy):
    """The paper's policy (§3.1). One run per level; capacities from Eq. 5
    grow with the total level count L; last-level compactions are delayed by
    growing L instead."""

    name = "garnering"

    def capacity(self, i: int, L: int, B: int) -> float:
        # Eq. 5: C_i = T^i / c^((2L-1-i) i / 2) * B.  With c = 1 this is
        # exactly Leveling, as the paper notes (§4.1).
        expo = (2 * L - 1 - i) * i / 2.0
        return B * (self.T ** i) / (self.c ** expo)

    def predicted_levels(self, N: int, B: int) -> float:
        """Eq. 6: L = O(sqrt(-log_c(N/(B*T))))."""
        x = max(N / (B * self.T), 1.000001)
        if self.c >= 1.0:
            return math.log(x) / math.log(self.T) + 1
        return math.sqrt(math.log(x) / math.log(1.0 / self.c))

    def plan(self, levels, L, B):
        L = max(L, _deepest(levels), 1)
        delayed = 0
        # Delayed last-level compaction: grow L until the last level fits.
        while _level_bytes(levels, L) > self.capacity(L, L, B):
            L += 1
            delayed += 1
        t = self._l0_task(levels)
        if t:
            return L, t, delayed
        # Lower levels first — Garnering inherently concentrates merges there.
        for i in range(1, min(len(levels), L)):
            if _level_bytes(levels, i) > self.capacity(i, L, B):
                return L, CompactionTask(i, i + 1, True, "over-capacity"), delayed
        return L, None, delayed


POLICIES = {p.name: p for p in (Leveling, Tiering, LazyLeveling, QLSMBush, Garnering)}


def make_policy(name: str, T: float = 2.0, c: float = 1.0,
                l0_trigger: int = 4) -> MergePolicy:
    if name not in POLICIES:
        raise ValueError(f"unknown policy {name!r}; options: {sorted(POLICIES)}")
    return POLICIES[name](T=T, c=c, l0_trigger=l0_trigger)


def _deepest(levels: LevelSizes) -> int:
    deepest = 0
    for i in range(len(levels)):
        if levels[i]:
            deepest = i
    return deepest
