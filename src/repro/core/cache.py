"""Block cache + DRAM-pinned L0: the engine's memory-management subsystem.

The paper's second headline idea (beyond the Garnering merge policy) is that a
*small bounded amount of DRAM* can absorb most of the read cost of the upper
tree: the first level is kept memory-resident, and a shared block cache serves
the hot tail of the deeper levels (PAPER.md, "bounded space of DRAM").  This
module is that subsystem:

``BlockCache``
    A charged-bytes cache of ``(run_id, block_id)`` entries with two eviction
    policies — ``"lru"`` (exact recency order) and ``"clock"`` (second-chance:
    a hit sets a reference bit; the eviction hand clears bits until it finds a
    cold entry, approximating LRU at O(1) per touch).  Every block read in the
    engine flows through :meth:`read_block`, which either records a hit
    (``IOStats.cache_hit_blocks``; no block I/O charged) or a miss
    (``IOStats.cache_miss_blocks`` + ``blocks_read``) and admits the block.

``PinnedLevelManager``
    Keeps level-0 runs *resident*: after every flush/compaction commit it
    re-derives the pin set from the current L0, newest run first, admitting
    whole runs while they fit in ``pin_l0_bytes``.  Pinned blocks live outside
    the eviction order (they can never be evicted by capacity pressure) and
    are charged to the pin budget, not ``cache_bytes`` — total DRAM use is
    bounded by ``cache_bytes + pin_l0_bytes``.  Pinning on the flush path
    charges no read I/O (a freshly flushed run is already in memory; its
    write cost is counted by ``blocks_written`` at flush), but repinning on
    recovery or on a mid-life cache attach charges a miss + block read per
    block — those loads are real device reads.

Invalidation protocol (DESIGN.md §9): cached blocks are keyed by immutable run
id, so a run's cached blocks can never go stale — compaction *replaces* runs
rather than mutating them.  After each manifest commit the engine calls
:meth:`BlockCache.retain` with the ids still live in ``RunStorage`` (current
version + snapshot-pinned versions), dropping blocks of dead runs, then
``PinnedLevelManager.repin`` with the new L0.  A run that leaves L0 loses its
pinned status but may re-enter the cache on demand like any other run.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .types import IOStats

# (run_id, block_id).  In sharded use the run-id slot is a *namespaced*
# composite ``(shard_id, raw_run_id)`` tuple minted by BlockCacheView, so two
# shards can never alias each other's blocks and namespace-scoped
# retain/set_pinned/clear can select a shard's entries by key alone.
CacheKey = Tuple[int, int]


def _ns_of(key: CacheKey):
    """Namespace of a cache key: ``None`` for plain (unsharded) run ids."""
    rid = key[0]
    return rid[0] if isinstance(rid, tuple) else None


class BlockCache:
    """Charged-bytes block cache with LRU or CLOCK (second-chance) eviction.

    Thread-safety: one reentrant mutex guards the eviction order, the pinned
    set, and the byte/hit counters, so reader threads admitting blocks race
    safely with the async scheduler's post-install :meth:`retain`/
    :meth:`set_pinned` calls (batched reads take the lock once per batch,
    not per block).
    """

    def __init__(self, capacity_bytes: int, policy: str = "clock"):
        if policy not in ("lru", "clock"):
            raise ValueError(f"unknown cache policy {policy!r}")
        self.capacity_bytes = int(capacity_bytes)
        self.policy = policy
        self._mu = threading.RLock()
        # Eviction order: front = next eviction candidate. CLOCK entries carry
        # a reference bit; the "hand" is the front of the same ordered dict
        # (a second chance moves the entry to the back with its bit cleared).
        self._entries: "OrderedDict[CacheKey, List[int]]" = OrderedDict()
        self._pinned: Dict[CacheKey, int] = {}  # key -> nbytes (L0 residency)
        self._bytes = 0          # charged bytes, evictable entries only
        self._pinned_bytes = 0   # charged bytes, pinned entries
        # Sharded use (DESIGN.md §12): per-namespace charged-byte budgets.
        # With no budgets registered the cache behaves exactly as before
        # (one global budget, one eviction domain).  ``_ns_keys`` mirrors
        # ``_entries``'s order per namespace so namespace-scoped eviction
        # stays O(1) amortized instead of rescanning the shared dict.
        self._ns_budget: Dict = {}
        self._ns_bytes: Dict = {}
        self._ns_keys: Dict = {}   # ns -> OrderedDict[key, None], hand order
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        # Optional Telemetry facade (DESIGN.md §14): every 512th eviction
        # emits a "cache_pressure" trace event so sustained churn shows up
        # in the timeline without per-eviction cost.  The trace mutex is a
        # leaf lock, safe to take under this cache's mutex.
        self.telemetry = None

    # -------------------------------------------------------------- accounting
    @property
    def charged_bytes(self) -> int:
        return self._bytes

    @property
    def pinned_bytes(self) -> int:
        return self._pinned_bytes

    def __len__(self) -> int:
        return len(self._entries) + len(self._pinned)

    def __contains__(self, key: CacheKey) -> bool:
        return key in self._pinned or key in self._entries

    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    # ------------------------------------------------------------------- reads
    def read_block(self, run_id: int, block_id: int, nbytes: int,
                   stats: IOStats) -> bool:
        """Account one block read through the cache.

        Returns True on a hit (no block I/O charged).  On a miss the block is
        charged to ``stats.blocks_read`` — the same charge the uncached path
        makes — and admitted, evicting cold entries to stay within
        ``capacity_bytes``.
        """
        with self._mu:
            key = (run_id, block_id)
            if key in self._pinned:
                self.hits += 1
                stats.cache_hit_blocks += 1
                return True
            e = self._entries.get(key)
            if e is not None:
                self.hits += 1
                stats.cache_hit_blocks += 1
                if self.policy == "lru":
                    self._entries.move_to_end(key)
                else:
                    e[1] = 1  # clock reference bit
                return True
            self.misses += 1
            stats.cache_miss_blocks += 1
            stats.blocks_read += 1
            self._admit(key, nbytes)
            return False

    def read_blocks(self, run_id: int, block_ids, block_bytes,
                    stats: IOStats) -> int:
        """Charge a batch of block reads in one call (the vectorized lane).

        Semantically identical to calling :meth:`read_block` once per id in
        order — same hit/miss decisions and the same admission/eviction
        sequence — but the per-block Python call and counter traffic is
        amortized over the batch, and block payload sizes are resolved
        lazily (``block_bytes(bid)``, typically ``SortedRun.block_bytes``)
        only on a miss.  Returns the number of hits.
        """
        with self._mu:
            pinned = self._pinned
            entries = self._entries
            lru = self.policy == "lru"
            move = entries.move_to_end
            get = entries.get
            hits = misses = 0
            for bid in block_ids:
                key = (run_id, bid)
                if key in pinned:
                    hits += 1
                    continue
                e = get(key)
                if e is not None:
                    hits += 1
                    if lru:
                        move(key)
                    else:
                        e[1] = 1
                    continue
                misses += 1
                self._admit(key, block_bytes(bid))
            self.hits += hits
            self.misses += misses
            stats.cache_hit_blocks += hits
            stats.cache_miss_blocks += misses
            stats.blocks_read += misses
            return hits

    def read_block_span(self, run_id: int, first_block: int, last_block: int,
                        block_bytes, stats: IOStats) -> int:
        """Charge the contiguous block span [first_block, last_block].

        ``MergingIterator`` cursor advances consume runs of consecutive
        blocks; this charges the whole span in one call instead of a
        per-block Python loop (``point_get_batch`` uses :meth:`read_blocks`
        for its scattered candidates; ``PinnedLevelManager.repin`` keeps
        its own one-pass residency count, since pinned loads must not admit
        into the evictable order).  Returns hit count.
        """
        if last_block < first_block:
            return 0
        return self.read_blocks(run_id, range(first_block, last_block + 1),
                                block_bytes, stats)

    # -------------------------------------------------------------- admission
    def _admit(self, key: CacheKey, nbytes: int) -> None:
        nbytes = int(nbytes)
        ns = _ns_of(key) if self._ns_budget else None
        budget = self._ns_budget.get(ns, self.capacity_bytes)
        if nbytes <= 0 or nbytes > budget:
            return  # uncacheable (oversized block, or cache disabled)
        if ns is not None:
            # Namespace budget first: one shard's pressure evicts only its
            # own cold entries, never a sibling's working set.
            while (self._ns_bytes.get(ns, 0) + nbytes > budget
                   and self._evict_one_ns(ns)):
                pass
            if self._ns_bytes.get(ns, 0) + nbytes > budget:
                return  # nothing evictable left in this namespace
        # Global backstop (the only loop in unsharded use, where it is the
        # exact pre-namespace behavior).
        while self._bytes + nbytes > self.capacity_bytes and self._entries:
            self._evict_one()
        self._entries[key] = [nbytes, 0]
        self._bytes += nbytes
        if ns is not None:
            self._ns_bytes[ns] = self._ns_bytes.get(ns, 0) + nbytes
            self._ns_keys.setdefault(ns, OrderedDict())[key] = None

    def _drop_entry(self, key: CacheKey) -> None:
        nb = self._entries.pop(key)[0]
        self._bytes -= nb
        ns = _ns_of(key)
        if ns is not None:
            if ns in self._ns_bytes:
                self._ns_bytes[ns] -= nb
            nsk = self._ns_keys.get(ns)
            if nsk is not None:
                nsk.pop(key, None)
        self.evictions += 1
        tel = self.telemetry
        if tel is not None and self.evictions % 512 == 0:
            tel.emit("cache_pressure", evictions=self.evictions,
                     charged_bytes=self._bytes,
                     capacity_bytes=self.capacity_bytes)

    def _evict_one(self) -> None:
        if self.policy == "lru":
            key = next(iter(self._entries))
            self._drop_entry(key)
            return
        # CLOCK: sweep from the hand, granting second chances to hot entries.
        while True:
            key, e = next(iter(self._entries.items()))
            if e[1]:
                e[1] = 0
                self._entries.move_to_end(key)
            else:
                self._drop_entry(key)
                return

    def _evict_one_ns(self, ns) -> bool:
        """Evict one cold entry belonging to ``ns`` (same policy semantics,
        eviction domain scoped to the namespace; other namespaces' entries
        are never touched or reordered).  Walks the namespace's own ordered
        index (``_ns_keys``), so the cost is O(1) amortized — one shard's
        churn never rescans the siblings' entries under the shared mutex.
        Returns False when the namespace holds nothing evictable."""
        nsk = self._ns_keys.get(ns)
        if not nsk:
            return False
        if self.policy == "lru":
            self._drop_entry(next(iter(nsk)))
            return True
        # CLOCK within the namespace: grant second chances in hand order;
        # each hot entry is cleared and moved to the back of BOTH orders,
        # so if every entry was hot the hand wraps to the (now cold)
        # oldest entry and evicts it — one full sweep, amortized O(1).
        for _ in range(len(nsk)):
            key = next(iter(nsk))
            e = self._entries[key]
            if e[1]:
                e[1] = 0
                self._entries.move_to_end(key)
                nsk.move_to_end(key)
            else:
                self._drop_entry(key)
                return True
        self._drop_entry(next(iter(nsk)))
        return True

    # --------------------------------------------------------------- resizing
    def resize(self, capacity_bytes: int) -> None:
        """Gentle budget change (the online tuner's cache↔pin actuator,
        DESIGN.md §17): set the new capacity and evict down to it.  Unlike
        ``configure_cache``'s rebuild, surviving entries keep serving hits —
        a shrink sheds only the coldest bytes, a grow is free."""
        with self._mu:
            self.capacity_bytes = int(capacity_bytes)
            while self._bytes > self.capacity_bytes and self._entries:
                self._evict_one()

    # ------------------------------------------------------------- pin control
    def set_pinned(self, blocks: Dict[CacheKey, int]) -> None:
        """Replace the pinned set (the DRAM-resident L0) wholesale.

        Newly pinned blocks are removed from the evictable order (their bytes
        move from the cache budget to the pin budget); blocks leaving the set
        simply lose residency and re-enter the cache on demand.
        """
        with self._mu:
            self._pinned = dict(blocks)
            self._pinned_bytes = sum(self._pinned.values())
            for key in self._pinned:
                self._unadmit(key)

    def _unadmit(self, key: CacheKey) -> None:
        """Remove an evictable entry (not an eviction: no counter charge)."""
        e = self._entries.pop(key, None)
        if e is not None:
            self._bytes -= e[0]
            ns = _ns_of(key)
            if ns is not None:
                if ns in self._ns_bytes:
                    self._ns_bytes[ns] -= e[0]
                nsk = self._ns_keys.get(ns)
                if nsk is not None:
                    nsk.pop(key, None)

    # ------------------------------------------------------------- namespaces
    def set_ns_budget(self, ns, budget_bytes: int) -> None:
        """Register a per-namespace charged-byte budget (sharded use: one
        namespace per shard, budgets summing to ``capacity_bytes``)."""
        self._ns_budget[ns] = int(budget_bytes)

    def ns_charged_bytes(self, ns) -> int:
        with self._mu:
            return self._ns_bytes.get(ns, 0)

    def ns_pinned_bytes(self, ns) -> int:
        with self._mu:
            return sum(nb for k, nb in self._pinned.items()
                       if _ns_of(k) == ns)

    def set_pinned_ns(self, ns, blocks: Dict[CacheKey, int]) -> None:
        """Namespace-scoped :meth:`set_pinned`: replace only the pinned set
        belonging to ``ns``; other namespaces' pinned blocks are untouched
        (a shard's L0 repin must never wipe a sibling's resident L0)."""
        with self._mu:
            kept = {k: nb for k, nb in self._pinned.items()
                    if _ns_of(k) != ns}
            kept.update(blocks)
            self._pinned = kept
            self._pinned_bytes = sum(kept.values())
            for key in blocks:
                self._unadmit(key)

    # ------------------------------------------------------------ invalidation
    def retain(self, live_run_ids: Iterable[int]) -> None:
        """Drop every cached block belonging to a run that no longer exists."""
        with self._mu:
            live = set(live_run_ids)
            dead = [k for k in self._entries if k[0] not in live]
            for k in dead:
                self._unadmit(k)
            dead_p = [k for k in self._pinned if k[0] not in live]
            for k in dead_p:
                self._pinned_bytes -= self._pinned.pop(k)

    def retain_ns(self, ns, live_raw_ids: Iterable[int]) -> None:
        """Namespace-scoped :meth:`retain`: drop dead runs of ``ns`` only.

        The satellite fix for the sharded facade: a shard invalidating
        after its manifest commit knows only its *own* live run ids, so an
        unscoped ``retain`` would evict (never alias — keys are namespaced)
        every sibling shard's live blocks.
        """
        with self._mu:
            live = set(live_raw_ids)
            dead = [k for k in self._ns_keys.get(ns, ())
                    if k[0][1] not in live]
            for k in dead:
                self._unadmit(k)
            dead_p = [k for k in self._pinned
                      if _ns_of(k) == ns and k[0][1] not in live]
            for k in dead_p:
                self._pinned_bytes -= self._pinned.pop(k)

    def clear_ns(self, ns) -> None:
        """Drop one namespace's entries + pins (a shard's crash/recover)."""
        with self._mu:
            for k in list(self._ns_keys.get(ns, ())):
                self._unadmit(k)
            for k in [k for k in self._pinned if _ns_of(k) == ns]:
                self._pinned_bytes -= self._pinned.pop(k)
            self._ns_bytes.pop(ns, None)
            self._ns_keys.pop(ns, None)

    def clear(self) -> None:
        """Drop everything (process restart: DRAM contents are volatile)."""
        with self._mu:
            self._entries.clear()
            self._pinned.clear()
            self._bytes = 0
            self._pinned_bytes = 0
            self._ns_bytes.clear()
            self._ns_keys.clear()


class BlockCacheView:
    """A shard's namespaced, budget-scoped lens over a shared BlockCache.

    Presents the exact cache protocol ``LSMStore``/``PinnedLevelManager``
    speak (``read_block``/``read_blocks``/``read_block_span``/``retain``/
    ``set_pinned``/``clear``/``__contains__``), with every key namespaced as
    ``((namespace, run_id), block_id)`` — so N shards share one budgeted
    cache (admissions beyond the view's ``budget_bytes`` evict only this
    namespace's cold entries) and one shard's invalidation/repin/clear can
    never touch a sibling's blocks.  Hit/miss/eviction counters are shared
    (one cache, one hit rate); ``charged_bytes``/``pinned_bytes`` report the
    namespace's slice.
    """

    def __init__(self, cache: BlockCache, namespace, budget_bytes: int):
        self.cache = cache
        self.namespace = namespace
        self.budget_bytes = int(budget_bytes)
        cache.set_ns_budget(namespace, budget_bytes)

    def resize(self, budget_bytes: int) -> None:
        """Retarget this namespace's admission budget (tuner cache-budget
        shifting, DESIGN.md §17).  Gentle: entries over the new budget are
        not dropped eagerly — the namespace-first eviction loop sheds them
        on the shard's own subsequent admissions, so a budget shuffle never
        costs a cold sibling its working set up front."""
        self.budget_bytes = int(budget_bytes)
        self.cache.set_ns_budget(self.namespace, self.budget_bytes)

    # ---------------------------------------------------- cache protocol
    def read_block(self, run_id, block_id: int, nbytes: int,
                   stats: IOStats) -> bool:
        return self.cache.read_block((self.namespace, run_id), block_id,
                                     nbytes, stats)

    def read_blocks(self, run_id, block_ids, block_bytes,
                    stats: IOStats) -> int:
        return self.cache.read_blocks((self.namespace, run_id), block_ids,
                                      block_bytes, stats)

    def read_block_span(self, run_id, first_block: int, last_block: int,
                        block_bytes, stats: IOStats) -> int:
        return self.cache.read_block_span((self.namespace, run_id),
                                          first_block, last_block,
                                          block_bytes, stats)

    def retain(self, live_run_ids: Iterable[int]) -> None:
        self.cache.retain_ns(self.namespace, live_run_ids)

    def set_pinned(self, blocks: Dict[CacheKey, int]) -> None:
        self.cache.set_pinned_ns(
            self.namespace,
            {((self.namespace, rid), bid): nb
             for (rid, bid), nb in blocks.items()})

    def clear(self) -> None:
        self.cache.clear_ns(self.namespace)

    def __contains__(self, key: CacheKey) -> bool:
        return ((self.namespace, key[0]), key[1]) in self.cache

    # ------------------------------------------------- shared accounting
    # PinnedLevelManager counts residency misses under the cache mutex and
    # bumps the shared miss counter; cache_summary reads the rest.
    @property
    def _mu(self):
        return self.cache._mu

    @property
    def hits(self) -> int:
        return self.cache.hits

    @property
    def misses(self) -> int:
        return self.cache.misses

    @misses.setter
    def misses(self, v: int) -> None:
        self.cache.misses = v

    @property
    def evictions(self) -> int:
        return self.cache.evictions

    def hit_rate(self) -> float:
        return self.cache.hit_rate()

    @property
    def charged_bytes(self) -> int:
        return self.cache.ns_charged_bytes(self.namespace)

    @property
    def pinned_bytes(self) -> int:
        return self.cache.ns_pinned_bytes(self.namespace)


class PinnedLevelManager:
    """Keeps L0 runs resident in the block cache within ``pin_l0_bytes``."""

    def __init__(self, cache: BlockCache, pin_l0_bytes: int):
        self.cache = cache
        self.pin_l0_bytes = int(pin_l0_bytes)
        self.pinned_run_ids: List[int] = []

    def repin(self, l0_runs: Sequence,
              stats: Optional[IOStats] = None) -> None:
        """Re-derive the pin set from the current L0 (newest run first).

        Whole runs are admitted while they fit the budget; a run that does not
        fit is skipped (a smaller, older run may still fit).  Engine keeps L0
        newest-last, so iteration is reversed.

        ``stats=None`` (the flush/compaction path) pins for free: the runs
        were just built in memory and their write cost was counted at flush.
        Passing ``stats`` (recovery, or attaching a cache to a live store)
        charges one miss + block read for every pinned block not already
        cached — on a block device those blocks must be read to repopulate
        DRAM.
        """
        budget = self.pin_l0_bytes
        blocks: Dict[CacheKey, int] = {}
        pinned_ids: List[int] = []
        for run in reversed(list(l0_runs)):
            if len(run) == 0 or run.data_bytes > budget:
                continue
            budget -= run.data_bytes
            pinned_ids.append(run.run_id)
            for bid in range(run.n_blocks):
                blocks[(run.run_id, bid)] = run.block_bytes(bid)
        if stats is not None:
            # one batched pass: blocks not already resident are real reads
            # (counted under the cache mutex — hits/misses are shared with
            # concurrent reader threads' locked increments)
            with self.cache._mu:
                missing = sum(1 for key in blocks if key not in self.cache)
                self.cache.misses += missing    # keep hit_rate() in step
            stats.cache_miss_blocks += missing  # with IOStats accounting
            stats.blocks_read += missing
        self.pinned_run_ids = pinned_ids
        self.cache.set_pinned(blocks)

    def is_resident(self, run_id: int) -> bool:
        return run_id in self.pinned_run_ids
