"""Online workload-adaptive tuning (DESIGN.md §17).

Every knob that realizes the paper's capacity-ratio schedule in this repo —
Garnering ``c``/``T``, the cache/pin split, ``compaction_workers``,
``slowdown_trigger`` — was static config frozen at open time, while "How to
Grow an LSM-tree" (arxiv 2504.17178) shows the optimal point moves with the
read/write mix and data size, and Monkey-style reasoning (arxiv 2004.01833)
shows the same for memory allocation.  PR 7 built the sensor suite (per-op
latency histograms, stall/hit-rate counters, the flush/compaction event
trace); :class:`OnlineTuner` is the actuator half that closes the loop.

The loop is sense → decide → actuate:

**Sense.**  Each tick consumes *windowed deltas*: ``Telemetry.delta(prev)``
(histogram diffs per op class + ``EventTrace.since`` events) and
``IOStats.delta`` counter diffs.  Both snapshots merge lock-free per-thread
shards at read time — the tick adds zero locking to the lock-free read path
(it runs on the foreground write thread, at boundaries only).

**Decide.**  A bounded hill-climb, one knob per tick (round-robin coordinate
descent with per-knob direction memory): the previous tick's trial is
accepted if the objective did not worsen beyond ``tolerance``, else reverted
and the direction flipped.  Knobs and bounds (:data:`KNOB_BOUNDS`):

* ``c`` ∈ [0.4, 1.0] and ``T`` ∈ [2, 6] — Garnering level-ratio adjustment
  within the paper's family.  Retuning swaps in a fresh policy object that
  only affects *future* compaction targets; the installed tree is never
  rewritten.
* ``pin_frac`` — the ``cache_bytes`` ↔ ``pin_l0_bytes`` split at constant
  total memory (gentle resize: surviving cache entries keep serving hits).
* ``slowdown_trigger`` (multiplicative steps) and ``compaction_workers``
  (facade worker-budget semaphore) — pressure/worker reallocation.

The objective (:func:`tuning_objective`) is the p99-weighted cost behind
``benchmarks/serve_latency.py``'s metric — the ops-weighted mean of per-op-
class p99 latency over the window's *foreground* classes — not mean
throughput; stall time is inside the put histograms, so write pressure is
priced into the same number.  ``benchmarks/hillclimb.py`` scores its offline
sweeps with this very function so offline and online scoring cannot drift.

**Actuate.**  The tuner never applies anything itself mid-op: stores call
``tick`` only from ``apply_tuning()`` at compaction-chain / quiesce
boundaries (scheduler idle; sync mode is always at a boundary), so COW
readers and the bit-for-bit oracles are never perturbed mid-op.  Every
decision is emitted as a ``tuner_step`` trace event carrying before/after
knob values and the objective.

One tuner owns one store: the sharded facade binds the tuner and hands its
shards ``tuner=None`` configs, so per-shard write paths never double-drive
the controller (mirroring the live-config telemetry sharing).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

__all__ = ["KNOB_BOUNDS", "FOREGROUND_OPS", "OnlineTuner", "TunerStep",
           "tuning_objective"]

# Hill-climb bounds per knob (the paper's family for c/T; pressure/memory
# knobs bounded to sane engine ranges).  Stores expose only the knobs that
# exist on them (e.g. no pin_frac without a cache, no workers on a plain
# sync store) — the tuner round-robins whatever the store offers.
KNOB_BOUNDS: Dict[str, Tuple[float, float]] = {
    "c": (0.4, 1.0),
    "T": (2.0, 6.0),
    "pin_frac": (0.0, 0.75),
    "slowdown_trigger": (8, 512),
    "compaction_workers": (1, 8),
}

# Proposal step per knob: additive for the smooth knobs, multiplicative for
# slowdown_trigger (its useful range spans orders of magnitude).
_KNOB_STEP: Dict[str, float] = {
    "c": 0.1,
    "T": 1.0,
    "pin_frac": 0.125,
    "slowdown_trigger": 2.0,
    "compaction_workers": 1.0,
}
_MULTIPLICATIVE = frozenset(("slowdown_trigger",))
_INT_KNOBS = frozenset(("slowdown_trigger", "compaction_workers"))
# First trial direction: lean read-optimized (smaller c), wider ratio,
# more cache headroom for pins, less throttling, more workers.
_INIT_DIR: Dict[str, int] = {
    "c": -1, "T": 1, "pin_frac": 1, "slowdown_trigger": 1,
    "compaction_workers": 1,
}

# Op classes the objective prices: the *served* surface.  Background classes
# (flush/compaction/wal_fsync/...) are excluded — their cost already shows
# up as foreground stalls and slow reads, which is where it should be paid.
FOREGROUND_OPS = ("get", "multi_get", "scan", "seek",
                  "put", "put_batch", "write_batch")


def tuning_objective(hists: Dict[str, "LatencyHistogram"],
                     ops: Tuple[str, ...] = FOREGROUND_OPS) -> float:
    """p99-weighted cost of a window: ops-weighted mean per-class p99 (ns).

    ``sum_op n_op * p99_ns(op) / sum_op n_op`` over the foreground classes —
    the per-op tail cost a serving client sees, the same metric
    ``benchmarks/serve_latency.py`` reports (lower is better).  Weighting by
    sample count keeps a rare op class from dominating; using p99 instead of
    the mean makes stalls and cache-miss storms visible to the controller.
    Returns ``inf`` for an empty window (no decision should be made on it).
    """
    total = 0
    cost = 0.0
    for op in ops:
        h = hists.get(op)
        if h is None or h.n <= 0:
            continue
        cost += h.n * h.percentile(99.0)
        total += h.n
    return cost / total if total else math.inf


@dataclasses.dataclass
class TunerStep:
    """One controller decision (also emitted as a ``tuner_step`` event)."""

    tick: int                      # 1-based decision index
    knob: Optional[str]            # knob trialled this tick (None: none fit)
    before: float                  # its value before this tick's proposal
    after: float                   # ... and after (== before when no move)
    objective: float               # window objective that informed the tick
    prev_objective: float          # baseline it was compared against (nan
                                   # on the first decision)
    accepted: bool                 # previous trial kept (False == reverted)
    window_ops: int                # foreground samples in the window
    knobs: Dict[str, float]        # full knob vector after actuation


class OnlineTuner:
    """Feedback controller over a live store's tuning knobs.

    Attach via ``LSMConfig.tuner``; the store (or sharded facade) binds
    itself as the single owner and calls :meth:`tick` from its
    ``apply_tuning()`` boundary hook every ``interval_ops`` writes.  See the
    module docstring for the control loop; :attr:`steps` keeps the full
    decision trajectory for benchmarks/tests.
    """

    def __init__(self, interval_ops: int = 4096, *,
                 min_window_ops: int = 64, tolerance: float = 0.05,
                 bounds: Optional[Dict[str, Tuple[float, float]]] = None):
        assert interval_ops > 0 and min_window_ops > 0
        self.interval_ops = int(interval_ops)
        self.min_window_ops = int(min_window_ops)
        self.tolerance = float(tolerance)
        self.bounds = dict(KNOB_BOUNDS)
        if bounds:
            self.bounds.update(bounds)
        self.owner = None              # the one store driving this tuner
        self.ticks = 0                 # boundary ticks consumed (incl. the
                                       # baseline + too-small-window ones)
        self.steps: List[TunerStep] = []
        self._prev_tel = None          # TelemetrySnapshot at window start
        self._prev_stats = None        # IOStats at window start
        self._baseline = None          # objective the next trial compares to
        self._pending: Optional[Tuple[str, float]] = None  # (knob, before)
        self._dirs: Dict[str, int] = {}
        self._rr = 0

    # ------------------------------------------------------------ ownership
    def bind(self, store) -> bool:
        """First binder wins; per-shard configs carry ``tuner=None`` so the
        facade is the owner in sharded mode.  Returns True iff ``store`` is
        (now) the owner."""
        if self.owner is None:
            self.owner = store
        return self.owner is store

    # -------------------------------------------------------------- control
    def tick(self, store) -> Optional[TunerStep]:
        """One sense → decide → actuate pass.  Caller guarantees a
        compaction-chain/quiesce boundary (``apply_tuning`` does).

        Returns the :class:`TunerStep` when a decision was made, or None on
        the baseline tick, a too-small window, or a store without telemetry
        (no sensors → the controller stays inert, never guesses).
        """
        if self.owner is not store:
            return None
        tel = store.config.telemetry
        if tel is None:
            return None
        self.ticks += 1
        if self._prev_tel is None:      # baseline: open the first window
            self._prev_tel = tel.snapshot()
            self._prev_stats = store.stats
            return None
        window = tel.delta(self._prev_tel)
        fg = {op: h for op, h in window.hists.items()
              if op in FOREGROUND_OPS}
        window_ops = sum(h.n for h in fg.values())
        if window_ops < self.min_window_ops:
            return None                 # keep the window open: too noisy
        stats_now = store.stats
        stats_delta = stats_now.delta(self._prev_stats)
        self._prev_tel = window.end
        self._prev_stats = stats_now
        objective = tuning_objective(fg)
        acts = store._tuning_actuators()

        # -- judge the previous trial: paired windows ---------------------
        # The trial window is compared against the window *immediately
        # before* the trial was applied, and the baseline re-anchors to
        # every measured window (accepted or not).  A sticky
        # best-objective baseline wedges the controller: one lucky window
        # becomes a bar no honest window clears, and every later move —
        # including good ones — gets rejected forever.  Paired windows
        # keep judgments local; a noise-driven mis-accept is self-
        # correcting the next time the knob comes around.
        accepted = True
        if self._pending is not None:
            knob, before = self._pending
            self._pending = None
            if (self._baseline is not None and knob in acts
                    and objective > self._baseline * (1.0 + self.tolerance)):
                acts[knob][1](before)   # revert (we are at a boundary)
                self._dirs[knob] = -self._dirs.get(knob, 1)
                accepted = False
        prev_objective = self._baseline
        self._baseline = objective

        # -- propose the next move (round-robin coordinate descent) ------
        knob = None
        before = after = float("nan")
        names = [k for k in acts if k in self.bounds]
        if names:
            knob = names[self._rr % len(names)]
            self._rr += 1
            get, set_ = acts[knob]
            before = after = float(get())
            d = self._dirs.setdefault(knob, _INIT_DIR.get(knob, 1))
            proposal = self._propose(knob, before, d)
            if proposal == before:      # pinned at a bound: flip and retry
                self._dirs[knob] = d = -d
                proposal = self._propose(knob, before, d)
            if proposal != before:
                set_(proposal)
                after = proposal
                self._pending = (knob, before)

        knobs = {k: float(g()) for k, (g, _) in acts.items()}
        step = TunerStep(
            tick=len(self.steps) + 1, knob=knob, before=before, after=after,
            objective=objective,
            prev_objective=(float("nan") if prev_objective is None
                            else prev_objective),
            accepted=accepted, window_ops=window_ops, knobs=knobs)
        self.steps.append(step)
        tel.emit("tuner_step", knob=knob or "", before=round(before, 4),
                 after=round(after, 4), objective=round(objective, 1),
                 accepted=accepted, window_ops=window_ops,
                 knobs={k: round(v, 4) for k, v in knobs.items()})
        # Rule-based actuation (no hill-climb): e.g. the facade shifts
        # shared-cache namespace budgets toward hit-rate-starved shards.
        rules = getattr(store, "_tuning_rules", None)
        if rules is not None:
            rules(window, stats_delta)
        return step

    def _propose(self, knob: str, cur: float, direction: int) -> float:
        lo, hi = self.bounds[knob]
        step = _KNOB_STEP.get(knob, 0.1)
        if knob in _MULTIPLICATIVE:
            nxt = cur * step if direction > 0 else cur / step
        else:
            nxt = cur + direction * step
        nxt = min(float(hi), max(float(lo), nxt))
        if knob in _INT_KNOBS:
            return float(int(round(nxt)))
        return round(nxt, 4)

    # ------------------------------------------------------------ reporting
    def knob_trajectory(self) -> List[Dict[str, float]]:
        """Knob vector after each decision (benchmark convergence plots)."""
        return [dict(s.knobs) for s in self.steps]

    def last_knobs(self) -> Dict[str, float]:
        return dict(self.steps[-1].knobs) if self.steps else {}

    def best_knobs(self) -> Dict[str, float]:
        """Knob vector with the best *judged* objective.

        Step k's vector (trial included) is live for the whole of step
        k+1's window, so k+1's objective scores it.  On a noisy box the
        walk's last-visited vector is one random step; the best-judged one
        is the search's actual result — restore it when exploration ends
        (stochastic search's keep-the-incumbent rule)."""
        if not self.steps:
            return {}
        best_k, best_obj = len(self.steps) - 1, math.inf
        for k in range(len(self.steps) - 1):
            obj = self.steps[k + 1].objective
            if obj < best_obj:
                best_k, best_obj = k, obj
        return dict(self.steps[best_k].knobs)

    def restore_best(self, store) -> Dict[str, float]:
        """End-of-exploration restore: settle on the walk's *incumbent*.

        Reverts any still-unjudged trailing trial (it never earned its
        keep) and re-actuates the resulting vector clamped to the bounds
        (a knob never trialled can still carry an out-of-bounds *starting*
        value).  Deliberately NOT a global argmin over window objectives:
        store state drifts across an exploration phase (tree ages, cache
        churns), so early windows systematically score better than late
        ones and a cross-phase argmin just restores the starting knobs —
        only the paired adjacent-window judgments the walk already made
        are drift-safe, and their product is the incumbent.  Call at a
        quiesce boundary; returns the restored vector ({} if not the
        owner / no steps)."""
        if self.owner is not store:
            return {}
        if not self.steps:
            return {}
        acts = store._tuning_actuators()
        if self._pending is not None:
            knob, before = self._pending
            self._pending = None
            if knob in acts:
                acts[knob][1](before)
        ks = {}
        for k, (get, set_) in acts.items():
            v = float(get())
            if k in self.bounds:
                lo, hi = self.bounds[k]
                clamped = min(float(hi), max(float(lo), v))
                if clamped != v:
                    set_(clamped)
                    v = clamped
            ks[k] = v
        self._baseline = None
        return ks
