"""smollm-135m [hf:HuggingFaceTB/SmolLM-135M]: llama-arch small.

30 layers, d_model=576, 9 heads (GQA kv=3), d_ff=1536, vocab=49152.
9 query heads do not divide the 16-way model axis: the TP rule system falls
back to replicated attention projections (FFN/vocab still TP-sharded) —
see repro.launch.sharding.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm_135m",
    n_layers=30,
    d_model=576,
    n_q=9,
    n_kv=3,
    d_ff=1536,
    vocab=49152,
    d_head=64,
    tie_embeddings=True,
    subquadratic=False,
)

SMOKE = ModelConfig(
    name="smollm_135m_smoke",
    n_layers=3,
    d_model=48,
    n_q=6,
    n_kv=2,
    d_ff=96,
    vocab=128,
    d_head=8,
    tie_embeddings=True,
)
