"""Assigned architecture registry: ``get_config(arch_id)`` / ``get_smoke(arch_id)``.

Each <arch>.py defines CONFIG (the exact assigned full-size configuration) and
SMOKE (a reduced same-family config for CPU tests).  Shape sets (the 4 assigned
input shapes) live here; applicability rules follow the assignment spec:
``long_500k`` runs only for sub-quadratic stacks (mamba2, recurrentgemma,
gemma3) — see DESIGN.md §6.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Optional, Tuple

from repro.models.config import ModelConfig

ARCH_IDS = [
    "whisper_medium",
    "mamba2_130m",
    "minicpm_2b",
    "smollm_135m",
    "qwen3_4b",
    "gemma3_1b",
    "granite_moe_1b_a400m",
    "mixtral_8x22b",
    "recurrentgemma_2b",
    "llama32_vision_90b",
]

# Accept dashes too (CLI convenience).
def _canon(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    mode: str            # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_canon(arch)}")
    return mod.CONFIG


def get_smoke(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_canon(arch)}")
    return mod.SMOKE


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Assignment-spec applicability for an (arch, shape) cell."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("skipped: pure full-attention arch; long_500k requires "
                       "sub-quadratic attention (DESIGN.md §6)")
    return True, ""


def all_cells() -> List[Tuple[str, str]]:
    """Every (arch, shape) cell in the assignment — 40 total."""
    return [(a, s) for a in ARCH_IDS for s in SHAPES]
