"""whisper-medium [arXiv:2212.04356]: enc-dec audio transformer backbone.

24 decoder layers (self+cross+mlp), 24 encoder layers, d_model=1024, 16 heads
(MHA: kv=16), d_ff=4096, vocab=51865.  The conv audio frontend is a STUB per
the assignment: input_specs() provides precomputed frame embeddings
(B, 1500, 1024).  Deviation noted in DESIGN.md: decoder self-attn uses RoPE
instead of learned absolute positions (backbone-only fidelity; enables the
32k-sequence assigned shapes, which exceed whisper's native 448 positions).
"""
from repro.models.config import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper_medium",
    n_layers=24,
    d_model=1024,
    n_q=16,
    n_kv=16,
    d_ff=4096,
    vocab=51865,
    d_head=64,
    layer_pattern=("wdec",) * 24,
    encoder=EncoderConfig(n_layers=24, n_heads=16, d_ff=4096, seq_len=1500),
    tie_embeddings=True,
    subquadratic=False,
)

SMOKE = ModelConfig(
    name="whisper_medium_smoke",
    n_layers=3,
    d_model=32,
    n_q=4,
    n_kv=4,
    d_ff=64,
    vocab=128,
    d_head=8,
    layer_pattern=("wdec",) * 3,
    encoder=EncoderConfig(n_layers=2, n_heads=4, d_ff=64, seq_len=12),
    tie_embeddings=True,
)
