"""mamba2-130m [arXiv:2405.21060]: attention-free SSD (state-space duality).

24 layers, d_model=768, no MLP (d_ff=0), vocab=50280, ssm_state=128.
Sub-quadratic: runs the long_500k shape (O(1) decode state).
"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2_130m",
    n_layers=24,
    d_model=768,
    n_q=1,
    n_kv=1,
    d_ff=0,
    vocab=50280,
    d_head=64,
    layer_pattern=("ssd",) * 24,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk=256),
    tie_embeddings=True,
    subquadratic=True,
)

SMOKE = ModelConfig(
    name="mamba2_130m_smoke",
    n_layers=3,
    d_model=32,
    n_q=1,
    n_kv=1,
    d_ff=0,
    vocab=128,
    d_head=16,
    layer_pattern=("ssd",) * 3,
    ssm=SSMConfig(d_state=16, head_dim=16, expand=2, chunk=8),
    tie_embeddings=True,
    subquadratic=True,
)
