"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base].

24 layers, d_model=1024, 16 heads (GQA kv=8), expert d_ff=512, vocab=49155,
MoE 32 experts top-8.  EP: experts sharded over the model axis.
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite_moe_1b_a400m",
    n_layers=24,
    d_model=1024,
    n_q=16,
    n_kv=8,
    d_ff=512,
    vocab=49155,
    d_head=64,
    moe=MoEConfig(num_experts=32, top_k=8, capacity_factor=1.25),
    tie_embeddings=True,
    subquadratic=False,
)

SMOKE = ModelConfig(
    name="granite_moe_1b_a400m_smoke",
    n_layers=3,
    d_model=32,
    n_q=4,
    n_kv=2,
    d_ff=32,
    vocab=128,
    d_head=8,
    moe=MoEConfig(num_experts=8, top_k=4, capacity_factor=1.25),
    tie_embeddings=True,
)
