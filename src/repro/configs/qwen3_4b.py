"""qwen3-4b [hf:Qwen/Qwen3-8B family]: dense with qk_norm and GQA.

36 layers, d_model=2560, 32 heads (GQA kv=8), head_dim=128 (explicit, as in
Qwen3), d_ff=9728, vocab=151936, rope_theta=1e6.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3_4b",
    n_layers=36,
    d_model=2560,
    n_q=32,
    n_kv=8,
    d_ff=9728,
    vocab=151936,
    d_head=128,
    qk_norm=True,
    rope_theta=1e6,
    tie_embeddings=True,
    subquadratic=False,
)

SMOKE = ModelConfig(
    name="qwen3_4b_smoke",
    n_layers=3,
    d_model=48,
    n_q=8,
    n_kv=2,
    d_ff=96,
    vocab=128,
    d_head=8,
    qk_norm=True,
    tie_embeddings=True,
)
