"""llama-3.2-vision-90b [hf:meta-llama/Llama-3.2-11B-Vision family].

100 layers, d_model=8192, 64 heads (GQA kv=8), head_dim=128, d_ff=28672,
vocab=128256.  Every 5th layer is a gated cross-attention image layer
(pattern: 4 self + 1 cross, x20).  The vision patch frontend is a STUB per
the assignment: input_specs() provides precomputed patch embeddings
(B, 1600, 8192).
"""
from repro.models.config import ModelConfig, VisionConfig

CONFIG = ModelConfig(
    name="llama32_vision_90b",
    n_layers=100,
    d_model=8192,
    n_q=64,
    n_kv=8,
    d_ff=28672,
    vocab=128256,
    d_head=128,
    layer_pattern=(("attn",) * 4 + ("xattn",)) * 20,
    vision=VisionConfig(n_img_tokens=1600, xattn_every=5),
    rope_theta=500_000.0,
    tie_embeddings=False,
    subquadratic=False,
)

SMOKE = ModelConfig(
    name="llama32_vision_90b_smoke",
    n_layers=5,
    d_model=32,
    n_q=8,
    n_kv=2,
    d_ff=64,
    vocab=128,
    d_head=8,
    layer_pattern=("attn", "attn", "attn", "attn", "xattn"),
    vision=VisionConfig(n_img_tokens=8, xattn_every=5),
    tie_embeddings=False,
)
