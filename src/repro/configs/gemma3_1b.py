"""gemma3-1b [hf:google/gemma-3-1b-pt]: 5:1 local:global attention, 128k ctx.

26 layers, d_model=1152, 4 heads (GQA kv=1), head_dim=256, d_ff=6912,
vocab=262144, sliding window 512, qk_norm.  Pattern: (5 local, 1 global) x 4
+ 2 local.  Sub-quadratic enough for long_500k: local layers cache only their
512-token window; the few global layers keep the full 500k KV, which at
global_batch=1 is ~3 GB sharded — exact attention, no eviction needed
(DESIGN.md §6).  4 query heads do not divide the 16-way model axis: TP rules
fall back to replicated attention projections.
"""
from repro.models.config import ModelConfig

_PATTERN = (("lattn",) * 5 + ("attn",)) * 4 + ("lattn",) * 2

CONFIG = ModelConfig(
    name="gemma3_1b",
    n_layers=26,
    d_model=1152,
    n_q=4,
    n_kv=1,
    d_ff=6912,
    vocab=262144,
    d_head=256,
    layer_pattern=_PATTERN,
    window=512,
    qk_norm=True,
    rope_theta=1e6,
    tie_embeddings=True,
    subquadratic=True,
)

SMOKE = ModelConfig(
    name="gemma3_1b_smoke",
    n_layers=8,
    d_model=32,
    n_q=4,
    n_kv=1,
    d_ff=64,
    vocab=128,
    d_head=8,
    layer_pattern=(("lattn",) * 3 + ("attn",)) * 2,
    window=8,
    qk_norm=True,
    tie_embeddings=True,
    subquadratic=True,
)
