"""recurrentgemma-2b [arXiv:2402.19427]: Griffin — RG-LRU + local attention 1:2.

26 layers, d_model=2560, 10 heads (GQA kv=1), head_dim=256, d_ff=7680,
vocab=256000, window 2048.  Pattern: (rglru, rglru, lattn) x 8 + 2 rglru.
Sub-quadratic: O(1) recurrent state + bounded window KV => runs long_500k.
"""
from repro.models.config import ModelConfig, RGLRUConfig

_PATTERN = (("rglru", "rglru", "lattn")) * 8 + ("rglru", "rglru")

CONFIG = ModelConfig(
    name="recurrentgemma_2b",
    n_layers=26,
    d_model=2560,
    n_q=10,
    n_kv=1,
    d_ff=7680,
    vocab=256000,
    d_head=256,
    layer_pattern=_PATTERN,
    window=2048,
    rglru=RGLRUConfig(width=2560, conv_width=4, power=8.0),
    tie_embeddings=True,
    subquadratic=True,
)

SMOKE = ModelConfig(
    name="recurrentgemma_2b_smoke",
    n_layers=5,
    d_model=32,
    n_q=4,
    n_kv=1,
    d_ff=64,
    vocab=128,
    d_head=8,
    layer_pattern=("rglru", "rglru", "lattn", "rglru", "rglru"),
    window=8,
    rglru=RGLRUConfig(width=32, conv_width=4, power=8.0),
    tie_embeddings=True,
    subquadratic=True,
)
