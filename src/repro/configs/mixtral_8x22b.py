"""mixtral-8x22b [arXiv:2401.04088]: 8-expert top-2 MoE with sliding-window
attention.

56 layers, d_model=6144, 48 heads (GQA kv=8), head_dim=128, expert d_ff=16384,
vocab=32768, SWA window 4096.  ~141 B total / ~39 B active parameters —
requires FSDP+TP+EP sharding to fit (repro.launch.sharding).
Note: SWA everywhere is technically sub-quadratic, but the assignment's
long_500k set is SSM/hybrid/linear-attn only — mixtral reports 3 shapes.
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral_8x22b",
    n_layers=56,
    d_model=6144,
    n_q=48,
    n_kv=8,
    d_ff=16384,
    vocab=32768,
    d_head=128,
    layer_pattern=("lattn",) * 56,
    window=4096,
    moe=MoEConfig(num_experts=8, top_k=2, capacity_factor=1.25),
    tie_embeddings=False,
    subquadratic=False,
)

SMOKE = ModelConfig(
    name="mixtral_8x22b_smoke",
    n_layers=3,
    d_model=32,
    n_q=8,
    n_kv=2,
    d_ff=64,
    vocab=128,
    d_head=8,
    layer_pattern=("lattn",) * 3,
    window=8,
    moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=1.25),
    tie_embeddings=False,
)
