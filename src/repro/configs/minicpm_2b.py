"""minicpm-2b [arXiv:2404.06395]: dense llama-like, trained with the WSD
(warmup-stable-decay) schedule — implemented in repro.train.optimizer.

40 layers, d_model=2304, 36 heads (kv=36, MHA), d_ff=5760, vocab=122753.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm_2b",
    n_layers=40,
    d_model=2304,
    n_q=36,
    n_kv=36,
    d_ff=5760,
    vocab=122753,
    d_head=64,
    tie_embeddings=True,
    subquadratic=False,
    # 36-head MHA at 32k under sequence parallelism: halve the attention
    # score working set so prefill_32k fits 16 GiB/chip (dry-run §Dry-run).
    q_chunk=512,
)

SMOKE = ModelConfig(
    name="minicpm_2b_smoke",
    n_layers=3,
    d_model=48,
    n_q=6,
    n_kv=6,
    d_ff=96,
    vocab=128,
    d_head=8,
    tie_embeddings=True,
)
