"""Batched serving engine over the AutumnKV prefix cache.

The request path (per batch):
  1. batched AutumnKV lookup — one LSM multi_get resolves the whole wave's
     page keys (DESIGN.md §3); full-prompt hits skip prefill;
  2. misses are prefilled together (one jit'd batched prefill);
  3. all requests decode together for gen_len steps (one jit'd decode step);
  4. freshly prefilled prompts are inserted as content-addressed pages.

Continuous batching at framework scale would slot new requests into finished
rows; here a batch is a "wave", which is enough to exercise the storage path
and the decode kernels end-to-end.

Storage defaults are shard-aware (DESIGN.md §12): the prefix cache's LSM
runs as a 2-shard `ShardedLSMStore` (chain-hash keys are uniform over
uint64, so the default splitters balance), so page-insert bursts from
concurrent waves drain on parallel per-shard background schedulers.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kvcache.autumnkv import AutumnKVCache
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.models.layers import identity_shard

Pytree = Any


@dataclasses.dataclass
class Request:
    prompt: np.ndarray            # (S,) int32, S multiple of page for reuse
    gen_len: int = 8


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params: Pytree, batch: int,
                 s_max: int, shard=identity_shard,
                 use_prefix_cache: bool = True):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.s_max = s_max
        self.shard = shard
        self.kv = AutumnKVCache(cfg, 1, s_max) if use_prefix_cache else None
        self._prefill = jax.jit(
            lambda p, b: M.prefill(p, b, cfg, s_max=s_max, shard=shard))
        self._decode = jax.jit(
            lambda p, t, c: M.decode_step(p, t, c, cfg, shard=shard))
        self.metrics: Dict[str, float] = {"prefill_tokens": 0,
                                          "decoded_tokens": 0,
                                          "cache_hits": 0, "batches": 0}

    # ----------------------------------------------------------------- wave
    def serve_batch(self, requests: List[Request],
                    extras: Optional[Dict[str, np.ndarray]] = None
                    ) -> List[np.ndarray]:
        assert len(requests) <= self.batch
        t0 = time.time()
        S = max(len(r.prompt) for r in requests)
        assert all(len(r.prompt) == S for r in requests), \
            "one wave = one prompt length (bucketing upstream)"
        hits: Dict[int, Pytree] = {}
        if self.kv is not None:
            template = M.init_cache(self.cfg, 1, self.s_max)
            # one batched LSM multi_get across the whole wave's page keys
            got_list = self.kv.lookup_batch([r.prompt for r in requests],
                                            template)
            hits = {i: g for i, g in enumerate(got_list) if g is not None}
        self.metrics["cache_hits"] += len(hits)
        # batched prefill for everyone (cheap CPU smoke sizes); cache rows of
        # hit requests are replaced by their stored pages afterwards.
        tokens = jnp.asarray(np.stack([r.prompt for r in requests]))
        batch = {"tokens": tokens}
        if extras:
            batch.update({k: jnp.asarray(v) for k, v in extras.items()})
        miss_idx = [i for i in range(len(requests)) if i not in hits]
        logits, cache = self._prefill(self.params, batch)
        self.metrics["prefill_tokens"] += S * len(miss_idx)
        if self.kv is not None:
            for i in miss_idx:
                self.kv.insert(requests[i].prompt, _slice_batch_row(cache, i))
        # splice hit rows into the batched cache (validates stored pages)
        for i, row_cache in hits.items():
            cache = _set_batch_row(cache, row_cache, i)
        # greedy decode
        outs = [[] for _ in requests]
        last = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        gen = max(r.gen_len for r in requests)
        for _ in range(gen):
            for i in range(len(requests)):
                outs[i].append(int(last[i, 0]))
            logits, cache = self._decode(self.params, last, cache)
            last = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            self.metrics["decoded_tokens"] += len(requests)
        self.metrics["batches"] += 1
        self.metrics["last_wave_s"] = time.time() - t0
        return [np.asarray(o[:r.gen_len]) for o, r in zip(outs, requests)]

    def close(self) -> None:
        """Retire the engine: drain the prefix cache's background workers."""
        if self.kv is not None:
            self.kv.close()


def _slice_batch_row(cache: Pytree, i: int) -> Pytree:
    """Cache leaves are (layers, batch, ...); 'pos' is 0-dim."""
    def f(a):
        a = np.asarray(a)
        return a[:, i:i + 1] if a.ndim >= 2 else a
    return jax.tree.map(f, cache)


def _set_batch_row(cache: Pytree, row: Pytree, i: int) -> Pytree:
    def f(a, r):
        if hasattr(a, "ndim") and a.ndim >= 2:
            return a.at[:, i:i + 1].set(jnp.asarray(r))
        return a
    return jax.tree.map(f, cache, row)
