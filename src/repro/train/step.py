"""Train step: loss -> grads -> AdamW, with microbatch gradient accumulation.

Microbatching (`accum_steps > 1`) scans over batch slices, accumulating fp32
gradients — this is the main activation-memory lever for the big assigned
configs (mixtral-8x22b, llama-3.2-vision-90b) and composes with per-block
remat (ModelConfig.remat).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.models.layers import Shard, identity_shard
from .optimizer import OptConfig, adamw_update, init_opt_state

Pytree = Any


def _split_batch(batch: Dict[str, jax.Array], accum: int
                 ) -> Dict[str, jax.Array]:
    def re(x):
        B = x.shape[0]
        assert B % accum == 0, (B, accum)
        return x.reshape(accum, B // accum, *x.shape[1:])
    return {k: re(v) for k, v in batch.items()}


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig,
                    shard: Shard = identity_shard, accum_steps: int = 1
                    ) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics)."""

    def loss_of(p, mb):
        return M.loss_fn(p, mb, cfg, shard)

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def train_step(params: Pytree, opt_state: Pytree,
                   batch: Dict[str, jax.Array]):
        if accum_steps == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            mbs = _split_batch(batch, accum_steps)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params)

            def body(carry, mb):
                g_acc, l_acc = carry
                (l, met), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), met

            (grads, loss_sum), mets = jax.lax.scan(
                body, (zero, jnp.zeros(())), mbs)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = loss_sum / accum_steps
            metrics = jax.tree.map(lambda x: x[-1], mets)
        new_params, new_opt, opt_metrics = adamw_update(
            params, grads, opt_state, opt_cfg)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return new_params, new_opt, metrics

    return train_step


def make_eval_step(cfg: ModelConfig, shard: Shard = identity_shard):
    def eval_step(params, batch):
        loss, metrics = M.loss_fn(params, batch, cfg, shard)
        return dict(metrics, loss=loss)
    return eval_step
