"""AdamW with pluggable schedules, pure JAX (no optax).

Schedules: cosine (default) and WSD (warmup-stable-decay, used by MiniCPM —
arXiv:2404.06395 §4): linear warmup, long stable plateau at peak lr, then a
short exponential decay tail.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"        # "cosine" | "wsd" | "constant"
    wsd_decay_frac: float = 0.1     # fraction of total steps spent decaying
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def schedule_lr(step: jax.Array, cfg: OptConfig) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        frac = jnp.ones(())
    elif cfg.schedule == "wsd":
        decay_steps = max(int(cfg.total_steps * cfg.wsd_decay_frac), 1)
        decay_start = cfg.total_steps - decay_steps
        in_decay = jnp.maximum(s - decay_start, 0.0) / decay_steps
        # exponential-ish decay tail to min_lr_frac
        frac = jnp.where(s < decay_start, 1.0,
                         cfg.min_lr_frac ** jnp.minimum(in_decay, 1.0))
    else:  # cosine
        prog = jnp.clip((s - cfg.warmup_steps) /
                        jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                        0.0, 1.0)
        frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * \
            0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.peak_lr * warm * frac


def init_opt_state(params: Pytree, master: bool = False) -> Dict[str, Any]:
    """master=True keeps an fp32 master copy — use when params are bf16
    (halves parameter HBM traffic in the forward pass; see §Perf)."""
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    out = {"m": jax.tree.map(zeros, params),
           "v": jax.tree.map(zeros, params),
           "step": jnp.zeros((), jnp.int32)}
    if master:
        out["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return out


def global_norm(tree: Pytree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def adamw_update(params: Pytree, grads: Pytree, state: Dict[str, Any],
                 cfg: OptConfig) -> Tuple[Pytree, Dict[str, Any],
                                          Dict[str, jax.Array]]:
    step = state["step"] + 1
    lr = schedule_lr(step, cfg)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip > 0 else jnp.ones(())

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, w32):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        u = u + cfg.weight_decay * w32
        w32 = w32 - lr * u
        return w32.astype(p.dtype), m, v, w32

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_w = (treedef.flatten_up_to(state["master"]) if "master" in state
              else [p.astype(jnp.float32) for p in flat_p])
    out = [upd(p, g, m, v, w) for p, g, m, v, w in
           zip(flat_p, flat_g, flat_m, flat_v, flat_w)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step}
    if "master" in state:
        new_state["master"] = jax.tree.unflatten(treedef,
                                                 [o[3] for o in out])
    return new_p, new_state, {"lr": lr, "grad_norm": gnorm}
