from .optimizer import OptConfig, adamw_update, init_opt_state, schedule_lr
from .step import make_eval_step, make_train_step
from .compress import (compress_with_feedback, compressed_grad_allreduce,
                       dequantize, init_error_state, quantize)
