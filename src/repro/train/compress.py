"""Gradient compression for slow-link data parallelism (DESIGN.md §4).

int8 uniform quantization with per-tensor scale and *error feedback*
(Seide et al. / EF-SGD): the quantization residual is carried in the
optimizer-adjacent state and added back before the next compression, so the
scheme is unbiased over time and training converges to the uncompressed
fixed point.

Two entry points:
  quantize/dequantize           — pure tensor-level codecs (property-tested)
  compressed_psum (shard_map)   — explicit DP all-reduce of compressed grads
                                  over a named mesh axis, for deployments
                                  where the DP links are the bottleneck.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


def quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """int8 symmetric quantization; returns (q, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(g: jax.Array, err: jax.Array
                           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (q, scale, new_err). new_err = (g+err) - dequant(q)."""
    corrected = g.astype(jnp.float32) + err
    q, scale = quantize(corrected)
    new_err = corrected - dequantize(q, scale)
    return q, scale, new_err


def init_error_state(grads: Pytree) -> Pytree:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_grad_allreduce(grads: Pytree, err_state: Pytree,
                              axis_name: str) -> Tuple[Pytree, Pytree]:
    """Inside shard_map over `axis_name`: int8-compress each gradient leaf,
    psum the int32-widened codes (scales are psum'd separately and averaged),
    and return (mean_grads, new_err_state).

    Wire format per leaf: int8 codes + one f32 scale => 4x less DP traffic
    than f32 (and ~2x less than bf16) at equal step count.
    """
    n = jax.lax.psum(1, axis_name)

    def leaf(g, e):
        q, scale, new_e = compress_with_feedback(g, e)
        # Widen to int32 for an exact integer all-reduce of the codes.
        qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        ssum = jax.lax.psum(scale, axis_name)
        # Each worker used its own scale; approximate the sum with the mean
        # scale (error absorbed by feedback next step).
        mean = qsum.astype(jnp.float32) * (ssum / n) / n
        return mean.astype(g.dtype), new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err_state)
    out = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))
