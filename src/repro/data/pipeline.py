"""Deterministic, seekable data pipeline.

Every batch is a pure function of (seed, step, host) — there is no cursor
state to checkpoint, restoring at step k after a failure reproduces the exact
token stream, and elastic rescaling (different host count) re-partitions the
same global stream.  This is the property the straggler/failure-recovery
logic in repro.launch.train relies on (DESIGN.md §4).

Two sources:
  SyntheticTokens — splitmix64-hash token stream (self-labelling next-token
                    targets with a planted bigram structure so loss must fall)
  MemmapCorpus    — windows over a tokenized numpy corpus on disk
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.core.types import splitmix64
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts


class SyntheticTokens:
    """Deterministic pseudo-corpus. Token t_{i+1} depends on t_i through a
    fixed planted bigram table for 50% of positions, so a model that learns
    the table halves its loss — useful as a real training signal in tests."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed + 7)
        self._bigram = rng.integers(0, cfg.vocab, size=cfg.vocab,
                                    dtype=np.int64)

    def get_batch(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        B, S = c.host_batch, c.seq_len
        row0 = step * c.global_batch + c.host_id * B
        idx = (np.arange(row0, row0 + B, dtype=np.uint64)[:, None] *
               np.uint64(1_000_003) +
               np.arange(S, dtype=np.uint64)[None, :] +
               np.uint64(c.seed) * np.uint64(0x9E37_79B9))
        raw = (splitmix64(idx) % np.uint64(c.vocab)).astype(np.int64)
        # plant structure: each odd position is bigram[previous even token]
        tokens = raw.copy()
        n_odd = len(range(1, S, 2))
        tokens[:, 1::2] = self._bigram[tokens[:, 0::2][:, :n_odd]]
        labels = np.roll(tokens, -1, axis=1)
        labels[:, -1] = tokens[:, 0]
        return {"tokens": tokens.astype(np.int32),
                "labels": labels.astype(np.int32)}


class MemmapCorpus:
    """Sequential windows over a flat tokenized corpus (np.memmap-able)."""

    def __init__(self, cfg: DataConfig, path: str):
        self.cfg = cfg
        self.data = np.load(path, mmap_mode="r")
        assert self.data.ndim == 1

    def get_batch(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        B, S = c.host_batch, c.seq_len
        n = self.data.shape[0] - (S + 1)
        starts = (np.arange(B, dtype=np.int64) +
                  (step * c.global_batch + c.host_id * B)) * S % max(n, 1)
        toks = np.stack([self.data[s:s + S + 1] for s in starts])
        return {"tokens": toks[:, :S].astype(np.int32),
                "labels": toks[:, 1:S + 1].astype(np.int32)}


def stub_frontend_inputs(cfg: ModelConfig, batch_size: int, rng_seed: int = 0
                         ) -> Dict[str, np.ndarray]:
    """Precomputed modality-frontend embeddings (the assignment's STUB):
    whisper frame embeddings / vision patch embeddings."""
    out: Dict[str, np.ndarray] = {}
    rng = np.random.default_rng(rng_seed)
    if cfg.encoder is not None:
        out["enc_frames"] = rng.standard_normal(
            (batch_size, cfg.encoder.seq_len, cfg.d_model),
            dtype=np.float32) * 0.02
    if cfg.vision is not None:
        out["img_embeds"] = rng.standard_normal(
            (batch_size, cfg.vision.n_img_tokens, cfg.d_model),
            dtype=np.float32) * 0.02
    return out
