from .pipeline import DataConfig, MemmapCorpus, SyntheticTokens, stub_frontend_inputs
