"""Pallas TPU kernel: paged decode attention — AutumnKV's on-TPU read path.

The block table plays the role of the paper's fence pointers: it maps each
sequence's logical page index to a physical page in the HBM page pool, so a
decode step reads exactly the pages it needs (no contiguous KV buffer, no
copy at prefix-cache hits).  Grid is (batch, pages); the block table and
sequence lengths ride in scalar-prefetch so the BlockSpec index_map can
DMA-schedule the right page while the previous one computes — the
overlap-compute-and-memory trick that makes decode HBM-bandwidth-bound
instead of latency-bound.

Flash-decoding accumulation: running (m, l, acc) in VMEM scratch across the
page axis; output written on the last page.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def paged_attention_kernel(block_tables_ref, lengths_ref,   # scalar prefetch
                           q_ref, k_ref, v_ref, out_ref,
                           m_ref, l_ref, acc_ref,
                           *, page: int, n_pages: int):
    b = pl.program_id(0)
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...]                       # (H, dh)
    k = k_ref[...]                       # (page, KH, dh)
    v = v_ref[...]
    H, dh = q.shape
    KH = k.shape[1]
    G = H // KH
    qg = q.reshape(KH, G, dh)
    s = jnp.einsum("kgd,pkd->kgp", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * (dh ** -0.5)
    pos = p * page + jax.lax.broadcasted_iota(jnp.int32, (1, 1, page), 2)
    s = jnp.where(pos < lengths_ref[b], s, -1e30)

    m_prev = m_ref[...]                  # (KH, G)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    pexp = jnp.exp(s - m_new[..., None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(pexp, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + \
        jnp.einsum("kgp,pkd->kgd", pexp, v.astype(jnp.float32))
    m_ref[...] = m_new

    @pl.when(p == n_pages - 1)
    def _finish():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]
        out_ref[...] = out.reshape(H, dh).astype(out_ref.dtype)


def paged_attention_pallas(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, block_tables: jax.Array,
                           lengths: jax.Array,
                           interpret: bool = True) -> jax.Array:
    """q: (B,H,dh); k/v_pages: (n_phys_pages, page, KH, dh);
    block_tables: (B, pages_per_seq) int32; lengths: (B,) int32.
    Returns (B,H,dh)."""
    B, H, dh = q.shape
    n_phys, page, KH, _ = k_pages.shape
    pages_per_seq = block_tables.shape[1]
    kern = functools.partial(paged_attention_kernel, page=page,
                             n_pages=pages_per_seq)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, pages_per_seq),
        in_specs=[
            pl.BlockSpec((None, H, dh), lambda b, p, bt, ln: (b, 0, 0)),
            pl.BlockSpec((None, page, KH, dh),
                         lambda b, p, bt, ln: (bt[b, p], 0, 0, 0)),
            pl.BlockSpec((None, page, KH, dh),
                         lambda b, p, bt, ln: (bt[b, p], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, H, dh), lambda b, p, bt, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((KH, H // KH), jnp.float32),
            pltpu.VMEM((KH, H // KH), jnp.float32),
            pltpu.VMEM((KH, H // KH, dh), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, dh), q.dtype),
        interpret=interpret,
    )(block_tables, lengths, q, k_pages, v_pages)
