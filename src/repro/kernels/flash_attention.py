"""Pallas TPU kernel: flash attention (prefill/train hotspot).

The XLA fallback materializes (q_chunk, S) fp32 score buffers through a
multi-fusion softmax chain — the dominant HBM term in the dry-run roofline
for every attention arch (EXPERIMENTS.md §Perf).  This kernel streams KV
blocks through VMEM with running-softmax scratch, so score traffic never
touches HBM: per-(q-block) HBM traffic drops from O(S) score rows to the
q/k/v/o tiles themselves.

Supports causal masking, sliding windows, and GQA (KV heads repeated on the
fly inside the kernel).  Block sizes default to MXU-aligned (128, 128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def flash_attention_kernel(q_ref, k_ref, v_ref, out_ref, m_ref, l_ref,
                           acc_ref, *, bq: int, bk: int, causal: bool,
                           window: int, n_kv_blocks: int, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...]                                   # (bq, dh)
    k = k_ref[...]                                   # (bk, dh)
    v = v_ref[...]
    s = jnp.dot(q.astype(jnp.float32), k.astype(jnp.float32).T,
                preferred_element_type=jnp.float32) * scale
    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        ok &= kpos <= qpos
    if window > 0:
        ok &= kpos > qpos - window
    s = jnp.where(ok, s, -1e30)

    m_prev = m_ref[...]                              # (bq,)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + \
        jnp.dot(p, v.astype(jnp.float32),
                preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        out_ref[...] = (acc_ref[...] /
                        jnp.maximum(l_ref[...], 1e-30)[:, None]
                        ).astype(out_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int = 0,
                           bq: int = 128, bk: int = 128,
                           interpret: bool = True) -> jax.Array:
    """q: (B,Sq,H,dh); k/v: (B,Sk,KH,dh). Returns (B,Sq,H,dh)."""
    B, Sq, H, dh = q.shape
    Sk, KH = k.shape[1], k.shape[2]
    G = H // KH
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    # layout: (B, H, S, dh) with KV heads repeated via the index map (free)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    kern = functools.partial(
        flash_attention_kernel, bq=bq, bk=bk, causal=causal, window=window,
        n_kv_blocks=Sk // bk, scale=dh ** -0.5)
    out = pl.pallas_call(
        kern,
        grid=(B, H, Sq // bq, Sk // bk),
        in_specs=[
            pl.BlockSpec((None, None, bq, dh),
                         lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((None, None, bk, dh),
                         lambda b, h, i, j, _G=G: (b, h // _G, j, 0)),
            pl.BlockSpec((None, None, bk, dh),
                         lambda b, h, i, j, _G=G: (b, h // _G, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, bq, dh),
                               lambda b, h, i, j: (b, h, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, dh), q.dtype),
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
