"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True (this container is CPU-only; on TPU pass
interpret=False and the same BlockSpecs drive real Mosaic lowering).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .bloom_probe import bloom_probe_pallas
from .bloom_probe import hash_pair as _kernel_hash_pair
from .flash_attention import flash_attention_pallas
from .merge_path import bitonic_merge_pallas, merge_path_partition
from .paged_attention import paged_attention_pallas


def split_u64(keys) -> Tuple[jax.Array, jax.Array]:
    """u64 -> (lo32, hi32). Done in numpy: jax's default x32 mode would
    silently truncate uint64."""
    keys = np.asarray(keys, dtype=np.uint64)
    lo = (keys & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    hi = (keys >> np.uint64(32)).astype(np.uint32)
    return jnp.asarray(lo), jnp.asarray(hi)


@partial(jax.jit, static_argnames=("k_hashes", "interpret"))
def _bloom_probe_jit(lo, hi, bits, k_hashes, interpret):
    return bloom_probe_pallas(lo, hi, bits, k_hashes, interpret=interpret)


def bloom_probe(keys, bits: jax.Array, k_hashes: int = 7,
                interpret: bool = True) -> jax.Array:
    """Probe u64 keys against a u32-word bitset. Returns bool 'maybe'."""
    lo, hi = split_u64(keys)
    return _bloom_probe_jit(lo, hi, bits, k_hashes, interpret)


def bloom_probe_filter(bf, keys, interpret: bool = True) -> np.ndarray:
    """Probe a ``repro.core.bloom.BloomFilter`` with the Pallas kernel.

    The filter builds its bitset with the kernel's own 32-bit hash family, so
    this returns bit-identical answers to ``bf.may_contain`` — it is the
    engine's accelerator route for batched point reads (DESIGN.md §3).  Pads
    the query batch up to the kernel's block multiple and strips the pad.
    """
    from .bloom_probe import QUERY_BLOCK

    keys = np.asarray(keys, dtype=np.uint64)
    n = keys.size
    if bf.k == 0 or n == 0:
        return np.ones(n, dtype=bool)
    # Quantize the batch shape (pow2 up to a block, then block multiples) so
    # the jit cache holds a handful of kernels instead of one per batch size.
    if n < QUERY_BLOCK:
        m = 64
        while m < n:
            m *= 2
    else:
        m = -(-n // QUERY_BLOCK) * QUERY_BLOCK
    if m != n:
        keys = np.concatenate([keys, np.zeros(m - n, np.uint64)])
    out = np.asarray(bloom_probe(keys, jnp.asarray(bf.bits), bf.k,
                                 interpret=interpret))
    return out[:n]


@partial(jax.jit, static_argnames=("interpret",))
def _merge_tiles_jit(a_hi, a_lo, b_hi, b_lo, pa, pb, interpret=True):
    return bitonic_merge_pallas(a_hi, a_lo, b_hi, b_lo, pa, pb,
                                interpret=interpret)


def merge_sorted_tiles(a: jax.Array, b: jax.Array, pa: jax.Array,
                       pb: jax.Array, interpret: bool = True):
    """Merge batches of sorted u32 tiles: (n,T)+(n,T) -> (n,2T) sorted.

    Thin single-lane wrapper over the lexicographic (hi, lo) kernel with
    hi = 0; u64 callers go through :func:`merge_runs_tiled`, which splits
    keys into both lanes.
    """
    zero = jnp.zeros_like(a)
    _, lo, payload = _merge_tiles_jit(zero, a, jnp.zeros_like(b), b, pa, pb,
                                      interpret=interpret)
    return lo, payload


def _to_u64_order(keys: np.ndarray) -> np.ndarray:
    """Order-preserving map of any integer dtype onto uint64.

    Unsigned dtypes widen directly; signed dtypes flip the sign bit after
    widening to int64 (the classic radix trick), so lexicographic (hi, lo)
    u32-lane comparison reproduces the native ordering exactly.  Float keys
    are rejected — the two-lane kernel compares integer lanes only.
    """
    if keys.dtype == np.uint64:
        return keys
    if np.issubdtype(keys.dtype, np.unsignedinteger):
        return keys.astype(np.uint64)
    if np.issubdtype(keys.dtype, np.signedinteger):
        return keys.astype(np.int64).view(np.uint64) ^ np.uint64(1 << 63)
    raise TypeError(f"merge_runs_tiled requires integer keys, "
                    f"got {keys.dtype}")


def _from_u64_order(merged: np.ndarray, dtype: np.dtype) -> np.ndarray:
    """Invert :func:`_to_u64_order` back to the caller's key dtype."""
    if dtype == np.uint64:
        return merged
    if np.issubdtype(dtype, np.unsignedinteger):
        return merged.astype(dtype)
    return (merged ^ np.uint64(1 << 63)).view(np.int64).astype(dtype)


def _split_key_lanes(keys: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """order-mapped u64 -> (hi32, lo32) kernel lanes."""
    return ((keys >> np.uint64(32)).astype(np.uint32),
            (keys & np.uint64(0xFFFFFFFF)).astype(np.uint32))


def merge_runs_tiled(keys_a: np.ndarray, keys_b: np.ndarray,
                     tile: int = 256, interpret: bool = True
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Full two-run merge: host-side merge-path partition + one bitonic
    kernel launch per tile pair (the engine's ``use_pallas_merge`` lane).

    The partition and the tile packing are fully vectorized
    (``merge_path_partition`` + two scatter passes — no per-tile Python
    loop); keys are carried as (hi, lo) u32 lanes so uint64 engine keys
    merge exactly.  Returns (merged_keys, source_index) where source_index
    is uint32 with bit 31 flagging entries from ``keys_b`` and the low bits
    giving the source row, so the engine can permute value rows.  Tile pads
    carry the lane maxima plus payload 0xFFFFFFFF, which the kernel's
    payload tie-break orders after any real entry — keys equal to the dtype
    maximum therefore merge correctly (runs longer than 2^31 - 1 entries
    would collide with the pad payload, far beyond this engine's scale).
    """
    out_dtype = keys_a.dtype
    keys_a = _to_u64_order(np.ascontiguousarray(keys_a))
    keys_b = _to_u64_order(np.ascontiguousarray(keys_b))
    na, nb = len(keys_a), len(keys_b)
    n_out = na + nb
    # Diagonal spacing = tile: merge-path guarantees each cell consumes at
    # most `tile` from either input; pads sort to the back (lane maxima), so
    # each cell's first `consumed` outputs are exact.
    bounds_a, bounds_b = merge_path_partition(keys_a, keys_b, tile)
    n_tiles = len(bounds_a) - 1
    lanes = []
    for keys, bounds, flag in ((keys_a, bounds_a, 0),
                               (keys_b, bounds_b, np.uint32(1 << 31))):
        n = len(keys)
        hi, lo = _split_key_lanes(keys)
        t_hi = np.full((n_tiles, tile), 0xFFFFFFFF, dtype=np.uint32)
        t_lo = np.full((n_tiles, tile), 0xFFFFFFFF, dtype=np.uint32)
        # pad payload 0xFFFFFFFF: sorts after every real source index, so
        # the kernel's payload tie-break keeps pads strictly behind real
        # entries even when a real key equals the dtype maximum
        t_p = np.full((n_tiles, tile), 0xFFFFFFFF, dtype=np.uint32)
        if n:
            idx = np.arange(n, dtype=np.int64)
            t_of = np.searchsorted(bounds, idx, side="right") - 1
            off = idx - bounds[t_of]
            t_hi[t_of, off] = hi
            t_lo[t_of, off] = lo
            t_p[t_of, off] = idx.astype(np.uint32) | flag
        lanes.extend((t_hi, t_lo, t_p))
    a_hi, a_lo, pa, b_hi, b_lo, pb = lanes
    ohi, olo, op = _merge_tiles_jit(
        jnp.asarray(a_hi), jnp.asarray(a_lo), jnp.asarray(b_hi),
        jnp.asarray(b_lo), jnp.asarray(pa), jnp.asarray(pb),
        interpret=interpret)
    ohi = np.asarray(ohi).reshape(-1)
    olo = np.asarray(olo).reshape(-1)
    op = np.asarray(op).reshape(-1)
    # strip padding: valid entries per cell sit at the front
    cnt = np.diff(bounds_a) + np.diff(bounds_b)
    keep = (np.arange(2 * tile)[None, :] < cnt[:, None]).ravel()
    merged = (ohi.astype(np.uint64) << np.uint64(32)) | olo
    return _from_u64_order(merged[keep], out_dtype), op[keep]


@jax.jit
def _bloom_hash_jit(lo, hi):
    return _kernel_hash_pair(lo, hi)


def bloom_build_hashes(keys) -> Tuple[np.ndarray, np.ndarray]:
    """Device-side hash pass for filter *construction* (DESIGN.md §10).

    The ``use_pallas_bloom`` build route: compaction's output-filter rebuild
    hashes every surviving key through the kernel's own u32 hash family on
    the accelerator, and ``core.bloom.build_bits`` packs the bitset from the
    returned pair — bit-identical to ``core.bloom.hash_pair`` (the numpy
    twin), so probes from either backend agree on the result.
    """
    lo, hi = split_u64(keys)
    h1, h2 = _bloom_hash_jit(lo, hi)
    return np.asarray(h1), np.asarray(h2)


@partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    block_tables: jax.Array, lengths: jax.Array,
                    interpret: bool = True) -> jax.Array:
    return paged_attention_pallas(q, k_pages, v_pages, block_tables, lengths,
                                  interpret=interpret)


@partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                   "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0, bq: int = 128,
                    bk: int = 128, interpret: bool = True) -> jax.Array:
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  bq=bq, bk=bk, interpret=interpret)
