"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True (this container is CPU-only; on TPU pass
interpret=False and the same BlockSpecs drive real Mosaic lowering).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .bloom_probe import bloom_probe_pallas
from .flash_attention import flash_attention_pallas
from .merge_path import bitonic_merge_pallas
from .paged_attention import paged_attention_pallas


def split_u64(keys) -> Tuple[jax.Array, jax.Array]:
    """u64 -> (lo32, hi32). Done in numpy: jax's default x32 mode would
    silently truncate uint64."""
    keys = np.asarray(keys, dtype=np.uint64)
    lo = (keys & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    hi = (keys >> np.uint64(32)).astype(np.uint32)
    return jnp.asarray(lo), jnp.asarray(hi)


@partial(jax.jit, static_argnames=("k_hashes", "interpret"))
def _bloom_probe_jit(lo, hi, bits, k_hashes, interpret):
    return bloom_probe_pallas(lo, hi, bits, k_hashes, interpret=interpret)


def bloom_probe(keys, bits: jax.Array, k_hashes: int = 7,
                interpret: bool = True) -> jax.Array:
    """Probe u64 keys against a u32-word bitset. Returns bool 'maybe'."""
    lo, hi = split_u64(keys)
    return _bloom_probe_jit(lo, hi, bits, k_hashes, interpret)


def bloom_probe_filter(bf, keys, interpret: bool = True) -> np.ndarray:
    """Probe a ``repro.core.bloom.BloomFilter`` with the Pallas kernel.

    The filter builds its bitset with the kernel's own 32-bit hash family, so
    this returns bit-identical answers to ``bf.may_contain`` — it is the
    engine's accelerator route for batched point reads (DESIGN.md §3).  Pads
    the query batch up to the kernel's block multiple and strips the pad.
    """
    from .bloom_probe import QUERY_BLOCK

    keys = np.asarray(keys, dtype=np.uint64)
    n = keys.size
    if bf.k == 0 or n == 0:
        return np.ones(n, dtype=bool)
    # Quantize the batch shape (pow2 up to a block, then block multiples) so
    # the jit cache holds a handful of kernels instead of one per batch size.
    if n < QUERY_BLOCK:
        m = 64
        while m < n:
            m *= 2
    else:
        m = -(-n // QUERY_BLOCK) * QUERY_BLOCK
    if m != n:
        keys = np.concatenate([keys, np.zeros(m - n, np.uint64)])
    out = np.asarray(bloom_probe(keys, jnp.asarray(bf.bits), bf.k,
                                 interpret=interpret))
    return out[:n]


@partial(jax.jit, static_argnames=("interpret",))
def merge_sorted_tiles(a: jax.Array, b: jax.Array, pa: jax.Array,
                       pb: jax.Array, interpret: bool = True):
    """Merge batches of sorted tiles: (n,T)+(n,T) -> (n,2T) sorted."""
    return bitonic_merge_pallas(a, b, pa, pb, interpret=interpret)


def merge_runs_tiled(keys_a: np.ndarray, keys_b: np.ndarray,
                     tile: int = 256, interpret: bool = True
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Full two-run merge: host-side merge-path partition (searchsorted on
    the fence keys) + one bitonic kernel launch per tile pair.

    Returns (merged_keys, source_index) where source_index encodes
    (run_id << 32 | position) so the engine can permute value rows.
    """
    na, nb = len(keys_a), len(keys_b)
    n_out = na + nb
    # Diagonal spacing = tile: merge-path guarantees each cell consumes at
    # most `tile` from either input; pads sort to the back (+inf), so each
    # cell's first `consumed` outputs are exact.
    n_tiles = max(1, -(-n_out // tile))
    pad_val = np.iinfo(keys_a.dtype).max if \
        np.issubdtype(keys_a.dtype, np.integer) else np.finfo(keys_a.dtype).max
    at = np.full((n_tiles, tile), pad_val, dtype=keys_a.dtype)
    bt = np.full((n_tiles, tile), pad_val, dtype=keys_b.dtype)
    pa = np.zeros((n_tiles, tile), dtype=np.uint32)
    pb = np.zeros((n_tiles, tile), dtype=np.uint32)
    bounds_a = [0]
    bounds_b = [0]
    for t in range(1, n_tiles + 1):
        d = min(t * tile, n_out)
        lo, hi = max(0, d - nb), min(d, na)
        while lo < hi:  # merge-path binary search on the diagonal
            mid = (lo + hi) // 2
            if keys_a[mid] < keys_b[d - mid - 1]:
                lo = mid + 1
            else:
                hi = mid
        bounds_a.append(lo)
        bounds_b.append(d - lo)
    for t in range(n_tiles):
        ia, ja = bounds_a[t], bounds_a[t + 1]
        ib, jb = bounds_b[t], bounds_b[t + 1]
        at[t, :ja - ia] = keys_a[ia:ja]
        pa[t, :ja - ia] = np.arange(ia, ja, dtype=np.uint32)
        bt[t, :jb - ib] = keys_b[ib:jb]
        pb[t, :jb - ib] = (np.arange(ib, jb, dtype=np.uint32) |
                           np.uint32(1 << 31))
    ok, op = merge_sorted_tiles(jnp.asarray(at), jnp.asarray(bt),
                                jnp.asarray(pa), jnp.asarray(pb),
                                interpret=interpret)
    ok = np.asarray(ok).reshape(-1)
    op = np.asarray(op).reshape(-1)
    # strip padding: valid entries per cell sit at the front
    keep = np.zeros(ok.shape[0], bool)
    for t in range(n_tiles):
        cnt = (bounds_a[t + 1] - bounds_a[t]) + (bounds_b[t + 1] - bounds_b[t])
        keep[t * 2 * tile: t * 2 * tile + cnt] = True
    return ok[keep], op[keep]


@partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    block_tables: jax.Array, lengths: jax.Array,
                    interpret: bool = True) -> jax.Array:
    return paged_attention_pallas(q, k_pages, v_pages, block_tables, lengths,
                                  interpret=interpret)


@partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                   "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0, bq: int = 128,
                    bk: int = 128, interpret: bool = True) -> jax.Array:
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  bq=bq, bk=bk, interpret=interpret)
