"""Pallas TPU kernel: bitonic two-way sorted merge (the compaction hotspot).

Hardware adaptation (DESIGN.md §2): a CPU/GPU merge walks two cursors
(branchy, serial) or binary-searches a merge path (dynamic control flow).
Neither maps to the TPU VPU.  Instead we use the classic bitonic-merge
network: concat(A, reverse(B)) of two sorted tiles is a bitonic sequence,
and log2(2T) static compare-exchange stages — pure jnp.minimum/maximum over
VMEM tiles with *static* strides — sort it.  Payloads (value indices) ride
along through the same selects, so the engine can permute value rows after
the kernel returns.

Keys are carried as *two u32 lanes* (hi, lo) compared lexicographically —
the VPU has no u64 lanes, exactly the split the bloom-probe kernel makes —
so the engine's uint64 user keys merge exactly (u32 callers pass hi = 0).

ops.py composes multi-tile runs: tile boundaries are partitioned with the
host-side :func:`merge_path_partition` (one vectorized ``np.searchsorted``
pass instead of a per-diagonal binary-search loop), and each pair of
partitions is merged by one grid cell.  The same BlockSpecs drive interpret
mode on CPU and Mosaic lowering on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _compare_exchange(hi: jnp.ndarray, lo: jnp.ndarray, payload: jnp.ndarray,
                      stride: int):
    """One bitonic stage over (2T,) tiles: static-stride compare-exchange of
    lexicographic (hi, lo, payload) triples.  The payload tie-break makes
    the network deterministic AND orders tile pads (payload 0xFFFFFFFF,
    larger than any real source index) strictly after real entries sharing
    their key — so even a real key equal to the dtype maximum cannot be
    displaced by padding."""
    n = hi.shape[0]

    def split(x):
        x2 = x.reshape(n // (2 * stride), 2, stride)
        return x2[:, 0], x2[:, 1]

    hi_l, hi_r = split(hi)
    lo_l, lo_r = split(lo)
    p_l, p_r = split(payload)
    keys_eq = (hi_l == hi_r) & (lo_l == lo_r)
    swap = (hi_l > hi_r) | ((hi_l == hi_r) & (lo_l > lo_r)) \
        | (keys_eq & (p_l > p_r))

    def merge(l, r):
        new_l = jnp.where(swap, r, l)
        new_r = jnp.where(swap, l, r)
        return jnp.stack([new_l, new_r], axis=1).reshape(n)

    return merge(hi_l, hi_r), merge(lo_l, lo_r), merge(p_l, p_r)


def bitonic_merge_kernel(a_hi_ref, a_lo_ref, b_hi_ref, b_lo_ref,
                         pa_ref, pb_ref, ohi_ref, olo_ref, op_ref,
                         *, tile: int):
    """Merge two sorted (T,) tiles (split-u64 keys + payloads) into (2T,)."""
    hi = jnp.concatenate([a_hi_ref[...], b_hi_ref[...][::-1]])
    lo = jnp.concatenate([a_lo_ref[...], b_lo_ref[...][::-1]])
    payload = jnp.concatenate([pa_ref[...], pb_ref[...][::-1]])
    stride = tile
    while stride >= 1:
        hi, lo, payload = _compare_exchange(hi, lo, payload, stride)
        stride //= 2
    ohi_ref[...] = hi
    olo_ref[...] = lo
    op_ref[...] = payload


def bitonic_merge_pallas(a_hi: jax.Array, a_lo: jax.Array, b_hi: jax.Array,
                         b_lo: jax.Array, pa: jax.Array, pb: jax.Array,
                         interpret: bool = True):
    """a/b: sorted (n, T) tile batches as (hi, lo) u32 lanes; pa, pb: u32
    payloads.  Returns merged (n, 2T) key lanes + payloads — one grid cell
    per tile pair."""
    n, tile = a_lo.shape
    kern = functools.partial(bitonic_merge_kernel, tile=tile)
    return pl.pallas_call(
        kern,
        grid=(n,),
        in_specs=[pl.BlockSpec((None, tile), lambda i: (i, 0))] * 6,
        out_specs=[pl.BlockSpec((None, 2 * tile), lambda i: (i, 0))] * 3,
        out_shape=[jax.ShapeDtypeStruct((n, 2 * tile), a_hi.dtype),
                   jax.ShapeDtypeStruct((n, 2 * tile), a_lo.dtype),
                   jax.ShapeDtypeStruct((n, 2 * tile), pa.dtype)],
        interpret=interpret,
    )(a_hi, a_lo, b_hi, b_lo, pa, pb)


def merge_path_partition(keys_a: np.ndarray, keys_b: np.ndarray, tile: int):
    """Host-side merge-path split at every ``tile``-th output diagonal.

    One vectorized pass: each element's final slot in the merged output is
    its own index plus its rank in the other input (ties break a-first), so
    the count of A-elements before diagonal ``d`` is one ``searchsorted``
    into those slots.  Replaces the per-diagonal binary-search loop; each
    cell consumes at most ``tile`` from either input by construction.

    Returns ``(bounds_a, bounds_b)``, int64 arrays of length n_tiles + 1.
    """
    na, nb = len(keys_a), len(keys_b)
    n_out = na + nb
    n_tiles = max(1, -(-n_out // tile))
    pos_a = np.arange(na, dtype=np.int64) + np.searchsorted(keys_b, keys_a,
                                                            side="left")
    diag = np.minimum(np.arange(n_tiles + 1, dtype=np.int64) * tile, n_out)
    bounds_a = np.searchsorted(pos_a, diag, side="left")
    return bounds_a, diag - bounds_a
