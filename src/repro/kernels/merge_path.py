"""Pallas TPU kernel: bitonic two-way sorted merge (the compaction hotspot).

Hardware adaptation (DESIGN.md §2): a CPU/GPU merge walks two cursors
(branchy, serial) or binary-searches a merge path (dynamic control flow).
Neither maps to the TPU VPU.  Instead we use the classic bitonic-merge
network: concat(A, reverse(B)) of two sorted tiles is a bitonic sequence,
and log2(2T) static compare-exchange stages — pure jnp.minimum/maximum over
VMEM tiles with *static* strides — sort it.  Payloads (value indices) ride
along through the same selects, so the engine can permute value rows after
the kernel returns.

ops.py composes multi-tile runs: tile boundaries are partitioned with
jnp.searchsorted (host-side merge path), each pair of partitions is merged
by one grid cell.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _compare_exchange(keys: jnp.ndarray, payload: jnp.ndarray, stride: int):
    """One bitonic stage over a (2T,) tile: static-stride compare-exchange."""
    n = keys.shape[0]
    k2 = keys.reshape(n // (2 * stride), 2, stride)
    p2 = payload.reshape(n // (2 * stride), 2, stride)
    lo_k, hi_k = k2[:, 0], k2[:, 1]
    lo_p, hi_p = p2[:, 0], p2[:, 1]
    swap = lo_k > hi_k
    new_lo_k = jnp.where(swap, hi_k, lo_k)
    new_hi_k = jnp.where(swap, lo_k, hi_k)
    new_lo_p = jnp.where(swap, hi_p, lo_p)
    new_hi_p = jnp.where(swap, lo_p, hi_p)
    keys = jnp.stack([new_lo_k, new_hi_k], axis=1).reshape(n)
    payload = jnp.stack([new_lo_p, new_hi_p], axis=1).reshape(n)
    return keys, payload


def bitonic_merge_kernel(a_ref, b_ref, pa_ref, pb_ref, ok_ref, op_ref,
                         *, tile: int):
    """Merge two sorted (T,) tiles (keys + payloads) into sorted (2T,)."""
    keys = jnp.concatenate([a_ref[...], b_ref[...][::-1]])
    payload = jnp.concatenate([pa_ref[...], pb_ref[...][::-1]])
    stride = tile
    while stride >= 1:
        keys, payload = _compare_exchange(keys, payload, stride)
        stride //= 2
    ok_ref[...] = keys
    op_ref[...] = payload


def bitonic_merge_pallas(a: jax.Array, b: jax.Array, pa: jax.Array,
                         pb: jax.Array, interpret: bool = True):
    """a, b: sorted (n, T) tile batches; pa, pb: payloads. Returns merged
    (n, 2T) keys + payloads — one grid cell per tile pair."""
    n, tile = a.shape
    kern = functools.partial(bitonic_merge_kernel, tile=tile)
    return pl.pallas_call(
        kern,
        grid=(n,),
        in_specs=[pl.BlockSpec((None, tile), lambda i: (i, 0))] * 4,
        out_specs=[pl.BlockSpec((None, 2 * tile), lambda i: (i, 0))] * 2,
        out_shape=[jax.ShapeDtypeStruct((n, 2 * tile), a.dtype),
                   jax.ShapeDtypeStruct((n, 2 * tile), pa.dtype)],
        interpret=interpret,
    )(a, b, pa, pb)
