"""Pure-jnp oracles for every Pallas kernel (the allclose reference)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .bloom_probe import hash_pair


def bloom_probe_ref(keys_lo: jax.Array, keys_hi: jax.Array, bits: jax.Array,
                    k_hashes: int) -> jax.Array:
    h1, h2 = hash_pair(keys_lo, keys_hi)
    m = jnp.uint32(bits.shape[0] * 32)
    maybe = jnp.ones(keys_lo.shape, bool)
    for i in range(k_hashes):
        pos = (h1 + jnp.uint32(i) * h2) % m
        word = bits[(pos >> jnp.uint32(5)).astype(jnp.int32)]
        maybe &= ((word >> (pos & jnp.uint32(31))) & jnp.uint32(1)) != 0
    return maybe


def bloom_build_ref(keys_lo: np.ndarray, keys_hi: np.ndarray, m_words: int,
                    k_hashes: int) -> np.ndarray:
    """Host-side filter construction matching the kernel's hash family."""
    h1, h2 = jax.device_get(hash_pair(jnp.asarray(keys_lo),
                                      jnp.asarray(keys_hi)))
    bits = np.zeros(m_words, dtype=np.uint32)
    m = np.uint32(m_words * 32)
    for i in range(k_hashes):
        pos = (h1 + np.uint32(i) * h2) % m
        np.bitwise_or.at(bits, (pos >> np.uint32(5)).astype(np.int64),
                         np.uint32(1) << (pos & np.uint32(31)))
    return bits


def bitonic_merge_ref(a: jax.Array, b: jax.Array, pa: jax.Array,
                      pb: jax.Array):
    """Sorted merge of per-row tile pairs via argsort (stable order of equal
    keys may differ from the network; tests compare keys exactly and check
    payload/key pairing consistency)."""
    keys = jnp.concatenate([a, b], axis=-1)
    pay = jnp.concatenate([pa, pb], axis=-1)
    order = jnp.argsort(keys, axis=-1)
    return (jnp.take_along_axis(keys, order, -1),
            jnp.take_along_axis(pay, order, -1))


def paged_attention_ref(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                        block_tables: jax.Array, lengths: jax.Array
                        ) -> jax.Array:
    B, H, dh = q.shape
    n_phys, page, KH, _ = k_pages.shape
    G = H // KH
    P = block_tables.shape[1]
    k = k_pages[block_tables]            # (B, P, page, KH, dh)
    v = v_pages[block_tables]
    k = k.reshape(B, P * page, KH, dh)
    v = v.reshape(B, P * page, KH, dh)
    k = jnp.repeat(k, G, axis=2)
    v = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (dh ** -0.5)
    mask = jnp.arange(P * page)[None] < lengths[:, None]
    s = jnp.where(mask[:, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0) -> jax.Array:
    B, Sq, H, dh = q.shape
    KH = k.shape[2]
    G = H // KH
    kr = jnp.repeat(k, G, axis=2)
    vr = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bshd->bhqs", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * (dh ** -0.5)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    ok = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        ok &= kpos <= qpos
    if window > 0:
        ok &= kpos > qpos - window
    s = jnp.where(ok, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqs,bshd->bqhd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)
