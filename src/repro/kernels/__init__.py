"""Pallas TPU kernels for the paper's compute hot-spots (validated with
interpret=True on CPU; same BlockSpecs lower via Mosaic on real TPUs):
  bloom_probe      — batched point-read filter probes (paper §3.1 CPU cost)
  merge_path       — bitonic two-way sorted merge (compaction)
  paged_attention  — AutumnKV decode read path (block table = fence pointers)
  flash_attention  — prefill/train attention (kills the XLA softmax-chain HBM
                     traffic that dominates the dry-run roofline)
"""
from .ops import (bloom_probe, flash_attention, merge_runs_tiled,
                  merge_sorted_tiles, paged_attention, split_u64)
