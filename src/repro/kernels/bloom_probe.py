"""Pallas TPU kernel: batched bloom-filter probe (the point-read CPU hotspot).

The paper (§3.1 "CPU Optimization") argues filter probing is the emerging
point-read bottleneck; Autumn reduces probe count via fewer levels, and this
kernel makes each batch of probes one VPU pass: queries are tiled into VMEM
blocks, the k double-hashes are computed vectorially (splitmix64 on two u32
lanes — the TPU VPU has no u64 lanes), and the bitset is held in VMEM.

TPU adaptation notes (DESIGN.md §2): the per-probe random bitset access is a
dynamic gather; on TPU we express it as `jnp.take` over the VMEM-resident
bitset (Mosaic lowers small-table dynamic gathers; filters larger than VMEM
are probed level-by-level by ops.py, matching Monkey's per-level filters).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

QUERY_BLOCK = 512


def _mix32(x: jnp.ndarray, c1: int, c2: int) -> jnp.ndarray:
    """32-bit finalizer (murmur3-style), vectorizable on the VPU."""
    x = x.astype(jnp.uint32)
    x ^= x >> jnp.uint32(16)
    x *= jnp.uint32(c1)
    x ^= x >> jnp.uint32(13)
    x *= jnp.uint32(c2)
    x ^= x >> jnp.uint32(16)
    return x


def hash_pair(keys_lo: jnp.ndarray, keys_hi: jnp.ndarray):
    """Two independent 32-bit hashes from the (lo, hi) halves of u64 keys."""
    h1 = _mix32(keys_lo ^ _mix32(keys_hi, 0x85EBCA6B, 0xC2B2AE35),
                0xCC9E2D51, 0x1B873593)
    h2 = _mix32(keys_hi ^ _mix32(keys_lo, 0x27D4EB2F, 0x165667B1),
                0x9E3779B9, 0x85EBCA77) | jnp.uint32(1)
    return h1, h2


def bloom_probe_kernel(lo_ref, hi_ref, bits_ref, out_ref, *, k_hashes: int,
                       m_bits: int):
    lo = lo_ref[...]
    hi = hi_ref[...]
    h1, h2 = hash_pair(lo, hi)
    maybe = jnp.ones(lo.shape, jnp.bool_)
    m = jnp.uint32(m_bits)
    bits = bits_ref[...]
    for i in range(k_hashes):
        pos = (h1 + jnp.uint32(i) * h2) % m
        word = jnp.take(bits, (pos >> jnp.uint32(5)).astype(jnp.int32))
        maybe &= ((word >> (pos & jnp.uint32(31))) & jnp.uint32(1)) != 0
    out_ref[...] = maybe


def bloom_probe_pallas(keys_lo: jax.Array, keys_hi: jax.Array,
                       bits: jax.Array, k_hashes: int,
                       interpret: bool = True) -> jax.Array:
    """keys_lo/hi: (N,) uint32; bits: (W,) uint32 bitset. Returns (N,) bool."""
    n = keys_lo.shape[0]
    m_bits = bits.shape[0] * 32
    block = min(QUERY_BLOCK, n)
    assert n % block == 0, (n, block)
    grid = (n // block,)
    return pl.pallas_call(
        functools.partial(bloom_probe_kernel, k_hashes=k_hashes,
                          m_bits=m_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec(bits.shape, lambda i: (0,)),  # bitset: whole in VMEM
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.bool_),
        interpret=interpret,
    )(keys_lo, keys_hi, bits)
