from .config import (EncoderConfig, ModelConfig, MoEConfig, RGLRUConfig,
                     SSMConfig, Stage, VisionConfig, expand_stages,
                     find_stages)
from .params import (abstract_params, count_params, init_params,
                     logical_specs, param_table)
from .model import (abstract_cache, cache_logical_specs, decode_step,
                    init_cache, loss_fn, prefill)
