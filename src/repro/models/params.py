"""Declarative parameter tables for all architecture families.

Every parameter is described once by a ParamSpec (shape, logical axes, init);
``init_params``, ``abstract_params``, ``logical_specs`` and ``count_params``
all derive from the same table, so shapes, shardings and roofline parameter
counts cannot drift apart.

Logical axis names (mapped to mesh axes by repro.launch.sharding rules):
  vocab, embed, mlp, heads, kv_heads, head_dim, expert, ssm_inner, ssm_heads,
  rec, conv_w, norm, layers (the scan/stack dimension)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig, Stage, find_stages

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"        # normal | zeros | ones | output (scaled 1/sqrt(2L))
    fan_in_axes: Tuple[int, ...] = (0,)

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


# --------------------------------------------------------------------- table
def _mlp_specs(cfg: ModelConfig) -> Dict[str, Any]:
    D, F = cfg.d_model, cfg.d_ff
    if cfg.moe is not None:
        E = cfg.moe.num_experts
        return {
            "router": ParamSpec((D, E), ("embed", "expert")),
            "wg": ParamSpec((E, D, F), ("expert", "embed", "mlp"), fan_in_axes=(1,)),
            "wu": ParamSpec((E, D, F), ("expert", "embed", "mlp"), fan_in_axes=(1,)),
            "wd": ParamSpec((E, F, D), ("expert", "mlp", "embed"), "output",
                            fan_in_axes=(1,)),
        }
    return {
        "wg": ParamSpec((D, F), ("embed", "mlp")),
        "wu": ParamSpec((D, F), ("embed", "mlp")),
        "wd": ParamSpec((F, D), ("mlp", "embed"), "output"),
    }


def _attn_core_specs(cfg: ModelConfig, src_dim: Optional[int] = None
                     ) -> Dict[str, Any]:
    D, H, KH, dh = cfg.d_model, cfg.n_q, cfg.n_kv, cfg.d_head
    S = src_dim or D
    out: Dict[str, Any] = {
        "wq": ParamSpec((D, H, dh), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((S, KH, dh), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((S, KH, dh), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((H, dh, D), ("heads", "head_dim", "embed"), "output",
                        fan_in_axes=(0, 1)),
    }
    if cfg.qk_norm:
        out["q_norm"] = ParamSpec((dh,), ("norm",), "ones")
        out["k_norm"] = ParamSpec((dh,), ("norm",), "ones")
    return out


def _block_specs(cfg: ModelConfig, kind: str) -> Dict[str, Any]:
    D = cfg.d_model
    ln = lambda: ParamSpec((D,), ("norm",), "ones")
    if kind in ("attn", "lattn"):
        return {"ln": ln(), **_attn_core_specs(cfg), "ln2": ln(),
                "mlp": _mlp_specs(cfg)}
    if kind == "xattn":
        return {"ln": ln(), **_attn_core_specs(cfg),
                "xgate": ParamSpec((1,), ("norm",), "zeros"),
                "ln2": ln(), "mlp": _mlp_specs(cfg),
                "mgate": ParamSpec((1,), ("norm",), "zeros")}
    if kind == "wdec":  # whisper decoder block: self-attn + cross-attn + mlp
        return {"ln": ln(), **_attn_core_specs(cfg),
                "ln_x": ln(),
                "x": _attn_core_specs(cfg),
                "ln2": ln(), "mlp": _mlp_specs(cfg)}
    if kind == "ssd":
        s = cfg.ssm
        d_inner = s.expand * D
        H = d_inner // s.head_dim
        conv_dim = d_inner + 2 * s.d_state
        d_in_proj = 2 * d_inner + 2 * s.d_state + H
        out = {
            "ln": ln(),
            "in_proj": ParamSpec((D, d_in_proj), ("embed", "ssm_inner")),
            "conv_w": ParamSpec((s.conv_width, conv_dim), ("conv_w", "ssm_inner"),
                                fan_in_axes=(0,)),
            "conv_b": ParamSpec((conv_dim,), ("ssm_inner",), "zeros"),
            "A_log": ParamSpec((H,), ("ssm_heads",), "ones"),
            "D": ParamSpec((H,), ("ssm_heads",), "ones"),
            "dt_bias": ParamSpec((H,), ("ssm_heads",), "zeros"),
            "norm": ParamSpec((d_inner,), ("ssm_inner",), "ones"),
            "out_proj": ParamSpec((d_inner, D), ("ssm_inner", "embed"), "output"),
        }
        if cfg.d_ff > 0:
            out["ln2"] = ln()
            out["mlp"] = _mlp_specs(cfg)
        return out
    if kind == "rglru":
        r = cfg.rglru
        W = r.width or D
        nb = r.gate_blocks
        if nb:
            assert W % nb == 0, (W, nb)
            gate = lambda: ParamSpec((nb, W // nb, W // nb),
                                     ("rec_blocks", "rec_blk_in",
                                      "rec_blk_out"), fan_in_axes=(1,))
        else:
            gate = lambda: ParamSpec((W, W), ("rec_in", "rec"))
        return {
            "ln": ln(),
            "wx": ParamSpec((D, W), ("embed", "rec")),       # recurrent branch
            "wy": ParamSpec((D, W), ("embed", "rec")),       # gate branch (GeLU)
            "conv_w": ParamSpec((r.conv_width, W), ("conv_w", "rec"),
                                fan_in_axes=(0,)),
            "conv_b": ParamSpec((W,), ("rec",), "zeros"),
            "wa_gate": gate(),                               # recurrence gate
            "ba_gate": ParamSpec((W,), ("rec",), "zeros"),
            "wi_gate": gate(),                               # input gate
            "bi_gate": ParamSpec((W,), ("rec",), "zeros"),
            "Lambda": ParamSpec((W,), ("rec",), "ones"),
            "wout": ParamSpec((W, D), ("rec", "embed"), "output"),
            "ln2": ln(),
            "mlp": _mlp_specs(cfg),
        }
    raise ValueError(f"unknown layer kind {kind!r}")


def _encoder_block_specs(cfg: ModelConfig) -> Dict[str, Any]:
    e = cfg.encoder
    D = cfg.d_model
    dh = D // e.n_heads
    ln = lambda: ParamSpec((D,), ("norm",), "ones")
    return {
        "ln": ln(),
        "wq": ParamSpec((D, e.n_heads, dh), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((D, e.n_heads, dh), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((D, e.n_heads, dh), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((e.n_heads, dh, D), ("heads", "head_dim", "embed"),
                        "output", fan_in_axes=(0, 1)),
        "ln2": ln(),
        "mlp": {
            "wg": ParamSpec((D, e.d_ff), ("embed", "mlp")),
            "wu": ParamSpec((D, e.d_ff), ("embed", "mlp")),
            "wd": ParamSpec((e.d_ff, D), ("mlp", "embed"), "output"),
        },
    }


def param_table(cfg: ModelConfig) -> Dict[str, Any]:
    """Full pytree of ParamSpec. Stage leaves carry a leading 'layers' axis."""
    D = cfg.d_model
    V = cfg.vocab_padded
    table: Dict[str, Any] = {
        "embed": ParamSpec((V, D), ("vocab", "embed")),
        "final_norm": ParamSpec((D,), ("norm",), "ones"),
    }
    if not cfg.tie_embeddings:
        table["lm_head"] = ParamSpec((D, V), ("embed", "vocab"))
    stages = find_stages(cfg.layer_pattern)
    table["stages"] = []
    for st in stages:
        blocks = [_stack_specs(_block_specs(cfg, k), st.repeat) for k in st.block]
        table["stages"].append({"blocks": blocks})
    if cfg.encoder is not None:
        e = cfg.encoder
        table["encoder"] = {
            "blocks": _stack_specs(_encoder_block_specs(cfg), e.n_layers),
            "final_norm": ParamSpec((D,), ("norm",), "ones"),
        }
    return table


def _stack_specs(tree: Pytree, repeat: int) -> Pytree:
    def stack(spec: ParamSpec) -> ParamSpec:
        return ParamSpec((repeat,) + spec.shape, ("layers",) + spec.logical,
                         spec.init,
                         tuple(a + 1 for a in spec.fan_in_axes))
    return jax.tree.map(stack, tree,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


# ------------------------------------------------------------ materializers
def _init_one(spec: ParamSpec, key: jax.Array, dtype, n_layers_total: int
              ) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    fan_in = 1
    for a in spec.fan_in_axes:
        fan_in *= spec.shape[a]
    scale = 1.0 / math.sqrt(max(fan_in, 1))
    if spec.init == "output":  # residual-output scaling
        scale /= math.sqrt(2.0 * max(n_layers_total, 1))
    return (jax.random.normal(key, spec.shape, jnp.float32) * scale).astype(dtype)


def init_params(cfg: ModelConfig, key: jax.Array) -> Pytree:
    table = param_table(cfg)
    leaves, treedef = jax.tree.flatten(
        table, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    dtype = jnp.dtype(cfg.param_dtype)
    vals = [_init_one(s, k, dtype, cfg.n_layers) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(cfg: ModelConfig) -> Pytree:
    """ShapeDtypeStruct pytree — used by the dry-run (no allocation)."""
    table = param_table(cfg)
    dtype = jnp.dtype(cfg.param_dtype)
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, dtype), table,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def logical_specs(cfg: ModelConfig) -> Pytree:
    table = param_table(cfg)
    return jax.tree.map(lambda s: s.logical, table,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    """Analytic count; with active_only, MoE experts count top_k/E of weights
    (for MODEL_FLOPS = 6 * N_active * D)."""
    table = param_table(cfg)
    total = 0
    for path, spec in jax.tree_util.tree_flatten_with_path(
            table, is_leaf=lambda x: isinstance(x, ParamSpec))[0]:
        n = int(np.prod(spec.shape))
        names = [getattr(p, "key", getattr(p, "idx", "")) for p in path]
        if active_only and cfg.moe and "mlp" in names and "expert" in spec.logical:
            n = n * cfg.moe.top_k // cfg.moe.num_experts
        total += n
    return total
