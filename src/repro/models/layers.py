"""Compute layers shared by all 10 architectures (pure JAX, jit/scan-safe).

Conventions:
  x          : (B, S, D) activations, compute_dtype (bf16)
  attention  : q (B,S,H,dh), kv (B,S,KH,dh); GQA groups G = H // KH
  shard(x, *logical) : activation sharding-constraint callback (identity on CPU)
All softmax/norm statistics are computed in float32.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig

Shard = Callable[..., jax.Array]
NEG_INF = -1e30


class _IdentityShard:
    """No-op Sharder (single-device tests)."""

    def __call__(self, x: jax.Array, *logical: Optional[str]) -> jax.Array:
        return x

    def is_sharded(self, name: str) -> bool:
        return False


identity_shard = _IdentityShard()


def shard_knows(shard: "Shard", name: str) -> bool:
    fn = getattr(shard, "is_sharded", None)
    return bool(fn(name)) if fn else False


# ------------------------------------------------------------------- norms
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(dt)


# -------------------------------------------------------------------- rope
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, dh) rotated by position; positions: (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------- attention
def _mask_bias(q_pos: jax.Array, k_pos: jax.Array, causal: bool,
               window: Optional[int], k_len: Optional[jax.Array]) -> jax.Array:
    """(…, Sq, Sk) additive bias. window counts positions (q-w, q]."""
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    ok = kp >= 0  # ring-buffer slots that were never written carry kp < 0
    if causal:
        ok &= kp <= qp
    if window is not None:
        ok &= kp > qp - window
    if k_len is not None:
        ok &= kp < k_len
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def gqa_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  q_positions: jax.Array, k_positions: jax.Array,
                  causal: bool, window: Optional[int],
                  k_len: Optional[jax.Array] = None,
                  q_chunk: int = 1024,
                  scores_dtype: str = "float32",
                  shard: Shard = identity_shard) -> jax.Array:
    """Memory-bounded GQA attention (repeat-KV formulation).

    q: (B,Sq,H,dh), k/v: (B,Sk,KH,dh) with H = G*KH.  KV heads are repeated
    to H before the contraction so every einsum carries a single `h` axis —
    this keeps TP sharding trivial (heads over 'model') and, when the KV
    *sequence* is the sharded axis instead (flash-decoding for GQA counts
    that don't divide the mesh), GSPMD reduces the softmax stats and PV
    partial sums with two small all-reduces.  Scores materialize one q-chunk
    at a time (q_chunk), bounding the fp32 score buffer.
    """
    B, Sq, H, dh = q.shape
    KH = k.shape[2]
    G = H // KH
    scale = 1.0 / math.sqrt(dh)
    sdt = jnp.dtype(scores_dtype)

    if Sq == 1 and G > 1:
        # Decode fast path: grouped einsum against the *unrepeated* cache —
        # avoids materializing a Gx copy of the KV cache per step (§Perf).
        k = shard(k, "batch", "att_kv_seq", "kv_heads", "head_dim")
        v = shard(v, "batch", "att_kv_seq", "kv_heads", "head_dim")
        qg = q.reshape(B, 1, KH, G, dh)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                       preferred_element_type=sdt) * scale
        bias = _mask_bias(q_positions, k_positions, causal, window, k_len)
        s = s + bias[:, None, None].astype(sdt)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - jax.lax.stop_gradient(m))
        l = jnp.sum(p, axis=-1, keepdims=True)
        o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v,
                       preferred_element_type=jnp.float32)
        o = o / jnp.maximum(jnp.transpose(l, (0, 3, 1, 2, 4)),
                            1e-30).astype(jnp.float32)
        return o.reshape(B, 1, H, dh).astype(q.dtype)

    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    # 'att_kv_seq' (not 'kv_seq'): the in-attention KV sharding can differ
    # from the cache-storage sharding (SP-prefill gathers KV while the cache
    # stays sequence-sharded for decode).
    k = shard(k, "batch", "att_kv_seq", "heads", "head_dim")
    v = shard(v, "batch", "att_kv_seq", "heads", "head_dim")

    def attend(q_blk: jax.Array, qpos_blk: jax.Array) -> jax.Array:
        # q_blk: (B, C, H, dh).  The softmax normalizer is folded into the
        # (C, dh)-sized output instead of a (C, Sk)-sized divide pass.
        # Re-assert SP inside the chunk loop: slicing a seq-sharded array
        # into chunks makes GSPMD replicate each chunk otherwise, and every
        # device would redundantly compute the full chunk (16x waste).
        q_blk = shard(q_blk, "batch", "seq", "heads", "head_dim")
        s = jnp.einsum("bqhd,bshd->bhqs", q_blk, k,
                       preferred_element_type=sdt) * scale
        bias = _mask_bias(qpos_blk, k_positions, causal, window, k_len)
        s = s + bias[:, None].astype(sdt)            # (B,H,C,Sk)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - jax.lax.stop_gradient(m))
        l = jnp.sum(p, axis=-1, keepdims=True)       # (B,H,C,1)
        o = jnp.einsum("bhqs,bshd->bqhd", p.astype(v.dtype), v,
                       preferred_element_type=jnp.float32)
        o = o / jnp.maximum(l.swapaxes(1, 2), 1e-30).astype(jnp.float32)
        return o.astype(q.dtype)

    if Sq <= q_chunk or Sq % q_chunk != 0:
        return attend(q, q_positions)
    nq = Sq // q_chunk
    qc = q.reshape(B, nq, q_chunk, H, dh).swapaxes(0, 1)
    pc = q_positions.reshape(B, nq, q_chunk).swapaxes(0, 1) \
        if q_positions.ndim == 2 else q_positions.reshape(nq, q_chunk)
    out = jax.lax.map(lambda args: attend(*args), (qc, pc))
    return out.swapaxes(0, 1).reshape(B, Sq, H, dh)


def attn_project_qkv(p: Dict[str, Any], x: jax.Array, src: jax.Array,
                     cfg: ModelConfig, positions: Optional[jax.Array],
                     src_positions: Optional[jax.Array],
                     shard: Shard) -> Tuple[jax.Array, jax.Array, jax.Array]:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"].astype(x.dtype))
    q = shard(q, "batch", "seq", "heads", "head_dim")
    if k.shape[1] > 1:  # decode's single fresh token stays replicated
        k = shard(k, "batch", "att_kv_seq", "kv_heads", "head_dim")
        v = shard(v, "batch", "att_kv_seq", "kv_heads", "head_dim")
    if cfg.qk_norm and "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if positions is not None:
        q = rope(q, positions, cfg.rope_theta)
    if src_positions is not None:
        k = rope(k, src_positions, cfg.rope_theta)
    return q, k, v


def attn_output(p: Dict[str, Any], ctx: jax.Array, x_dtype) -> jax.Array:
    return jnp.einsum("bshk,hkd->bsd", ctx, p["wo"].astype(x_dtype))


# -------------------------------------------------------------------- mlps
def dense_mlp(p: Dict[str, Any], x: jax.Array, shard: Shard) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, p["wu"].astype(x.dtype))
    h = shard(jax.nn.silu(h) * u, "batch", "seq", "mlp")
    return jnp.einsum("bsf,fd->bsd", h, p["wd"].astype(x.dtype))


def moe_mlp(p: Dict[str, Any], x: jax.Array, cfg: ModelConfig,
            shard: Shard) -> Tuple[jax.Array, jax.Array]:
    """Sort-based token-choice top-k MoE (drop-on-capacity, per sequence).

    Avoids (B,S,E,C) one-hot dispatch tensors: tokens are replicated k times,
    sorted by expert id, packed into (B, E, C, D) buffers, run through batched
    expert matmuls (E sharded over the 'model'/EP axis), then unsorted.
    Returns (output, load_balancing_aux_loss).
    """
    m = cfg.moe
    B, S, D = x.shape
    E, K = m.num_experts, m.top_k
    C = max(1, int(math.ceil(S * K * m.capacity_factor / E)))
    C = min(C, S * K)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_ids = jax.lax.top_k(probs, K)          # (B,S,K)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    # load-balance aux (Switch-style): E * sum_e f_e * p_e
    dispatch_frac = jnp.mean(
        jax.nn.one_hot(top_ids, E, dtype=jnp.float32), axis=(1, 2))
    aux = E * jnp.mean(jnp.sum(dispatch_frac * jnp.mean(probs, 1), -1))

    ids = top_ids.reshape(B, S * K)
    w = top_w.reshape(B, S * K)
    order = jnp.argsort(ids, axis=-1, stable=True)    # (B, S*K)
    sids = jnp.take_along_axis(ids, order, 1)
    sw = jnp.take_along_axis(w, order, 1)
    tok = order // K                                  # source token index
    seg_start = jax.vmap(lambda row: jnp.searchsorted(row, jnp.arange(E)))(sids)
    pos_in_e = jnp.arange(S * K)[None] - jnp.take_along_axis(seg_start, sids, 1)
    keep = (pos_in_e < C)
    slot = sids * C + jnp.minimum(pos_in_e, C - 1)    # (B, S*K)

    xg = jnp.take_along_axis(x, tok[..., None], axis=1)          # (B,S*K,D)
    keepf = keep.astype(x.dtype)[..., None]

    def scatter_row(xr, sr, kr):
        return jnp.zeros((E * C, D), x.dtype).at[sr].add(xr * kr)

    buf = jax.vmap(scatter_row)(xg, slot, keepf).reshape(B, E, C, D)
    buf = shard(buf, "batch", "expert", None, None)
    h = jnp.einsum("becd,edf->becf", buf, p["wg"].astype(x.dtype))
    u = jnp.einsum("becd,edf->becf", buf, p["wu"].astype(x.dtype))
    act = shard(jax.nn.silu(h) * u, "batch", "expert", None, "mlp")
    y = jnp.einsum("becf,efd->becd", act, p["wd"].astype(x.dtype))
    y = shard(y, "batch", "expert", None, None).reshape(B, E * C, D)

    yg = jnp.take_along_axis(y, slot[..., None], axis=1)         # (B,S*K,D)
    yg = yg * keepf * sw.astype(x.dtype)[..., None]

    def gather_back(yr, tr):
        return jnp.zeros((S, D), x.dtype).at[tr].add(yr)

    out = jax.vmap(gather_back)(yg, tok)
    return shard(out, "batch", "seq", "embed"), aux


def mlp(p: Dict[str, Any], x: jax.Array, cfg: ModelConfig,
        shard: Shard) -> Tuple[jax.Array, jax.Array]:
    if not p:  # no-op stand-in (e.g. whisper blocks reuse attn plumbing)
        return jnp.zeros_like(x), jnp.zeros((), jnp.float32)
    if cfg.moe is not None and "router" in p:
        return moe_mlp(p, x, cfg, shard)
    return dense_mlp(p, x, shard), jnp.zeros((), jnp.float32)


# ------------------------------------------------------- causal conv (SSM)
def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B,S,C), w: (K,C)."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(K):  # K is tiny (4); unrolled adds, no conv primitive needed
        out = out + pad[:, i:i + x.shape[1]].astype(jnp.float32) * \
            w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def causal_conv1d_step(x_t: jax.Array, conv_state: jax.Array, w: jax.Array,
                       b: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One decode step. x_t: (B,C); conv_state: (B,K-1,C)."""
    K = w.shape[0]
    full = jnp.concatenate([conv_state, x_t[:, None]], axis=1)  # (B,K,C)
    y = jnp.einsum("bkc,kc->bc", full.astype(jnp.float32),
                   w.astype(jnp.float32)) + b.astype(jnp.float32)
    return y.astype(x_t.dtype), full[:, 1:]


# ------------------------------------------------------------- Mamba-2 SSD
def _segsum(x: jax.Array) -> jax.Array:
    """x: (..., T) -> (..., T, T) with out[i,j] = sum_{k in (j, i]} x[k], -inf i<j."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, -1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool))
    return jnp.where(mask, out, -jnp.inf)


def ssd_scan(xh: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
             Cm: jax.Array, chunk: int,
             init_state: Optional[jax.Array] = None
             ) -> Tuple[jax.Array, jax.Array]:
    """Chunked state-space-duality scan (Mamba-2, arXiv:2405.21060 listing 1).

    xh: (B,S,H,P) dt: (B,S,H) A: (H,)<0  Bm,Cm: (B,S,N) (one group).
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    nc = S // chunk
    assert nc * chunk == S, (S, chunk)
    x_ = (xh * dt[..., None]).reshape(Bsz, nc, chunk, H, P)
    dA = (dt * A).reshape(Bsz, nc, chunk, H)                      # (b,z,q,h)
    dA_cs = jnp.cumsum(dA, axis=2)
    Bc = Bm.reshape(Bsz, nc, chunk, N)
    Cc = Cm.reshape(Bsz, nc, chunk, N)

    # (1) within-chunk ("diagonal block") — attention-like, fp32 accumulation
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))                # (b,z,h,q,k)
    scores = jnp.einsum("bzqn,bzkn->bzqk", Cc.astype(jnp.float32),
                        Bc.astype(jnp.float32))
    y_diag = jnp.einsum("bzhqk,bzqk,bzkhp->bzqhp", L, scores,
                        x_.astype(jnp.float32))

    # (2) per-chunk outgoing states
    decay_out = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)              # (b,z,q,h)
    states = jnp.einsum("bzkn,bzkh,bzkhp->bzhpn", Bc.astype(jnp.float32),
                        decay_out, x_.astype(jnp.float32))

    # (3) inter-chunk recurrence (scan over chunks)
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])                     # (b,z,h)
    s0 = (jnp.zeros((Bsz, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(carry, inp):
        st, dec = inp                                             # (b,h,p,n),(b,h)
        new = carry * dec[..., None, None] + st
        return new, carry                                         # emit state *before* chunk

    final, prev_states = jax.lax.scan(
        step, s0, (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    prev_states = prev_states.swapaxes(0, 1)                      # (b,z,h,p,n)

    # (4) within-chunk contribution of the incoming state
    decay_in = jnp.exp(dA_cs)                                     # (b,z,q,h)
    y_off = jnp.einsum("bzqn,bzqh,bzhpn->bzqhp", Cc.astype(jnp.float32),
                       decay_in, prev_states)
    y = (y_diag + y_off).reshape(Bsz, S, H, P).astype(xh.dtype)
    return y, final.astype(jnp.float32)


def ssd_step(x_t: jax.Array, dt: jax.Array, A: jax.Array, B_t: jax.Array,
             C_t: jax.Array, state: jax.Array
             ) -> Tuple[jax.Array, jax.Array]:
    """Single decode step. x_t: (B,H,P) dt: (B,H) B_t,C_t: (B,N) state: (B,H,P,N)."""
    dA = jnp.exp(dt * A)                                          # (B,H)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, x_t.astype(jnp.float32),
                     B_t.astype(jnp.float32))
    state = state * dA[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, C_t.astype(jnp.float32))
    return y.astype(x_t.dtype), state


# ------------------------------------------------------------------ RG-LRU
def rglru_scan(u: jax.Array, r: jax.Array, i: jax.Array, lam: jax.Array,
               power: float, init_h: Optional[jax.Array] = None
               ) -> Tuple[jax.Array, jax.Array]:
    """Griffin RG-LRU over a sequence via associative scan.

    u,r,i: (B,S,W); lam: (W,). h_t = a_t h_{t-1} + sqrt(1-a_t^2) (i_t*u_t),
    a_t = exp(-power * softplus(lam) * r_t).
    Returns (h (B,S,W), final_h (B,W)).
    """
    log_a = -power * jax.nn.softplus(lam.astype(jnp.float32)) * \
        r.astype(jnp.float32)                                     # (B,S,W)
    a = jnp.exp(log_a)
    gated = (i * u).astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6)) * gated
    if init_h is not None:
        a = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
        b = jnp.concatenate([init_h.astype(jnp.float32)[:, None], b], axis=1)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, b), axis=1)
    if init_h is not None:
        hh = hh[:, 1:]
    return hh.astype(u.dtype), hh[:, -1]


def rglru_step(u_t: jax.Array, r_t: jax.Array, i_t: jax.Array, lam: jax.Array,
               power: float, h: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One decode step; u_t,r_t,i_t: (B,W); h: (B,W) fp32."""
    log_a = -power * jax.nn.softplus(lam.astype(jnp.float32)) * \
        r_t.astype(jnp.float32)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6)) * \
        (i_t * u_t).astype(jnp.float32)
    h = a * h + b
    return h.astype(u_t.dtype), h
