"""Model assembly: stage scanning, train/prefill/decode entry points, caches.

All stacks lower through ``lax.scan`` over super-blocks (config.find_stages),
so the HLO size is independent of depth — a 100-layer model compiles as fast
as a 2-layer one, which is what makes the 80-compile dry-run tractable.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import blocks
from .config import ModelConfig, Stage, find_stages
from .layers import (Shard, gqa_attention, identity_shard, mlp, rms_norm)

Pytree = Any


# ---------------------------------------------------------------- embedding
def embed_tokens(params: Pytree, tokens: jax.Array, cfg: ModelConfig,
                 shard: Shard) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0)
    return shard(x.astype(cfg.compute_dtype), "batch", "seq", "embed")


def unembed(params: Pytree, x: jax.Array, cfg: ModelConfig,
            shard: Shard) -> jax.Array:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    if cfg.vocab_padded != cfg.vocab:  # mask TP-padding rows out of the lse
        pad_mask = jnp.arange(cfg.vocab_padded) >= cfg.vocab
        logits = jnp.where(pad_mask, jnp.asarray(-1e30, logits.dtype), logits)
    return shard(logits, "batch", "seq", "vocab")


def sinusoid_positions(T: int, D: int) -> jax.Array:
    half = D // 2
    freqs = jnp.exp(-math.log(10_000.0) *
                    jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = jnp.arange(T, dtype=jnp.float32)[:, None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)[:, :D]


# ------------------------------------------------------------------ encoder
def encoder_forward(params: Pytree, frames: jax.Array, cfg: ModelConfig,
                    shard: Shard) -> jax.Array:
    """Whisper-style bidirectional encoder over (stubbed) frame embeddings."""
    e = cfg.encoder
    enc = params["encoder"]
    x = frames.astype(cfg.compute_dtype)
    x = x + sinusoid_positions(x.shape[1], cfg.d_model).astype(x.dtype)
    x = shard(x, "batch", "enc_seq", "embed")
    B, T, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))

    def body(xc, p):
        h = rms_norm(xc, p["ln"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(h.dtype))
        k = jnp.einsum("bsd,dhk->bshk", h, p["wk"].astype(h.dtype))
        v = jnp.einsum("bsd,dhk->bshk", h, p["wv"].astype(h.dtype))
        ctxv = gqa_attention(q, k, v, q_positions=pos, k_positions=pos,
                             causal=False, window=None, q_chunk=cfg.q_chunk,
                         scores_dtype=cfg.scores_dtype, shard=shard)
        xc = xc + jnp.einsum("bshk,hkd->bsd", ctxv, p["wo"].astype(h.dtype))
        y, _ = mlp(p["mlp"], rms_norm(xc, p["ln2"], cfg.norm_eps), cfg, shard)
        return xc + y, ()

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, enc["blocks"])
    return rms_norm(x, enc["final_norm"], cfg.norm_eps)


# ------------------------------------------------------------------- stages
def _make_ctx_train(cfg: ModelConfig, params: Pytree, batch: Dict[str, Any],
                    shard: Shard, S: int, B: int) -> Dict[str, Any]:
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    ctx: Dict[str, Any] = {"positions": pos, "s_max": S}
    if cfg.encoder is not None:
        ctx["enc_out"] = encoder_forward(params, batch["enc_frames"], cfg, shard)
    if cfg.vision is not None:
        ctx["img_embeds"] = batch["img_embeds"].astype(cfg.compute_dtype)
    return ctx


def _remat2_group(repeat: int) -> int:
    """Largest divisor of `repeat` not exceeding sqrt(repeat)."""
    g = int(math.isqrt(repeat))
    while g > 1 and repeat % g:
        g -= 1
    return max(g, 1)


def run_stages_train(params: Pytree, x: jax.Array, ctx: Dict[str, Any],
                     cfg: ModelConfig, shard: Shard
                     ) -> Tuple[jax.Array, jax.Array]:
    stages = find_stages(cfg.layer_pattern)
    aux_total = jnp.zeros((), jnp.float32)
    for si, st in enumerate(stages):
        sp = params["stages"][si]

        def body(xc, lp, _st=st):
            aux = jnp.zeros((), jnp.float32)
            for j, kind in enumerate(_st.block):
                xc, a = blocks.TRAIN[kind](kind, lp["blocks"][j], xc, ctx,
                                           cfg, shard)
                aux = aux + a
            return xc, aux

        g = _remat2_group(st.repeat) if (cfg.remat2 and cfg.remat) else 1
        if g > 1:
            # remat^2: outer scan saves G=repeat/g carries; each group of g
            # layers is one rematerialized unit (see ModelConfig.remat2).
            sp2 = jax.tree.map(
                lambda a: a.reshape((st.repeat // g, g) + a.shape[1:]), sp)

            def group(xc, gp):
                xc, auxs = jax.lax.scan(body, xc, gp)
                return xc, jnp.sum(auxs)

            x, auxs = jax.lax.scan(jax.checkpoint(group), x, sp2)
        else:
            body_fn = jax.checkpoint(body) if cfg.remat else body
            x, auxs = jax.lax.scan(body_fn, x, sp)
        aux_total = aux_total + jnp.sum(auxs)
    return x, aux_total


def run_stages_prefill(params: Pytree, x: jax.Array, ctx: Dict[str, Any],
                       cfg: ModelConfig, shard: Shard
                       ) -> Tuple[jax.Array, List[Pytree]]:
    stages = find_stages(cfg.layer_pattern)
    cache_stages: List[Pytree] = []
    for si, st in enumerate(stages):
        sp = params["stages"][si]

        def body(xc, lp, _st=st):
            caches = []
            for j, kind in enumerate(_st.block):
                xc, c, _ = blocks.PREFILL[kind](kind, lp["blocks"][j], xc, ctx,
                                                cfg, shard)
                caches.append(c)
            return xc, {"blocks": caches}

        x, cache = jax.lax.scan(body, x, sp)
        cache_stages.append(cache)
    return x, cache_stages


def run_stages_decode(params: Pytree, cache_stages: List[Pytree],
                      x: jax.Array, ctx: Dict[str, Any], cfg: ModelConfig,
                      shard: Shard) -> Tuple[jax.Array, List[Pytree]]:
    """Decode scans layers with the cache as a fori_loop *carry* (not scan
    xs/ys): XLA updates the carried buffers in place, so decode peak memory
    is one cache (+1 layer temp) instead of input-cache + stacked-ys-cache
    (2x) — see EXPERIMENTS.md §Perf iteration log."""
    stages = find_stages(cfg.layer_pattern)
    new_stages: List[Pytree] = []
    for si, st in enumerate(stages):
        sp = params["stages"][si]

        def body(i, carry, _st=st, _sp=sp):
            xc, cache = carry
            take = lambda a: jax.lax.dynamic_index_in_dim(a, i, 0,
                                                          keepdims=False)
            lp = jax.tree.map(take, _sp)
            lc = jax.tree.map(take, cache)
            new_blocks = []
            for j, kind in enumerate(_st.block):
                xc, c = blocks.DECODE[kind](kind, lp["blocks"][j],
                                            lc["blocks"][j], xc, ctx, cfg,
                                            shard)
                new_blocks.append(c)
            put = lambda buf, upd: jax.lax.dynamic_update_index_in_dim(
                buf, upd.astype(buf.dtype), i, 0)
            cache = jax.tree.map(put, cache, {"blocks": new_blocks})
            return (xc, cache)

        x, new_cache = jax.lax.fori_loop(0, st.repeat, body,
                                         (x, cache_stages[si]))
        new_stages.append(new_cache)
    return x, new_stages


# --------------------------------------------------------------------- loss
def _nll_of_chunk(params: Pytree, xc: jax.Array, lc: jax.Array,
                  mc: jax.Array, cfg: ModelConfig, shard: Shard):
    logits = unembed(params, xc, cfg, shard).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, lc[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    nll = lse - gold
    return (jnp.sum(nll * mc), jnp.sum((lse ** 2) * mc))


def loss_fn(params: Pytree, batch: Dict[str, Any], cfg: ModelConfig,
            shard: Shard = identity_shard,
            aux_coef: float = 0.01, z_coef: float = 1e-4
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    tokens, labels = batch["tokens"], batch["labels"]
    B, S = tokens.shape
    x = embed_tokens(params, tokens, cfg, shard)
    ctx = _make_ctx_train(cfg, params, batch, shard, S, B)
    x, aux = run_stages_train(params, x, ctx, cfg, shard)
    mask = batch.get("loss_mask",
                     jnp.ones((B, S), jnp.float32)).astype(jnp.float32)
    ntok = jnp.maximum(jnp.sum(mask), 1.0)
    # Vocab-chunked loss: (B, S, V) fp32 logits never materialize for the
    # whole sequence at once (memory lever for the 128k-262k vocab configs).
    C = cfg.loss_chunk
    if S > C and S % C == 0:
        nc = S // C
        xs = x.reshape(B, nc, C, -1).swapaxes(0, 1)
        ls = labels.reshape(B, nc, C).swapaxes(0, 1)
        ms = mask.reshape(B, nc, C).swapaxes(0, 1)
        fn = jax.checkpoint(
            lambda args: _nll_of_chunk(params, *args, cfg, shard))
        nlls, zs = jax.lax.map(fn, (xs, ls, ms))
        nll_sum, z_sum = jnp.sum(nlls), jnp.sum(zs)
    else:
        nll_sum, z_sum = _nll_of_chunk(params, x, labels, mask, cfg, shard)
    ce = nll_sum / ntok
    zloss = z_sum / ntok
    loss = ce + aux_coef * aux + z_coef * zloss
    return loss, {"ce": ce, "aux": aux, "zloss": zloss, "ntokens": ntok}


# ------------------------------------------------------------------ serving
def prefill(params: Pytree, batch: Dict[str, Any], cfg: ModelConfig,
            s_max: int, shard: Shard = identity_shard
            ) -> Tuple[jax.Array, Pytree]:
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed_tokens(params, tokens, cfg, shard)
    ctx = _make_ctx_train(cfg, params, batch, shard, S, B)
    ctx["s_max"] = s_max
    x, cache_stages = run_stages_prefill(params, x, ctx, cfg, shard)
    logits = unembed(params, x[:, -1:], cfg, shard)[:, 0]
    return logits, {"stages": cache_stages, "pos": jnp.asarray(S, jnp.int32)}


def decode_step(params: Pytree, tokens: jax.Array, cache: Pytree,
                cfg: ModelConfig, shard: Shard = identity_shard
                ) -> Tuple[jax.Array, Pytree]:
    """tokens: (B, 1). cache['pos'] is the write position of this token."""
    pos = cache["pos"]
    x = embed_tokens(params, tokens, cfg, shard)
    ctx = {"pos": pos, "s_max": 0}
    x, new_stages = run_stages_decode(params, cache["stages"], x, ctx, cfg,
                                      shard)
    logits = unembed(params, x, cfg, shard)[:, 0]
    return logits, {"stages": new_stages, "pos": pos + 1}


# ------------------------------------------------------------------- caches
@dataclasses.dataclass(frozen=True)
class CacheSpec:
    shape: Tuple[int, ...]
    dtype: Any
    logical: Tuple[Optional[str], ...]


def _block_cache_spec(cfg: ModelConfig, kind: str, B: int, s_max: int
                      ) -> Dict[str, CacheSpec]:
    cd = jnp.dtype(cfg.compute_dtype)
    KH, dh = cfg.n_kv, cfg.d_head
    kv_logical = ("batch", "kv_seq", "kv_heads", "head_dim")
    if kind == "attn":
        sc = s_max
        return {"k": CacheSpec((B, sc, KH, dh), cd, kv_logical),
                "v": CacheSpec((B, sc, KH, dh), cd, kv_logical)}
    if kind == "lattn":
        sc = min(cfg.window, s_max)
        return {"k": CacheSpec((B, sc, KH, dh), cd, kv_logical),
                "v": CacheSpec((B, sc, KH, dh), cd, kv_logical)}
    if kind == "xattn":
        T = cfg.vision.n_img_tokens
        lg = ("batch", "enc_seq", "kv_heads", "head_dim")
        return {"k": CacheSpec((B, T, KH, dh), cd, lg),
                "v": CacheSpec((B, T, KH, dh), cd, lg)}
    if kind == "wdec":
        T = cfg.encoder.seq_len
        lg = ("batch", "enc_seq", "kv_heads", "head_dim")
        return {"k": CacheSpec((B, s_max, KH, dh), cd, kv_logical),
                "v": CacheSpec((B, s_max, KH, dh), cd, kv_logical),
                "xk": CacheSpec((B, T, KH, dh), cd, lg),
                "xv": CacheSpec((B, T, KH, dh), cd, lg)}
    if kind == "ssd":
        s = cfg.ssm
        d_inner = s.expand * cfg.d_model
        H = d_inner // s.head_dim
        conv_dim = d_inner + 2 * s.d_state
        return {"state": CacheSpec((B, H, s.head_dim, s.d_state), jnp.float32,
                                   ("batch", "ssm_heads", "head_dim",
                                    "ssm_state")),
                "conv": CacheSpec((B, s.conv_width - 1, conv_dim), cd,
                                  ("batch", None, "ssm_inner"))}
    if kind == "rglru":
        W = cfg.rglru.width or cfg.d_model
        return {"h": CacheSpec((B, W), jnp.float32, ("batch", "rec")),
                "conv": CacheSpec((B, cfg.rglru.conv_width - 1, W), cd,
                                  ("batch", None, "rec"))}
    raise ValueError(kind)


def cache_table(cfg: ModelConfig, B: int, s_max: int) -> Pytree:
    stages = find_stages(cfg.layer_pattern)
    out: List[Pytree] = []
    for st in stages:
        blocks_specs = []
        for kind in st.block:
            spec = _block_cache_spec(cfg, kind, B, s_max)
            spec = {k: CacheSpec((st.repeat,) + v.shape, v.dtype,
                                 ("layers",) + v.logical)
                    for k, v in spec.items()}
            blocks_specs.append(spec)
        out.append({"blocks": blocks_specs})
    return {"stages": out,
            "pos": CacheSpec((), jnp.int32, ())}


def _is_cache_spec(x):
    return isinstance(x, CacheSpec)


def init_cache(cfg: ModelConfig, B: int, s_max: int) -> Pytree:
    t = cache_table(cfg, B, s_max)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), t,
                        is_leaf=_is_cache_spec)


def abstract_cache(cfg: ModelConfig, B: int, s_max: int,
                   pos: Optional[int] = None) -> Pytree:
    t = cache_table(cfg, B, s_max)
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), t,
                        is_leaf=_is_cache_spec)


def cache_logical_specs(cfg: ModelConfig, B: int, s_max: int) -> Pytree:
    t = cache_table(cfg, B, s_max)
    return jax.tree.map(lambda s: s.logical, t, is_leaf=_is_cache_spec)
