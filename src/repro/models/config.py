"""Model configuration covering all 10 assigned architecture families.

A model is a sequence of *stages*; each stage scans a stack of identical
super-blocks (an ordered tuple of layer kinds).  ``find_stages`` compresses an
explicit per-layer pattern (e.g. gemma3's 5 local : 1 global) into
(super_block, repeat) stages so heterogeneous stacks still lower to compact
``lax.scan`` HLO — essential for the 40-cell dry-run on a single host.

Layer kinds:
  attn    — global self-attention (GQA, optional qk_norm)
  lattn   — local/sliding-window self-attention
  xattn   — cross-attention (vision / encoder-decoder)
  ssd     — Mamba-2 state-space duality block
  rglru   — RG-LRU recurrent block (Griffin/RecurrentGemma)
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

LayerKind = str
ATTN_KINDS = ("attn", "lattn", "xattn")
RECURRENT_KINDS = ("ssd", "rglru")


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    chunk: int = 256
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    width: int = 0          # 0 => d_model
    conv_width: int = 4
    power: float = 8.0      # the "c" constant in a = exp(-c*softplus(L)*r)
    # Griffin uses BlockDiagonalLinear for the r/i gates; block count chosen
    # mesh-divisible (16) so each TP shard owns whole blocks and the gate
    # matmuls need no collectives (EXPERIMENTS.md §Perf). 0 = dense gates.
    gate_blocks: int = 0


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec models. The modality frontend is a stub:
    input_specs() provides precomputed frame embeddings (B, seq, d_model)."""
    n_layers: int
    n_heads: int
    d_ff: int
    seq_len: int            # number of frames after the (stubbed) conv frontend


@dataclasses.dataclass(frozen=True)
class VisionConfig:
    """Cross-attention image layers. The patch frontend is a stub: input_specs
    provides precomputed patch embeddings (B, n_img_tokens, d_model)."""
    n_img_tokens: int = 1600
    xattn_every: int = 5    # every 5th layer is cross-attention


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_q: int
    n_kv: int
    d_ff: int
    vocab: int
    d_head: int = 0                     # 0 => d_model // n_q
    layer_pattern: Tuple[LayerKind, ...] = ()  # () => all "attn"
    window: int = 4096                  # sliding window for "lattn" kinds
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    encoder: Optional[EncoderConfig] = None
    vision: Optional[VisionConfig] = None
    max_seq_len: int = 131_072
    q_chunk: int = 1024              # attention score-buffer bound (memory lever)
    loss_chunk: int = 1024           # vocab-loss seq chunking (memory lever)
    pad_vocab_to: int = 256          # TP-divisible vocab padding
    scores_dtype: str = "float32"    # attention score dtype (bf16 = traffic lever)
    # long_500k applicability: True only for sub-quadratic stacks
    subquadratic: bool = False
    # distribution knobs (overridable per shape by launch configs)
    remat: bool = True
    # remat^2: two-level sqrt(L) checkpointing of the layer scan — saves
    # G ~ sqrt(L) residual carries instead of L (peak-memory lever for the
    # 56/100-layer configs) at ~one extra rematerialized forward.
    remat2: bool = False
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // max(self.n_q, 1))
        if not self.layer_pattern:
            object.__setattr__(self, "layer_pattern", ("attn",) * self.n_layers)
        assert len(self.layer_pattern) == self.n_layers, \
            f"{self.name}: pattern len {len(self.layer_pattern)} != {self.n_layers}"

    @property
    def has_decoder_attn_cache(self) -> bool:
        return any(k in ATTN_KINDS for k in self.layer_pattern)

    @property
    def vocab_padded(self) -> int:
        """Vocab padded for clean TP sharding (Megatron's
        make-vocab-divisible); pad logits are masked to -inf in the loss."""
        pad = self.pad_vocab_to
        return -(-self.vocab // pad) * pad

    def param_count(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS = 6*N*D roofline)."""
        from .params import count_params  # local import to avoid cycle
        return count_params(self)

    def active_param_count(self) -> int:
        from .params import count_params
        return count_params(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class Stage:
    block: Tuple[LayerKind, ...]   # kinds inside one super-block
    repeat: int                    # scan length


def find_stages(pattern: Sequence[LayerKind], max_period: int = 8) -> List[Stage]:
    """Compress a layer pattern into scanned stages of repeating super-blocks.

    Finds the smallest period p (<= max_period) such that a prefix of the
    pattern is a whole number of repetitions of pattern[:p]; the remainder is
    recursively compressed.  Guarantees: concatenation of stage blocks x
    repeats reproduces ``pattern`` exactly, and the number of stages is tiny
    (1-2 for every assigned arch), keeping the lowered HLO compact.
    """
    pattern = tuple(pattern)
    if not pattern:
        return []
    best: Optional[Stage] = None
    for p in range(1, min(max_period, len(pattern)) + 1):
        block = pattern[:p]
        reps = 0
        while (reps + 1) * p <= len(pattern) and \
                pattern[reps * p:(reps + 1) * p] == block:
            reps += 1
        covered = reps * p
        if best is None or covered > best.repeat * len(best.block):
            best = Stage(block, reps)
    covered = best.repeat * len(best.block)
    return [best] + find_stages(pattern[covered:], max_period)


def expand_stages(stages: Sequence[Stage]) -> Tuple[LayerKind, ...]:
    out: List[LayerKind] = []
    for s in stages:
        out.extend(s.block * s.repeat)
    return tuple(out)
