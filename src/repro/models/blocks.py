"""Per-kind transformer/SSM/recurrent blocks in three modes:
  train   — full sequence, no cache (remat-friendly)
  prefill — full sequence, emits a decode cache
  decode  — one token, consumes + updates the cache

Decode KV caches are ring buffers: slot = pos % S_cache, and each slot's
absolute position is reconstructed as  kp = pos - ((pos - slot) % S_cache),
which (a) makes sliding-window caches exactly window-sized and (b) reduces to
the ordinary prefix cache when S_cache >= pos (stale slots fall out of the
causal mask).  This is the block-table-free analog of AutumnKV's fence
pointers for the in-step hot path.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (Shard, attn_output, attn_project_qkv, causal_conv1d,
                     causal_conv1d_step, dense_mlp, gqa_attention,
                     identity_shard, mlp, rglru_scan, rglru_step, rms_norm,
                     rope, ssd_scan, ssd_step)

Params = Dict[str, Any]
Cache = Dict[str, jax.Array]
Ctx = Dict[str, Any]   # positions / enc_out / img_embeds / pos scalar


def ring_positions(pos: jax.Array, s_cache: int) -> jax.Array:
    slots = jnp.arange(s_cache, dtype=jnp.int32)
    return pos - ((pos - slots) % s_cache)


# ====================================================================== attn
def _self_attn(p: Params, h: jax.Array, cfg: ModelConfig, shard: Shard,
               positions: jax.Array, window: Optional[int]) -> jax.Array:
    q, k, v = attn_project_qkv(p, h, h, cfg, positions, positions, shard)
    ctxv = gqa_attention(q, k, v, q_positions=positions, k_positions=positions,
                         causal=True, window=window, q_chunk=cfg.q_chunk,
                         scores_dtype=cfg.scores_dtype, shard=shard)
    return attn_output(p, ctxv, h.dtype)


def attn_train(kind: str, p: Params, x: jax.Array, ctx: Ctx, cfg: ModelConfig,
               shard: Shard) -> Tuple[jax.Array, jax.Array]:
    window = cfg.window if kind == "lattn" else None
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    x = x + _self_attn(p, h, cfg, shard, ctx["positions"], window)
    x = shard(x, "batch", "seq", "embed")
    y, aux = mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg, shard)
    return shard(x + y, "batch", "seq", "embed"), aux


def attn_prefill(kind: str, p: Params, x: jax.Array, ctx: Ctx,
                 cfg: ModelConfig, shard: Shard
                 ) -> Tuple[jax.Array, Cache, jax.Array]:
    window = cfg.window if kind == "lattn" else None
    S = x.shape[1]
    s_cache = min(window, ctx["s_max"]) if window else ctx["s_max"]
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q, k, v = attn_project_qkv(p, h, h, cfg, ctx["positions"],
                               ctx["positions"], shard)
    ctxv = gqa_attention(q, k, v, q_positions=ctx["positions"],
                         k_positions=ctx["positions"], causal=True,
                         window=window, q_chunk=cfg.q_chunk,
                         scores_dtype=cfg.scores_dtype, shard=shard)
    x = x + attn_output(p, ctxv, x.dtype)
    B, _, KH, dh = k.shape
    ck = jnp.zeros((B, s_cache, KH, dh), k.dtype)
    cv = jnp.zeros((B, s_cache, KH, dh), v.dtype)
    take = min(S, s_cache)
    slots = (jnp.arange(take) + S - take) % s_cache
    ck = ck.at[:, slots].set(k[:, S - take:])
    cv = cv.at[:, slots].set(v[:, S - take:])
    cache = {"k": shard(ck, "batch", "kv_seq", "kv_heads", "head_dim"),
             "v": shard(cv, "batch", "kv_seq", "kv_heads", "head_dim")}
    y, aux = mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg, shard)
    return shard(x + y, "batch", "seq", "embed"), cache, aux


def attn_decode(kind: str, p: Params, cache: Cache, x: jax.Array, ctx: Ctx,
                cfg: ModelConfig, shard: Shard
                ) -> Tuple[jax.Array, Cache]:
    window = cfg.window if kind == "lattn" else None
    pos = ctx["pos"]                                   # scalar int32
    h = rms_norm(x, p["ln"], cfg.norm_eps)             # (B,1,D)
    B = x.shape[0]
    qpos = jnp.full((B, 1), pos, jnp.int32)
    q, k, v = attn_project_qkv(p, h, h, cfg, qpos, qpos, shard)
    s_cache = cache["k"].shape[1]
    slot = (pos % s_cache).astype(jnp.int32)
    from .layers import shard_knows
    if shard_knows(shard, "kv_seq"):
        # Sequence-sharded cache: a dynamic-update-slice on the sharded dim
        # would make GSPMD gather the cache; a one-hot masked select is fully
        # elementwise and stays sharded (the Pallas paged-attention kernel
        # replaces this read-modify-write on real TPUs).
        sel = (jnp.arange(s_cache, dtype=jnp.int32) == slot)[None, :, None,
                                                             None]
        ck = jnp.where(sel, k.astype(cache["k"].dtype), cache["k"])
        cv = jnp.where(sel, v.astype(cache["v"].dtype), cache["v"])
    else:
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
    ck = shard(ck, "batch", "kv_seq", "kv_heads", "head_dim")
    cv = shard(cv, "batch", "kv_seq", "kv_heads", "head_dim")
    kp = ring_positions(pos, s_cache)[None]            # (1, S_c) broadcast
    ctxv = gqa_attention(q, ck, cv, q_positions=qpos, k_positions=kp,
                         causal=True, window=window, q_chunk=cfg.q_chunk,
                         scores_dtype=cfg.scores_dtype, shard=shard)
    x = x + attn_output(p, ctxv, x.dtype)
    y, _ = mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg, shard)
    return x + y, {"k": ck, "v": cv}


# ================================================================ cross-attn
def _cross_attn(p: Params, h: jax.Array, src_k: jax.Array, src_v: jax.Array,
                cfg: ModelConfig, shard: Shard) -> jax.Array:
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(h.dtype))
    if cfg.qk_norm and "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    Sk = src_k.shape[1]
    kpos = jnp.zeros((1, Sk), jnp.int32)
    qpos = jnp.zeros(h.shape[:2], jnp.int32)
    ctxv = gqa_attention(q, src_k, src_v, q_positions=qpos, k_positions=kpos,
                         causal=False, window=None, q_chunk=cfg.q_chunk,
                         scores_dtype=cfg.scores_dtype, shard=shard)
    return attn_output(p, ctxv, h.dtype)


def cross_kv(p: Params, src: jax.Array, cfg: ModelConfig,
             shard: Shard) -> Tuple[jax.Array, jax.Array]:
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"].astype(src.dtype))
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"].astype(src.dtype))
    if cfg.qk_norm and "k_norm" in p:
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    k = shard(k, "batch", "enc_seq", "kv_heads", "head_dim")
    v = shard(v, "batch", "enc_seq", "kv_heads", "head_dim")
    return k, v


def xattn_train(kind: str, p: Params, x: jax.Array, ctx: Ctx, cfg: ModelConfig,
                shard: Shard) -> Tuple[jax.Array, jax.Array]:
    src = ctx["img_embeds"]
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    k, v = cross_kv(p, src, cfg, shard)
    gate = jnp.tanh(p["xgate"].astype(jnp.float32)).astype(x.dtype)
    x = x + gate * _cross_attn(p, h, k, v, cfg, shard)
    mgate = jnp.tanh(p["mgate"].astype(jnp.float32)).astype(x.dtype)
    y, aux = mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg, shard)
    return x + mgate * y, aux


def xattn_prefill(kind, p, x, ctx, cfg, shard):
    src = ctx["img_embeds"]
    k, v = cross_kv(p, src, cfg, shard)
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    gate = jnp.tanh(p["xgate"].astype(jnp.float32)).astype(x.dtype)
    x = x + gate * _cross_attn(p, h, k, v, cfg, shard)
    mgate = jnp.tanh(p["mgate"].astype(jnp.float32)).astype(x.dtype)
    y, aux = mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg, shard)
    return x + mgate * y, {"k": k, "v": v}, aux


def xattn_decode(kind, p, cache, x, ctx, cfg, shard):
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    gate = jnp.tanh(p["xgate"].astype(jnp.float32)).astype(x.dtype)
    x = x + gate * _cross_attn(p, h, cache["k"], cache["v"], cfg, shard)
    mgate = jnp.tanh(p["mgate"].astype(jnp.float32)).astype(x.dtype)
    y, _ = mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg, shard)
    return x + mgate * y, cache


# ========================================== whisper decoder (self + cross)
def wdec_train(kind, p, x, ctx, cfg, shard):
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    x = x + _self_attn(p, h, cfg, shard, ctx["positions"], None)
    hx = rms_norm(x, p["ln_x"], cfg.norm_eps)
    k, v = cross_kv(p["x"], ctx["enc_out"], cfg, shard)
    x = x + _cross_attn(p["x"], hx, k, v, cfg, shard)
    y, aux = mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg, shard)
    return x + y, aux


def wdec_prefill(kind, p, x, ctx, cfg, shard):
    x, self_cache, _ = attn_prefill("attn", {**p, "mlp": _NOOP_MLP}, x,
                                    ctx, cfg, shard)
    hx = rms_norm(x, p["ln_x"], cfg.norm_eps)
    k, v = cross_kv(p["x"], ctx["enc_out"], cfg, shard)
    x = x + _cross_attn(p["x"], hx, k, v, cfg, shard)
    y, aux = mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg, shard)
    return x + y, {**self_cache, "xk": k, "xv": v}, aux


def wdec_decode(kind, p, cache, x, ctx, cfg, shard):
    x, self_cache = attn_decode("attn", {**p, "mlp": _NOOP_MLP},
                                {"k": cache["k"], "v": cache["v"]},
                                x, ctx, cfg, shard)
    hx = rms_norm(x, p["ln_x"], cfg.norm_eps)
    x = x + _cross_attn(p["x"], hx, cache["xk"], cache["xv"], cfg, shard)
    y, _ = mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg, shard)
    return x + y, {**self_cache, "xk": cache["xk"], "xv": cache["xv"]}


class _Noop(dict):
    """mlp params stand-in that contributes zero (used to reuse attn blocks)."""


_NOOP_MLP = _Noop()


# ================================================================== Mamba-2
def _ssd_proj(p: Params, x: jax.Array, cfg: ModelConfig, shard: Shard):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    proj = jnp.einsum("bsd,de->bse", h, p["in_proj"].astype(x.dtype))
    proj = shard(proj, "batch", "seq", "ssm_inner")
    z = proj[..., :d_inner]
    xBC = proj[..., d_inner:2 * d_inner + 2 * s.d_state]
    dt_raw = proj[..., 2 * d_inner + 2 * s.d_state:]
    return z, xBC, dt_raw, d_inner, H


def _ssd_split(xBC, d_inner, d_state):
    return (xBC[..., :d_inner], xBC[..., d_inner:d_inner + d_state],
            xBC[..., d_inner + d_state:])


def _ssd_chunk(S: int, pref: int) -> int:
    """Largest divisor of S not exceeding the preferred chunk size."""
    for c in range(min(pref, S), 0, -1):
        if S % c == 0:
            return c
    return 1


def ssd_train(kind, p, x, ctx, cfg, shard):
    s = cfg.ssm
    z, xBC, dt_raw, d_inner, H = _ssd_proj(p, x, cfg, shard)
    xBC = jax.nn.silu(causal_conv1d(xBC, p["conv_w"], p["conv_b"]))
    xs, Bm, Cm = _ssd_split(xBC, d_inner, s.d_state)
    B_, S, _ = x.shape
    xh = xs.reshape(B_, S, H, s.head_dim)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    chunk = _ssd_chunk(S, s.chunk)
    y, _ = ssd_scan(xh, dt, A, Bm, Cm, chunk)
    y = y + xh * p["D"].astype(jnp.float32)[None, None, :, None].astype(x.dtype)
    y = y.reshape(B_, S, d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    x = shard(x + out, "batch", "seq", "embed")
    if "mlp" in p:
        y2, aux = mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg, shard)
        return x + y2, aux
    return x, jnp.zeros((), jnp.float32)


def ssd_prefill(kind, p, x, ctx, cfg, shard):
    s = cfg.ssm
    z, xBC, dt_raw, d_inner, H = _ssd_proj(p, x, cfg, shard)
    conv_in = xBC
    xBC = jax.nn.silu(causal_conv1d(conv_in, p["conv_w"], p["conv_b"]))
    xs, Bm, Cm = _ssd_split(xBC, d_inner, s.d_state)
    B_, S, _ = x.shape
    xh = xs.reshape(B_, S, H, s.head_dim)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    chunk = _ssd_chunk(S, s.chunk)
    y, state = ssd_scan(xh, dt, A, Bm, Cm, chunk)
    y = y + xh * p["D"].astype(jnp.float32)[None, None, :, None].astype(x.dtype)
    y = y.reshape(B_, S, d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    x = x + out
    cache = {"state": state,                              # (B,H,P,N) fp32
             "conv": conv_in[:, S - (s.conv_width - 1):]}  # (B,K-1,convdim)
    if "mlp" in p:
        y2, aux = mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg, shard)
        return x + y2, cache, aux
    return x, cache, jnp.zeros((), jnp.float32)


def ssd_decode(kind, p, cache, x, ctx, cfg, shard):
    s = cfg.ssm
    z, xBC, dt_raw, d_inner, H = _ssd_proj(p, x, cfg, shard)
    xBC_t, conv_state = causal_conv1d_step(xBC[:, 0], cache["conv"],
                                           p["conv_w"], p["conv_b"])
    xBC_t = jax.nn.silu(xBC_t)
    xs = xBC_t[..., :d_inner]
    B_t = xBC_t[..., d_inner:d_inner + s.d_state]
    C_t = xBC_t[..., d_inner + s.d_state:]
    B_ = x.shape[0]
    xh = xs.reshape(B_, H, s.head_dim)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, state = ssd_step(xh, dt, A, B_t, C_t, cache["state"])
    y = y + xh * p["D"].astype(jnp.float32)[None, :, None].astype(x.dtype)
    y = y.reshape(B_, 1, d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    x = x + out
    new_cache = {"state": state, "conv": conv_state}
    if "mlp" in p:
        y2, _ = mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg, shard)
        x = x + y2
    return x, new_cache


# =================================================================== RG-LRU
def _rglru_gates(p: Params, x: jax.Array, cfg: ModelConfig, shard: Shard):
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", h, p["wy"].astype(x.dtype)))
    u = jnp.einsum("bsd,dw->bsw", h, p["wx"].astype(x.dtype))
    return shard(u, "batch", "seq", "rec"), shard(gate, "batch", "seq", "rec")


def _rglru_ri(p, u):
    if p["wa_gate"].ndim == 3:  # block-diagonal gates (Griffin): TP-local
        B_, S_, W_ = u.shape
        nb, wb, _ = p["wa_gate"].shape
        ub = u.reshape(B_, S_, nb, wb)
        r = jnp.einsum("bsnw,nwv->bsnv", ub, p["wa_gate"].astype(u.dtype)
                       ).reshape(B_, S_, W_) + p["ba_gate"].astype(u.dtype)
        i = jnp.einsum("bsnw,nwv->bsnv", ub, p["wi_gate"].astype(u.dtype)
                       ).reshape(B_, S_, W_) + p["bi_gate"].astype(u.dtype)
        return jax.nn.sigmoid(r), jax.nn.sigmoid(i)
    r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", u, p["wa_gate"].astype(u.dtype))
                       + p["ba_gate"].astype(u.dtype))
    i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", u, p["wi_gate"].astype(u.dtype))
                       + p["bi_gate"].astype(u.dtype))
    return r, i


def rglru_train(kind, p, x, ctx, cfg, shard):
    u, gate = _rglru_gates(p, x, cfg, shard)
    u = causal_conv1d(u, p["conv_w"], p["conv_b"])
    r, i = _rglru_ri(p, u)
    h, _ = rglru_scan(u, r, i, p["Lambda"], cfg.rglru.power)
    out = jnp.einsum("bsw,wd->bsd", h * gate, p["wout"].astype(x.dtype))
    x = shard(x + out, "batch", "seq", "embed")
    y, aux = mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg, shard)
    return x + y, aux


def rglru_prefill(kind, p, x, ctx, cfg, shard):
    u_raw, gate = _rglru_gates(p, x, cfg, shard)
    u = causal_conv1d(u_raw, p["conv_w"], p["conv_b"])
    r, i = _rglru_ri(p, u)
    h, h_last = rglru_scan(u, r, i, p["Lambda"], cfg.rglru.power)
    out = jnp.einsum("bsw,wd->bsd", h * gate, p["wout"].astype(x.dtype))
    x = x + out
    K = cfg.rglru.conv_width
    cache = {"h": h_last.astype(jnp.float32),
             "conv": u_raw[:, x.shape[1] - (K - 1):]}
    y, aux = mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg, shard)
    return x + y, cache, aux


def rglru_decode(kind, p, cache, x, ctx, cfg, shard):
    u_raw, gate = _rglru_gates(p, x, cfg, shard)
    u_t, conv_state = causal_conv1d_step(u_raw[:, 0], cache["conv"],
                                         p["conv_w"], p["conv_b"])
    r, i = _rglru_ri(p, u_t[:, None])
    h, h_new = rglru_step(u_t, r[:, 0], i[:, 0], p["Lambda"],
                          cfg.rglru.power, cache["h"])
    out = jnp.einsum("bw,wd->bd", h * gate[:, 0], p["wout"].astype(x.dtype))
    x = x + out[:, None]
    y, _ = mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg, shard)
    return x + y, {"h": h_new, "conv": conv_state}


# ------------------------------------------------------------------ routing
TRAIN = {"attn": attn_train, "lattn": attn_train, "xattn": xattn_train,
         "wdec": wdec_train, "ssd": ssd_train, "rglru": rglru_train}
PREFILL = {"attn": attn_prefill, "lattn": attn_prefill, "xattn": xattn_prefill,
           "wdec": wdec_prefill, "ssd": ssd_prefill, "rglru": rglru_prefill}
DECODE = {"attn": attn_decode, "lattn": attn_decode, "xattn": xattn_decode,
          "wdec": wdec_decode, "ssd": ssd_decode, "rglru": rglru_decode}
