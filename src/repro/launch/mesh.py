"""Mesh construction. Functions only — importing this module never touches
jax device state (required so tests/benches see 1 device while dryrun.py sees
512 placeholder devices via XLA_FLAGS)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod (TPU v5e); 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Mesh over whatever devices actually exist (tests / local training)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))


def dp_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_size(mesh) -> int:
    out = 1
    for a in dp_axes(mesh):
        out *= mesh.shape[a]
    return out
