"""End-to-end training driver with Autumn-checkpoint fault tolerance.

Runs a real training loop (CPU-sized configs; the same code path jits under
the production mesh) with:
  * periodic async checkpoints through the Autumn store,
  * crash/restart recovery (--inject-failure simulates a host dying: volatile
    state is dropped, the WAL/manifest recover the last durable checkpoint,
    and the deterministic seekable data pipeline resumes at the exact step),
  * elastic rescale (--rescale re-places restored params on a new mesh),
  * optional int8+error-feedback gradient compression over the data axis.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch smollm_135m --smoke \
      --steps 60 --checkpoint-every 20 --inject-failure 37
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import AsyncCheckpointer, CheckpointStore
from repro.configs import get_config, get_smoke
from repro.data import DataConfig, SyntheticTokens, stub_frontend_inputs
from repro.launch.mesh import make_host_mesh
from repro.launch.sharding import Sharder, make_rules, tree_shardings
from repro.models.params import init_params, logical_specs
from repro.train import OptConfig, init_opt_state, make_train_step


class Trainer:
    def __init__(self, cfg, opt_cfg: OptConfig, data_cfg: DataConfig,
                 store: Optional[CheckpointStore] = None,
                 checkpoint_every: int = 0, mesh=None):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.data = SyntheticTokens(data_cfg)
        self.data_cfg = data_cfg
        self.store = store or CheckpointStore()
        self.ckpt = AsyncCheckpointer(self.store) if checkpoint_every else None
        self.checkpoint_every = checkpoint_every
        self.mesh = mesh
        if mesh is not None:
            _, act_rules = make_rules(cfg, mesh, "train",
                                      data_cfg.global_batch, data_cfg.seq_len)
            self.sharder = Sharder(mesh, act_rules)
        else:
            from repro.models.layers import identity_shard
            self.sharder = identity_shard
        self.step_fn = jax.jit(make_train_step(cfg, opt_cfg, self.sharder))
        self.params = None
        self.opt_state = None
        self.step = 0

    # ------------------------------------------------------------------ init
    def init(self, seed: int = 0, try_restore: bool = True):
        restored_step = self.store.latest_step() if try_restore else None
        self.params = init_params(self.cfg, jax.random.PRNGKey(seed))
        self.opt_state = init_opt_state(self.params)
        if restored_step is not None:
            state = {"params": self.params, "opt": self.opt_state}
            shardings = None
            if self.mesh is not None:
                p_rules, _ = make_rules(self.cfg, self.mesh, "train",
                                        self.data_cfg.global_batch,
                                        self.data_cfg.seq_len)
                p_sh = tree_shardings(logical_specs(self.cfg), self.mesh,
                                      p_rules)
                shardings = {"params": p_sh,
                             "opt": {"m": p_sh, "v": p_sh, "step": None}}
                shardings = None  # step scalar spec mismatch; device_put per-leaf skipped
            restored = self.store.restore_tree(restored_step, state, None)
            if restored is not None:
                self.params = restored["params"]
                self.opt_state = restored["opt"]
                self.step = restored_step
        return self.step

    # ------------------------------------------------------------------ run
    def batch_for(self, step: int) -> Dict[str, Any]:
        b = {k: jnp.asarray(v) for k, v in self.data.get_batch(step).items()}
        extras = stub_frontend_inputs(self.cfg, self.data_cfg.host_batch,
                                      rng_seed=step)
        b.update({k: jnp.asarray(v) for k, v in extras.items()})
        return b

    def run(self, steps: int, inject_failure_at: Optional[int] = None,
            log_every: int = 10):
        metrics_hist = []
        t0 = time.time()
        while self.step < steps:
            if inject_failure_at is not None and self.step == inject_failure_at:
                raise SimulatedHostFailure(self.step)
            batch = self.batch_for(self.step)
            self.params, self.opt_state, m = self.step_fn(
                self.params, self.opt_state, batch)
            self.step += 1
            if self.checkpoint_every and \
                    self.step % self.checkpoint_every == 0:
                self.ckpt.submit(self.step,
                                 {"params": self.params, "opt": self.opt_state})
            if self.step % log_every == 0 or self.step == steps:
                loss = float(m["loss"])
                metrics_hist.append((self.step, loss))
                print(f"step {self.step:5d} loss {loss:8.4f} "
                      f"lr {float(m['lr']):.2e} "
                      f"({(time.time()-t0)/max(self.step,1)*1e3:.0f} ms/step)",
                      flush=True)
        if self.ckpt:
            self.ckpt.submit(self.step,
                             {"params": self.params, "opt": self.opt_state})
            self.ckpt.close()
            self.ckpt = None
        return metrics_hist

    def simulate_crash(self):
        """Volatile state gone; durable LSM state survives."""
        if self.ckpt:
            self.ckpt.close()
            self.ckpt = None
        self.store.crash()
        self.params = self.opt_state = None
        self.step = 0


class SimulatedHostFailure(RuntimeError):
    def __init__(self, step: int):
        super().__init__(f"simulated host failure at step {step}")
        self.step = step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_135m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--checkpoint-every", type=int, default=20)
    ap.add_argument("--inject-failure", type=int, default=None)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--schedule", default="wsd")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    opt_cfg = OptConfig(peak_lr=args.lr, warmup_steps=10,
                        total_steps=args.steps, schedule=args.schedule)
    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                          global_batch=args.batch)
    store = CheckpointStore()
    trainer = Trainer(cfg, opt_cfg, data_cfg, store,
                      checkpoint_every=args.checkpoint_every)
    trainer.init()
    try:
        hist = trainer.run(args.steps, inject_failure_at=args.inject_failure)
    except SimulatedHostFailure as e:
        print(f"!! {e} — recovering from Autumn checkpoint store")
        trainer.simulate_crash()
        resumed = trainer.init(try_restore=True)
        print(f"   restored at step {resumed}; resuming")
        trainer.ckpt = AsyncCheckpointer(store) \
            if args.checkpoint_every else None
        hist = trainer.run(args.steps)
    first, last = hist[0][1], hist[-1][1]
    print(f"loss {first:.4f} -> {last:.4f}  "
          f"(delta-skipped chunks: {store.stats_deltas_skipped}, "
          f"written: {store.stats_chunks_written}, "
          f"L={store.db.num_levels_in_use}, "
          f"WA={store.db.stats.write_amplification():.2f})")
    assert last < first, "training did not reduce loss"


if __name__ == "__main__":
    main()
