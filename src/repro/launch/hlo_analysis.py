"""Post-SPMD HLO-text cost model for the roofline analysis.

Why not ``compiled.cost_analysis()``?  XLA's HloCostAnalysis counts each
while-loop *body once* — a scanned 56-layer stack (or a microbatch
accumulation loop) would be under-counted by the trip count.  This module
parses ``compiled.as_text()`` (the per-device program after GSPMD
partitioning) into a call graph, recovers scan trip counts from loop
condition constants, and accumulates per-device:

  flops       — 2 * out_elems * contraction for every `dot` (weighted by the
                product of enclosing trip counts).  Elementwise flops are
                ignored (they are not the 197 TF/s MXU term).
  hbm_bytes   — sum of operand+output bytes of every *sequenced* instruction
                (instructions in ENTRY / while bodies / conditional branches;
                fusion internals are counted once at their call site, which
                is exactly XLA's fusion buffer-traffic semantics).
  coll_bytes  — wire bytes of all-gather / all-reduce / reduce-scatter /
                all-to-all / collective-permute, with ring-algorithm
                multipliers and replica-group sizes.

Validated against analytic model FLOPs in tests/test_dryrun.py.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1, "token": 0,
}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_SKIP_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "iota"}


def _parse_shape_dims(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _bytes_of(text: str) -> int:
    total = 0
    for dt, dims in _parse_shape_dims(text):
        total += _DTYPE_BYTES[dt] * math.prod(dims) if dims else _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    shape_text: str       # everything between '=' and the op name
    op: str
    operands: List[str]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    symbols: Dict[str, str]  # instr name -> shape text


_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")


def _parse_instr_line(line: str):
    """Parse `  [ROOT] %name = <shape> op(args...) ...` robustly.

    Tuple shapes contain `/*index=k*/` comments (with '=' inside), so the
    shape is scanned with paren balancing rather than a regex.
    Returns (name, shape_text, op, args_text) or None.
    """
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    m = re.match(r"%?([\w\.\-]+)\s*=\s*", s)
    if not m:
        return None
    name = m.group(1)
    rest = s[m.end():]
    if rest.startswith("("):  # tuple shape: scan to matching paren
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        shape_text, rest = rest[:i + 1], rest[i + 1:]
    else:
        m2 = re.match(r"[\w\[\]\{\},\d]+", rest)
        if not m2:
            return None
        shape_text, rest = m2.group(0), rest[m2.end():]
    m3 = re.match(r"\s*([\w\-]+)\(", rest)
    if not m3:
        return None
    op = m3.group(1)
    paren = rest[m3.end():]
    depth, args = 1, []
    for ch in paren:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        args.append(ch)
    return name, shape_text, op, "".join(args)


def _split_operands(args: str) -> List[Tuple[str, str]]:
    """Split an operand list into (name, inline_shape) pairs.

    Operands may be bare (``%p0``) or typed (``f32[32,256]{1,0} %p0`` —
    newer HLO emitters print the shape inline), and shapes contain commas,
    so the split must respect bracket/brace/paren nesting.  The inline
    shape (empty string when absent) lets callers resolve operand shapes
    even when the producing instruction lives in another computation.
    """
    parts: List[str] = []
    depth, cur = 0, []
    for ch in args:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    out: List[Tuple[str, str]] = []
    for p in parts:
        p = p.strip()
        if not p:
            continue
        m = re.search(r"%?([\w\.\-]+)$", p)
        if not m:
            continue
        out.append((m.group(1), p[: m.start()].strip()))
    return out


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry = ""
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if not line.strip() or line.startswith("HloModule"):
            continue
        if not line.startswith(" "):  # computation header at col 0
            m = _HDR_RE.match(line.strip())
            if m:
                cur = Computation(m.group(2), [], {})
                comps[cur.name] = cur
                if m.group(1):
                    entry = cur.name
                # header may declare params; record them
                for pm in re.finditer(r"%?([\w\.\-]+):\s*((?:\([^)]*\))|[\w\[\]\{\},]+)",
                                      line):
                    cur.symbols[pm.group(1)] = pm.group(2)
            continue
        if cur is None:
            continue
        parsed = _parse_instr_line(line)
        if parsed is None:
            continue
        name, shape_text, op, args = parsed
        pairs = _split_operands(args)
        operands = [n for n, _ in pairs]
        cur.symbols[name] = shape_text
        for n, inline_shape in pairs:
            # typed operands carry their shape inline; record it so shape
            # lookups work even when the producer wasn't parsed (or the
            # emitter never declares it separately)
            if inline_shape and n not in cur.symbols:
                cur.symbols[n] = inline_shape
        # parameters declared as `%p = f32[..] parameter(0)` already recorded
        cur.instrs.append(Instr(name, shape_text, op, operands, line.strip()))
    return comps, entry


def _trip_count(cond: Computation) -> int:
    """Largest scalar-int constant in the loop condition = scan length."""
    best = 1
    for ins in cond.instrs:
        m = re.match(r"%?[\w\.\-]+\s*=\s*[su]\d+\[\]\s*constant\((\d+)\)",
                     ins.line)
        if m:
            best = max(best, int(m.group(1)))
    return best


def _call_edges(comp: Computation) -> List[Tuple[str, float, str]]:
    """(callee, weight, kind) edges from a computation."""
    edges = []
    for ins in comp.instrs:
        line = ins.line
        if ins.op == "while":
            bm = re.search(r"body=%?([\w\.\-]+)", line)
            cm = re.search(r"condition=%?([\w\.\-]+)", line)
            if bm:
                edges.append((bm.group(1), -1.0, "while_body"))  # weight=trip
            if cm:
                edges.append((cm.group(1), -1.0, "while_cond"))
        elif ins.op == "conditional":
            for g in re.findall(r"(?:branch_computations=\{([^}]*)\}|"
                                r"true_computation=%?([\w\.\-]+)|"
                                r"false_computation=%?([\w\.\-]+))", line):
                for part in g:
                    for c in re.findall(r"%?([\w\.\-]+)", part):
                        if c:
                            edges.append((c, 1.0, "branch"))
        else:
            for cm in re.finditer(r"(?:calls=|to_apply=)%?([\w\.\-]+)", line):
                edges.append((cm.group(1), 1.0, "call"))
    return edges


def _multiplicities(comps: Dict[str, Computation], entry: str
                    ) -> Dict[str, float]:
    mult: Dict[str, float] = {name: 0.0 for name in comps}
    if entry not in comps:
        return {name: 1.0 for name in comps}
    mult[entry] = 1.0
    # call graph is a DAG; fixpoint iterate (few levels deep in practice)
    for _ in range(16):
        new = {name: 0.0 for name in comps}
        new[entry] = 1.0
        changed = False
        for name, comp in comps.items():
            w = mult.get(name, 0.0)
            if w <= 0:
                continue
            for callee, weight, kind in _call_edges(comp):
                if callee not in comps:
                    continue
                if weight < 0:  # while: weight = trip count of condition
                    cond_name = None
                    for c2, w2, k2 in _call_edges(comp):
                        if k2 == "while_cond":
                            cond_name = c2
                    trips = _trip_count(comps[cond_name]) if cond_name else 1
                    weight = float(trips) if kind == "while_body" else float(trips + 1)
                new[callee] = new.get(callee, 0.0) + w * weight
        for k in comps:
            if abs(new.get(k, 0.0) - mult.get(k, 0.0)) > 1e-9:
                changed = True
        mult = new
        if not changed:
            break
    # computations never reached (dead) still get 1 for safety in flop count
    return mult


def _sequenced(comps: Dict[str, Computation], entry: str) -> set:
    """ENTRY + while bodies/conds + conditional branches (not fusions)."""
    seq = {entry}
    frontier = [entry]
    while frontier:
        name = frontier.pop()
        comp = comps.get(name)
        if comp is None:
            continue
        for callee, _, kind in _call_edges(comp):
            if kind in ("while_body", "while_cond", "branch") and \
                    callee in comps and callee not in seq:
                seq.add(callee)
                frontier.append(callee)
    return seq


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_elems = 0
    for dt, dims in _parse_shape_dims(ins.shape_text):
        out_elems += math.prod(dims) if dims else 1
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    lhs_shape = comp.symbols.get(ins.operands[0], "") if ins.operands else ""
    kdims = [int(d) for d in m.group(1).split(",") if d] if m else []
    parsed = _parse_shape_dims(lhs_shape)
    k = 1
    if parsed and kdims:
        dims = parsed[0][1]
        for d in kdims:
            if d < len(dims):
                k *= dims[d]
    return 2.0 * out_elems * k


@dataclasses.dataclass
class HloCost:
    flops: float
    hbm_bytes: float
    coll_bytes: float
    coll_by_kind: Dict[str, float]
    coll_count: int
    trip_counts: Dict[str, float]


def analyze(hlo_text: str, total_devices: int) -> HloCost:
    comps, entry = parse_hlo(hlo_text)
    mult = _multiplicities(comps, entry)
    seq = _sequenced(comps, entry)
    flops = 0.0
    hbm = 0.0
    coll: Dict[str, float] = {k: 0.0 for k in _COLL_KINDS}
    n_coll = 0
    for name, comp in comps.items():
        w = mult.get(name, 0.0)
        if w <= 0:
            continue
        for ins in comp.instrs:
            if ins.op == "dot":
                flops += w * _dot_flops(ins, comp)
            kind = next((k for k in _COLL_KINDS
                         if ins.op in (k, k + "-start")), None)
            if kind is not None:
                out_b = _bytes_of(ins.shape_text)
                in_b = sum(_bytes_of(comp.symbols.get(o, ""))
                           for o in ins.operands)
                n = _group_size(ins.line, total_devices)
                frac = (n - 1) / max(n, 1)
                if kind == "all-gather":
                    b = out_b * frac
                elif kind == "reduce-scatter":
                    b = (in_b or out_b) * frac
                elif kind == "all-reduce":
                    b = 2 * out_b * frac
                elif kind == "all-to-all":
                    b = out_b * frac
                else:
                    b = out_b
                coll[kind] += w * b
                n_coll += 1
            if name in seq and ins.op not in _SKIP_OPS:
                hbm += w * _instr_traffic(ins, comp, comps)
    trips = {n: m for n, m in mult.items() if m > 1.0}
    return HloCost(flops, hbm, sum(coll.values()), coll, n_coll, trips)


def _instr_traffic(ins: Instr, comp: Computation,
                   comps: Dict[str, Computation]) -> float:
    """operand+output bytes, with dynamic-slice/update-slice awareness.

    A fusion that only *dynamic-slices* a big operand (decode indexing one
    layer of a stacked cache) physically reads the slice, not the buffer;
    a fusion rooted in dynamic-update-slice writes the update region in
    place.  Counting full buffers would overstate decode HBM traffic by the
    layer count (observed 100x on the qwen3 decode cell — §Perf).
    """
    out_b = _bytes_of(ins.shape_text)
    callee = None
    m = re.search(r"calls=%?([\w\.\-]+)", ins.line)
    if ins.op == "fusion" and m:
        callee = comps.get(m.group(1))
    if callee is None:
        return out_b + sum(_bytes_of(comp.symbols.get(o, ""))
                           for o in ins.operands)
    # map fusion params -> how they are consumed inside
    param_shape: Dict[int, str] = {}
    param_name_to_idx: Dict[str, int] = {}
    for fi in callee.instrs:
        pm = re.match(r"%?([\w\.\-]+)\s*=.*parameter\((\d+)\)",
                      fi.line.replace("ROOT ", ""))
        if pm:
            param_name_to_idx[pm.group(1)] = int(pm.group(2))
            param_shape[int(pm.group(2))] = fi.shape_text
    sliced_only: Dict[int, float] = {}
    full_use: set = set()
    # in-place DUS detection: any DUS inside the fusion whose target is a
    # parameter with the fusion's output shape (roots are often wrapped in
    # convert/bitcast, so match on shape rather than rootness)
    out_dims = _parse_shape_dims(ins.shape_text)
    for fi in callee.instrs:
        if fi.op != "dynamic-update-slice" or not fi.operands:
            continue
        tgt = fi.operands[0]
        if tgt in param_name_to_idx and \
                _parse_shape_dims(callee.symbols.get(tgt, ""))[:1] and \
                _parse_shape_dims(callee.symbols.get(tgt, ""))[0][1] == \
                (out_dims[0][1] if out_dims else None):
            upd = fi.operands[1] if len(fi.operands) > 1 else None
            upd_b = _bytes_of(callee.symbols.get(upd, "")) if upd else 0
            idx = param_name_to_idx[tgt]
            sliced_only[idx] = sliced_only.get(idx, 0.0) + upd_b
            out_b = upd_b  # written in place: only the region
    for fi in callee.instrs:
        for oi, o in enumerate(fi.operands):
            if o not in param_name_to_idx:
                continue
            idx = param_name_to_idx[o]
            if fi.op == "dynamic-slice":
                sliced_only[idx] = sliced_only.get(idx, 0.0) + \
                    _bytes_of(fi.shape_text)
            elif fi.op == "dynamic-update-slice" and oi == 0 and \
                    idx in sliced_only:
                pass  # already accounted as the in-place region
            else:
                full_use.add(idx)
    total = out_b
    for i, o in enumerate(ins.operands):
        b = _bytes_of(comp.symbols.get(o, ""))
        if i in sliced_only and i not in full_use:
            b = min(b, sliced_only[i])
        total += b
    return total


def _group_size(line: str, total_devices: int) -> int:
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:  # iota format [num_groups, group_size]
        return max(int(m.group(2)), 1)
    return total_devices
