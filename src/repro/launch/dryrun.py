import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces a JSON artifact under benchmarks/artifacts/ with:
  memory_analysis   — per-device argument/output/temp bytes (proves it fits)
  cost_analysis     — XLA's flat per-device estimates (single loop iteration)
  hlo_cost          — our trip-count-aware per-device flops / HBM bytes /
                      collective wire bytes (launch.hlo_analysis)
  roofline          — the three terms in seconds + dominant bottleneck
                      (single-pod only, per the assignment)

Usage:
  python -m repro.launch.dryrun                       # all cells, both meshes
  python -m repro.launch.dryrun --arch smollm_135m --shape train_4k
  python -m repro.launch.dryrun --multi-pod           # 2x16x16 only
  python -m repro.launch.dryrun --force               # ignore artifact cache
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell
from repro.models.params import count_params

ARTIFACT_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts"

# TPU v5e hardware constants (per chip) — assignment §Roofline.
PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_BW_PER_LINK = 50e9


def roofline_terms(hlo_cost: hlo_analysis.HloCost, chips: int,
                   cfg, shape) -> dict:
    """Three terms in seconds/step (per-device quantities / per-chip rates)."""
    compute_s = hlo_cost.flops / PEAK_FLOPS_BF16
    memory_s = hlo_cost.hbm_bytes / HBM_BW
    collective_s = hlo_cost.coll_bytes / ICI_BW_PER_LINK
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dom = max(terms, key=terms.get)
    n_active = count_params(cfg, active_only=True)
    tokens = shape.global_batch * (1 if shape.mode == "decode"
                                   else shape.seq_len)
    if shape.mode == "train":
        model_flops = 6.0 * n_active * tokens          # fwd 2ND + bwd 4ND
    else:
        model_flops = 2.0 * n_active * tokens
    model_flops_per_chip = model_flops / chips
    hlo_total = hlo_cost.flops
    return dict(terms, dominant=dom.replace("_s", ""),
                model_flops_per_chip=model_flops_per_chip,
                useful_flop_ratio=(model_flops_per_chip / hlo_total
                                   if hlo_total else 0.0),
                roofline_fraction=(model_flops_per_chip / PEAK_FLOPS_BF16)
                / max(terms.values()) if max(terms.values()) > 0 else 0.0)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             force: bool = False, tag: str = "", cfg_override=None,
             accum=None) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    out_path = ARTIFACT_DIR / f"{arch}__{shape_name}__{mesh_name}{tag}.json"
    if out_path.exists() and not force:
        cached = json.loads(out_path.read_text())
        if cached.get("status") != "error":   # errored cells always retry
            return cached
    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "mode": shape.mode, "tag": tag}
    if not ok:
        result.update(status="skipped", reason=why)
        _write(out_path, result)
        return result
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        chips = mesh.devices.size
        cell = build_cell(arch, shape_name, mesh, cfg_override=cfg_override,
                          accum=accum)
        with mesh:
            lowered = jax.jit(cell.fn, donate_argnums=cell.donate
                              ).lower(*cell.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = dict(compiled.cost_analysis() or {})
            hlo_text = compiled.as_text()
        hc = hlo_analysis.analyze(hlo_text, chips)
        result.update(
            status="ok",
            chips=chips,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            accum=cell.accum,
            n_params=count_params(cfg),
            n_active_params=count_params(cfg, active_only=True),
            memory_analysis={
                "argument_bytes": int(mem.argument_size_in_bytes),
                "output_bytes": int(mem.output_size_in_bytes),
                "temp_bytes": int(mem.temp_size_in_bytes),
                "alias_bytes": int(mem.alias_size_in_bytes),
                "peak_estimate_bytes": int(mem.argument_size_in_bytes +
                                           mem.output_size_in_bytes +
                                           mem.temp_size_in_bytes -
                                           mem.alias_size_in_bytes),
            },
            cost_analysis={k: v for k, v in cost.items()
                           if k in ("flops", "bytes accessed",
                                    "transcendentals", "optimal_seconds")},
            hlo_cost={
                "flops_per_device": hc.flops,
                "hbm_bytes_per_device": hc.hbm_bytes,
                "collective_bytes_per_device": hc.coll_bytes,
                "collective_by_kind": hc.coll_by_kind,
                "collective_sites": hc.coll_count,
                "scan_trip_counts": {k: v for k, v in
                                     sorted(hc.trip_counts.items())[:12]},
            },
        )
        if not multi_pod:
            result["roofline"] = roofline_terms(hc, chips, cfg, shape)
    except Exception as e:  # record failures as artifacts too
        result.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
    result["wall_s"] = round(time.time() - t0, 2)
    _write(out_path, result)
    return result


def _write(path: Path, obj: dict):
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(obj, indent=1, default=float))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_IDS + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true",
                    help="run only the 2x16x16 multi-pod mesh")
    ap.add_argument("--single-pod", action="store_true",
                    help="run only the 16x16 single-pod mesh")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True]
    if args.multi_pod:
        meshes = [True]
    if args.single_pod:
        meshes = [False]
    n_ok = n_skip = n_err = 0
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                r = run_cell(arch, shape, mp, force=args.force)
                tag = {"ok": "OK ", "skipped": "SKIP", "error": "ERR "}[
                    r["status"]]
                extra = ""
                if r["status"] == "ok":
                    mb = r["memory_analysis"]["peak_estimate_bytes"] / 2**30
                    extra = f"peak/dev={mb:7.2f}GiB compile={r['compile_s']:6.1f}s"
                    if "roofline" in r:
                        rf = r["roofline"]
                        extra += (f" dom={rf['dominant']:10s} "
                                  f"frac={rf['roofline_fraction']:.3f}")
                elif r["status"] == "error":
                    extra = r["error"][:120]
                    n_err += 1
                n_ok += r["status"] == "ok"
                n_skip += r["status"] == "skipped"
                print(f"[{tag}] {('2x16x16' if mp else '16x16  ')} "
                      f"{arch:24s} {shape:12s} {extra}", flush=True)
    print(f"done: ok={n_ok} skip={n_skip} err={n_err}")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
