"""Per-cell (arch x shape x mesh) abstract inputs + step functions.

Everything here is ShapeDtypeStruct-based (the shannon/kernels pattern):
weak-type-correct, sharding-annotated, zero device allocation — consumed by
dryrun.py for lower()+compile() and by benchmarks/roofline.py.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, ShapeSpec, get_config, shape_applicable
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.models.params import abstract_params, logical_specs
from repro.train.optimizer import OptConfig
from repro.train.step import make_train_step
from .sharding import Sharder, make_rules, spec_for, tree_shardings

Pytree = Any

# Microbatch (gradient-accumulation) factors for the train_4k shape — the
# activation-memory lever for the biggest configs (DESIGN.md §4); sized from
# the dry-run memory_analysis so every cell fits 16 GiB/chip (v5e).
TRAIN_ACCUM: Dict[str, int] = {
    "mixtral_8x22b": 16,
    "llama32_vision_90b": 16,
    "qwen3_4b": 4,
    "minicpm_2b": 2,
    "whisper_medium": 2,
    "granite_moe_1b_a400m": 4,
    "recurrentgemma_2b": 2,
    "gemma3_1b": 2,
}


def _sds(shape, dtype, sharding=None):
    if sharding is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _with_shardings(abs_tree: Pytree, shardings: Pytree) -> Pytree:
    return jax.tree.map(
        lambda a, s: _sds(a.shape, a.dtype, s), abs_tree, shardings)


@dataclasses.dataclass
class Cell:
    arch: str
    shape: ShapeSpec
    cfg: ModelConfig
    fn: Callable          # the step function to jit
    args: Tuple           # abstract args with shardings attached
    mode: str
    accum: int = 1
    donate: Tuple[int, ...] = ()

    @property
    def name(self) -> str:
        return f"{self.arch}/{self.shape.name}"


def opt_abstract(params_abs: Pytree, param_shardings: Pytree) -> Pytree:
    m = jax.tree.map(lambda a, s: _sds(a.shape, jnp.float32, s),
                     params_abs, param_shardings)
    v = jax.tree.map(lambda a, s: _sds(a.shape, jnp.float32, s),
                     params_abs, param_shardings)
    return {"m": m, "v": v, "step": _sds((), jnp.int32)}


def build_cell(arch: str, shape_name: str, mesh,
               cfg_override: Optional[ModelConfig] = None,
               opt_cfg: Optional[OptConfig] = None,
               accum: Optional[int] = None) -> Cell:
    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        raise ValueError(f"{arch}/{shape_name}: {why}")
    param_rules, act_rules = make_rules(cfg, mesh, shape.mode,
                                        shape.global_batch, shape.seq_len)
    sharder = Sharder(mesh, act_rules)
    p_abs = abstract_params(cfg)
    p_shard = tree_shardings(logical_specs(cfg), mesh, param_rules)
    params_arg = _with_shardings(p_abs, p_shard)
    B, S = shape.global_batch, shape.seq_len
    tok_sh = NamedSharding(mesh, spec_for(("batch", "seq"), act_rules))
    emb3_sh = NamedSharding(mesh, spec_for(("batch", "enc_seq", "embed"),
                                           act_rules))

    def batch_specs(seq: int) -> Dict[str, Any]:
        out = {"tokens": _sds((B, seq), jnp.int32, tok_sh)}
        if cfg.encoder is not None:
            out["enc_frames"] = _sds((B, cfg.encoder.seq_len, cfg.d_model),
                                     jnp.float32, emb3_sh)
        if cfg.vision is not None:
            out["img_embeds"] = _sds((B, cfg.vision.n_img_tokens, cfg.d_model),
                                     jnp.float32, emb3_sh)
        return out

    if shape.mode == "train":
        acc = accum if accum is not None else TRAIN_ACCUM.get(arch, 1)
        ocfg = opt_cfg or OptConfig()
        step = make_train_step(cfg, ocfg, sharder, accum_steps=acc)
        batch = dict(batch_specs(S), labels=_sds((B, S), jnp.int32, tok_sh))
        args = (params_arg, opt_abstract(p_abs, p_shard), batch)
        return Cell(arch, shape, cfg, step, args, "train", acc,
                    donate=(0, 1))
    if shape.mode == "prefill":
        fn = partial(M.prefill, cfg=cfg, s_max=S, shard=sharder)

        def prefill_fn(params, batch):
            return fn(params, batch)

        args = (params_arg, batch_specs(S))
        return Cell(arch, shape, cfg, prefill_fn, args, "prefill")
    # decode: one new token against a cache of seq_len
    cache_abs = M.abstract_cache(cfg, B, S)
    cache_sh = tree_shardings(M.cache_logical_specs(cfg, B, S), mesh,
                              act_rules)
    cache_arg = _with_shardings(cache_abs, cache_sh)

    def decode_fn(params, tokens, cache):
        return M.decode_step(params, tokens, cache, cfg, sharder)

    args = (params_arg, _sds((B, 1), jnp.int32, tok_sh), cache_arg)
    return Cell(arch, shape, cfg, decode_fn, args, "decode", donate=(2,))
