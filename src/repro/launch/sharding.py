"""Logical-axis -> mesh-axis rules and the activation Sharder.

Rules are built per (model config, mesh, mode, global batch) so divisibility
fallbacks are explicit rather than left to GSPMD padding (which would
silently waste up to 4x on e.g. gemma3's 4 query heads over a 16-way model
axis — DESIGN.md §4):

  * heads/kv_heads/expert shard over 'model' only when divisible;
  * decode KV caches shard their *sequence* dim over 'model' whenever the kv
    head count cannot use the axis (flash-decoding style: GSPMD turns the
    softmax/contraction over the sharded key axis into small all-reduces);
  * batch=1 cells (long_500k) additionally fold the idle 'data' axis into
    the cache sequence sharding.

``spec_for`` assigns mesh axes greedily left-to-right, dropping duplicates,
so a single rule table cannot produce an invalid PartitionSpec.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from .mesh import dp_axes

Rules = Dict[str, Union[None, str, Tuple[str, ...]]]


def make_rules(cfg: ModelConfig, mesh, mode: str = "train",
               global_batch: int = 0, seq_len: int = 0
               ) -> Tuple[Rules, Rules]:
    """Returns (param_rules, act_rules)."""
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model = "model" if "model" in axes else None
    msize = axes.get("model", 1)
    dp = dp_axes(mesh)
    dpsize = 1
    for a in dp:
        dpsize *= axes[a]

    def div(n: int):
        return model if (model and n and n % msize == 0) else None

    rec_width = (cfg.rglru.width or cfg.d_model) if cfg.rglru else 0
    param_rules: Rules = {
        "vocab": model,
        "embed": dp,                       # FSDP/ZeRO over the data axes
        "mlp": model,
        "heads": div(cfg.n_q),
        "kv_heads": div(cfg.n_kv),
        "head_dim": None,
        "expert": div(cfg.moe.num_experts) if cfg.moe else None,
        "ssm_inner": None,
        "ssm_heads": None,
        "ssm_state": None,
        "rec": div(rec_width),
        "rec_in": None,
        "rec_blocks": div(cfg.rglru.gate_blocks) if cfg.rglru else None,
        "rec_blk_in": None,
        "rec_blk_out": None,
        "conv_w": None,
        "norm": None,
        "layers": None,
        "enc_seq": None,
    }

    batch_rule: Union[None, Tuple[str, ...]] = dp
    if global_batch and dpsize and global_batch % dpsize != 0:
        batch_rule = None  # e.g. long_500k's global_batch=1
    total_heads = cfg.n_q  # post repeat-KV, every attention axis has n_q heads
    heads_rule = div(total_heads)
    # Sequence parallelism fallback: when heads cannot use the model axis
    # (smollm 9H, gemma3 4H, minicpm 36H, recurrentgemma 10H), shard the
    # query-sequence dim of activations instead.
    sp = (mode in ("train", "prefill") and heads_rule is None and model
          and seq_len and seq_len % msize == 0)
    seq_rule = model if sp else None
    # KV caches shard their sequence dim whenever the raw KV head count
    # cannot use the model axis (GQA kv=8 on a 16-way axis is the common
    # case).  Decode then runs flash-decoding style: scores sharded over the
    # key sequence, softmax stats + PV partials combined by small
    # all-reduces — so the query heads must stay replicated in decode.
    kv_seq: Union[None, str, Tuple[str, ...]] = None
    att_kv_seq: Union[None, str, Tuple[str, ...]] = None
    if mode in ("prefill", "decode") and div(cfg.n_kv) is None:
        kv_seq = model
        if batch_rule is None:
            kv_seq = dp + (model,) if model else dp
        if mode == "decode":
            att_kv_seq = kv_seq
            heads_rule = None
    act_rules: Rules = {
        "batch": batch_rule,
        "seq": seq_rule,
        "embed": None,
        "mlp": model,
        "heads": heads_rule,
        "kv_heads": div(cfg.n_kv),
        "head_dim": None,
        "vocab": model,
        "expert": div(cfg.moe.num_experts) if cfg.moe else None,
        "kv_seq": kv_seq,
        "att_kv_seq": att_kv_seq,
        "enc_seq": None,
        "ssm_inner": None,
        "ssm_heads": None,
        "ssm_state": None,
        "rec": div(rec_width),
        "rec_in": None,
        "rec_blocks": div(cfg.rglru.gate_blocks) if cfg.rglru else None,
        "rec_blk_in": None,
        "rec_blk_out": None,
        "layers": None,
        "conv_w": None,
    }
    return param_rules, act_rules


def spec_for(logical: Sequence[Optional[str]], rules: Rules) -> P:
    used: set = set()
    parts = []
    for name in logical:
        m = rules.get(name) if name is not None else None
        if m is None:
            parts.append(None)
            continue
        ms = (m,) if isinstance(m, str) else tuple(m)
        ms = tuple(a for a in ms if a not in used)
        if not ms:
            parts.append(None)
        else:
            used.update(ms)
            parts.append(ms[0] if len(ms) == 1 else ms)
    return P(*parts)


def tree_shardings(logical_tree: Any, mesh, rules: Rules) -> Any:
    """Map a pytree of logical-axis tuples to NamedShardings."""
    return jax.tree.map(
        lambda lg: NamedSharding(mesh, spec_for(lg, rules)),
        logical_tree, is_leaf=lambda x: isinstance(x, tuple))


class Sharder:
    """Activation sharding-constraint callback passed through the model."""

    def __init__(self, mesh=None, act_rules: Optional[Rules] = None):
        self.mesh = mesh
        self.rules = act_rules or {}

    def __call__(self, x: jax.Array, *logical: Optional[str]) -> jax.Array:
        if self.mesh is None:
            return x
        spec = spec_for(logical, self.rules)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def is_sharded(self, name: str) -> bool:
        return bool(self.rules.get(name))
