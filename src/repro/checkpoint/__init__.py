from .store import CHUNK_BYTES, AsyncCheckpointer, CheckpointStore
