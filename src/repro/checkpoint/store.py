"""Autumn delta-checkpoint store (DESIGN.md §2).

Checkpoints are stored in the Autumn LSM engine:

  * every pytree leaf is chunked into CHUNK_BYTES values keyed by a
    *sequential* uint64 id (insertion-ordered registry), so a full restore is
    one contiguous **range read** — its cost is O(#runs) = O(sqrt(log N))
    under Garnering, vs O(log N) under Leveling;
  * a save only writes chunks whose content hash changed (delta checkpoints:
    cheap for optimizer state that updates sparsely, e.g. frozen towers,
    error-feedback buffers, or infrequently-updated embeddings).  Chunk slots
    are overwritten in place, so the *latest* durable checkpoint is always
    exactly restorable; older manifests remain valid only for chunks that
    have not changed since (single-latest retention — the fault-tolerance
    path only ever needs the newest durable state);
  * the checkpoint *manifest* (step -> chunk ids + tree metadata) is written
    last; a crash mid-save can never expose a partial checkpoint because
    restore goes through the manifest (MVCC: LSM versions are immutable);
  * restoring a single host's shard is a **point read** per chunk (bloom
    filters skip runs), the paper's fast-point-read case.

``AsyncCheckpointer`` moves serialization + LSM writes off the training
thread (overlap with compute), with a bounded queue for back-pressure.
"""
from __future__ import annotations

import hashlib
import io
import json
import queue
import threading
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core import LSMConfig, make_store

Pytree = Any

CHUNK_BYTES = 1 << 16
_MANIFEST_KEY_BASE = np.uint64(1) << np.uint64(62)  # manifest id space


def _leaf_paths(tree: Pytree) -> List[Tuple[str, Any]]:
    import jax
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


class CheckpointStore:
    def __init__(self, lsm_config: Optional[LSMConfig] = None):
        # make_store: a shard-aware config (LSMConfig.shards > 1) transparently
        # range-partitions the chunk-id keyspace behind the same API
        self.db = make_store(lsm_config or LSMConfig(
            policy="garnering", T=2.0, c=0.8,
            memtable_bytes=1 << 20, base_level_bytes=4 << 20,
            bits_per_key=10, bloom_allocation="monkey"))
        # path -> first chunk id; ids are insertion-ordered so restores scan
        self._registry: Dict[str, int] = {}
        self._chunk_counts: Dict[str, int] = {}
        self._next_id = 1
        self._hashes: Dict[int, bytes] = {}   # chunk id -> content hash
        self.stats_deltas_skipped = 0
        self.stats_chunks_written = 0

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Pytree) -> Dict[str, Any]:
        import jax
        entries = []
        for path, leaf in _leaf_paths(tree):
            arr = np.asarray(leaf)
            data = arr.tobytes()
            n_chunks = max(1, -(-len(data) // CHUNK_BYTES))
            if path not in self._registry:
                self._registry[path] = self._next_id
                self._chunk_counts[path] = n_chunks
                self._next_id += n_chunks
            assert self._chunk_counts[path] == n_chunks, \
                f"{path}: chunk count changed (elastic reshape not per-leaf)"
            base = self._registry[path]
            for ci in range(n_chunks):
                chunk = data[ci * CHUNK_BYTES:(ci + 1) * CHUNK_BYTES]
                h = hashlib.blake2b(chunk, digest_size=16).digest()
                cid = base + ci
                if self._hashes.get(cid) == h:
                    self.stats_deltas_skipped += 1
                    continue
                self._hashes[cid] = h
                self.db.put(cid, chunk)
                self.stats_chunks_written += 1
            entries.append({"path": path, "base": base, "chunks": n_chunks,
                            "dtype": str(arr.dtype), "shape": list(arr.shape)})
        manifest = {"step": step, "entries": entries}
        self.db.put(int(_MANIFEST_KEY_BASE) + step,
                    json.dumps(manifest).encode())
        self.db.flush()
        self.db.fsync_wal()
        return manifest

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        res = self.db.scan(int(_MANIFEST_KEY_BASE), count=1 << 20)
        steps = [k - int(_MANIFEST_KEY_BASE) for k, _ in res]
        return max(steps) if steps else None

    def restore(self, step: Optional[int] = None) -> Optional[Pytree]:
        """Full restore = range read over the chunk id space."""
        if step is None:
            step = self.latest_step()
            if step is None:
                return None
        raw = self.db.get(int(_MANIFEST_KEY_BASE) + step)
        if raw is None:
            return None
        manifest = json.loads(raw.decode())
        out: Dict[str, np.ndarray] = {}
        for e in manifest["entries"]:
            # contiguous ids => the engine's range-read path (seek + nexts)
            rows = self.db.scan(e["base"], count=e["chunks"])
            data = b"".join(v for _, v in rows[:e["chunks"]])
            arr = np.frombuffer(data, dtype=np.dtype(e["dtype"]))
            out[e["path"]] = arr.reshape(e["shape"]).copy()
        return out

    def restore_leaf(self, step: int, path: str) -> Optional[np.ndarray]:
        """Single-shard recovery = bloom-filtered point reads."""
        raw = self.db.get(int(_MANIFEST_KEY_BASE) + step)
        if raw is None:
            return None
        manifest = json.loads(raw.decode())
        for e in manifest["entries"]:
            if e["path"] == path:
                chunks = [self.db.get(e["base"] + i) for i in range(e["chunks"])]
                if any(c is None for c in chunks):
                    return None
                arr = np.frombuffer(b"".join(chunks), np.dtype(e["dtype"]))
                return arr.reshape(e["shape"]).copy()
        return None

    # ------------------------------------------------------------- recovery
    def crash(self):
        self.db.crash()
        self.db.recover()
        # in-memory delta hashes die with the process: rebuild conservatively
        self._hashes.clear()

    def restore_tree(self, step: Optional[int], like: Pytree,
                     shardings: Optional[Pytree] = None) -> Optional[Pytree]:
        """Rebuild a pytree (optionally placing leaves with NamedShardings —
        elastic rescale: the target mesh may differ from the writer's)."""
        import jax
        flat_restored = self.restore(step)
        if flat_restored is None:
            return None
        leaves = []
        flat = jax.tree_util.tree_flatten_with_path(like)
        shard_leaves = (jax.tree.leaves(
            shardings, is_leaf=lambda s: hasattr(s, "spec"))
            if shardings is not None else [None] * len(flat[0]))
        for (path, leaf), sh in zip(flat[0], shard_leaves):
            key = jax.tree_util.keystr(path)
            arr = flat_restored[key].astype(leaf.dtype)
            if sh is not None:
                arr = jax.device_put(arr, sh)
            leaves.append(arr)
        return jax.tree.unflatten(flat[1], leaves)


class AsyncCheckpointer:
    """Background writer thread: serialize + LSM-write off the train loop."""

    def __init__(self, store: CheckpointStore, max_pending: int = 2):
        self.store = store
        self._q: "queue.Queue" = queue.Queue(maxsize=max_pending)
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, tree = item
            try:
                self.store.save(step, tree)
            except BaseException as e:  # surfaced on next submit/close
                self._err = e

    def submit(self, step: int, tree: Pytree):
        if self._err:
            raise self._err
        import jax
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot before enqueue
        self._q.put((step, host_tree))

    def close(self):
        self._q.put(None)
        self._thread.join()
        if self._err:
            raise self._err
