"""§Perf hillclimb driver: tagged dry-run variants for the three chosen cells.

Cells (chosen per the assignment from the baseline roofline table):
  A. minicpm_2b/prefill_32k    — worst roofline fraction (memory-dominated:
                                 36-head MHA at 32k, fp32 softmax chain)
  B. recurrentgemma_2b/train_4k — most collective-bound (dense RG-LRU gate
                                 matmuls force per-layer all-gathers)
  C. qwen3_4b/decode_32k       — most representative of the paper (AutumnKV
                                 serving read path: KV-cache-bound decode)

Each iteration is a config-level change; artifacts are tagged and the
before/after terms land in EXPERIMENTS.md §Perf.

Run AFTER the main dry-run sweep:  PYTHONPATH=src python -m benchmarks.hillclimb
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import dataclasses
import json

from repro.configs import get_config
from repro.launch.dryrun import run_cell


def show(tag, r):
    if r["status"] != "ok":
        print(f"  {tag}: {r['status']} {r.get('error','')[:120]}")
        return
    h = r["hlo_cost"]
    rf = r.get("roofline", {})
    print(f"  {tag:28s} mem={h['hbm_bytes_per_device']/819e9:8.3f}s "
          f"coll={h['collective_bytes_per_device']/50e9:8.3f}s "
          f"comp={h['flops_per_device']/197e12:8.3f}s "
          f"peak={r['memory_analysis']['peak_estimate_bytes']/2**30:6.2f}GiB "
          f"frac={rf.get('roofline_fraction', 0):.4f}")


def main():
    # ---- Cell A: minicpm prefill ------------------------------------------
    print("[A] minicpm_2b / prefill_32k")
    base = get_config("minicpm_2b")
    show("baseline(q_chunk=512)",
         run_cell("minicpm_2b", "prefill_32k", False, force=True))
    it1 = dataclasses.replace(base, scores_dtype="bfloat16")
    show("it1: scores bf16",
         run_cell("minicpm_2b", "prefill_32k", False, force=True,
                  tag="_it1", cfg_override=it1))
    it2 = dataclasses.replace(base, scores_dtype="bfloat16", q_chunk=256)
    show("it2: + q_chunk 256",
         run_cell("minicpm_2b", "prefill_32k", False, force=True,
                  tag="_it2", cfg_override=it2))

    # ---- Cell B: recurrentgemma train -------------------------------------
    print("[B] recurrentgemma_2b / train_4k")
    base = get_config("recurrentgemma_2b")
    show("baseline(dense gates)",
         run_cell("recurrentgemma_2b", "train_4k", False, force=True))
    it1 = dataclasses.replace(
        base, rglru=dataclasses.replace(base.rglru, gate_blocks=16))
    show("it1: block-diag gates",
         run_cell("recurrentgemma_2b", "train_4k", False, force=True,
                  tag="_it1", cfg_override=it1))
    it2 = dataclasses.replace(it1, scores_dtype="bfloat16")
    show("it2: + scores bf16",
         run_cell("recurrentgemma_2b", "train_4k", False, force=True,
                  tag="_it2", cfg_override=it2))

    # ---- Cell C: qwen3 decode ---------------------------------------------
    print("[C] qwen3_4b / decode_32k")
    base = get_config("qwen3_4b")
    show("current(grouped+in-place)",
         run_cell("qwen3_4b", "decode_32k", False, force=True))
    it1 = dataclasses.replace(base, scores_dtype="bfloat16")
    show("it1: scores bf16",
         run_cell("qwen3_4b", "decode_32k", False, force=True,
                  tag="_it1", cfg_override=it1))
    it2 = dataclasses.replace(it1, param_dtype="bfloat16")
    show("it2: + params bf16",
         run_cell("qwen3_4b", "decode_32k", False, force=True,
                  tag="_it2", cfg_override=it2))


if __name__ == "__main__":
    main()
