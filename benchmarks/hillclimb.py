"""Hillclimb drivers: offline greedy search over both tuning surfaces.

Two climbs share one scoring contract:

  * ``--lsm`` — offline LSM-knob hill-climb: candidate (c, T, pin_frac)
    sets are each measured on a fresh store under a short mixed workload
    and scored with ``repro.core.tuning_objective`` — the *same*
    p99-weighted foreground cost the online ``OnlineTuner`` optimises
    (DESIGN.md §17), so offline and online scoring cannot drift apart.
    The online counterpart (convergence from a mis-tuned start, YCSB A-F,
    phase-change re-convergence) is ``benchmarks/tuner_bench.py``.
  * default — tagged dry-run variants for three model cells (roofline
    table follow-ups): minicpm_2b/prefill_32k (memory-dominated),
    recurrentgemma_2b/train_4k (collective-bound), qwen3_4b/decode_32k
    (AutumnKV serving read path).

Run AFTER the main dry-run sweep:  PYTHONPATH=src python -m benchmarks.hillclimb
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import dataclasses
import json


def lsm_score(c: float, T: float, pin_frac: float, n: int = 20_000,
              n_ops: int = 4_000, total_mem_kb: int = 512) -> float:
    """Measure one LSM knob set and score it with the online tuner's own
    objective (ns; lower is better).  Import is local so the default
    model-cell path stays importable without the core package on path."""
    from repro.core import Telemetry, tuning_objective

    from .common import make_db
    from .ycsb import _load, _mix

    tel = Telemetry()
    pin_kb = int(total_mem_kb * pin_frac)
    db = make_db(c=c, T=T, bits_per_key=10, bloom_allocation="monkey",
                 cache_kb=total_mem_kb - pin_kb, pin_l0_kb=pin_kb,
                 telemetry=tel)
    _load(db, n)
    prev = tel.snapshot()
    _mix(db, n, n_ops, read_frac=0.5, seed=13)
    score = tuning_objective(tel.delta(prev).hists)
    db.close()
    return score


def lsm_main(n: int = 20_000, n_ops: int = 4_000):
    """Greedy coordinate climb over (c, T, pin_frac) on measured stores —
    the offline twin of OnlineTuner's bounded hill-climb, one store per
    candidate instead of one live store retuned at boundaries."""
    from repro.core.tuner import KNOB_BOUNDS

    cur = dict(c=1.0, T=2.0, pin_frac=0.5)
    steps = {"c": 0.2, "T": 1.0, "pin_frac": 0.25}
    best = lsm_score(n=n, n_ops=n_ops, **cur)
    print(f"start {cur} objective={best/1e3:.1f}us")
    improved = True
    while improved:
        improved = False
        for k in cur:
            lo, hi = KNOB_BOUNDS[k]
            for d in (+1, -1):
                cand = dict(cur)
                cand[k] = min(hi, max(lo, round(cur[k] + d * steps[k], 4)))
                if cand[k] == cur[k]:
                    continue
                s = lsm_score(n=n, n_ops=n_ops, **cand)
                print(f"  try {k}={cand[k]}: {s/1e3:.1f}us "
                      f"({'accept' if s < best else 'reject'})")
                if s < best:
                    best, cur, improved = s, cand, True
                    break
    print(f"settled {cur} objective={best/1e3:.1f}us")
    return cur, best


def show(tag, r):
    if r["status"] != "ok":
        print(f"  {tag}: {r['status']} {r.get('error','')[:120]}")
        return
    h = r["hlo_cost"]
    rf = r.get("roofline", {})
    print(f"  {tag:28s} mem={h['hbm_bytes_per_device']/819e9:8.3f}s "
          f"coll={h['collective_bytes_per_device']/50e9:8.3f}s "
          f"comp={h['flops_per_device']/197e12:8.3f}s "
          f"peak={r['memory_analysis']['peak_estimate_bytes']/2**30:6.2f}GiB "
          f"frac={rf.get('roofline_fraction', 0):.4f}")


def main():
    from repro.configs import get_config
    from repro.launch.dryrun import run_cell

    # ---- Cell A: minicpm prefill ------------------------------------------
    print("[A] minicpm_2b / prefill_32k")
    base = get_config("minicpm_2b")
    show("baseline(q_chunk=512)",
         run_cell("minicpm_2b", "prefill_32k", False, force=True))
    it1 = dataclasses.replace(base, scores_dtype="bfloat16")
    show("it1: scores bf16",
         run_cell("minicpm_2b", "prefill_32k", False, force=True,
                  tag="_it1", cfg_override=it1))
    it2 = dataclasses.replace(base, scores_dtype="bfloat16", q_chunk=256)
    show("it2: + q_chunk 256",
         run_cell("minicpm_2b", "prefill_32k", False, force=True,
                  tag="_it2", cfg_override=it2))

    # ---- Cell B: recurrentgemma train -------------------------------------
    print("[B] recurrentgemma_2b / train_4k")
    base = get_config("recurrentgemma_2b")
    show("baseline(dense gates)",
         run_cell("recurrentgemma_2b", "train_4k", False, force=True))
    it1 = dataclasses.replace(
        base, rglru=dataclasses.replace(base.rglru, gate_blocks=16))
    show("it1: block-diag gates",
         run_cell("recurrentgemma_2b", "train_4k", False, force=True,
                  tag="_it1", cfg_override=it1))
    it2 = dataclasses.replace(it1, scores_dtype="bfloat16")
    show("it2: + scores bf16",
         run_cell("recurrentgemma_2b", "train_4k", False, force=True,
                  tag="_it2", cfg_override=it2))

    # ---- Cell C: qwen3 decode ---------------------------------------------
    print("[C] qwen3_4b / decode_32k")
    base = get_config("qwen3_4b")
    show("current(grouped+in-place)",
         run_cell("qwen3_4b", "decode_32k", False, force=True))
    it1 = dataclasses.replace(base, scores_dtype="bfloat16")
    show("it1: scores bf16",
         run_cell("qwen3_4b", "decode_32k", False, force=True,
                  tag="_it1", cfg_override=it1))
    it2 = dataclasses.replace(it1, param_dtype="bfloat16")
    show("it2: + params bf16",
         run_cell("qwen3_4b", "decode_32k", False, force=True,
                  tag="_it2", cfg_override=it2))


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--lsm", action="store_true",
                    help="offline LSM knob climb scored by tuning_objective")
    ap.add_argument("-n", type=int, default=20_000,
                    help="--lsm: loaded keys per candidate store")
    ap.add_argument("--ops", type=int, default=4_000,
                    help="--lsm: mixed ops per candidate store")
    args = ap.parse_args()
    if args.lsm:
        lsm_main(n=args.n, n_ops=args.ops)
    else:
        main()
