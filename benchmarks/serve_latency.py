"""Concurrent serving harness: tail latency under mixed read/write churn.

Everything the other benchmarks measure is single-client throughput; the
ROADMAP's "millions of users" claim is about what the *slowest* requests see
when N clients hammer one store while flush/compaction churns underneath.
This harness runs N client threads against one ``ShardedLSMStore`` (writes
serialize through the facade's write gate — the supported multi-client write
discipline, DESIGN.md §13) doing a mixed get/scan/put workload, and reports:

* per-op-class p50/p99/p999 + max from exact client-side samples
  (``time.perf_counter_ns`` around each call — the same clock the telemetry
  subsystem stamps trace events with);
* a **stall-attribution breakdown**: every tail sample (latency >= that
  op's p99) is intersected with the engine's trace-event intervals
  (flush/compaction/stall/view-rebuild, DESIGN.md §14), answering "which
  background event was in flight while this request was slow";
* the telemetry histograms' own percentiles as a cross-check (bucketed to
  ~±19%, recorded inside the engine);
* a **telemetry-overhead lane**: the same single-thread batch load run
  telemetry-off and telemetry-on (best-of-R), with the resulting trees
  asserted bit-for-bit equal (`levels_bit_equal`) — telemetry must be an
  observer, never a behavior change.

``--smoke`` runs a seconds-scale configuration and asserts the CSV contract:
every op class served from >= 4 concurrent clients, p99 finite and nonzero,
ordered percentiles, and disabled-telemetry overhead within noise.
"""
from __future__ import annotations

import argparse
import bisect
import json
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import Telemetry
from repro.core.run import levels_bit_equal

from benchmarks.common import make_db, pct, stats_row

OPS = ("get", "scan", "put")
ATTRIB_KINDS = ("flush", "compaction", "stall", "view_rebuild", "rebalance")
CSV_HEADER = "op,count,p50_us,p99_us,p999_us,max_us,tel_p99_us"


# --------------------------------------------------------------- client load
def _client(tid: int, db, stop: threading.Event, barrier: threading.Barrier,
            key_space: int, value_size: int, read_pct: float, scan_pct: float,
            scan_len: int, out: dict) -> None:
    """One serving client: mixed point reads / range scans / writes.

    Records exact (start_ns, dur_ns) per op into thread-private lists (no
    shared state on the hot loop); op choice and keys are pregenerated in
    chunks so sampling overhead stays off the measured path.
    """
    rng = np.random.default_rng(0xC11E27 + tid)
    val = bytes(value_size)
    t_samples = {op: [] for op in OPS}
    d_samples = {op: [] for op in OPS}
    CHUNK = 2048
    barrier.wait()
    while not stop.is_set():
        us = rng.random(CHUNK)
        ks = rng.integers(0, key_space, CHUNK, dtype=np.uint64)
        for u, k in zip(us, ks):
            if u < read_pct:
                op = "get"
                t0 = time.perf_counter_ns()
                db.get(int(k))
            elif u < read_pct + scan_pct:
                op = "scan"
                t0 = time.perf_counter_ns()
                db.scan(int(k), scan_len)
            else:
                op = "put"
                t0 = time.perf_counter_ns()
                db.put(int(k), val)
            d_samples[op].append(time.perf_counter_ns() - t0)
            t_samples[op].append(t0)
        if stop.is_set():
            break
    out[tid] = (t_samples, d_samples)


def run_serving(clients: int, seconds: float, n_preload: int,
                value_size: int, read_pct: float, scan_pct: float,
                scan_len: int, telemetry: Telemetry
                ) -> Tuple[dict, dict, object]:
    """Preload, then serve from ``clients`` threads for ``seconds``.

    Returns (t_samples, d_samples, db): per-op concatenated start/duration
    arrays pooled across clients, plus the (closed) store."""
    key_space = n_preload * 2
    db = make_db(bits_per_key=10, memtable_kb=32, base_kb=256,
                 cache_kb=1024, pin_l0_kb=256,
                 async_compaction=True, compaction_workers=2,
                 shards=2, shard_key_space=key_space,
                 use_range_views=True, telemetry=telemetry,
                 # rebalancing armed (DESIGN.md §15): the uniform client
                 # keys stay under the trigger, but a skewed tenant would
                 # migrate mid-serving and its window lands in the trace —
                 # tail attribution can then blame "rebalance"
                 rebalance_interval_ops=25_000, rebalance_ratio=1.5)
    rng = np.random.default_rng(11)
    keys = rng.integers(0, key_space, n_preload, dtype=np.uint64)
    val = bytes(value_size)
    for i in range(0, n_preload, 4096):
        db.put_batch(keys[i:i + 4096].tolist(), val)
    db.flush()
    db.wait_for_quiesce(600)

    stop = threading.Event()
    barrier = threading.Barrier(clients + 1)
    out: dict = {}
    threads = [threading.Thread(
        target=_client, name=f"serve-client-{t}",
        args=(t, db, stop, barrier, key_space, value_size,
              read_pct, scan_pct, scan_len, out))
        for t in range(clients)]
    for t in threads:
        t.start()
    barrier.wait()          # all clients poised: start the clock together
    time.sleep(seconds)
    stop.set()
    for t in threads:
        t.join()
    db.flush()
    db.wait_for_quiesce(600)
    db.close()
    t_pool = {op: np.concatenate([np.asarray(out[t][0][op], np.int64)
                                  for t in out] or
                                 [np.zeros(0, np.int64)]) for op in OPS}
    d_pool = {op: np.concatenate([np.asarray(out[t][1][op], np.int64)
                                  for t in out] or
                                 [np.zeros(0, np.int64)]) for op in OPS}
    return t_pool, d_pool, db


# ---------------------------------------------------------- tail attribution
class IntervalCollector:
    """Incremental (t0, t1) interval index per attributable event kind.

    End events carry ``t0``/``dur_ns`` (DESIGN.md §14), so intervals come
    from single records: flush_end, compaction_end, stall_exit/slowdown
    (grouped as "stall"), view_rebuild.  ``consume(trace)`` pulls only the
    records appended since the last call (``EventTrace.since`` cursor —
    the Telemetry windowed-delta API, §17) and folds them into the sorted
    merged lists, so a long-running server can re-attribute tails each
    reporting window without re-scanning and re-merging the full trace
    history every tick."""

    _KIND_MAP = {"flush_end": "flush", "compaction_end": "compaction",
                 "stall_exit": "stall", "slowdown": "stall",
                 "view_rebuild": "view_rebuild",
                 "rebalance_end": "rebalance"}

    def __init__(self):
        self._cursor = 0
        self._merged: Dict[str, List[Tuple[int, int]]] = \
            {k: [] for k in ATTRIB_KINDS}

    def consume(self, trace) -> Dict[str, List[Tuple[int, int]]]:
        """Fold events appended since the last consume; returns the merged
        interval lists (sorted, disjoint) per kind."""
        events, self._cursor = trace.since(self._cursor)
        fresh: Dict[str, List[Tuple[int, int]]] = {}
        for e in events:
            kind = self._KIND_MAP.get(e.kind)
            if kind is None:
                continue
            iv = e.interval()
            if iv is not None:
                fresh.setdefault(kind, []).append(iv)
        for kind, ivs in fresh.items():
            ivs.sort()
            out: List[List[int]] = [list(t) for t in self._merged[kind]]
            for s, e in ivs:
                i = bisect.bisect_left([x[0] for x in out], s)
                out.insert(i, [s, e])
            merged: List[List[int]] = []
            for s, e in out:
                if merged and s <= merged[-1][1]:
                    merged[-1][1] = max(merged[-1][1], e)
                else:
                    merged.append([s, e])
            self._merged[kind] = [(s, e) for s, e in merged]
        return self._merged


def _overlaps(starts: List[int], ends: List[int], s: int, e: int) -> bool:
    """Does [s, e] intersect any of the (sorted, disjoint) intervals?"""
    i = bisect.bisect_right(starts, e) - 1
    return i >= 0 and ends[i] >= s


def attribute_tails(t_pool, d_pool, trace,
                    collector: Optional[IntervalCollector] = None
                    ) -> Dict[str, Dict[str, float]]:
    """For each op class: % of tail samples (>= exact p99) overlapping each
    background event kind (overlaps are not exclusive — a sample slow under
    both a flush and a compaction counts toward both; "none" = overlapped
    nothing attributable).  Pass a long-lived ``collector`` to attribute
    repeatedly against a growing trace at incremental cost."""
    intervals = (collector or IntervalCollector()).consume(trace)
    cols = {k: (list(map(lambda iv: iv[0], ivs)),
                list(map(lambda iv: iv[1], ivs)))
            for k, ivs in intervals.items()}
    out: Dict[str, Dict[str, float]] = {}
    for op in OPS:
        d = d_pool[op]
        if d.size == 0:
            continue
        p99 = np.percentile(d, 99)
        tail = np.nonzero(d >= p99)[0]
        row = {k: 0 for k in ATTRIB_KINDS}
        none = 0
        for j in tail:
            s = int(t_pool[op][j])
            e = s + int(d[j])
            hit = False
            for kind in ATTRIB_KINDS:
                starts, ends = cols[kind]
                if starts and _overlaps(starts, ends, s, e):
                    row[kind] += 1
                    hit = True
            if not hit:
                none += 1
        n_tail = len(tail)
        res = {k: 100.0 * v / n_tail for k, v in row.items()}
        res["none"] = 100.0 * none / n_tail
        res["tail_samples"] = float(n_tail)
        out[op] = res
    return out


# ------------------------------------------------------------- overhead lane
def telemetry_overhead(n: int = 20_000, value_size: int = 64,
                       repeats: int = 3) -> Tuple[float, float, float]:
    """(off_us_op, on_us_op, overhead_pct) for the batch-load lane, plus a
    bit-for-bit tree-equality assertion between the off and on stores.

    Sync single-shard stores so the comparison is deterministic compute,
    not scheduling; best-of-R absorbs container timer noise.  This is the
    measured "zero-overhead when disabled" claim: the off lane *is* the
    micro_dbbench load lane (telemetry=None), so any regression here is a
    regression of the seed path itself.
    """
    rng = np.random.default_rng(5)
    keys = rng.integers(0, n * 8, n, dtype=np.uint64)
    val = bytes(value_size)

    def one(tel: Optional[Telemetry]):
        db = make_db(bits_per_key=10, memtable_kb=32, base_kb=256,
                     telemetry=tel)
        t0 = time.perf_counter()
        for i in range(0, n, 4096):
            db.put_batch(keys[i:i + 4096].tolist(), val)
        db.flush()
        return (time.perf_counter() - t0) / n * 1e6, db

    one(None)      # warm-up (allocator/code paths), untimed
    off_us = on_us = float("inf")
    db_off = db_on = None
    for _ in range(repeats):   # interleaved so drift hits both lanes alike
        us, db_off = one(None)
        off_us = min(off_us, us)
        us, db_on = one(Telemetry())
        on_us = min(on_us, us)
    assert levels_bit_equal(db_off._levels, db_on._levels), \
        "telemetry-on tree diverged from telemetry-off (must be an observer)"
    overhead = 100.0 * (on_us - off_us) / off_us if off_us else 0.0
    return off_us, on_us, overhead


# --------------------------------------------------------------------- main
def main(clients: int = 4, seconds: float = 4.0, n_preload: int = 40_000,
         value_size: int = 64, read_pct: float = 0.70, scan_pct: float = 0.10,
         scan_len: int = 20, smoke: bool = False,
         json_path: Optional[str] = None) -> None:
    tel = Telemetry(trace_capacity=8192)
    t_pool, d_pool, db = run_serving(clients, seconds, n_preload, value_size,
                                     read_pct, scan_pct, scan_len, tel)
    tel_summary = tel.summary()

    print(CSV_HEADER)
    rows = {}
    for op in OPS:
        d_ns = d_pool[op]
        if d_ns.size == 0:
            continue
        d_us = d_ns / 1e3
        tel_key = {"get": "get", "scan": "scan", "put": "put"}[op]
        tel_p99 = tel_summary.get(tel_key, {}).get("p99_ns", float("nan"))
        rows[op] = dict(count=int(d_ns.size),
                        p50_us=pct(d_us, 50), p99_us=pct(d_us, 99),
                        p999_us=pct(d_us, 99.9),
                        max_us=float(d_us.max()),
                        tel_p99_us=tel_p99 / 1e3)
        r = rows[op]
        print(f"{op},{r['count']},{r['p50_us']:.1f},{r['p99_us']:.1f},"
              f"{r['p999_us']:.1f},{r['max_us']:.1f},{r['tel_p99_us']:.1f}")

    attrib = attribute_tails(t_pool, d_pool, tel.trace)
    print("tail_attrib,op,kind,pct_of_tail")
    for op, row in attrib.items():
        for kind in ATTRIB_KINDS + ("none",):
            print(f"tail_attrib,{op},{kind},{row[kind]:.1f}")

    off_us, on_us, overhead = telemetry_overhead(
        n=8_000 if smoke else 20_000, value_size=value_size,
        repeats=2 if smoke else 3)
    print(f"tel_overhead,off_us_op={off_us:.3f},on_us_op={on_us:.3f},"
          f"overhead_pct={overhead:.1f}")

    ev_counts: Dict[str, int] = {}
    for e in tel.trace.dump():
        ev_counts[e.kind] = ev_counts.get(e.kind, 0) + 1
    print("trace_events," + ",".join(f"{k}={v}"
                                     for k, v in sorted(ev_counts.items())))

    # per-shard op skew the serving window actually saw (max/mean share;
    # 1.0 = balanced) — the signal the §15 rebalance trigger watches
    from benchmarks.common import shard_imbalance
    imb = (shard_imbalance(db.shard_load_ops())
           if hasattr(db, "shard_load_ops") else 1.0)
    print(f"shard_imbalance,{imb:.3f},rebalances="
          f"{getattr(db, 'rebalances', 0)}")

    if json_path:
        with open(json_path, "w") as f:
            json.dump(dict(rows=rows, attribution=attrib,
                           overhead_pct=overhead,
                           telemetry=tel_summary,
                           io=stats_row(db.stats)), f, indent=2,
                      default=float)
        print(f"wrote {json_path}")

    if smoke:
        assert clients >= 4, "smoke requires >=4 concurrent clients"
        for op in OPS:
            assert op in rows, f"op class {op} recorded no samples"
            r = rows[op]
            assert r["count"] > 0
            assert np.isfinite(r["p99_us"]) and r["p99_us"] > 0.0, \
                f"{op} p99 not finite/nonzero"
            assert r["p50_us"] <= r["p99_us"] <= r["p999_us"] <= r["max_us"]
            assert np.isfinite(r["tel_p99_us"]) and r["tel_p99_us"] > 0.0
        assert attrib, "no tail attribution computed"
        for op, row in attrib.items():
            assert row["tail_samples"] > 0
        # flushes must have happened under churn (the trace saw the engine)
        assert ev_counts.get("flush_end", 0) > 0, "no flush events traced"
        assert imb >= 1.0, "shard_imbalance must be >= 1.0 by construction"
        # disabled-mode overhead within noise: generous CI bound (container
        # timers are coarse); the measured figure goes in DESIGN.md §14
        assert overhead < 30.0, f"telemetry-off overhead {overhead:.1f}%"
        print(f"serve-ok: {clients} clients, "
              f"get p99 {rows['get']['p99_us']:.0f}us "
              f"p999 {rows['get']['p999_us']:.0f}us, "
              f"tel overhead {overhead:.1f}%")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--seconds", type=float, default=4.0)
    ap.add_argument("--n", type=int, default=40_000,
                    help="preloaded keys (key space is 2x)")
    ap.add_argument("--value-size", type=int, default=64)
    ap.add_argument("--read-pct", type=float, default=0.70)
    ap.add_argument("--scan-pct", type=float, default=0.10)
    ap.add_argument("--scan-len", type=int, default=20)
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale run + CSV-contract assertions")
    ap.add_argument("--json", type=str, default=None)
    args = ap.parse_args()
    if args.smoke:
        main(clients=max(4, args.clients), seconds=2.0, n_preload=15_000,
             value_size=50, smoke=True, json_path=args.json)
    else:
        main(clients=args.clients, seconds=args.seconds, n_preload=args.n,
             value_size=args.value_size, read_pct=args.read_pct,
             scan_pct=args.scan_pct, scan_len=args.scan_len,
             json_path=args.json)
