"""Paper Fig. 5: bloom-filter optimization (Monkey) comparison vs DB size.

Autumn (Garnering + Monkey allocation) vs LevelDB baseline (Leveling +
Monkey — i.e., the Monkey system of [17]) across growing DB sizes:
writes, point reads without filters, point reads with 2 bits/key optimized
filters, and small range reads.  Also validates Eq. 9 empirically via the
zero-result read cost (sum of per-level FPRs).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from .common import fill_random, make_db, read_random, seek_random


def run(sizes=(30_000, 60_000, 120_000, 240_000)) -> List[Dict]:
    rows = []
    n_reads = 4_000
    for n in sizes:
        for name, c in (("leveldb+monkey", 1.0), ("autumn", 0.8)):
            for bits in (0.0, 2.0):
                db = make_db(c=c, T=2.0, bits_per_key=bits,
                             bloom_allocation="monkey")
                t_w = fill_random(db, n, 100)
                key_space = n * 8
                s0 = db.stats.snapshot()
                t_r = read_random(db, n_reads, key_space)
                d = db.stats.delta(s0)
                t_rng = seek_random(db, n_reads // 2, key_space, nexts=10)
                rows.append(dict(
                    system=name, n=n, bits_per_key=bits,
                    levels=db.num_levels_in_use,
                    fillrandom_us=t_w, readrandom_us=t_r,
                    seeknext10_us=t_rng,
                    zero_read_blocks=d.blocks_read / n_reads,
                    bloom_negatives=d.bloom_negatives / max(d.bloom_probes, 1)))
    return rows


def main():
    rows = run()
    print("system,n,bits_per_key,levels,fillrandom_us,readrandom_us,"
          "seeknext10_us,zero_read_blocks,bloom_neg_frac")
    for r in rows:
        print(f"{r['system']},{r['n']},{r['bits_per_key']:.0f},{r['levels']},"
              f"{r['fillrandom_us']:.2f},{r['readrandom_us']:.2f},"
              f"{r['seeknext10_us']:.2f},{r['zero_read_blocks']:.3f},"
              f"{r['bloom_negatives']:.3f}")
    return rows


if __name__ == "__main__":
    main()
