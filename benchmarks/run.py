"""Benchmark entry point — one function per paper table/figure.

  fig2_micro        db_bench six ops x value sizes (Autumn vs RocksDB)
  fig3_sensitivity  c/T sweep on writes + small range reads
  fig4_ycsb         YCSB A-F + load + tail latencies (Table 3)
  fig5_bloom        Monkey bloom optimization vs DB size
  table2_complexity levels/runs/WA/zero-read vs N for all five policies
  roofline          dry-run roofline table (from artifacts, if present)

Usage: PYTHONPATH=src python -m benchmarks.run [--quick|--full] [names...]
"""
from __future__ import annotations

import sys
import time

from . import (bloom_opt, complexity_check, micro_dbbench, roofline,
               sensitivity_ct, ycsb)


def main() -> None:
    args = [a for a in sys.argv[1:]]
    scale = 1.0
    for flag, s in (("--quick", 0.25), ("--full", 10.0)):
        if flag in args:
            scale = s
            args.remove(flag)
    names = args or ["fig2_micro", "fig3_sensitivity", "fig4_ycsb",
                     "fig5_bloom", "table2_complexity", "roofline"]
    for name in names:
        t0 = time.perf_counter()
        print(f"\n=== {name} ===")
        if name == "fig2_micro":
            micro_dbbench.main(n=int(100_000 * scale))
        elif name == "fig3_sensitivity":
            sensitivity_ct.main(n=int(80_000 * scale))
        elif name == "fig4_ycsb":
            ycsb.main(n=int(50_000 * scale), n_ops=int(6_000 * scale))
        elif name == "fig5_bloom":
            bloom_opt.main()
        elif name == "table2_complexity":
            complexity_check.main()
        elif name == "roofline":
            try:
                roofline.main()
            except Exception as e:
                print(f"(roofline artifacts unavailable: {e})")
        else:
            print(f"unknown benchmark {name!r}")
        print(f"# {name} took {time.perf_counter() - t0:.1f}s")


if __name__ == "__main__":
    main()
