"""Paper Fig. 2: db_bench micro benchmark — six operations x value sizes.

Autumn (Garnering c=0.8, T=2) vs the RocksDB baseline (Leveling == Garnering
with c=1.0, exactly as the paper's §4.1 notes).  No bloom filters (worst-case
point reads, §4.2.1).  Reports us/op wall time and block-I/O counts, plus the
batched read subsystem (DESIGN.md §3): ``multi_get`` vs the scalar ``get``
loop and the streaming ``MergingIterator`` scan vs the reference seek-retry
``scan_scalar`` loop, with their speedups.

Memory-subsystem lane (DESIGN.md §9): after the uncached measurements the
same filled tree gets a block cache + pinned L0 attached
(``LSMStore.configure_cache``) and the point and range reads are each
re-run — one cold pass to warm the cache, one measured warm pass —
reporting the cached us/op, the block-cache hit rate over both warm lanes,
and the warm point-read blocks/op against the uncached
``point_blocks_per_op`` (cached-vs-uncached read cost).

Write-subsystem lane (DESIGN.md §10): the same ``fillrandom`` key stream is
loaded through ``put_batch`` waves on a fresh tree (``load_batch_kops`` +
``load_batch_speedup`` over the scalar put loop — identical flush
boundaries, so the resulting trees are bit-for-bit equal), and the filled
tree's runs are merged by both compaction paths on the same inputs
(``compact_mb_s`` for the vectorized ``merge_runs``, ``compact_speedup``
over the ``merge_runs_scalar`` oracle), asserting identical IOStats and
bit-identical output along the way.

Async-scheduler lane (DESIGN.md §11): the same stream again through an
``async_compaction=True`` store — ``load_async_kops`` is the *foreground*
write-path throughput (rotation + enqueue; flush/compaction drain on the
background worker), ``load_async_speedup`` its gain over the synchronous
batched load, and ``stall_pct`` the share of that foreground wall clock
lost to write-pressure stalls (``IOStats.stall_ns``).  After
``wait_for_quiesce`` the async tree is asserted bit-for-bit equal to the
synchronous one — the scheduler's determinism contract.

Sharded lane (DESIGN.md §12): the same stream once more through a
``shards=SHARD_N`` ``ShardedLSMStore`` (range splitters over the key space,
parallel per-shard schedulers under the SAME ``BG_WORKERS`` budget as the
async lane — both lanes pin ``compaction_workers`` explicitly so
``shard_speedup`` measures sharding, not worker drift).
``load_shard{N}_kops`` is end-to-end (quiesced) throughput and
``shard_speedup`` its gain over the shards=1 async lane's end-to-end wall
clock; reads are asserted byte-identical to the single-store oracle.

Range-view lane (DESIGN.md §13): the same stream through an async store
with ``use_range_views=True`` — after quiesce the REMIX-style sorted view
is in place (rebuilt by the background scheduler; zero foreground rebuilds
is asserted), sampled scans are asserted bit-for-bit equal to the
``scan_scalar`` oracle, and ``scan_view_kops``/``scan_view_speedup`` report
the view-scan throughput and its gain over the ``MergingIterator`` scan on
the same stream.  The measured window is asserted rebuild- and
fallback-free, and a tombstone-dense band is carved and re-checked against
the oracle afterwards.

Skew/rebalance lane (DESIGN.md §15): a hotspot-skewed stream (90% of ops
into the lowest 10% of the key space) through the same sharded facade
twice — static splitters vs dynamic rebalancing (``load_hot_kops``,
``hot_rebal_speedup``, ``rebalances``); both trees are asserted
byte-identical to a single-store oracle fed the same stream.

``--smoke`` runs a seconds-scale configuration exercising every column and
asserts the write-subsystem columns are present and nonzero (CI uses it to
keep the benchmark code paths green on every PR).
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import numpy as np

from .common import (DEFAULT_N, Hotspot, cache_hit_pct, fill_random,
                     fill_random_batch, fill_random_batch_async, fill_seq,
                     make_db, multiget_random, read_random, scan_random,
                     seek_random, tune_bulk_load)

VALUE_SIZES = (50, 100, 200)   # Zippy/UP2X, UDB/VAR, APP/ETC (paper §4.2.1)
SCAN_LEN = 100                 # entries per iterator scan (db_bench seek+next)
CACHE_KB = 2048                # block-cache budget for the cached lane
PIN_L0_KB = 256                # DRAM-resident L0 budget
def _cores() -> int:
    import os
    try:
        return len(os.sched_getaffinity(0))
    except (AttributeError, OSError):
        return os.cpu_count() or 1


# Shards in the sharded-facade lane (§12): matched to the cores actually
# available, capped at 4.  The scaling law measured on this engine: wall
# clock improves while shards <= cores (parallel drains + shallower trees);
# oversubscribing (4 shards on a 2-core container) lands at parity — the
# extra always-draining pipelines take GIL slices from the writer and
# fragment merges below the size where numpy amortizes.  On a >=4-core box
# this is the issue's 4-way lane.
SHARD_N = max(2, min(4, _cores()))
BG_WORKERS = 4                 # background worker budget, pinned EXPLICITLY
                               # in BOTH the async (shards=1) and sharded
                               # lanes so shard_speedup measures sharding,
                               # not worker-count drift between rows (the
                               # shards=1 turnstile can't use extras anyway;
                               # the facade needs budget >= shards or the
                               # pipelines convoy — see DESIGN.md §12)


def assert_trees_equal(db_a, db_b) -> None:
    """Bit-for-bit level equality — the async scheduler's oracle check
    (`core.run.levels_bit_equal` is the one definition of tree equality)."""
    from repro.core.run import levels_bit_equal

    assert levels_bit_equal(db_a._levels, db_b._levels), "async tree diverged"


def assert_sharded_reads_equal(db_shard, db_oracle, n: int) -> None:
    """Cross-shard differential check (§12): a sharded store's reads must
    be byte-identical to the single-store oracle's — the full-range scan
    (shard-ordered concatenation vs merged iterator) and a multi_get wave
    across the whole key space."""
    assert db_shard.total_live_entries() == db_oracle.total_live_entries(), \
        "sharded live-entry count diverged"
    assert db_shard.scan(0, n + 1) == db_oracle.scan(0, n + 1), \
        "sharded scan diverged from single-store oracle"
    keys = np.random.default_rng(9).integers(0, n * 8, 4096, np.uint64)
    assert db_shard.multi_get(keys) == db_oracle.multi_get(keys), \
        "sharded multi_get diverged from single-store oracle"


def compact_bench(db) -> Dict[str, float]:
    """Merge the filled tree's runs with both compaction paths (same inputs).

    Asserts the vectorized ``merge_runs`` is a bit-for-bit drop-in for the
    ``merge_runs_scalar`` oracle — identical keys/seqs/vlens/vals and
    identical compaction IOStats — then reports its throughput (input MB/s)
    and the speedup over the oracle.
    """
    import numpy as np

    from repro.core import IOStats
    from repro.core.run import merge_runs, merge_runs_scalar

    runs = [r for lvl in db._levels for r in lvl if len(r)]
    if len(runs) < 2:
        return dict(compact_mb_s=0.0, compact_speedup=0.0)
    # best-of-3 per path: this container's wall clock is noisy, and min()
    # is the standard estimator for compute-bound kernels
    s_ref, s_vec = IOStats(), IOStats()
    ref = out = None
    t_ref = t_vec = float("inf")
    for _ in range(3):
        s_ref = IOStats()
        t0 = time.perf_counter()
        ref = merge_runs_scalar(runs, 0.0, s_ref)
        t_ref = min(t_ref, time.perf_counter() - t0)
        s_vec = IOStats()
        t0 = time.perf_counter()
        out = merge_runs(runs, 0.0, s_vec)
        t_vec = min(t_vec, time.perf_counter() - t0)
    assert np.array_equal(ref.keys, out.keys) and \
        np.array_equal(ref.seqs, out.seqs) and \
        np.array_equal(ref.vlens, out.vlens) and \
        np.array_equal(ref.vals, out.vals), "compaction paths diverged"
    for f in ("blocks_read", "blocks_written", "entries_compacted",
              "bytes_compacted", "compactions"):
        assert getattr(s_ref, f) == getattr(s_vec, f), f
    in_mb = sum(r.data_bytes for r in runs) / 1e6
    return dict(compact_mb_s=in_mb / t_vec if t_vec else 0.0,
                compact_speedup=t_ref / t_vec if t_vec else 0.0)


def run(n: int = DEFAULT_N, value_sizes=VALUE_SIZES) -> List[Dict]:
    rows = []
    n_reads = max(n // 4, 1000)
    n_scans = max(n_reads // 25, 100)
    for vs in value_sizes:
        for name, c in (("rocksdb(leveling)", 1.0), ("autumn(c=.8)", 0.8)):
            db_seq = make_db(c=c)
            t_fillseq = fill_seq(db_seq, n, vs)
            db = make_db(c=c)
            t_fillrand = fill_random(db, n, vs)
            # ---- write-subsystem lane: same stream through put_batch ----
            db_batch = make_db(c=c)
            t_fillbatch = fill_random_batch(db_batch, n, vs)
            assert db_batch.total_entries == db.total_entries
            # extra sync-batch timings so the async speedup is min-vs-min
            # over 3 runs each (its own column keeps the single-shot PR-3
            # methodology; this container's clock is ±30% noisy)
            t_fillbatch_best = t_fillbatch
            for _ in range(2):
                db_batch2 = make_db(c=c)
                t_fillbatch_best = min(t_fillbatch_best,
                                       fill_random_batch(db_batch2, n, vs))
                del db_batch2
            # ---- async-scheduler lane: same stream, background pipeline ----
            # best-of-3 fresh stores (this container's wall clock is noisy,
            # and min() is the standard estimator — same as compact_bench).
            # compaction_workers is pinned to BG_WORKERS, the same budget
            # the sharded lane gets (honesty: shard_speedup must measure
            # sharding, not worker-count drift between rows).
            # The sharded lane (§12) rides in the same loop: SHARD_N
            # range-partitioned stores draining flush/compaction on parallel
            # per-shard schedulers under the same BG_WORKERS budget.
            # shard_speedup is total-wall-clock vs total-wall-clock against
            # the shards=1 async lane: the honest number — sharding wins by
            # running background work in parallel AND by making each
            # shard's tree shallower (less total compaction), not by
            # deferring work.  The two lanes run back-to-back inside each
            # repetition (paired measurement): this container's load drifts
            # on the minutes scale, so shard_speedup is the MEDIAN of the
            # per-rep total/total ratios — each ratio's numerator and
            # denominator share one drift window, and the median discards
            # spike reps (independent mins could pair a quiet async rep
            # with a noisy sharded one, or vice versa).
            t_fillasync_fg = t_fillasync_total = float("inf")
            t_shard_total = float("inf")
            pair_ratios = []
            stall_pct = 0.0
            for _ in range(5):   # 5 paired reps: the noise spikes on this
                                 # container last whole seconds; a true
                                 # median of 5 ratios tolerates two spiked
                                 # pairs
                db_async = make_db(c=c, async_compaction=True,
                                   compaction_workers=BG_WORKERS)
                # bulk-load tuning, as RocksDB documents for offline
                # ingest (shared with the sharded lane): soft pressure off,
                # hard stall sized to the whole burst
                tune_bulk_load(db_async, n, vs)
                fg, total = fill_random_batch_async(db_async, n, vs)
                assert_trees_equal(db_batch, db_async)
                t_async_total = total
                if fg < t_fillasync_fg:
                    t_fillasync_fg, t_fillasync_total = fg, total
                    stall_pct = (100.0 * db_async.stats.stall_ns
                                 / max(fg * n * 1e3, 1.0))
                db_async.close()
                db_shard = make_db(c=c, async_compaction=True,
                                   compaction_workers=BG_WORKERS,
                                   shards=SHARD_N, shard_key_space=n * 8)
                tune_bulk_load(db_shard, n, vs)
                _, total = fill_random_batch_async(db_shard, n, vs)
                assert_sharded_reads_equal(db_shard, db_batch, n)
                t_shard_total = min(t_shard_total, total)
                if total:
                    pair_ratios.append(t_async_total / total)
                db_shard.close()
            # ---- skew/rebalance lane (§15): a hotspot-skewed stream (90%
            # of ops into the lowest 10% of the key space) through the
            # same SHARD_N facade twice — static splitters vs dynamic
            # rebalancing (load tracked online, splitters re-derived at
            # quiesce boundaries, runs migrated cross-shard).  End-to-end
            # (quiesced) timing; both trees are then asserted byte-identical
            # to a single-store oracle fed the same stream — reads must
            # survive the migration bit-for-bit.
            hot_keys = Hotspot(n, seed=23).sample(n)
            hot_val = b"h" * vs
            t_hot = {}
            hot_stores = {}
            for tag, extra in (("static", {}),
                               ("rebal", dict(
                                   rebalance_interval_ops=max(2000, n // 8),
                                   rebalance_ratio=1.2))):
                d = make_db(c=c, async_compaction=True,
                            compaction_workers=BG_WORKERS,
                            shards=SHARD_N, shard_key_space=n, **extra)
                tune_bulk_load(d, n, vs)
                t0 = time.perf_counter()
                for i in range(0, n, 4096):
                    d.put_batch(hot_keys[i:i + 4096].tolist(), hot_val)
                d.flush()
                assert d.wait_for_quiesce(600), "hot lane quiesce"
                t_hot[tag] = time.perf_counter() - t0
                hot_stores[tag] = d
            db_hot_oracle = make_db(c=c)
            for i in range(0, n, 4096):
                db_hot_oracle.put_batch(hot_keys[i:i + 4096].tolist(),
                                        hot_val)
            db_hot_oracle.flush()
            for d in hot_stores.values():
                assert_sharded_reads_equal(d, db_hot_oracle, n)
            hot_rebalances = hot_stores["rebal"].rebalances
            for d in (*hot_stores.values(), db_hot_oracle):
                d.close()
            compact = compact_bench(db)
            key_space = n * 8
            s0 = db.stats.snapshot()
            t_read = read_random(db, n_reads, key_space)
            d_read = db.stats.delta(s0)
            # ---- paranoid read lane (§16.2): the same point-read stream
            # with per-block checksum verification on.  Results must be
            # byte-identical to the unchecked lane (verification only
            # checks, never transforms); the column reports the overhead.
            probe = np.random.default_rng(31).integers(
                0, key_space, 512, dtype=np.uint64).tolist()
            plain_mg = db.multi_get(probe)
            plain_pt = [db.get(int(k)) for k in probe[:64]]
            db.config.paranoid_checks = True
            assert db.multi_get(probe) == plain_mg, \
                "paranoid lane changed multi_get results"
            assert [db.get(int(k)) for k in probe[:64]] == plain_pt, \
                "paranoid lane changed point-read results"
            t_read_paranoid = read_random(db, n_reads, key_space)
            db.config.paranoid_checks = False
            paranoid_overhead_pct = ((t_read_paranoid - t_read) / t_read
                                     * 100.0 if t_read else 0.0)
            t_multiget = multiget_random(db, n_reads, key_space)
            s0 = db.stats.snapshot()
            t_seek = seek_random(db, n_reads, key_space, 0)
            d_seek = db.stats.delta(s0)
            t_next10 = seek_random(db, n_reads, key_space, 10)
            t_next100 = seek_random(db, max(n_reads // 4, 250), key_space, 100)
            t_scan_scalar = scan_random(db, n_scans, key_space, SCAN_LEN,
                                        scalar=True)
            t_scan_iter = scan_random(db, n_scans, key_space, SCAN_LEN,
                                      scalar=False)
            # ---- range-view lane (§13): same stream through an async
            # store with REMIX-style sorted views enabled.  Rebuilds are
            # charged to the background scheduler (zero foreground
            # rebuilds is asserted below), and the measured window must
            # be rebuild- and fallback-free: it times the sweep, not the
            # sort.
            db_view = make_db(c=c, async_compaction=True,
                              compaction_workers=BG_WORKERS,
                              use_range_views=True)
            tune_bulk_load(db_view, n, vs)
            fill_random_batch(db_view, n, vs)
            db_view.flush()
            assert db_view.wait_for_quiesce(600), "view lane quiesce"
            assert_trees_equal(db_batch, db_view)
            assert db_view.stats.bg_view_rebuilds > 0, \
                "view lane: no background rebuilds ran"
            assert db_view.stats.view_rebuilds == \
                db_view.stats.bg_view_rebuilds, \
                "view lane: foreground rebuild on the write path"
            probe_rng = np.random.default_rng(11)
            for k in probe_rng.integers(0, key_space, 8, dtype=np.uint64):
                assert db_view.scan(int(k), SCAN_LEN) == \
                    db_view.scan_scalar(int(k), SCAN_LEN), \
                    "view scan diverged from scan_scalar oracle"
            sv0 = db_view.stats.snapshot()
            t_scan_view = scan_random(db_view, n_scans, key_space, SCAN_LEN,
                                      scalar=False)
            d_view = db_view.stats.delta(sv0)
            assert d_view.view_rebuilds == 0, \
                "view lane: rebuild charged inside the measured window"
            assert d_view.view_fallbacks == 0, \
                "view lane: stale-view fallback inside the measured window"
            assert d_view.view_scans >= n_scans, d_view.view_scans
            # tombstone-dense lane: carve a dead band through the keyspace
            # and re-check the scan against the seek-retry oracle (the
            # PR-6 refill fix keeps this O(log deleted), not O(deleted))
            dead_lo, dead_hi = key_space // 4, key_space // 4 + 2_000
            db_view.delete_batch(list(range(dead_lo, dead_hi)))
            db_view.flush()
            assert db_view.wait_for_quiesce(600), "tombstone lane quiesce"
            for k in (dead_lo - 1, dead_lo, (dead_lo + dead_hi) // 2,
                      dead_hi - 1, dead_hi):
                assert db_view.scan(int(k), SCAN_LEN) == \
                    db_view.scan_scalar(int(k), SCAN_LEN), \
                    "tombstone-dense scan diverged from oracle"
            db_view.close()
            # ---- memory-subsystem lane: same tree, cache attached ----
            db.configure_cache(CACHE_KB << 10, PIN_L0_KB << 10)
            read_random(db, n_reads, key_space)            # cold passes warm
            scan_random(db, n_scans, key_space, SCAN_LEN)  # the cache
            s0 = db.stats.snapshot()
            t_read_cached = read_random(db, n_reads, key_space)
            d_read_cached = db.stats.delta(s0)
            t_scan_cached = scan_random(db, n_scans, key_space, SCAN_LEN,
                                        scalar=False)
            d_cached = db.stats.delta(s0)  # hit rate over both warm lanes
            rows.append(dict(
                system=name, value_size=vs, levels=db.num_levels_in_use,
                fillseq_us=t_fillseq, fillrandom_us=t_fillrand,
                load_batch_kops=(1e3 / t_fillbatch) if t_fillbatch else 0.0,
                load_batch_speedup=(t_fillrand / t_fillbatch
                                    if t_fillbatch else 0.0),
                load_async_kops=(1e3 / t_fillasync_fg
                                 if t_fillasync_fg else 0.0),
                load_async_speedup=(t_fillbatch_best / t_fillasync_fg
                                    if t_fillasync_fg else 0.0),
                load_async_total_us=t_fillasync_total,
                stall_pct=stall_pct,
                # load_shard{N}_kops: end-to-end (quiesced) load throughput
                # of the SHARD_N-way facade (best rep); shard_speedup:
                # median per-rep paired ratio vs the shards=1 async lane's
                # end-to-end wall clock, same worker budget
                **{f"load_shard{SHARD_N}_kops":
                   (1e3 / t_shard_total if t_shard_total else 0.0)},
                shard_speedup=(float(np.median(pair_ratios))
                               if pair_ratios else 0.0),
                # load_hot_kops: end-to-end throughput of the rebalancing
                # facade under the hotspot stream; hot_rebal_speedup: its
                # gain over static splitters on the same stream (§15 —
                # single-rep, the 100k-scale claim lives in the ycsb
                # gauntlet); rebalances: migrations that landed
                load_hot_kops=(n / t_hot["rebal"] / 1e3
                               if t_hot["rebal"] else 0.0),
                hot_rebal_speedup=(t_hot["static"] / t_hot["rebal"]
                                   if t_hot["rebal"] else 0.0),
                rebalances=hot_rebalances,
                compact_mb_s=compact["compact_mb_s"],
                compact_speedup=compact["compact_speedup"],
                readrandom_us=t_read,
                paranoid_overhead_pct=paranoid_overhead_pct,
                seekrandom_us=t_seek,
                seeknext10_us=t_next10, seeknext100_us=t_next100,
                multiget_us=t_multiget,
                multiget_speedup=t_read / t_multiget if t_multiget else 0.0,
                scanscalar100_us=t_scan_scalar,
                iterscan100_us=t_scan_iter,
                iterscan_speedup=(t_scan_scalar / t_scan_iter
                                  if t_scan_iter else 0.0),
                # scan_view_kops: range-view scan throughput (§13);
                # scan_view_speedup: vs the MergingIterator scan on the
                # same stream (the PR-5 baseline)
                scan_view_kops=(1e3 / t_scan_view) if t_scan_view else 0.0,
                scan_view_speedup=(t_scan_iter / t_scan_view
                                   if t_scan_view else 0.0),
                readcached_us=t_read_cached,
                scancached100_us=t_scan_cached,
                cachehit_pct=cache_hit_pct(d_cached),
                cached_blocks_per_op=d_read_cached.blocks_read / n_reads,
                write_amp=db.stats.write_amplification(),
                point_blocks_per_op=d_read.blocks_read / n_reads,
                seek_blocks_per_op=d_seek.blocks_read / n_reads,
            ))
    return rows


def main(n: int = DEFAULT_N, value_sizes=VALUE_SIZES, smoke: bool = False,
         json_path: str = None):
    rows = run(n, value_sizes)
    hdr = ("system,value_size,levels,fillseq_us,fillrandom_us,"
           "load_batch_kops,load_batch_speedup,load_async_kops,"
           "load_async_speedup,stall_pct,"
           f"load_shard{SHARD_N}_kops,shard_speedup,"
           "load_hot_kops,hot_rebal_speedup,rebalances,"
           "compact_mb_s,compact_speedup,"
           "readrandom_us,paranoid_overhead_pct,"
           "seekrandom_us,seeknext10_us,seeknext100_us,multiget_us,"
           "multiget_speedup,scanscalar100_us,iterscan100_us,"
           "iterscan_speedup,scan_view_kops,scan_view_speedup,"
           "readcached_us,scancached100_us,cachehit_pct,"
           "cached_blocks,write_amp,point_blocks,seek_blocks")
    print(hdr)
    for r in rows:
        print(f"{r['system']},{r['value_size']},{r['levels']},"
              f"{r['fillseq_us']:.2f},{r['fillrandom_us']:.2f},"
              f"{r['load_batch_kops']:.1f},{r['load_batch_speedup']:.1f},"
              f"{r['load_async_kops']:.1f},{r['load_async_speedup']:.1f},"
              f"{r['stall_pct']:.1f},"
              f"{r[f'load_shard{SHARD_N}_kops']:.1f},"
              f"{r['shard_speedup']:.2f},"
              f"{r['load_hot_kops']:.1f},{r['hot_rebal_speedup']:.2f},"
              f"{r['rebalances']},"
              f"{r['compact_mb_s']:.1f},{r['compact_speedup']:.1f},"
              f"{r['readrandom_us']:.2f},{r['paranoid_overhead_pct']:.1f},"
              f"{r['seekrandom_us']:.2f},"
              f"{r['seeknext10_us']:.2f},{r['seeknext100_us']:.2f},"
              f"{r['multiget_us']:.2f},{r['multiget_speedup']:.1f},"
              f"{r['scanscalar100_us']:.2f},{r['iterscan100_us']:.2f},"
              f"{r['iterscan_speedup']:.1f},"
              f"{r['scan_view_kops']:.1f},{r['scan_view_speedup']:.2f},"
              f"{r['readcached_us']:.2f},{r['scancached100_us']:.2f},"
              f"{r['cachehit_pct']:.1f},{r['cached_blocks_per_op']:.3f},"
              f"{r['write_amp']:.2f},{r['point_blocks_per_op']:.3f},"
              f"{r['seek_blocks_per_op']:.3f}")
    if smoke:
        # CI gate: the write-subsystem columns must be present and nonzero
        for r in rows:
            assert r["load_batch_kops"] > 0 and r["load_batch_speedup"] > 0, r
            assert r["compact_mb_s"] > 0 and r["compact_speedup"] > 0, r
            # async scheduler lane (bit-for-bit vs sync is asserted inline
            # by run(); here the columns must exist and be sane)
            assert r["load_async_kops"] > 0 and r["load_async_speedup"] > 0, r
            assert r["stall_pct"] >= 0, r
            # sharded lane (§12): bit-for-bit reads vs the single-store
            # oracle are asserted inline by run(); the columns must exist
            # and be sane here
            assert r[f"load_shard{SHARD_N}_kops"] > 0, r
            assert r["shard_speedup"] > 0, r
            # skew/rebalance lane (§15): byte-identical reads vs the
            # single-store oracle are asserted inline by run(); here the
            # columns must exist, at least one migration must have landed
            # under the hotspot stream, and the speedup must be sane (the
            # >=1.2x claim is a 100k-scale ycsb-gauntlet number — at smoke
            # scale migration overhead dominates the tiny run)
            assert r["load_hot_kops"] > 0 and r["hot_rebal_speedup"] > 0, r
            assert r["rebalances"] >= 1, r
            # range-view lane (§13): bit-for-bit vs scan_scalar, the
            # tombstone-dense band, and zero foreground rebuilds are all
            # asserted inline by run(); the columns must exist and be
            # sane here (the >=2x speedup claim is a 100k-scale number —
            # at smoke scale the tree is too shallow to gate on it)
            assert r["scan_view_kops"] > 0 and r["scan_view_speedup"] > 0, r
            # paranoid lane (§16.2): bit-identical reads are asserted
            # inline by run(); the overhead column must exist and be a
            # sane percentage (noise can make a tiny run come out
            # slightly negative)
            assert "paranoid_overhead_pct" in r, r
            assert r["paranoid_overhead_pct"] > -90.0, r
        print(f"smoke-ok: load_batch {rows[0]['load_batch_speedup']:.1f}x, "
              f"load_async {rows[0]['load_async_speedup']:.1f}x "
              f"(stall {rows[0]['stall_pct']:.1f}%), "
              f"shard{SHARD_N} {rows[0]['shard_speedup']:.2f}x, "
              f"hot-rebal {rows[0]['hot_rebal_speedup']:.2f}x "
              f"({rows[0]['rebalances']} rebalances), "
              f"compaction {rows[0]['compact_speedup']:.1f}x, "
              f"view-scan {rows[0]['scan_view_speedup']:.2f}x")
    if json_path:
        import json

        def _geomean(vals):
            g = 1.0
            for s in vals:
                g *= s
            return g ** (1.0 / len(vals))

        speedups = [r["load_async_speedup"] for r in rows]
        shard_speedups = [r["shard_speedup"] for r in rows]
        summary = dict(
            n=n,
            load_scalar_us=rows[0]["fillrandom_us"],
            load_batch_us=(1e3 / rows[0]["load_batch_kops"]
                           if rows[0]["load_batch_kops"] else 0.0),
            load_async_speedup_min=min(speedups),
            load_async_speedup_max=max(speedups),
            load_async_speedup_geomean=_geomean(speedups),
            stall_pct_max=max(r["stall_pct"] for r in rows),
            shards=SHARD_N,
            cores=_cores(),
            bg_workers=BG_WORKERS,
            shard_speedup_min=min(shard_speedups),
            shard_speedup_max=max(shard_speedups),
            shard_speedup_geomean=_geomean(shard_speedups),
            hot_rebal_speedup_min=min(r["hot_rebal_speedup"] for r in rows),
            hot_rebal_speedup_max=max(r["hot_rebal_speedup"] for r in rows),
            rebalances_total=sum(r["rebalances"] for r in rows),
            scan_view_speedup_min=min(r["scan_view_speedup"] for r in rows),
            scan_view_speedup_max=max(r["scan_view_speedup"] for r in rows),
        )
        with open(json_path, "w") as f:
            json.dump(dict(bench="micro_dbbench", summary=summary,
                           rows=rows), f, indent=1, sort_keys=True)
        print(f"wrote {json_path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("-n", type=int, default=DEFAULT_N,
                    help="entries to load per configuration")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI run covering every column")
    ap.add_argument("--json", type=str, default=None, metavar="PATH",
                    help="also dump rows + sync-vs-async summary as JSON "
                         "(the BENCH_pr*.json perf-trajectory artifacts)")
    args = ap.parse_args()
    if args.smoke:
        main(n=5_000, value_sizes=(50,), smoke=True, json_path=args.json)
    else:
        main(n=args.n, json_path=args.json)
