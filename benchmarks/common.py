"""Shared benchmark utilities: db factories, key generators, timing."""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import LSMConfig, LSMStore, make_store, uniform_splitters

# Scaled for the 1-core container; pass --full for paper-scale runs.
DEFAULT_N = 200_000


def make_db(policy: str = "garnering", c: float = 0.8, T: float = 2.0,
            bits_per_key: float = 0.0, bloom_allocation: str = "monkey",
            memtable_kb: int = 32, base_kb: int = 128,
            cache_kb: int = 0, pin_l0_kb: int = 0,
            cache_policy: str = "clock",
            async_compaction: bool = False,
            compaction_workers: int = 1,
            shards: int = 1,
            shard_key_space: Optional[int] = None,
            use_range_views: bool = False,
            telemetry=None,
            tuner=None,
            rebalance_interval_ops: int = 0,
            rebalance_ratio: float = 2.0) -> LSMStore:
    """OptimizeForSmallDb-flavoured config (paper §4.2), scaled down with the
    container-scale datasets so the tree reaches realistic depths (L=4..9).
    ``cache_kb``/``pin_l0_kb`` enable the memory subsystem (DESIGN.md §9);
    ``async_compaction`` the background scheduler (DESIGN.md §11);
    ``shards`` the range-partitioned facade (DESIGN.md §12) — pass
    ``shard_key_space`` for dense key ranges (micro_dbbench's ``[0, 8n)``
    streams) so the splitters balance; hashed keys (ycsb's scrambled keys)
    balance under the default full-uint64 splitters; ``telemetry`` attaches
    a ``repro.core.Telemetry`` facade (DESIGN.md §14) for latency
    histograms + event tracing (None keeps the zero-overhead disabled
    path — the default for every existing lane); ``tuner`` attaches a
    ``repro.core.OnlineTuner`` feedback controller (DESIGN.md §17 —
    requires ``telemetry`` for its objective sensor);
    ``rebalance_interval_ops``/``rebalance_ratio`` enable dynamic shard
    rebalancing under skew (DESIGN.md §15; 0 keeps static splitters)."""
    splitters = None
    if shards > 1 and shard_key_space is not None:
        splitters = uniform_splitters(shards, shard_key_space)
    return make_store(LSMConfig(
        policy=policy, c=c, T=T,
        memtable_bytes=memtable_kb << 10,
        base_level_bytes=base_kb << 10,
        bits_per_key=bits_per_key,
        bloom_allocation=bloom_allocation,
        cache_bytes=cache_kb << 10,
        pin_l0_bytes=pin_l0_kb << 10,
        cache_policy=cache_policy,
        async_compaction=async_compaction,
        compaction_workers=compaction_workers,
        shards=shards,
        shard_splitters=splitters,
        use_range_views=use_range_views,
        telemetry=telemetry,
        tuner=tuner,
        rebalance_interval_ops=rebalance_interval_ops,
        rebalance_ratio=rebalance_ratio))


def tune_bulk_load(db, n: int, value_size: int) -> None:
    """RocksDB-documented offline-ingest pressure settings, applied
    identically to the async and sharded load lanes (so their speedup
    columns compare scheduling, not trigger drift): soft pressure off,
    hard stall sized to the whole burst.  On a sharded facade the config
    is live-shared with every shard, and the burst is sized per shard
    (each shard sees ~1/N of the rotations)."""
    shards = len(db.shards) if hasattr(db, "shards") else 1
    db.config.slowdown_trigger = 0
    rotations = n * (value_size + 16) // (shards * db.config.memtable_bytes)
    db.config.stall_trigger = max(256, rotations + 64)


def cache_hit_pct(delta) -> float:
    """Block-cache hit rate (%) over an ``IOStats`` delta window."""
    touched = delta.cache_hit_blocks + delta.cache_miss_blocks
    return 100.0 * delta.cache_hit_blocks / touched if touched else 0.0


def stats_row(stats) -> Dict[str, float]:
    """An ``IOStats`` (or delta) as a stable-key-order dict — the one dump
    harnesses use for JSON/CSV output instead of ad-hoc field reaching."""
    return stats.to_dict()


def fill_random(db: LSMStore, n: int, value_size: int, seed: int = 1,
                key_space: Optional[int] = None) -> float:
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, key_space or (n * 8), n, dtype=np.uint64)
    val = bytes(value_size)
    t0 = time.perf_counter()
    for k in keys:
        db.put(int(k), val)
    db.flush()
    return (time.perf_counter() - t0) / n * 1e6  # us/op


def fill_random_batch(db: LSMStore, n: int, value_size: int, seed: int = 1,
                      key_space: Optional[int] = None,
                      batch: int = 4096) -> float:
    """Same key stream as ``fill_random``, loaded through ``put_batch``
    waves (the vectorized ingest lane, DESIGN.md §10)."""
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, key_space or (n * 8), n, dtype=np.uint64)
    val = bytes(value_size)
    t0 = time.perf_counter()
    for i in range(0, n, batch):
        db.put_batch(keys[i:i + batch].tolist(), val)
    db.flush()
    return (time.perf_counter() - t0) / n * 1e6  # us/op


def fill_random_batch_async(db: LSMStore, n: int, value_size: int,
                            seed: int = 1, key_space: Optional[int] = None,
                            batch: int = 4096) -> Tuple[float, float]:
    """Same key stream as ``fill_random_batch`` through an *async* store.

    Returns ``(foreground_us_op, total_us_op)``: foreground is the write
    path the client actually waits on (puts + rotation enqueues, including
    any write-pressure stalls — compaction is off this path, DESIGN.md
    §11); total additionally waits for the background pipeline to quiesce,
    i.e. the same end state the sync path reaches inline.

    Two scheduling knobs are applied for the burst and restored after,
    mirroring how a production writer thread would be run against a
    dedicated background pool:

      * the GIL switch interval is raised to 20 ms — at the default 5 ms
        the worker preempts the writer mid-burst and the two serialize;
      * the calling thread is pinned off the workers' core (the scheduler
        pins its workers to the last core of the affinity set; without the
        complementary pin the OS migrates the writer onto that core
        mid-burst and they ping-pong).
    """
    import os
    import sys

    rng = np.random.default_rng(seed)
    keys = rng.integers(0, key_space or (n * 8), n, dtype=np.uint64)
    val = bytes(value_size)
    prev_switch = sys.getswitchinterval()
    sys.setswitchinterval(0.02)
    prev_aff = None
    try:
        aff = sorted(os.sched_getaffinity(0))
        if len(aff) > 1:
            prev_aff = set(aff)
            os.sched_setaffinity(0, set(aff[:-1]))
    except (AttributeError, OSError):
        pass
    try:
        t0 = time.perf_counter()
        for i in range(0, n, batch):
            db.put_batch(keys[i:i + batch].tolist(), val)
        db.flush()                  # rotate + enqueue, does not wait
        t_fg = time.perf_counter() - t0
        assert db.wait_for_quiesce(600), "async load failed to quiesce"
        t_total = time.perf_counter() - t0
    finally:
        sys.setswitchinterval(prev_switch)
        if prev_aff is not None:
            os.sched_setaffinity(0, prev_aff)
    return t_fg / n * 1e6, t_total / n * 1e6   # us/op


def fill_seq(db: LSMStore, n: int, value_size: int) -> float:
    val = bytes(value_size)
    t0 = time.perf_counter()
    for k in range(n):
        db.put(k, val)
    db.flush()
    return (time.perf_counter() - t0) / n * 1e6


def read_random(db: LSMStore, n_ops: int, key_space: int,
                seed: int = 2) -> float:
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, key_space, n_ops, dtype=np.uint64)
    t0 = time.perf_counter()
    for k in keys:
        db.get(int(k))
    return (time.perf_counter() - t0) / n_ops * 1e6


def multiget_random(db: LSMStore, n_ops: int, key_space: int, seed: int = 2,
                    batch: int = 4096) -> float:
    """Batched point reads over the same key stream as ``read_random``."""
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, key_space, n_ops, dtype=np.uint64)
    t0 = time.perf_counter()
    for i in range(0, n_ops, batch):
        db.multi_get(keys[i:i + batch])
    return (time.perf_counter() - t0) / n_ops * 1e6


def scan_random(db: LSMStore, n_ops: int, key_space: int, length: int,
                seed: int = 3, scalar: bool = False) -> float:
    """Random range reads of ``length`` entries; ``scalar=True`` uses the
    reference seek-retry path (``scan_scalar``) as the baseline."""
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, key_space, n_ops, dtype=np.uint64)
    fn = db.scan_scalar if scalar else db.scan
    t0 = time.perf_counter()
    for k in keys:
        fn(int(k), length)
    return (time.perf_counter() - t0) / n_ops * 1e6


def seek_random(db: LSMStore, n_ops: int, key_space: int, nexts: int = 0,
                seed: int = 3) -> float:
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, key_space, n_ops, dtype=np.uint64)
    t0 = time.perf_counter()
    if nexts == 0:
        for k in keys:
            db.seek(int(k))
    else:
        for k in keys:
            db.scan(int(k), nexts)
    return (time.perf_counter() - t0) / n_ops * 1e6


class Zipfian:
    """YCSB's zipfian generator (theta=0.99) over [0, n)."""

    def __init__(self, n: int, theta: float = 0.99, seed: int = 7):
        self.n = n
        self.theta = theta
        self.rng = np.random.default_rng(seed)
        zeta = np.cumsum(1.0 / np.arange(1, n + 1) ** theta)
        self.zeta_n = zeta[-1]
        self.cdf = zeta / self.zeta_n

    def sample(self, size: int) -> np.ndarray:
        u = self.rng.random(size)
        return np.searchsorted(self.cdf, u)


class Hotspot:
    """YCSB's hotspot generator over [0, n): ``hot_ops_frac`` of ops hit a
    contiguous ``hot_frac`` slice of the keyspace (the classic skew that
    piles every op into one range-partitioned shard)."""

    def __init__(self, n: int, hot_frac: float = 0.1,
                 hot_ops_frac: float = 0.9, seed: int = 7,
                 hot_start: int = 0):
        self.n = n
        self.width = max(1, int(n * hot_frac))
        self.hot_start = int(hot_start) % max(1, n - self.width + 1)
        self.hot_ops_frac = hot_ops_frac
        self.rng = np.random.default_rng(seed)

    def sample(self, size: int) -> np.ndarray:
        hot = self.rng.random(size) < self.hot_ops_frac
        cold = self.rng.integers(0, self.n, size, dtype=np.uint64)
        hotk = self.hot_start + self.rng.integers(0, self.width, size,
                                                  dtype=np.uint64)
        return np.where(hot, hotk, cold)


class ShiftingHotspot:
    """Hotspot whose hot range jumps to a new (seeded-pseudorandom)
    location every ``period`` sampled ops — the adversarial case for
    rebalancing: splitters tuned for the last phase are wrong for the
    next."""

    def __init__(self, n: int, hot_frac: float = 0.1,
                 hot_ops_frac: float = 0.9, period: int = 20_000,
                 seed: int = 7):
        self.n = n
        self.width = max(1, int(n * hot_frac))
        self.hot_ops_frac = hot_ops_frac
        self.period = max(1, period)
        self.seed = seed
        self.rng = np.random.default_rng(seed)
        self._i = 0            # sampled-op position drives the phase

    def _hot_start(self, phase: int) -> int:
        from repro.core.types import splitmix64
        h = splitmix64(np.asarray([phase * 2654435761 + self.seed],
                                  dtype=np.uint64))[0]
        return int(h % max(1, self.n - self.width))

    def sample(self, size: int) -> np.ndarray:
        out = np.empty(size, dtype=np.uint64)
        done = 0
        while done < size:
            phase = self._i // self.period
            take = min(size - done, self.period - self._i % self.period)
            hs = self._hot_start(phase)
            hot = self.rng.random(take) < self.hot_ops_frac
            cold = self.rng.integers(0, self.n, take, dtype=np.uint64)
            hotk = hs + self.rng.integers(0, self.width, take,
                                          dtype=np.uint64)
            out[done:done + take] = np.where(hot, hotk, cold)
            done += take
            self._i += take
        return out


def shard_imbalance(counts) -> float:
    """max/mean per-shard op share: 1.0 = perfectly balanced, N = all ops
    in one of N shards.  The load metric the rebalance trigger uses and
    the skew-gauntlet rows report."""
    counts = [int(c) for c in counts]
    tot = sum(counts)
    if not counts or tot <= 0:
        return 1.0
    return max(counts) * len(counts) / tot


def fnv_scramble(x: np.ndarray) -> np.ndarray:
    """YCSB-style key scrambling so zipf-hot keys spread over the space."""
    from repro.core.types import splitmix64
    return splitmix64(x.astype(np.uint64))


def pct(vals: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(vals), q))
