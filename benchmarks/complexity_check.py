"""Paper Table 2 / Eq. 6 empirical validation.

For every policy, load growing N and measure: number of levels (vs Eq. 6 for
Garnering), total runs, write amplification, and zero-result point read
blocks.  The orderings claimed in Table 2 must hold:
  runs:  garnering/leveling < lazy-leveling < tiering  (read cost)
  WA:    qlsm-bush < tiering < lazy < garnering(c<1) ~< leveling*T
  L:     garnering grows as sqrt(log N) — sub-logarithmic.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from .common import fill_random, make_db, read_random


POLICIES = (("leveling", 1.0), ("tiering", 1.0), ("lazy-leveling", 1.0),
            ("qlsm-bush", 1.0), ("garnering", 0.8), ("garnering", 0.5))


def run(sizes=(25_000, 50_000, 100_000, 200_000)) -> List[Dict]:
    rows = []
    for n in sizes:
        for policy, c in POLICIES:
            db = make_db(policy=policy, c=c, T=2.0, memtable_kb=16,
                         base_kb=64)
            fill_random(db, n, 50)
            runs = sum(len(l) for l in db._levels)
            s0 = db.stats.snapshot()
            read_random(db, 1000, 1 << 62, seed=5)  # all-absent keys
            d = db.stats.delta(s0)
            name = policy if c == 1.0 or policy != "garnering" \
                else f"garnering({c})"
            pred = db.policy.predicted_levels(
                n * 66, db.config.base_level_bytes) \
                if policy == "garnering" else float("nan")
            rows.append(dict(policy=name, n=n, levels=db.num_levels_in_use,
                             predicted_L=pred, runs=runs,
                             write_amp=db.stats.write_amplification(),
                             zero_read_blocks=d.blocks_read / 1000,
                             delayed=db.stats.delayed_last_level_compactions))
    return rows


def main():
    rows = run()
    print("policy,n,levels,predicted_L,runs,write_amp,zero_read_blocks,"
          "delayed_compactions")
    for r in rows:
        print(f"{r['policy']},{r['n']},{r['levels']},{r['predicted_L']:.1f},"
              f"{r['runs']},{r['write_amp']:.2f},{r['zero_read_blocks']:.2f},"
              f"{r['delayed']}")
    return rows


if __name__ == "__main__":
    main()
