"""Deliverable (g): roofline table from the dry-run artifacts.

Reads benchmarks/artifacts/*.json (produced by repro.launch.dryrun), prints
per (arch x shape) on the single-pod mesh:
  compute / memory / collective terms (seconds/step, per-chip),
  dominant bottleneck, MODEL_FLOPS/HLO_FLOPs ratio, roofline fraction,
plus the multi-pod pass/fail summary.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

ARTIFACT_DIR = Path(__file__).resolve().parent / "artifacts"

PEAK = 197e12
HBM = 819e9
ICI = 50e9


def load(mesh: str = "pod16x16", tag: str = "") -> List[Dict]:
    rows = []
    for p in sorted(ARTIFACT_DIR.glob(f"*__{mesh}{tag}.json")):
        a = json.loads(p.read_text())
        if tag == "" and a.get("tag"):
            continue
        rows.append(a)
    return rows


def terms_of(a: Dict) -> Optional[Dict]:
    if a.get("status") != "ok":
        return None
    h = a["hlo_cost"]
    compute = h["flops_per_device"] / PEAK
    memory = h["hbm_bytes_per_device"] / HBM
    coll = h["collective_bytes_per_device"] / ICI
    terms = dict(compute_s=compute, memory_s=memory, collective_s=coll)
    dom = max(terms, key=terms.get)
    r = a.get("roofline", {})
    mf = r.get("model_flops_per_chip", 0.0)
    return dict(terms, dominant=dom.replace("_s", ""),
                model_flops_per_chip=mf,
                useful=mf / h["flops_per_device"] if h["flops_per_device"]
                else 0.0,
                fraction=(mf / PEAK) / max(terms.values())
                if max(terms.values()) > 0 else 0.0,
                peak_gib=a["memory_analysis"]["peak_estimate_bytes"] / 2**30)


def table(tag: str = "") -> List[Dict]:
    rows = []
    for a in load("pod16x16", tag):
        t = terms_of(a)
        base = dict(arch=a["arch"], shape=a["shape"], status=a["status"])
        if t:
            base.update(t)
        else:
            base["reason"] = a.get("reason", a.get("error", ""))[:60]
        rows.append(base)
    return rows


def main():
    print("arch,shape,status,dominant,compute_s,memory_s,collective_s,"
          "useful_flop_ratio,roofline_fraction,peak_GiB")
    for r in table():
        if r["status"] != "ok":
            print(f"{r['arch']},{r['shape']},{r['status']},,,,,,,")
            continue
        print(f"{r['arch']},{r['shape']},ok,{r['dominant']},"
              f"{r['compute_s']:.4g},{r['memory_s']:.4g},"
              f"{r['collective_s']:.4g},{r['useful']:.3f},"
              f"{r['fraction']:.4f},{r['peak_gib']:.2f}")
    mp = load("pod2x16x16")
    ok = sum(1 for a in mp if a["status"] == "ok")
    sk = sum(1 for a in mp if a["status"] == "skipped")
    er = len(mp) - ok - sk
    print(f"# multi-pod 2x16x16: ok={ok} skipped={sk} err={er}")


if __name__ == "__main__":
    main()
