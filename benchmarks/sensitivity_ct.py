"""Paper Fig. 3: write and small-range-read sensitivity to c and T.

FillRandom then SeekRandomNext10, varying c in [0.4, 1.0] with T in {3, 5}.
Expected (paper §4.2.2): lower c => fewer levels => better reads, worse
writes; higher T => fewer levels => better reads.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from .common import DEFAULT_N, fill_random, make_db, seek_random


def run(n: int = DEFAULT_N // 2) -> List[Dict]:
    rows = []
    for T in (3.0, 5.0):
        for c in (0.4, 0.6, 0.8, 1.0):
            db = make_db(c=c, T=T)
            t_write = fill_random(db, n, 100)
            t_range = seek_random(db, max(n // 8, 500), n * 8, nexts=10)
            # Eq. 6 wants the data volume N in bytes: measure the on-disk
            # per-entry footprint from the store's own flush accounting
            # (bytes/entry actually written, i.e. key + metadata + value)
            # instead of hardcoding this run's value size.
            st = db.stats
            footprint = (st.bytes_flushed / st.entries_flushed
                         if st.entries_flushed else 0.0)
            rows.append(dict(T=T, c=c, levels=db.num_levels_in_use,
                             fillrandom_us=t_write, seeknext10_us=t_range,
                             write_amp=db.stats.write_amplification(),
                             predicted_L=db.policy.predicted_levels(
                                 int(db.total_entries * footprint),
                                 db.config.base_level_bytes)))
    return rows


def main(n: int = DEFAULT_N // 2):
    rows = run(n)
    print("T,c,levels,predicted_L,fillrandom_us,seeknext10_us,write_amp")
    for r in rows:
        print(f"{r['T']:.0f},{r['c']:.1f},{r['levels']},{r['predicted_L']:.1f},"
              f"{r['fillrandom_us']:.2f},{r['seeknext10_us']:.2f},"
              f"{r['write_amp']:.2f}")
    return rows


if __name__ == "__main__":
    main()
