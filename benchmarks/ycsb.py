"""Paper Fig. 4 + Table 3: YCSB core workloads A-F.

Load + six workloads with zipfian (0.99) key selection, comparing RocksDB
(Leveling) vs Autumn c=.8 vs Autumn c=.4, reporting throughput (kops/s),
avg/p95/p99 read latencies, write stalls, and space amplification — the
paper's §4.3 metrics at container scale.  The load phase runs through the
batched ingest lane (``put_batch``, DESIGN.md §10).

Two extra lanes ride on the read-only workload C tree state:
``Cbatch*`` resolves the same zipfian stream through ``multi_get`` waves
(numpy probes, then the Pallas bloom kernel route — ``Cbatch_pallas_kops``),
and the ``autumn(.8)+cache`` system row runs with the memory subsystem
(block cache + pinned L0, DESIGN.md §9) enabled, reporting its block-cache
hit rate (``cachehit_pct``) across the whole workload sweep.

The ``autumn(.8)+async`` row runs the whole sweep with the background
compaction scheduler (DESIGN.md §11): the load phase reports the
*foreground* ingest rate (flush/compaction drain on a worker thread) and
every mixed workload exercises reads racing live background installs.

The ``autumn(.8)+sharded`` row runs the sweep on a 4-shard
``ShardedLSMStore`` (DESIGN.md §12): the scrambled keys range-partition
uniformly, background work drains on parallel per-shard schedulers, and
every workload exercises the facade's cross-shard read paths.

The **skew gauntlet** (``skew_gauntlet``, DESIGN.md §15) is the measured
claim behind dynamic shard rebalancing: uniform / zipfian(0.99) / hotspot /
shifting-hotspot rows, each driving a static-splitter facade, a
rebalancing facade, and the single-store oracle in lockstep with an
identical batched op stream.  Crucially the gauntlet routes the **raw
order-preserving key stream** — the classic sharded lanes above hash every
key through ``fnv_scramble``, which uniformizes the keyspace and *hides*
skew from the splitters, so a hotspot would never reach one shard in the
first place.  Reads are byte-compared against the oracle before, during,
and after the rebalancing epoch (inline asserts), and each row reports the
per-shard op imbalance (max/mean) both lanes actually saw.
"""
from __future__ import annotations

import os
import sys
import time
from typing import Dict, List

import numpy as np

from repro.core import LSMStore

from .common import (Hotspot, ShiftingHotspot, Zipfian, cache_hit_pct,
                     fnv_scramble, make_db, pct, shard_imbalance)

VALUE = 256   # scaled from the paper's 1 KB


def _load(db: LSMStore, n: int, batch: int = 4096) -> Dict:
    """YCSB load phase through the batched ingest lane (``put_batch``
    waves, DESIGN.md §10) — identical resulting tree to a scalar put loop."""
    val = bytes(VALUE)
    keys = fnv_scramble(np.arange(n, dtype=np.uint64))
    t0 = time.perf_counter()
    for i in range(0, n, batch):
        db.put_batch(keys[i:i + batch].tolist(), val)
    db.flush()
    dt = time.perf_counter() - t0
    return dict(kops=n / dt / 1e3, stalls=db.stats.write_stalls)


def _mix(db: LSMStore, n: int, n_ops: int, read_frac: float,
         insert_frac: float = 0.0, rmw_frac: float = 0.0,
         scan_frac: float = 0.0, scan_len: int = 100, latest: bool = False,
         seed: int = 11) -> Dict:
    zipf = Zipfian(n, seed=seed)
    rng = np.random.default_rng(seed)
    idx = zipf.sample(n_ops)
    if latest:  # read-latest: recency-weighted (YCSB D)
        idx = n - 1 - idx
    keys = fnv_scramble(idx.astype(np.uint64))
    ops = rng.random(n_ops)
    next_insert = n
    val = bytes(VALUE)
    read_lat: List[float] = []
    scan_lat: List[float] = []
    t0 = time.perf_counter()
    for i in range(n_ops):
        u = ops[i]
        if u < read_frac:
            t1 = time.perf_counter()
            db.get(int(keys[i]))
            read_lat.append((time.perf_counter() - t1) * 1e6)
        elif u < read_frac + scan_frac:
            t1 = time.perf_counter()
            db.scan(int(keys[i]), scan_len)
            scan_lat.append((time.perf_counter() - t1) * 1e6)
        elif u < read_frac + scan_frac + rmw_frac:
            t1 = time.perf_counter()
            db.get(int(keys[i]))
            db.put(int(keys[i]), val)
            read_lat.append((time.perf_counter() - t1) * 1e6)
        elif u < read_frac + scan_frac + rmw_frac + insert_frac:
            db.put(int(fnv_scramble(np.asarray([next_insert],
                                               np.uint64))[0]), val)
            next_insert += 1
        else:
            db.put(int(keys[i]), val)
    dt = time.perf_counter() - t0
    lat = read_lat or scan_lat
    return dict(kops=n_ops / dt / 1e3,
                avg_us=float(np.mean(lat)) if lat else 0.0,
                p95_us=pct(lat, 95) if lat else 0.0,
                p99_us=pct(lat, 99) if lat else 0.0)


def _mix_batched_reads(db: LSMStore, n: int, n_ops: int, batch: int = 256,
                       seed: int = 11) -> Dict:
    """Workload C through the batched read path: zipfian keys resolved in
    ``batch``-sized ``multi_get`` waves (the KV-serving lookup shape)."""
    zipf = Zipfian(n, seed=seed)
    keys = fnv_scramble(zipf.sample(n_ops).astype(np.uint64))
    lat: List[float] = []          # per-key us, one sample per wave
    t0 = time.perf_counter()
    for i in range(0, n_ops, batch):
        wave = keys[i:i + batch]
        t1 = time.perf_counter()
        db.multi_get(wave)
        lat.append((time.perf_counter() - t1) * 1e6 / len(wave))
    dt = time.perf_counter() - t0
    return dict(kops=n_ops / dt / 1e3,
                avg_us=float(np.mean(lat)),
                p95_us=pct(lat, 95),
                p99_us=pct(lat, 99))


WORKLOADS = {
    "A": dict(read_frac=0.5),                                  # 50r/50u
    "B": dict(read_frac=0.95),                                 # 95r/5u
    "C": dict(read_frac=1.0),                                  # read only
    "D": dict(read_frac=0.95, insert_frac=0.05, latest=True),  # read latest
    "E": dict(read_frac=0.0, scan_frac=0.95, insert_frac=0.05),
    "F": dict(read_frac=0.5, rmw_frac=0.5),                    # rmw
}


SYSTEMS = (  # (name, c, cache_kb, pin_l0_kb, async_compaction, shards)
    ("rocksdb", 1.0, 0, 0, False, 1),
    ("autumn(.8)", 0.8, 0, 0, False, 1),
    ("autumn(.4)", 0.4, 0, 0, False, 1),
    ("autumn(.8)+cache", 0.8, 1024, 128, False, 1),
    # background flush/compaction (DESIGN.md §11) at the steady-state
    # pressure defaults: load_kops is the *foreground* ingest rate, the
    # workload mixes then run with reads racing live background churn
    ("autumn(.8)+async", 0.8, 0, 0, True, 1),
    # sharded keyspace (DESIGN.md §12): 4 range-partitioned stores, parallel
    # per-shard schedulers under a 4-worker budget; the scrambled YCSB keys
    # are uniform over uint64, so the default splitters balance
    ("autumn(.8)+sharded", 0.8, 0, 0, True, 4),
)


def run(n: int = 60_000, n_ops: int = 8_000) -> List[Dict]:
    rows = []
    for name, c, cache_kb, pin_l0_kb, async_c, shards in SYSTEMS:
        db = make_db(c=c, T=5.0, bits_per_key=10, bloom_allocation="monkey",
                     cache_kb=cache_kb, pin_l0_kb=pin_l0_kb,
                     async_compaction=async_c, shards=shards,
                     compaction_workers=shards)
        load = _load(db, n)
        # levels/space_amp need the settled tree; stalls are re-read after
        # quiesce so the async row's count is deterministic (the background
        # L0 rate limiter shares the write_stalls counter)
        assert db.wait_for_quiesce(600), f"{name}: load failed to quiesce"
        row = dict(system=name, load_kops=load["kops"],
                   stalls=db.stats.write_stalls, levels=db.num_levels_in_use,
                   space_amp=db.space_amplification())
        s_sweep = db.stats.snapshot()
        for w, kw in WORKLOADS.items():
            ops = n_ops if w != "E" else max(n_ops // 8, 500)
            m = _mix(db, n, ops, **kw)
            row[f"{w}_kops"] = m["kops"]
            if w in ("A", "C", "E"):
                row[f"{w}_avg_us"] = m["avg_us"]
                row[f"{w}_p95_us"] = m["p95_us"]
                row[f"{w}_p99_us"] = m["p99_us"]
            if w == "C":
                # same tree state as C (read-only workload): batched vs
                # scalar point reads are a like-for-like comparison here
                mb = _mix_batched_reads(db, n, n_ops)
                row["Cbatch_kops"] = mb["kops"]
                row["Cbatch_speedup"] = (mb["kops"] / m["kops"]
                                         if m["kops"] else 0.0)
                # same stream again through the Pallas bloom-probe route
                # (falls back to numpy when jax is unavailable)
                db.config.use_pallas_bloom = True
                row["Cbatch_pallas_kops"] = _mix_batched_reads(
                    db, n, n_ops)["kops"]
                db.config.use_pallas_bloom = False
        # drain churn from the last write mix before the sweep-wide stats
        assert db.wait_for_quiesce(600), f"{name}: sweep failed to quiesce"
        row["cachehit_pct"] = cache_hit_pct(db.stats.delta(s_sweep))
        rows.append(row)
        db.close()
    return rows


# -------------------------------------------------- skew gauntlet (§15)

SKEW_WORKLOADS = ("uniform", "zipfian", "hotspot", "shifting")


def _skew_stream(name: str, n: int, n_ops: int, seed: int = 13
                 ) -> np.ndarray:
    """RAW order-preserving keys over [0, n) — no fnv_scramble, so shard
    routing actually sees the hot range (satellite bugfix: the hashed
    lanes' scrambling made every distribution look uniform to the
    splitters)."""
    if name == "uniform":
        return np.random.default_rng(seed).integers(0, n, n_ops,
                                                    dtype=np.uint64)
    if name == "zipfian":
        return Zipfian(n, seed=seed).sample(n_ops).astype(np.uint64)
    if name == "hotspot":
        # 90% of ops on [0, n/10): entirely inside one static shard —
        # the worst case for fixed splitters
        return Hotspot(n, seed=seed).sample(n_ops)
    if name == "shifting":
        return ShiftingHotspot(n, period=max(1, n_ops // 4),
                               seed=seed).sample(n_ops)
    raise ValueError(name)


def _gauntlet_check(systems: Dict, oracle, n: int, keys: np.ndarray,
                    tag: str) -> None:
    """Inline byte-identity asserts vs the single-store oracle — run
    before / during / after the rebalancing epoch."""
    rng = np.random.default_rng(5)
    probe = np.unique(np.concatenate(
        [keys[: min(2000, keys.size)],
         rng.integers(0, n, 1000, dtype=np.uint64)]))
    exp = oracle.multi_get(probe)
    s0 = int(keys[0]) if keys.size else 0
    exp_scan = oracle.scan(s0, 300)
    for name, db in systems.items():
        assert db.multi_get(probe) == exp, \
            f"{tag}: {name} multi_get diverged from single-store oracle"
        assert db.scan(s0, 300) == exp_scan, \
            f"{tag}: {name} scan diverged from single-store oracle"


def skew_gauntlet(n: int = 100_000, n_ops: int = 0, shards: int = 0,
                  batch: int = 2048, quiet: bool = False) -> List[Dict]:
    """Static splitters vs dynamic rebalancing vs the single-store oracle,
    lockstep-fed the same skewed op stream (7/8 update waves, 1/8
    ``multi_get`` waves, wave-varying values so stale reads cannot pass the
    oracle compare).  Per-store time = its own foreground calls + its own
    drain, so a hot shard's serialized background backlog lands on the lane
    that caused it."""
    try:
        cores = len(os.sched_getaffinity(0))
    except (AttributeError, OSError):
        cores = os.cpu_count() or 2
    shards = shards or max(2, min(4, cores))
    n_ops = n_ops or n
    rows: List[Dict] = []
    for wl in SKEW_WORKLOADS:
        keys = _skew_stream(wl, n, n_ops)
        oracle = make_db(c=0.8, T=5.0, bits_per_key=10,
                         bloom_allocation="monkey")
        systems = {
            "static": make_db(c=0.8, T=5.0, bits_per_key=10,
                              bloom_allocation="monkey",
                              async_compaction=True,
                              compaction_workers=shards, shards=shards,
                              shard_key_space=n),
            # the rebal lane is built UNARMED (interval 0) and armed after
            # the preload: a sequential bulk load looks maximally skewed to
            # the windowed tracker (every sorted wave lands in one shard),
            # and migrating during it both churns and poisons the splitters
            # for the serving phase — arm_rebalancing is the documented
            # bulk-load-then-serve protocol (DESIGN.md §15)
            "rebal": make_db(c=0.8, T=5.0, bits_per_key=10,
                             bloom_allocation="monkey",
                             async_compaction=True,
                             compaction_workers=shards, shards=shards,
                             shard_key_space=n),
        }
        # balanced preload of the full keyspace, identical waves everywhere
        load_keys = np.arange(n, dtype=np.uint64)
        val0 = bytes(VALUE)
        for db in (*systems.values(), oracle):
            for i in range(0, n, 4096):
                db.put_batch(load_keys[i:i + 4096].tolist(), val0)
            db.flush()
        for name, db in systems.items():
            assert db.wait_for_quiesce(600), f"{wl}/{name}: preload quiesce"
        systems["rebal"].arm_rebalancing(max(2000, n_ops // 16), ratio=1.4)
        _gauntlet_check(systems, oracle, n, keys, f"{wl}/before")
        loads0 = {name: db.shard_load_ops() for name, db in systems.items()}
        t_acc = {name: 0.0 for name in systems}
        t_acc["single"] = 0.0
        # same burst discipline as fill_random_batch_async: long GIL slices
        # for the writer, foreground pinned off the workers' core
        prev_switch = sys.getswitchinterval()
        sys.setswitchinterval(0.02)
        prev_aff = None
        try:
            aff = sorted(os.sched_getaffinity(0))
            if len(aff) > 1:
                prev_aff = set(aff)
                os.sched_setaffinity(0, set(aff[:-1]))
        except (AttributeError, OSError):
            pass
        try:
            half_wave = (n_ops // batch) // 2
            for wi, i in enumerate(range(0, n_ops, batch)):
                wave = keys[i:i + batch].tolist()
                write = wi % 8 != 7
                val = (b"%08d" % wi) * (VALUE // 8)
                for name, db in (*systems.items(), ("single", oracle)):
                    t1 = time.perf_counter()
                    if write:
                        db.put_batch(wave, val)
                    else:
                        db.multi_get(wave)
                    t_acc[name] += time.perf_counter() - t1
                if wi == half_wave:
                    # mid-epoch: rebalances (and background churn) live
                    _gauntlet_check(systems, oracle, n, keys,
                                    f"{wl}/during")
            for name, db in systems.items():
                t1 = time.perf_counter()
                db.flush()
                assert db.wait_for_quiesce(600), f"{wl}/{name}: quiesce"
                t_acc[name] += time.perf_counter() - t1
            t1 = time.perf_counter()
            oracle.flush()
            t_acc["single"] += time.perf_counter() - t1
        finally:
            sys.setswitchinterval(prev_switch)
            if prev_aff is not None:
                try:
                    os.sched_setaffinity(0, prev_aff)
                except OSError:
                    pass
        _gauntlet_check(systems, oracle, n, keys, f"{wl}/after")
        imb = {name: shard_imbalance(
                   [b - a for a, b in zip(loads0[name],
                                          db.shard_load_ops())])
               for name, db in systems.items()}
        row = dict(workload=wl, shards=shards,
                   single_kops=n_ops / t_acc["single"] / 1e3,
                   static_kops=n_ops / t_acc["static"] / 1e3,
                   rebal_kops=n_ops / t_acc["rebal"] / 1e3,
                   rebal_speedup=t_acc["static"] / t_acc["rebal"],
                   imb_static=imb["static"], imb_rebal=imb["rebal"],
                   rebalances=systems["rebal"].rebalances,
                   migrated_entries=systems["rebal"].migrated_entries)
        rows.append(row)
        if not quiet:
            print(f"# {wl}: static {row['static_kops']:.1f} kops, "
                  f"rebal {row['rebal_kops']:.1f} kops "
                  f"({row['rebal_speedup']:.2f}x), "
                  f"{row['rebalances']} rebalances, "
                  f"imbalance {imb['static']:.2f} -> {imb['rebal']:.2f}",
                  flush=True)
        for db in (*systems.values(), oracle):
            db.close()
    return rows


def _print_rows(rows: List[Dict]) -> None:
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(f"{r[c]:.2f}" if isinstance(r[c], float)
                       else str(r[c]) for c in cols))


def main(n: int = 60_000, n_ops: int = 8_000, gauntlet_n: int = 0,
         skew_only: bool = False, classic_only: bool = False,
         smoke: bool = False, json_path: str = None):
    out = {}
    if not skew_only:
        rows = run(n, n_ops)
        _print_rows(rows)
        out["classic"] = rows
    if not classic_only:
        grows = skew_gauntlet(n=gauntlet_n or n, quiet=smoke)
        _print_rows(grows)
        out["skew_gauntlet"] = grows
        if smoke:
            # CSV-contract + sanity: all four skew rows present, oracle
            # byte-identity held inline, and the hotspot row actually
            # rebalanced.  Speedup is asserted only at full scale — at
            # smoke scale the migration overhead dominates the tiny run.
            assert [r["workload"] for r in grows] == list(SKEW_WORKLOADS)
            assert all(r["static_kops"] > 0 and r["rebal_kops"] > 0
                       for r in grows)
            hot = next(r for r in grows if r["workload"] == "hotspot")
            assert hot["rebalances"] >= 1, "hotspot row never rebalanced"
            assert hot["migrated_entries"] > 0
            assert hot["imb_rebal"] <= hot["imb_static"] + 1e-9, \
                "rebalancing did not reduce hotspot imbalance"
            print(f"ycsb-ok: gauntlet rows={len(grows)} "
                  f"hotspot_rebalances={hot['rebalances']} "
                  f"imb {hot['imb_static']:.2f}->{hot['imb_rebal']:.2f}")
    if json_path:
        import json
        with open(json_path, "w") as f:
            json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("-n", type=int, default=60_000,
                    help="loaded keys (classic sweep + gauntlet default)")
    ap.add_argument("--ops", type=int, default=8_000,
                    help="ops per classic workload mix")
    ap.add_argument("--gauntlet-n", type=int, default=0,
                    help="skew-gauntlet keys/ops (defaults to -n)")
    ap.add_argument("--skew-only", action="store_true",
                    help="run only the skew gauntlet")
    ap.add_argument("--classic-only", action="store_true",
                    help="run only the classic A-F sweep")
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI smoke: tiny skew gauntlet + contract asserts")
    ap.add_argument("--json", type=str, default=None,
                    help="write rows to this JSON file")
    args = ap.parse_args()
    if args.smoke:
        main(n=4_000, gauntlet_n=4_000, skew_only=True, smoke=True,
             json_path=args.json)
    else:
        main(n=args.n, n_ops=args.ops, gauntlet_n=args.gauntlet_n,
             skew_only=args.skew_only, classic_only=args.classic_only,
             json_path=args.json)
