"""Paper Fig. 4 + Table 3: YCSB core workloads A-F.

Load + six workloads with zipfian (0.99) key selection, comparing RocksDB
(Leveling) vs Autumn c=.8 vs Autumn c=.4, reporting throughput (kops/s),
avg/p95/p99 read latencies, write stalls, and space amplification — the
paper's §4.3 metrics at container scale.  The load phase runs through the
batched ingest lane (``put_batch``, DESIGN.md §10).

Two extra lanes ride on the read-only workload C tree state:
``Cbatch*`` resolves the same zipfian stream through ``multi_get`` waves
(numpy probes, then the Pallas bloom kernel route — ``Cbatch_pallas_kops``),
and the ``autumn(.8)+cache`` system row runs with the memory subsystem
(block cache + pinned L0, DESIGN.md §9) enabled, reporting its block-cache
hit rate (``cachehit_pct``) across the whole workload sweep.

The ``autumn(.8)+async`` row runs the whole sweep with the background
compaction scheduler (DESIGN.md §11): the load phase reports the
*foreground* ingest rate (flush/compaction drain on a worker thread) and
every mixed workload exercises reads racing live background installs.

The ``autumn(.8)+sharded`` row runs the sweep on a 4-shard
``ShardedLSMStore`` (DESIGN.md §12): the scrambled keys range-partition
uniformly, background work drains on parallel per-shard schedulers, and
every workload exercises the facade's cross-shard read paths.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import LSMStore

from .common import Zipfian, cache_hit_pct, fnv_scramble, make_db, pct

VALUE = 256   # scaled from the paper's 1 KB


def _load(db: LSMStore, n: int, batch: int = 4096) -> Dict:
    """YCSB load phase through the batched ingest lane (``put_batch``
    waves, DESIGN.md §10) — identical resulting tree to a scalar put loop."""
    val = bytes(VALUE)
    keys = fnv_scramble(np.arange(n, dtype=np.uint64))
    t0 = time.perf_counter()
    for i in range(0, n, batch):
        db.put_batch(keys[i:i + batch].tolist(), val)
    db.flush()
    dt = time.perf_counter() - t0
    return dict(kops=n / dt / 1e3, stalls=db.stats.write_stalls)


def _mix(db: LSMStore, n: int, n_ops: int, read_frac: float,
         insert_frac: float = 0.0, rmw_frac: float = 0.0,
         scan_frac: float = 0.0, scan_len: int = 100, latest: bool = False,
         seed: int = 11) -> Dict:
    zipf = Zipfian(n, seed=seed)
    rng = np.random.default_rng(seed)
    idx = zipf.sample(n_ops)
    if latest:  # read-latest: recency-weighted (YCSB D)
        idx = n - 1 - idx
    keys = fnv_scramble(idx.astype(np.uint64))
    ops = rng.random(n_ops)
    next_insert = n
    val = bytes(VALUE)
    read_lat: List[float] = []
    scan_lat: List[float] = []
    t0 = time.perf_counter()
    for i in range(n_ops):
        u = ops[i]
        if u < read_frac:
            t1 = time.perf_counter()
            db.get(int(keys[i]))
            read_lat.append((time.perf_counter() - t1) * 1e6)
        elif u < read_frac + scan_frac:
            t1 = time.perf_counter()
            db.scan(int(keys[i]), scan_len)
            scan_lat.append((time.perf_counter() - t1) * 1e6)
        elif u < read_frac + scan_frac + rmw_frac:
            t1 = time.perf_counter()
            db.get(int(keys[i]))
            db.put(int(keys[i]), val)
            read_lat.append((time.perf_counter() - t1) * 1e6)
        elif u < read_frac + scan_frac + rmw_frac + insert_frac:
            db.put(int(fnv_scramble(np.asarray([next_insert],
                                               np.uint64))[0]), val)
            next_insert += 1
        else:
            db.put(int(keys[i]), val)
    dt = time.perf_counter() - t0
    lat = read_lat or scan_lat
    return dict(kops=n_ops / dt / 1e3,
                avg_us=float(np.mean(lat)) if lat else 0.0,
                p95_us=pct(lat, 95) if lat else 0.0,
                p99_us=pct(lat, 99) if lat else 0.0)


def _mix_batched_reads(db: LSMStore, n: int, n_ops: int, batch: int = 256,
                       seed: int = 11) -> Dict:
    """Workload C through the batched read path: zipfian keys resolved in
    ``batch``-sized ``multi_get`` waves (the KV-serving lookup shape)."""
    zipf = Zipfian(n, seed=seed)
    keys = fnv_scramble(zipf.sample(n_ops).astype(np.uint64))
    lat: List[float] = []          # per-key us, one sample per wave
    t0 = time.perf_counter()
    for i in range(0, n_ops, batch):
        wave = keys[i:i + batch]
        t1 = time.perf_counter()
        db.multi_get(wave)
        lat.append((time.perf_counter() - t1) * 1e6 / len(wave))
    dt = time.perf_counter() - t0
    return dict(kops=n_ops / dt / 1e3,
                avg_us=float(np.mean(lat)),
                p95_us=pct(lat, 95),
                p99_us=pct(lat, 99))


WORKLOADS = {
    "A": dict(read_frac=0.5),                                  # 50r/50u
    "B": dict(read_frac=0.95),                                 # 95r/5u
    "C": dict(read_frac=1.0),                                  # read only
    "D": dict(read_frac=0.95, insert_frac=0.05, latest=True),  # read latest
    "E": dict(read_frac=0.0, scan_frac=0.95, insert_frac=0.05),
    "F": dict(read_frac=0.5, rmw_frac=0.5),                    # rmw
}


SYSTEMS = (  # (name, c, cache_kb, pin_l0_kb, async_compaction, shards)
    ("rocksdb", 1.0, 0, 0, False, 1),
    ("autumn(.8)", 0.8, 0, 0, False, 1),
    ("autumn(.4)", 0.4, 0, 0, False, 1),
    ("autumn(.8)+cache", 0.8, 1024, 128, False, 1),
    # background flush/compaction (DESIGN.md §11) at the steady-state
    # pressure defaults: load_kops is the *foreground* ingest rate, the
    # workload mixes then run with reads racing live background churn
    ("autumn(.8)+async", 0.8, 0, 0, True, 1),
    # sharded keyspace (DESIGN.md §12): 4 range-partitioned stores, parallel
    # per-shard schedulers under a 4-worker budget; the scrambled YCSB keys
    # are uniform over uint64, so the default splitters balance
    ("autumn(.8)+sharded", 0.8, 0, 0, True, 4),
)


def run(n: int = 60_000, n_ops: int = 8_000) -> List[Dict]:
    rows = []
    for name, c, cache_kb, pin_l0_kb, async_c, shards in SYSTEMS:
        db = make_db(c=c, T=5.0, bits_per_key=10, bloom_allocation="monkey",
                     cache_kb=cache_kb, pin_l0_kb=pin_l0_kb,
                     async_compaction=async_c, shards=shards,
                     compaction_workers=shards)
        load = _load(db, n)
        # levels/space_amp need the settled tree; stalls are re-read after
        # quiesce so the async row's count is deterministic (the background
        # L0 rate limiter shares the write_stalls counter)
        assert db.wait_for_quiesce(600), f"{name}: load failed to quiesce"
        row = dict(system=name, load_kops=load["kops"],
                   stalls=db.stats.write_stalls, levels=db.num_levels_in_use,
                   space_amp=db.space_amplification())
        s_sweep = db.stats.snapshot()
        for w, kw in WORKLOADS.items():
            ops = n_ops if w != "E" else max(n_ops // 8, 500)
            m = _mix(db, n, ops, **kw)
            row[f"{w}_kops"] = m["kops"]
            if w in ("A", "C", "E"):
                row[f"{w}_avg_us"] = m["avg_us"]
                row[f"{w}_p95_us"] = m["p95_us"]
                row[f"{w}_p99_us"] = m["p99_us"]
            if w == "C":
                # same tree state as C (read-only workload): batched vs
                # scalar point reads are a like-for-like comparison here
                mb = _mix_batched_reads(db, n, n_ops)
                row["Cbatch_kops"] = mb["kops"]
                row["Cbatch_speedup"] = (mb["kops"] / m["kops"]
                                         if m["kops"] else 0.0)
                # same stream again through the Pallas bloom-probe route
                # (falls back to numpy when jax is unavailable)
                db.config.use_pallas_bloom = True
                row["Cbatch_pallas_kops"] = _mix_batched_reads(
                    db, n, n_ops)["kops"]
                db.config.use_pallas_bloom = False
        # drain churn from the last write mix before the sweep-wide stats
        assert db.wait_for_quiesce(600), f"{name}: sweep failed to quiesce"
        row["cachehit_pct"] = cache_hit_pct(db.stats.delta(s_sweep))
        rows.append(row)
        db.close()
    return rows


def main(n: int = 60_000, n_ops: int = 8_000):
    rows = run(n, n_ops)
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(f"{r[c]:.2f}" if isinstance(r[c], float)
                       else str(r[c]) for c in cols))
    return rows


if __name__ == "__main__":
    main()
