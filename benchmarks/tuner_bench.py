"""Online workload-adaptive tuning (DESIGN.md §17): convergence benchmark.

Four lanes run the YCSB A-F sweep (ycsb.py's workload defs, zipfian 0.99)
on identical data; all memory lanes get the same *total* memory budget:

  hand      — hand-tuned reference: the knob set the offline sweeps
              (sensitivity_ct.py, ycsb.py SYSTEMS) settled on.
  default   — out-of-the-box defaults, untuned: the no-regression floor.
  mistuned  — every knob pessimal: leveling-shaped ratios (c=1, T=2),
              cache budget mostly burned on pinned L0, one background
              worker, hair-trigger write slowdown.
  tuned     — starts at *exactly* the mistuned knobs with an
              ``OnlineTuner`` attached; a convergence phase of workload-A
              rounds lets the feedback loop climb (every second round ends
              at a quiesce boundary where ``apply_tuning()`` runs one
              sense→decide→actuate tick over the two-round window), then
              the walk settles on its incumbent vector
              (``OnlineTuner.restore_best``) and the measured A-F sweep
              runs at that converged config.

All four lanes run the same warmup rounds (equal tree op-history) and the
same post-warmup maintenance window (``compact_to_shape`` — a no-op for a
lane already in its policy's shape), so the sweep isolates *knob quality*
from tree-age and tree-shape history.

Headline columns (CSV contract, grepped by CI):
  tuner_steps             — decisions the controller took (trace-visible
                            as ``tuner_step`` events)
  tuned_vs_start_speedup  — tuned geomean kops / mistuned geomean kops
  tuned_vs_hand_pct       — tuned geomean as % of hand-tuned geomean
                            (acceptance: ≥ 90 at full scale)
  tuned_vs_default_pct    — tuned geomean as % of untuned defaults
                            (acceptance: no regression at full scale)

The **phase-change lane** then drives one tuned store through a
read-heavy phase (B: 95/5) followed by a write-heavy phase (10/90) and
reports per-phase steps + objective trajectory — the controller must
re-converge after the workload flips, not stay stuck in the read-tuned
basin.  ``--json`` dumps rows plus the full knob/objective trajectory
(BENCH_pr10.json is a full-scale capture of this).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import OnlineTuner, Telemetry

from .common import make_db
from .ycsb import VALUE, WORKLOADS, _load, _mix

# One shared memory budget for every lane (KB): hand/default split it well,
# mistuned burns it on pinned L0 (pin_frac .89, outside the tuner's own
# bound — the first pin_frac trial clamps back inside).
TOTAL_MEM_KB = 1152

HAND = dict(c=0.8, T=5.0, cache_kb=1024, pin_l0_kb=128,
            compaction_workers=2)
DEFAULT = dict(c=0.8, T=2.0, cache_kb=TOTAL_MEM_KB // 2,
               pin_l0_kb=TOTAL_MEM_KB // 2, compaction_workers=1)
MISTUNED = dict(c=1.0, T=2.0, cache_kb=128, pin_l0_kb=1024,
                compaction_workers=1)
MISTUNED_SLOWDOWN = 8   # hair-trigger soft write pressure (default 64)


def _make(knobs: Dict, telemetry=None, tuner=None):
    db = make_db(bits_per_key=10, bloom_allocation="monkey",
                 async_compaction=True, shards=2,
                 telemetry=telemetry, tuner=tuner, **knobs)
    return db


def _sweep(db, n: int, n_ops: int) -> Dict[str, float]:
    """The ycsb A-F mixes (kops per workload)."""
    out = {}
    for w, kw in WORKLOADS.items():
        ops = n_ops if w != "E" else max(n_ops // 8, 250)
        out[f"{w}_kops"] = _mix(db, n, ops, **kw)["kops"]
    return out


def _geomean(vals: List[float]) -> float:
    v = np.asarray([max(x, 1e-12) for x in vals])
    return float(np.exp(np.log(v).mean()))


def _lane(name: str, knobs: Dict, n: int, n_ops: int,
          slowdown: Optional[int] = None, rounds: int = 0,
          round_ops: int = 2_000, tuned: bool = False) -> Dict:
    """Load + warmup/convergence rounds + measured A-F sweep.

    EVERY lane runs the same ``rounds`` of workload-A warmup so all four
    measured sweeps see a tree with identical op history (dozens of extra
    update rounds measurably age the tree — without equal warmup the
    tuned lane would be scored on staler state than its baselines); only
    the ``tuned`` lane additionally ticks its controller every second
    round's quiesce boundary."""
    tel = tun = None
    if tuned:
        tel = Telemetry()
        # The bench drives every decision itself (apply_tuning below) so
        # the judged windows have a controlled span; the write-path
        # trigger is parked (production deployments would use it).
        tun = OnlineTuner(interval_ops=1 << 30, min_window_ops=64)
    db = _make(knobs, telemetry=tel, tuner=tun)
    if slowdown is not None:
        db.config.slowdown_trigger = slowdown
    load = _load(db, n)
    assert db.wait_for_quiesce(600), f"{name}: load failed to quiesce"

    t_conv = 0.0
    t0 = time.perf_counter()
    for r in range(rounds):
        _mix(db, n, round_ops, read_frac=0.5, seed=13)
        db.wait_for_quiesce(600)
        # One decision per TWO rounds: each judged window then spans two
        # identical-op rounds, halving the window-to-window system noise
        # the 1-core box injects (every round replays the same seed-13 op
        # sequence, so ALL window variance is system state, not workload).
        if tun is not None and r % 2 == 1:
            db.apply_tuning()
    t_conv = time.perf_counter() - t0
    final_knobs = {}
    if tun is not None:
        # Exploration done: settle on the walk's incumbent (revert the
        # unjudged trailing trial, clamp to bounds) — the measured sweep
        # runs one fixed, converged config.
        final_knobs = tun.restore_best(db) or tun.last_knobs()
    # Equal maintenance window for every lane: fold each tree to its
    # *current* policy's predicted shape (a no-op for lanes already in
    # shape).  Without it the tuned lane keeps paying the mistuned-start
    # tree's extra levels forever — a retune widens the caps, so organic
    # churn never consolidates them (see LSMStore.compact_to_shape).
    reshape = db.compact_to_shape()
    db.wait_for_quiesce(600)
    row = dict(lane=name, load_kops=load["kops"])
    row.update(_sweep(db, n, n_ops))
    row["geomean_kops"] = _geomean(
        [v for k, v in row.items() if k.endswith("_kops") and k != "load_kops"])
    row["tuner_steps"] = len(tun.steps) if tun is not None else 0
    row["reshape_merges"] = reshape
    row["converge_s"] = t_conv
    if tun is not None:
        row["final_knobs"] = final_knobs
        row["trajectory"] = [dict(tick=s.tick, knob=s.knob, before=s.before,
                                  after=s.after, accepted=s.accepted,
                                  objective_us=s.objective / 1e3,
                                  window_ops=s.window_ops)
                             for s in tun.steps]
    db.close()
    return row


def phase_change(n: int, rounds: int, round_ops: int) -> Dict:
    """Read-heavy → write-heavy flip on one live tuned store: the
    controller's accepted-step trail must continue into phase 2 (it keeps
    finding improving moves against the new workload, i.e. re-converges
    rather than coasting on the read-tuned knob set)."""
    tel = Telemetry()
    tun = OnlineTuner(interval_ops=1 << 30, min_window_ops=64)
    db = _make(dict(MISTUNED), telemetry=tel, tuner=tun)
    db.config.slowdown_trigger = MISTUNED_SLOWDOWN
    _load(db, n)
    assert db.wait_for_quiesce(600), "phase-change load failed to quiesce"

    def run_phase(read_frac: float) -> Dict:
        first = len(tun.steps)
        objs = []
        for _ in range(rounds):
            _mix(db, n, round_ops, read_frac=read_frac, seed=17)
            db.wait_for_quiesce(600)
            st = db.apply_tuning()
            if st is not None:
                objs.append(st.objective / 1e3)
        steps = tun.steps[first:]
        return dict(steps=len(steps),
                    accepted=sum(1 for s in steps if s.accepted),
                    obj_first_us=objs[0] if objs else 0.0,
                    obj_last_us=objs[-1] if objs else 0.0,
                    knobs=tun.last_knobs())
    p1 = run_phase(0.95)   # read-heavy (YCSB B shape)
    p2 = run_phase(0.10)   # write-heavy flip
    db.close()
    return dict(read_heavy=p1, write_heavy=p2)


def _print_rows(rows: List[Dict]) -> None:
    cols = [c for c in rows[0] if c not in ("final_knobs", "trajectory")]
    print(",".join(cols))
    for r in rows:
        print(",".join(f"{r[c]:.2f}" if isinstance(r[c], float)
                       else str(r[c]) for c in cols))


def main(n: int = 100_000, n_ops: int = 8_000, converge_rounds: int = 60,
         round_ops: int = 2_000, phase_rounds: int = 12,
         smoke: bool = False, json_path: str = None) -> Dict:
    rows = [
        _lane("hand", dict(HAND), n, n_ops,
              rounds=converge_rounds, round_ops=round_ops),
        _lane("default", dict(DEFAULT), n, n_ops,
              rounds=converge_rounds, round_ops=round_ops),
        _lane("mistuned", dict(MISTUNED), n, n_ops,
              slowdown=MISTUNED_SLOWDOWN,
              rounds=converge_rounds, round_ops=round_ops),
        _lane("tuned", dict(MISTUNED), n, n_ops,
              slowdown=MISTUNED_SLOWDOWN,
              rounds=converge_rounds, round_ops=round_ops, tuned=True),
    ]
    _print_rows(rows)
    g = {r["lane"]: r["geomean_kops"] for r in rows}
    tuned = next(r for r in rows if r["lane"] == "tuned")
    summary = dict(
        tuner_steps=tuned["tuner_steps"],
        tuned_vs_start_speedup=g["tuned"] / g["mistuned"],
        tuned_vs_hand_pct=100.0 * g["tuned"] / g["hand"],
        tuned_vs_default_pct=100.0 * g["tuned"] / g["default"],
    )
    _print_rows([summary])

    pc = phase_change(n, phase_rounds, round_ops)
    print("phase,steps,accepted,obj_first_us,obj_last_us")
    for ph in ("read_heavy", "write_heavy"):
        d = pc[ph]
        print(f"{ph},{d['steps']},{d['accepted']},"
              f"{d['obj_first_us']:.1f},{d['obj_last_us']:.1f}")

    if smoke:
        # Contract + liveness asserts only — speedups are asserted at full
        # scale (BENCH_pr10.json), smoke scale is noise-dominated.
        assert tuned["tuner_steps"] >= 3, "tuner took no decisions"
        assert all(v > 0 for v in g.values())
        assert pc["read_heavy"]["steps"] >= 1, "no steps in read phase"
        assert pc["write_heavy"]["steps"] >= 1, \
            "controller went dead after the workload flip"
        ks = tuned["final_knobs"]
        assert ks.get("pin_frac", 0.0) <= 0.75 + 1e-9, \
            "pin_frac escaped its bound"
        print(f"tuner-ok: steps={tuned['tuner_steps']} "
              f"speedup={summary['tuned_vs_start_speedup']:.2f} "
              f"vs_hand={summary['tuned_vs_hand_pct']:.0f}%")
    out = dict(rows=rows, summary=summary, phase_change=pc)
    if json_path:
        import json
        with open(json_path, "w") as f:
            json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("-n", type=int, default=100_000, help="loaded keys")
    ap.add_argument("--ops", type=int, default=8_000,
                    help="ops per measured workload mix")
    ap.add_argument("--rounds", type=int, default=60,
                    help="convergence rounds before the measured sweep")
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI smoke: tiny run + contract asserts")
    ap.add_argument("--json", type=str, default=None,
                    help="write rows + trajectory to this JSON file")
    args = ap.parse_args()
    if args.smoke:
        main(n=4_000, n_ops=1_000, converge_rounds=12, round_ops=500,
             phase_rounds=4, smoke=True, json_path=args.json)
    else:
        main(n=args.n, n_ops=args.ops, converge_rounds=args.rounds,
             json_path=args.json)
