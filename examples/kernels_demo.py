"""Pallas kernel demo: the paper's hot paths on TPU-shaped kernels
(interpret mode on CPU; pass interpret=False on a real TPU).

    PYTHONPATH=src python examples/kernels_demo.py
"""
import numpy as np
import jax.numpy as jnp

from repro.kernels import (bloom_probe, flash_attention, merge_runs_tiled,
                           paged_attention, ops)
from repro.kernels import ref

rng = np.random.default_rng(0)

# 1. bloom_probe: the point-read filter pass (paper §3.1 CPU optimization)
members = rng.integers(0, 2**62, 4096, dtype=np.uint64)
lo, hi = ops.split_u64(members)
bits = ref.bloom_build_ref(np.asarray(lo), np.asarray(hi), m_words=2048,
                           k_hashes=7)
absent = rng.integers(2**62, 2**63, 4096, dtype=np.uint64)
fpr = float(np.mean(np.asarray(bloom_probe(absent, jnp.asarray(bits), 7))))
print(f"bloom_probe      : members all hit, absent FPR={fpr:.4f}")

# 2. merge_path: bitonic compaction merge (two sorted runs -> one)
a = np.sort(rng.integers(0, 1 << 30, 3000, dtype=np.uint32))
b = np.sort(rng.integers(0, 1 << 30, 5000, dtype=np.uint32))
merged, src = merge_runs_tiled(a, b, tile=256)
print(f"merge_path       : {len(a)}+{len(b)} -> {len(merged)} sorted "
      f"({int((src >> 31).sum())} from run B)")

# 3. paged_attention: AutumnKV's decode read path (block table = fence ptrs)
B, H, KH, dh, page, P = 4, 8, 2, 64, 16, 8
q = jnp.asarray(rng.standard_normal((B, H, dh)), jnp.float32)
kp = jnp.asarray(rng.standard_normal((64, page, KH, dh)), jnp.float32)
vp = jnp.asarray(rng.standard_normal((64, page, KH, dh)), jnp.float32)
bt = jnp.asarray(rng.integers(0, 64, (B, P)), jnp.int32)
ln = jnp.asarray(rng.integers(page, P * page, B), jnp.int32)
out = paged_attention(q, kp, vp, bt, ln)
err = float(jnp.max(jnp.abs(out - ref.paged_attention_ref(q, kp, vp, bt, ln))))
print(f"paged_attention  : out {out.shape}, max err vs oracle {err:.2e}")

# 4. flash_attention: prefill hotspot (kills XLA softmax-chain HBM traffic)
q = jnp.asarray(rng.standard_normal((2, 512, 8, 64)), jnp.bfloat16)
k = jnp.asarray(rng.standard_normal((2, 512, 2, 64)), jnp.bfloat16)
v = jnp.asarray(rng.standard_normal((2, 512, 2, 64)), jnp.bfloat16)
o = flash_attention(q, k, v, causal=True, window=128)
e = ref.flash_attention_ref(q, k, v, causal=True, window=128)
err = float(jnp.max(jnp.abs(o.astype(jnp.float32) - e.astype(jnp.float32))))
print(f"flash_attention  : out {o.shape}, max err vs oracle {err:.2e}")
