"""End-to-end serving driver (the paper's kind: a read-optimized store
serving batched requests).

Serves a small qwen3-family model over the AutumnKV prefix cache: three
request waves with overlapping prompts show cache hits skipping prefill and
content-addressed pages deduplicating storage.

    PYTHONPATH=src python examples/serve_autumnkv.py
"""
import time

import jax
import numpy as np

from repro.configs import get_smoke
from repro.models.params import count_params, init_params
from repro.serve import Request, ServeEngine

cfg = get_smoke("qwen3_4b")
params = init_params(cfg, jax.random.PRNGKey(0))
print(f"model: {cfg.name} ({count_params(cfg)/1e6:.2f}M params)")

engine = ServeEngine(cfg, params, batch=4, s_max=96)
rng = np.random.default_rng(7)
system_prompt = rng.integers(0, cfg.vocab, 64, dtype=np.int32)  # shared
other_prompt = rng.integers(0, cfg.vocab, 64, dtype=np.int32)

waves = [
    ("cold wave (4 misses)", [Request(system_prompt, 8)] * 4),
    ("warm wave (4 hits) ", [Request(system_prompt, 8)] * 4),
    ("mixed wave         ", [Request(other_prompt, 8)] * 2 +
     [Request(system_prompt, 8)] * 2),
]
for name, reqs in waves:
    t0 = time.perf_counter()
    outs = engine.serve_batch(reqs)
    dt = time.perf_counter() - t0
    s = engine.kv.stats()
    print(f"{name}: {dt*1e3:7.1f} ms | hits={s['hits']:2d} "
          f"pages_written={s['pages_written']} deduped={s['pages_deduped']} "
          f"| first tokens: {[int(o[0]) for o in outs]}")

s = engine.kv.stats()
print(f"\nAutumnKV store: L={s['levels']} levels, "
      f"bloom probes={s['io']['bloom_probes']}, "
      f"blocks read={s['io']['blocks_read']}")
print(f"engine metrics: {engine.metrics}")
