"""Fault-tolerant training driver: train a smolLM-family model with async
Autumn checkpoints, kill the "host" mid-run, recover, and finish.

    PYTHONPATH=src python examples/train_with_failures.py
"""
from repro.checkpoint import AsyncCheckpointer, CheckpointStore
from repro.data import DataConfig
from repro.launch.train import SimulatedHostFailure, Trainer
from repro.train import OptConfig

from repro.configs import get_smoke

cfg = get_smoke("smollm_135m")
steps = 60
store = CheckpointStore()
trainer = Trainer(
    cfg,
    OptConfig(peak_lr=1e-3, warmup_steps=5, total_steps=steps,
              schedule="wsd"),
    DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8),
    store, checkpoint_every=15)
trainer.init(try_restore=False)

try:
    trainer.run(steps, inject_failure_at=40)
except SimulatedHostFailure as e:
    print(f"!! {e}")
    trainer.simulate_crash()
    resumed = trainer.init(try_restore=True)
    print(f"   restored from Autumn store at step {resumed} "
          f"(L={store.db.num_levels_in_use}, "
          f"delta-skipped={store.stats_deltas_skipped} chunks)")
    trainer.ckpt = AsyncCheckpointer(store)
    trainer.run(steps)

print(f"\ncheckpoint store: {store.stats_chunks_written} chunks written, "
      f"{store.stats_deltas_skipped} delta-skipped, "
      f"WA={store.db.stats.write_amplification():.2f}, "
      f"L={store.db.num_levels_in_use}")
