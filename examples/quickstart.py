"""Quickstart: the Autumn LSM engine and the Garnering policy in 60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import LSMConfig, LSMStore

# --- 1. a read-optimized store (the paper's Autumn: Garnering c=0.8) -------
db = LSMStore(LSMConfig(policy="garnering", T=2.0, c=0.8,
                        memtable_bytes=32 << 10, base_level_bytes=128 << 10,
                        bits_per_key=10, bloom_allocation="monkey"))

rng = np.random.default_rng(0)
keys = rng.integers(0, 1 << 40, 100_000, dtype=np.uint64)
for k in keys:
    db.put(int(k), b"value-" + int(k).to_bytes(8, "little"))
db.flush()

print("point read :", db.get(int(keys[123]))[:6])
print("range read :", [k for k, _ in db.scan(int(keys[0]), 5)])
db.delete(int(keys[123]))
assert db.get(int(keys[123])) is None

# --- 2. what Garnering buys you (paper Table 2 / Eq. 6) --------------------
print(f"\nlevels in use            : {db.num_levels_in_use} "
      f"(Eq. 6 predicts ~{db.policy.predicted_levels(100_000 * 70, 128 << 10):.1f})")
print(f"write amplification      : {db.stats.write_amplification():.2f}")
print(f"delayed last-level compactions: "
      f"{db.stats.delayed_last_level_compactions}")

s0 = db.stats.snapshot()
for k in rng.integers(1 << 62, 1 << 63, 1000):
    db.get(int(k))                      # zero-result lookups
d = db.stats.delta(s0)
print(f"zero-result point read   : {d.blocks_read / 1000:.3f} blocks/op "
      f"(Monkey bloom: {d.bloom_negatives}/{d.bloom_probes} probes negative)")

# --- 3. versus Leveling (RocksDB default) ----------------------------------
lv = LSMStore(LSMConfig(policy="leveling", memtable_bytes=32 << 10,
                        base_level_bytes=128 << 10))
for k in keys:
    lv.put(int(k), b"x" * 14)
lv.flush()
print(f"\nLeveling levels          : {lv.num_levels_in_use}  "
      f"(Autumn: {db.num_levels_in_use})")
print(f"Leveling write amp       : {lv.stats.write_amplification():.2f}  "
      f"(Autumn: {db.stats.write_amplification():.2f})")
