"""AutumnKV + serving engine: hit/miss equivalence, dedup, codec roundtrip."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.kvcache import AutumnKVCache, chain_hashes
from repro.models import model as M
from repro.models.params import init_params
from repro.serve import Request, ServeEngine


@pytest.mark.parametrize("arch", ["qwen3_4b", "recurrentgemma_2b",
                                  "mamba2_130m", "gemma3_1b"])
def test_hit_and_miss_paths_identical(arch):
    cfg = get_smoke(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch=2, s_max=80)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab, 64, dtype=np.int32)
    reqs = [Request(prompt, gen_len=4)] * 2
    out1 = eng.serve_batch(reqs)
    out2 = eng.serve_batch(reqs)
    for a, b in zip(out1, out2):
        np.testing.assert_array_equal(a, b)
    assert eng.kv.hits >= 2


def test_content_addressed_dedup():
    cfg = get_smoke("smollm_135m")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch=2, s_max=80)
    rng = np.random.default_rng(2)
    p = rng.integers(0, cfg.vocab, 64, dtype=np.int32)
    eng.serve_batch([Request(p, 2), Request(p, 2)])
    s = eng.kv.stats()
    assert s["pages_written"] == 1 and s["pages_deduped"] == 1


def test_different_prompts_no_false_hits():
    cfg = get_smoke("smollm_135m")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch=2, s_max=80)
    rng = np.random.default_rng(3)
    p1 = rng.integers(0, cfg.vocab, 64, dtype=np.int32)
    p2 = rng.integers(0, cfg.vocab, 64, dtype=np.int32)
    eng.serve_batch([Request(p1, 2), Request(p1, 2)])
    eng.serve_batch([Request(p2, 2), Request(p2, 2)])
    assert eng.kv.hits == 0 or not np.array_equal(p1, p2)
    assert eng.kv.pages_written == 2


def test_chain_hash_prefix_property():
    rng = np.random.default_rng(4)
    a = rng.integers(0, 1000, 192, dtype=np.int64)
    b = a.copy()
    b[130] += 1  # diverge in the 3rd page
    ha, hb = chain_hashes(a), chain_hashes(b)
    assert ha[0] == hb[0] and ha[1] == hb[1]
    assert ha[2] != hb[2]


def test_codec_page_state_roundtrip():
    cfg = get_smoke("recurrentgemma_2b")
    params = init_params(cfg, jax.random.PRNGKey(5))
    rng = np.random.default_rng(5)
    toks = jax.numpy.asarray(rng.integers(0, cfg.vocab, (1, 64)))
    _, cache = jax.jit(lambda p, b: M.prefill(p, b, cfg, s_max=80))(
        params, {"tokens": toks})
    kv = AutumnKVCache(cfg, 1, 80)
    blank = M.init_cache(cfg, 1, 80)
    rebuilt = kv.codec.write_state(blank, kv.codec.state_bytes(cache))
    rebuilt = kv.codec.write_page(rebuilt, kv.codec.page_bytes(cache, 0), 0)
    for a, b, lg in zip(jax.tree.leaves(cache), jax.tree.leaves(rebuilt),
                        jax.tree.leaves(kv.codec.logical,
                                        is_leaf=lambda x: isinstance(x, tuple))):
        a, b = np.asarray(a), np.asarray(b)
        if "kv_seq" in lg:
            sl = [slice(None)] * a.ndim
            sl[lg.index("kv_seq")] = slice(0, 64)
            np.testing.assert_array_equal(a[tuple(sl)], b[tuple(sl)])
        else:
            np.testing.assert_array_equal(a, b)
