"""Dynamic shard rebalancing (DESIGN.md §15): differential + crash safety.

The single synchronous store stays the retained oracle *through* splitter
migration: for any op sequence, a rebalancing ``ShardedLSMStore`` must
return byte-identical reads before, during, and after any number of
splits/merges/cross-shard run migrations, because the migration protocol
(quiesce -> import -> routing commit -> source strip) never makes an
out-of-routing byte reader-visible.  On top:

  * the automatic trigger fires under skew (write-boundary and quiesce
    paths), never under uniform load, and converges — the histogram-
    weighted derivation cuts a concentrated hot range in one step;
  * explicit ``rebalance_to`` splits and merges land exactly and the
    shared-cache budgets follow the load (hot shard > cold shard, and a
    merge-back restores them; the integer split always sums to the
    configured total);
  * snapshots pinned before a migration keep reading the pre-migration
    state (their routing travels with them; manifest pins keep source
    runs alive), and release leaks nothing;
  * a crash in either migration window — before the routing-log commit,
    or after it but before source cleanup — recovers to exactly the
    pre- or post-migration state respectively (the recovery clip
    finishes whichever side the log says);
  * ``shard_stats``/``shard_load_summary`` expose the per-shard load
    summary and ``EventTrace`` carries shard_split/shard_merge/
    run_migrate/rebalance_* so tail attribution can blame migrations.

All property tests run under both real hypothesis and the fixed-seed shim
(tests/_hypothesis_compat.py).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (LSMConfig, LSMStore, ShardedLSMStore, Telemetry,
                        make_store, uniform_splitters)

KEY_SPACE = 4_000


def cfg(**kw):
    base = dict(policy="garnering", T=2.0, c=0.8, memtable_bytes=1 << 12,
                base_level_bytes=1 << 14, bits_per_key=8,
                bloom_allocation="monkey")
    base.update(kw)
    return LSMConfig(**base)


def sharded_cfg(shards, key_space=KEY_SPACE, **kw):
    return cfg(shards=shards,
               shard_splitters=uniform_splitters(shards, key_space),
               **kw)


def close_quiet(db):
    if hasattr(db, "close"):
        db.close()


def hot_ops(seed, n_ops, hot_lo=0, hot_hi=KEY_SPACE // 10,
            hot_frac=0.9, del_frac=0.1):
    """Skewed op stream: ``hot_frac`` of ops in [hot_lo, hot_hi)."""
    rng = np.random.default_rng(seed)
    ops = []
    for i in range(n_ops):
        if rng.random() < hot_frac:
            k = int(rng.integers(hot_lo, hot_hi))
        else:
            k = int(rng.integers(0, KEY_SPACE))
        if rng.random() < del_frac:
            ops.append((k, None))
        else:
            ops.append((k, bytes([65 + i % 26]) * int(rng.integers(1, 60))))
    return ops


def assert_reads_equal(db, oracle, rng, scans=4):
    probes = rng.integers(0, KEY_SPACE, 256).tolist()
    assert db.multi_get(probes) == oracle.multi_get(probes)
    for _ in range(scans):
        start = int(rng.integers(0, KEY_SPACE))
        assert db.scan(start, 50) == oracle.scan(start, 50)
    k = int(rng.integers(0, KEY_SPACE))
    live = db.scan(k, 1)
    got = db.seek(k)
    if live:
        assert got is not None and k <= got <= live[0][0]


def no_leaked_pins(db):
    for s in db.shards:
        assert s.manifest.total_pin_refs() == 0, "leaked version pins"


# ------------------------------------------------- differential under churn
@given(st.integers(0, 10_000), st.sampled_from([2, 4]))
@settings(max_examples=6, deadline=None)
def test_rebalancing_reads_identical_to_single_store(seed, shards):
    """Property: a skewed stream auto-triggers migrations on an async
    facade while reads are compared wave-by-wave against the synchronous
    single store — byte identity must hold across every split/merge/
    migration the trigger decides to make."""
    oracle = LSMStore(cfg())
    db = make_store(sharded_cfg(shards, async_compaction=True,
                                compaction_workers=2,
                                rebalance_interval_ops=400,
                                rebalance_ratio=1.3))
    rng = np.random.default_rng(seed)
    try:
        for wave in range(6):
            ops = hot_ops(seed + 31 * wave, 400)
            oracle.write_batch(ops)
            db.write_batch(ops)
            assert_reads_equal(db, oracle, rng)
        db.flush()
        assert db.wait_for_quiesce(60)
        assert db.rebalances >= 1, "skewed stream never triggered"
        keys = list(range(KEY_SPACE))
        assert db.multi_get(keys) == oracle.multi_get(keys)
        assert db.scan(0, KEY_SPACE) == oracle.scan_scalar(0, KEY_SPACE)
        assert db.total_live_entries() == oracle.total_live_entries()
        no_leaked_pins(db)
    finally:
        close_quiet(db)


def test_uniform_load_never_triggers():
    db = ShardedLSMStore(sharded_cfg(2, rebalance_interval_ops=200,
                                     rebalance_ratio=1.5))
    rng = np.random.default_rng(5)
    ks = rng.integers(0, KEY_SPACE, 4_000, dtype=np.uint64)
    for i in range(0, ks.size, 256):
        db.put_batch(ks[i:i + 256].tolist(), b"u" * 24)
    assert db.rebalances == 0
    assert db.splitters == tuple(uniform_splitters(2, KEY_SPACE))


# ------------------------------------------- explicit split/merge + budgets
def test_rebalance_to_split_merge_and_cache_budgets():
    """Explicit split toward the hot range, then merge back: splitters land
    exactly, reads stay oracle-equal, and the shared-cache namespace
    budgets follow the measured load — hot shard above a cold one, integer
    split summing to the configured total in both directions."""
    total_cache = 1 << 16
    oracle = LSMStore(cfg())
    db = ShardedLSMStore(sharded_cfg(2, cache_bytes=total_cache,
                                     pin_l0_bytes=0))
    ops = hot_ops(11, 3_000)
    oracle.write_batch(ops)
    db.write_batch(ops)
    oracle.flush()
    hot_splitter = KEY_SPACE // 20
    assert db.rebalance_to([hot_splitter])
    assert db.splitters == (hot_splitter,)
    assert db.rebalances == 1 and db.migrated_entries > 0
    budgets = [s.block_cache.budget_bytes for s in db.shards]
    assert sum(budgets) == total_cache
    assert budgets[0] > budgets[1], \
        "hot shard should get the larger cache slice"
    keys = list(range(KEY_SPACE))
    assert db.multi_get(keys) == oracle.multi_get(keys)
    assert db.scan(0, KEY_SPACE) == oracle.scan_scalar(0, KEY_SPACE)
    # merge back to the uniform split: the cold-merge direction
    mid = KEY_SPACE // 2
    assert db.rebalance_to([mid])
    assert db.splitters == (mid,)
    budgets = [s.block_cache.budget_bytes for s in db.shards]
    assert sum(budgets) == total_cache
    assert db.multi_get(keys) == oracle.multi_get(keys)
    assert db.scan(0, KEY_SPACE) == oracle.scan_scalar(0, KEY_SPACE)
    no_leaked_pins(db)


def test_rebalance_to_validates_splitters():
    db = ShardedLSMStore(sharded_cfg(4))
    with pytest.raises(ValueError):
        db.rebalance_to([10, 20])            # wrong count
    with pytest.raises(ValueError):
        db.rebalance_to([30, 20, 10])        # not ascending


# --------------------------------------------------- snapshots vs migration
def test_snapshot_pins_survive_migration():
    """A snapshot taken before a migration reads the pre-migration state
    afterwards: its routing travels with it and the manifest pins keep the
    source runs alive through export/strip."""
    db = ShardedLSMStore(sharded_cfg(2))
    db.write_batch([(k, b"old-%d" % k) for k in range(0, KEY_SPACE, 3)])
    db.flush()
    snap = db.get_snapshot()
    try:
        assert db.rebalance_to([KEY_SPACE // 8])
        db.write_batch([(k, b"new-%d" % k) for k in range(0, KEY_SPACE, 3)])
        db.flush()
        for k in range(0, KEY_SPACE, 301):
            want = b"old-%d" % k if k % 3 == 0 else None
            assert db.get(k, snapshot=snap) == want
            got = db.get(k)
            assert got == (b"new-%d" % k if k % 3 == 0 else None)
        assert db.scan(0, KEY_SPACE, snapshot=snap) == \
            [(k, b"old-%d" % k) for k in range(0, KEY_SPACE, 3)]
    finally:
        db.release_snapshot(snap)
    no_leaked_pins(db)


# ------------------------------------------------------- crash mid-migration
def _filled_pair(seed=17):
    oracle = LSMStore(cfg())
    db = ShardedLSMStore(sharded_cfg(2, wal_fsync_every_write=True))
    ops = hot_ops(seed, 2_500)
    oracle.write_batch(ops)
    db.write_batch(ops)
    oracle.flush()
    db.flush()
    return oracle, db


def _assert_equal_after_recovery(db, oracle):
    keys = list(range(KEY_SPACE))
    assert db.multi_get(keys) == oracle.multi_get(keys)
    assert db.scan(0, KEY_SPACE) == oracle.scan_scalar(0, KEY_SPACE)
    assert db.total_live_entries() == oracle.total_live_entries()
    no_leaked_pins(db)


def test_crash_before_routing_commit_recovers_pre_migration(monkeypatch):
    """Window 1: imports were durably committed in the destinations but the
    routing log was not — recovery must clip the imported copies and land
    on the exact pre-migration state under the old splitters."""
    oracle, db = _filled_pair()
    old = db.splitters

    def boom(new):
        raise RuntimeError("crash before routing commit")

    monkeypatch.setattr(db, "_commit_routing", boom)
    with pytest.raises(RuntimeError):
        db.rebalance_to([KEY_SPACE // 8])
    monkeypatch.undo()
    db.crash()
    db.recover()
    assert db.splitters == old
    _assert_equal_after_recovery(db, oracle)


def test_crash_after_routing_commit_recovers_post_migration(monkeypatch):
    """Window 2: the routing log committed but the sources were not yet
    stripped — recovery must finish the cleanup and land on the exact
    post-migration state under the new splitters."""
    oracle, db = _filled_pair(seed=23)
    target = KEY_SPACE // 8

    def boom(new):
        raise RuntimeError("crash before source cleanup")

    monkeypatch.setattr(db, "_cleanup_sources", boom)
    with pytest.raises(RuntimeError):
        db.rebalance_to([target])
    monkeypatch.undo()
    db.crash()
    db.recover()
    assert db.splitters == (target,)
    _assert_equal_after_recovery(db, oracle)


def test_rebalance_then_crash_then_recover_roundtrip():
    """A completed migration survives crash/recover: new splitters are the
    durable routing and reads still match the oracle."""
    oracle, db = _filled_pair(seed=29)
    assert db.rebalance_to([KEY_SPACE // 8])
    db.crash()
    db.recover()
    assert db.splitters == (KEY_SPACE // 8,)
    _assert_equal_after_recovery(db, oracle)


# --------------------------------------------- quiesce trigger + telemetry
def test_quiesce_boundary_consumes_rebalance_flag():
    """The scheduler-idle hook only flags; ``wait_for_quiesce`` is a
    rebalance boundary that consumes the flag on the foreground thread and
    re-drains afterwards."""
    db = ShardedLSMStore(sharded_cfg(2, async_compaction=True,
                                     compaction_workers=2,
                                     rebalance_interval_ops=300,
                                     rebalance_ratio=1.3))
    try:
        ops = hot_ops(41, 2_000, del_frac=0.0)
        db.write_batch(ops)
        db.flush()
        assert db.wait_for_quiesce(60)
        assert db.rebalances >= 1
        assert not db._rebalance_needed
        hot_width = KEY_SPACE // 10
        assert db.splitters[0] < uniform_splitters(2, KEY_SPACE)[0], \
            "splitter should have moved toward the hot range"
        assert db.splitters[0] <= 2 * hot_width, db.splitters
    finally:
        close_quiet(db)


def test_rebalance_events_and_shard_stats():
    """Satellite 2: per-shard IOStats via ``shard_stats``, the load summary,
    and shard_split/shard_merge/run_migrate/rebalance_* on the EventTrace
    (what serve_latency's tail attribution blames)."""
    tel = Telemetry()
    db = ShardedLSMStore(sharded_cfg(2, telemetry=tel))
    db.write_batch(hot_ops(43, 2_000, del_frac=0.0))
    db.flush()
    stats = db.shard_stats
    assert len(stats) == 2 and all(isinstance(d, dict) for d in stats)
    assert sum(d["wal_appends"] for d in stats) > 0
    summary = db.shard_load_summary()
    assert [d["shard"] for d in summary] == [0, 1]
    assert summary[0]["lo"] == 0 and summary[1]["hi"] == 1 << 64
    assert abs(sum(d["op_share"] for d in summary) - 1.0) < 1e-9
    assert summary[0]["ops"] > summary[1]["ops"], "hot shard must lead"
    assert db.rebalance_now(force=True)
    kinds = [e.kind for e in tel.trace.dump()]
    assert "rebalance_start" in kinds and "rebalance_end" in kinds
    assert "run_migrate" in kinds
    assert "shard_split" in kinds or "shard_shift" in kinds \
        or "shard_merge" in kinds
    assert tel.percentile("rebalance", 50) > 0


def test_arm_rebalancing_resets_window():
    """arm_rebalancing (the bulk-load-then-serve protocol): a sequential
    preload with rebalancing disarmed never migrates; arming afterwards
    resets the load window so the preload's skew cannot trigger."""
    db = ShardedLSMStore(sharded_cfg(2))
    for i in range(0, KEY_SPACE, 256):
        db.put_batch(list(range(i, min(i + 256, KEY_SPACE))), b"s" * 24)
    assert db.rebalances == 0
    db.arm_rebalancing(500, ratio=1.4)
    assert db._load == [0, 0] and db._ops_since_check == 0
    assert db.config.rebalance_interval_ops == 500
    # balanced post-arm traffic: still no trigger
    rng = np.random.default_rng(47)
    ks = rng.integers(0, KEY_SPACE, 1_500, dtype=np.uint64)
    db.put_batch(ks.tolist(), b"t" * 24)
    db.flush()
    assert db.rebalances == 0
