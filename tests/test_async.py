"""Async compaction scheduler (DESIGN.md §11): differential + concurrency.

The synchronous engine is the bit-for-bit oracle: for any op sequence, the
async store after ``flush() + wait_for_quiesce()`` must hold *identical*
levels (keys/seqs/vlens/vals/bloom bits), memtable, and readable state —
the scheduler replays exactly the sync engine's apply trajectory, just off
the write path.  On top of that:

  * the immutable-memtable read window: rotated-but-unflushed data stays
    visible to every read path (observed deterministically by pausing the
    scheduler);
  * write-pressure control: slowdown/stall triggers engage under backlog
    and charge ``IOStats.stall_ns``;
  * crash mid-compaction: no leaked version pins, no orphaned block-cache
    entries, and full recovery of fsynced data;
  * concurrent snapshot readers see frozen, internally consistent views
    while background compaction churns.

All property tests run under both real hypothesis and the fixed-seed shim
(tests/_hypothesis_compat.py).
"""
import threading

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import LSMConfig, LSMStore

KEY_SPACE = 300


def cfg(**kw):
    base = dict(policy="garnering", T=2.0, c=0.8, memtable_bytes=1 << 12,
                base_level_bytes=1 << 14, bits_per_key=8,
                bloom_allocation="monkey")
    base.update(kw)
    return LSMConfig(**base)


def gen_ops(seed: int, n_ops: int, key_space: int = KEY_SPACE,
            del_frac: float = 0.2):
    rng = np.random.default_rng(seed)
    ops = []
    for i in range(n_ops):
        k = int(rng.integers(0, key_space))
        if rng.random() < del_frac:
            ops.append((k, None))
        else:
            ops.append((k, bytes([65 + i % 26]) * int(rng.integers(0, 100))))
    return ops


def apply_ops(db: LSMStore, ops):
    for k, v in ops:
        (db.delete(k) if v is None else db.put(k, v))


def assert_same_tree(db_a: LSMStore, db_b: LSMStore):
    # one definition of tree equality (level counts + per-run bit equality)
    from repro.core.run import levels_bit_equal

    assert levels_bit_equal(db_a._levels, db_b._levels)


# ------------------------------------------------------- differential oracle
@given(st.integers(0, 10_000), st.booleans())
@settings(max_examples=10, deadline=None)
def test_async_state_identical_to_sync_oracle(seed, use_batch):
    """Property: for any op sequence, the async store after quiesce is
    state-identical to the sync store — levels (every run array), max
    level, memtable contents, live entries, and every readable value."""
    ops = gen_ops(seed, 1200)
    db_s = LSMStore(cfg())
    db_a = LSMStore(cfg(async_compaction=True))
    try:
        if use_batch:
            db_s.write_batch(ops)
            db_a.write_batch(ops)
        else:
            apply_ops(db_s, ops)
            apply_ops(db_a, ops)
        db_s.flush()
        db_a.flush()
        assert db_a.wait_for_quiesce(60)
        assert not db_a._imm
        assert_same_tree(db_s, db_a)
        assert db_a._max_level == db_s._max_level
        assert db_a.memtable._data == db_s.memtable._data
        assert db_a.total_live_entries() == db_s.total_live_entries()
        keys = list(range(KEY_SPACE))
        assert db_a.multi_get(keys) == db_s.multi_get(keys)
        assert db_a.scan(0, KEY_SPACE) == db_s.scan(0, KEY_SPACE)
    finally:
        db_a.close()


def test_async_multiple_workers_still_deterministic():
    """The turnstile serializes jobs in queue order, so extra workers must
    not change the final state."""
    ops = gen_ops(77, 2000)
    db_s = LSMStore(cfg())
    db_a = LSMStore(cfg(async_compaction=True, compaction_workers=3))
    try:
        apply_ops(db_s, ops)
        apply_ops(db_a, ops)
        db_s.flush()
        db_a.flush()
        assert db_a.wait_for_quiesce(60)
        assert_same_tree(db_s, db_a)
        assert db_a.stats.bg_flushes > 0
    finally:
        db_a.close()


# --------------------------------------------------- pipelined flush window
def test_immutable_memtable_window_readable():
    """With the scheduler paused, rotated data lives only in the immutable
    queue — and every read path must still see it (between the active
    memtable and L0)."""
    db = LSMStore(cfg(memtable_bytes=1 << 20, async_compaction=True,
                      stall_trigger=0, slowdown_trigger=0))
    try:
        db._scheduler.pause()
        for k in range(100):
            db.put(k, f"imm{k}".encode())
        db.flush()                       # rotate: enqueue, don't wait
        db.put(7, b"active7")            # newer overwrite in active memtable
        db.delete(8)
        assert len(db._imm) == 1
        assert not db._levels[0]         # flush hasn't been applied
        assert db.get(5) == b"imm5"
        assert db.get(7) == b"active7"   # active shadows immutable
        assert db.get(8) is None         # tombstone shadows immutable
        assert db.multi_get([5, 7, 8, 250]) == [b"imm5", b"active7",
                                                None, None]
        assert db.scan(4, 4) == [(4, b"imm4"), (5, b"imm5"), (6, b"imm6"),
                                 (7, b"active7")]
        assert db.seek(5) == 5
        assert db.total_entries == 102   # 100 imm + overwrite + tombstone
        before = dict(db.scan(0, 200))
        db._scheduler.resume()
        assert db.wait_for_quiesce(60)
        assert not db._imm and db._levels[0]
        assert dict(db.scan(0, 200)) == before   # install changed nothing
    finally:
        db.close()


def test_write_pressure_triggers_engage():
    """Low triggers + sustained load: the foreground must record slowdowns
    and/or stalls with nonzero stall_ns, and the backlog must stay bounded
    by the stall trigger."""
    db = LSMStore(cfg(async_compaction=True, slowdown_trigger=1,
                      stall_trigger=3))
    try:
        bound = 3 + db.config.l0_compaction_trigger  # stall + steady-state L0
        for k, v in gen_ops(3, 4000, key_space=5000, del_frac=0.0):
            db.put(k, v)
            assert len(db._imm) + len(db._levels[0]) <= bound
        db.flush()
        assert db.wait_for_quiesce(60)
        assert db.stats.write_slowdowns + db.stats.write_stalls > 0
        assert db.stats.stall_ns > 0
    finally:
        db.close()


# ------------------------------------------------------------ crash safety
def test_crash_mid_compaction_leaks_nothing():
    """Crash with jobs in flight: pins return to baseline, the block cache
    holds only live run ids after recover(), and every fsynced write
    survives."""
    db = LSMStore(cfg(async_compaction=True, wal_fsync_every_write=True,
                      cache_bytes=1 << 18, pin_l0_bytes=1 << 16))
    ops = gen_ops(11, 3000)
    oracle = {}
    for k, v in ops:
        (db.delete(k) if v is None else db.put(k, v))
        if v is None:
            oracle.pop(k, None)
        else:
            oracle[k] = v
    db.crash()                            # jobs likely mid-flight: abort path
    assert db._scheduler.pending() == 0
    assert db.manifest.total_pin_refs() == 0, "leaked version pins"
    db.recover()
    live = set(db.storage.ids())
    cached = {rid for rid, _ in
              set(db.block_cache._entries) | set(db.block_cache._pinned)}
    assert cached <= live, f"orphaned cache entries: {cached - live}"
    for k in range(KEY_SPACE):            # every write was fsynced: all live
        assert db.get(k) == oracle.get(k), k
    # the store keeps working after recovery (scheduler survived idle)
    db.put(10**6, b"post-recover")
    db.flush()
    assert db.wait_for_quiesce(60)
    assert db.get(10**6) == b"post-recover"
    db.close()


def test_double_crash_recover_consolidated_wal():
    """recover() folds the immutable queue's WAL segments into one log, so
    an immediate second crash (before any rotation) still loses nothing."""
    db = LSMStore(cfg(async_compaction=True, wal_fsync_every_write=True))
    ops = gen_ops(23, 1500)
    oracle = {}
    for k, v in ops:
        (db.delete(k) if v is None else db.put(k, v))
        if v is None:
            oracle.pop(k, None)
        else:
            oracle[k] = v
    db.crash()
    db.recover()
    db.crash()
    db.recover()
    for k in range(KEY_SPACE):
        assert db.get(k) == oracle.get(k), k
    db.close()


# --------------------------------------------------- concurrent snapshots
@given(st.integers(0, 10_000))
@settings(max_examples=4, deadline=None)
def test_concurrent_snapshot_stress(seed):
    """N reader threads each pin a snapshot while the foreground churns
    writes through the async pipeline: every reader must see a *frozen*
    view (identical results across repeated reads) that is internally
    consistent (scans sorted, strictly increasing, agreeing with point
    reads)."""
    db = LSMStore(cfg(async_compaction=True, cache_bytes=1 << 18,
                      bits_per_key=6))
    errors = []
    stop = threading.Event()

    def reader(tid):
        rng = np.random.default_rng(seed + tid)
        try:
            while not stop.is_set():
                snap = db.get_snapshot()
                try:
                    keys = rng.integers(0, KEY_SPACE, 40).tolist()
                    first = db.multi_get(keys, snapshot=snap)
                    scan0 = db.scan(0, 60, snapshot=snap)
                    for _ in range(3):
                        assert db.multi_get(keys, snapshot=snap) == first, \
                            "snapshot view moved under a reader"
                    assert db.scan(0, 60, snapshot=snap) == scan0
                    ks = [k for k, _ in scan0]
                    assert ks == sorted(set(ks)), "scan not strictly sorted"
                    by_key = dict(scan0)
                    probe = db.multi_get(ks[:10], snapshot=snap)
                    assert probe == [by_key[k] for k in ks[:10]]
                finally:
                    db.release_snapshot(snap)
        except Exception as e:            # surface to the main thread
            errors.append(e)

    threads = [threading.Thread(target=reader, args=(t,)) for t in range(4)]
    for t in threads:
        t.start()
    try:
        for wave in range(6):
            db.write_batch(gen_ops(seed + wave, 600))
            db.flush()
        assert db.wait_for_quiesce(60)
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=30)
    assert not errors, errors
    db.close()


def test_background_failure_is_loud_and_recoverable():
    """A job that raises must not kill the pipeline silently: the queue
    drains (no deadlocked writers), wait_for_quiesce raises, and
    crash()+recover() restores a working store with all fsynced data."""
    db = LSMStore(cfg(async_compaction=True, wal_fsync_every_write=True))
    for k in range(100):
        db.put(k, b"pre")
    db.flush()
    assert db.wait_for_quiesce(60)

    def boom(imm):
        raise RuntimeError("injected background failure")

    db._bg_flush = boom
    for k in range(100, 200):
        db.put(k, b"post")
    db.flush()                            # rotates; the worker job explodes
    with pytest.raises(RuntimeError, match="background compaction failed"):
        db.wait_for_quiesce(60)
    assert db._scheduler.idle()           # dead pipeline reports idle:
    del db._bg_flush                      # stalled writers would escape
    db.crash()
    db.recover()                          # scheduler is reusable again
    for k in range(200):
        assert db.get(k) == (b"pre" if k < 100 else b"post"), k
    db.put(1000, b"alive")
    db.flush()
    assert db.wait_for_quiesce(60)
    assert db.get(1000) == b"alive"
    db.close()


def test_close_on_failed_pipeline_folds_stranded_rotations():
    """close() after a background failure must not strand rotated
    memtables: the sync path never reads the immutable queue, so close
    folds them (and their WAL segments) back into the active memtable."""
    db = LSMStore(cfg(async_compaction=True, wal_fsync_every_write=True))
    for k in range(100):
        db.put(k, b"pre")

    def boom(imm):
        raise RuntimeError("injected background failure")

    db._bg_flush = boom
    db.flush()                            # rotates; the worker job explodes
    with pytest.raises(RuntimeError, match="background compaction failed"):
        db.close()
    del db._bg_flush
    assert db._scheduler is None and not db._imm
    for k in range(100):                  # folded back, fully readable
        assert db.get(k) == b"pre", k
    assert db.total_entries == 100
    db.put(5, b"sync"); db.flush()        # sync path works, data merges
    assert db.get(5) == b"sync" and db.get(6) == b"pre"
    db.crash()
    db.recover()                          # consolidated WAL still durable
    assert db.get(7) == b"pre"


def test_snapshotless_readers_race_live_writer():
    """Reader threads on the *live* (snapshot-less) paths — scan, seek,
    multi_get, total_entries, space_amplification — must never crash while
    the writer churns (optimistic memtable iteration retries instead of
    raising 'dictionary changed size during iteration')."""
    db = LSMStore(cfg(async_compaction=True))
    stop = threading.Event()
    errors = []

    def reader(seed):
        rng = np.random.default_rng(seed)
        try:
            while not stop.is_set():
                k = int(rng.integers(0, KEY_SPACE))
                got = db.scan(k, 10)
                ks = [x for x, _ in got]
                assert ks == sorted(set(ks))
                db.seek(k)
                db.multi_get([k, k + 1, k + 2])
                assert db.total_entries >= 0
                assert db.space_amplification() >= 0.0
        except Exception as e:
            errors.append(e)

    threads = [threading.Thread(target=reader, args=(t,)) for t in range(3)]
    for t in threads:
        t.start()
    try:
        for wave in range(8):
            for k, v in gen_ops(wave, 400, del_frac=0.1):
                (db.delete(k) if v is None else db.put(k, v))
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=30)
    assert not errors, errors
    db.flush()
    assert db.wait_for_quiesce(60)
    db.close()


def test_close_reverts_to_sync_and_state_matches():
    db = LSMStore(cfg(async_compaction=True))
    ops = gen_ops(5, 800)
    apply_ops(db, ops)
    db.close()                            # drains, then sync mode
    apply_ops(db, ops)
    db.flush()
    db_s = LSMStore(cfg())
    apply_ops(db_s, ops)
    apply_ops(db_s, ops)
    db_s.flush()
    assert_same_tree(db, db_s)
