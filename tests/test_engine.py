"""LSM engine behaviour: reads/writes/deletes, MVCC, recovery, invariants."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import LSMConfig, LSMStore


def small_cfg(**kw):
    base = dict(policy="garnering", T=2.0, c=0.8, memtable_bytes=1 << 12,
                base_level_bytes=1 << 14, bits_per_key=10,
                bloom_allocation="monkey")
    base.update(kw)
    return LSMConfig(**base)


def test_put_get_delete_scan():
    db = LSMStore(small_cfg())
    for k in range(500):
        db.put(k, f"v{k}".encode())
    db.flush()
    db.delete(123)
    assert db.get(122) == b"v122"
    assert db.get(123) is None
    assert db.get(10_000) is None
    got = db.scan(120, 5)
    assert [k for k, _ in got] == [120, 121, 122, 124, 125]


def test_overwrite_newest_wins():
    db = LSMStore(small_cfg())
    for rep in range(4):
        for k in range(300):
            db.put(k, f"r{rep}k{k}".encode())
        db.flush()
    assert db.get(7) == b"r3k7"
    assert db.scan(7, 1) == [(7, b"r3k7")]


def test_runs_internally_sorted_unique():
    db = LSMStore(small_cfg())
    rng = np.random.default_rng(0)
    for k in rng.integers(0, 2000, 5000):
        db.put(int(k), b"x" * 20)
    db.flush()
    for lvl in db._levels:
        for run in lvl:
            assert (np.diff(run.keys.astype(np.int64)) > 0).all()


def test_mvcc_snapshot_isolation():
    db = LSMStore(small_cfg())
    for k in range(200):
        db.put(k, b"old")
    db.flush()
    snap = db.get_snapshot()
    for k in range(200):
        db.put(k, b"new")
    db.flush()
    assert db.get(5) == b"new"
    assert db.get(5, snapshot=snap) == b"old"
    got = db.scan(0, 3, snapshot=snap)
    assert [v for _, v in got] == [b"old"] * 3


def test_crash_recovery_wal():
    db = LSMStore(small_cfg(wal_fsync_every_write=True))
    for k in range(50):
        db.put(k, b"durable")
    db.flush()
    db.put(999, b"in-wal-only")
    db.crash()
    db.recover()
    assert db.get(999) == b"in-wal-only"   # WAL was fsynced per write
    assert db.get(10) == b"durable"


def test_crash_loses_unsynced_tail():
    db = LSMStore(small_cfg(wal_fsync_every_write=False))
    for k in range(50):
        db.put(k, b"durable")
    db.flush()                       # flush fsyncs + truncates WAL
    db.put(999, b"volatile")         # never fsynced
    db.crash()
    db.recover()
    assert db.get(999) is None
    assert db.get(10) == b"durable"


def test_tombstones_gcd_at_last_level():
    db = LSMStore(small_cfg())
    for k in range(400):
        db.put(k, b"x" * 30)
    for k in range(400):
        db.delete(k)
    db.flush()
    assert db.total_live_entries() == 0
    # force a full merge into the deepest level: tombstones must drop
    from repro.core import CompactionTask
    deepest = db._deepest_nonempty()
    for i in range(1, deepest):
        if db._levels[i]:
            db._apply(CompactionTask(i, deepest, True, "test-force"))
    if db._levels[0]:
        db._apply(CompactionTask(0, deepest, True, "test-force"))
    total = sum(len(r) for lvl in db._levels[1:] for r in lvl)
    assert total == 0
    assert db.get(5) is None


def test_write_stall_counter():
    db = LSMStore(small_cfg(l0_stop_writes_trigger=2,
                            l0_compaction_trigger=100))
    for k in range(4000):
        db.put(k, b"y" * 40)
    assert db.stats.write_stalls > 0


@given(st.lists(st.tuples(st.sampled_from(["put", "del", "get"]),
                          st.integers(0, 120)), min_size=1, max_size=300))
@settings(max_examples=40, deadline=None)
def test_against_dict_oracle(ops):
    """Property: the engine behaves exactly like a dict, across flushes."""
    db = LSMStore(small_cfg(memtable_bytes=1 << 9))
    oracle = {}
    for i, (op, k) in enumerate(ops):
        if op == "put":
            v = f"{i}".encode()
            db.put(k, v)
            oracle[k] = v
        elif op == "del":
            db.delete(k)
            oracle.pop(k, None)
        else:
            assert db.get(k) == oracle.get(k)
    db.flush()
    for k in range(121):
        assert db.get(k) == oracle.get(k), k
    got = db.scan(0, len(oracle) + 5)
    assert got == sorted(oracle.items())


def test_scan_crossing_tombstones_and_levels():
    db = LSMStore(small_cfg(memtable_bytes=1 << 10))
    for k in range(0, 1000, 2):
        db.put(k, b"even")
    db.flush()
    for k in range(0, 1000, 4):
        db.delete(k)
    db.flush()
    got = db.scan(0, 10)
    assert [k for k, _ in got] == [2, 6, 10, 14, 18, 22, 26, 30, 34, 38]
