"""End-to-end behaviour tests: the paper's headline claims, as assertions.

These are the system-level invariants the reproduction stands on:
  1. Garnering has fewer levels than Leveling at equal data (Eq. 6);
  2. zero-result point reads touch fewer runs (no bloom) and ~O(1) blocks
     (with Monkey bloom) — Table 2 point-query columns;
  3. range reads touch fewer runs than Leveling — Table 2 range column;
  4. write amplification stays between Tiering's and Leveling's and is
     sub-linear in N — Table 2 write column;
  5. delayed last-level compactions actually happen (§3.1);
  6. lower c => fewer levels (Fig. 3 mechanism).
"""
import numpy as np
import pytest

from repro.core import LSMConfig, LSMStore


def load(policy, c, n=120_000, bits=0.0):
    db = LSMStore(LSMConfig(policy=policy, c=c, T=2.0,
                            memtable_bytes=1 << 14, base_level_bytes=1 << 16,
                            bits_per_key=bits, bloom_allocation="monkey"))
    rng = np.random.default_rng(42)
    for k in rng.integers(0, n * 8, n, dtype=np.uint64):
        db.put(int(k), b"x" * 50)
    db.flush()
    return db


@pytest.fixture(scope="module")
def dbs():
    return {"leveling": load("leveling", 1.0),
            "garnering8": load("garnering", 0.8),
            "garnering5": load("garnering", 0.5),
            "tiering": load("tiering", 1.0)}


def zero_read_stats(db, n_ops=400):
    rng = np.random.default_rng(7)
    s0 = db.stats.snapshot()
    for k in rng.integers(1 << 62, 1 << 63, n_ops):
        db.get(int(k))
    d = db.stats.delta(s0)
    return (d.runs_touched_point / n_ops, d.blocks_read / n_ops)


def test_fewer_levels_than_leveling(dbs):
    assert dbs["garnering8"].num_levels_in_use < \
        dbs["leveling"].num_levels_in_use
    assert dbs["garnering5"].num_levels_in_use <= \
        dbs["garnering8"].num_levels_in_use


def test_point_reads_touch_fewer_runs(dbs):
    runs_lv, _ = zero_read_stats(dbs["leveling"])
    runs_g, _ = zero_read_stats(dbs["garnering5"])
    assert runs_g <= runs_lv


def test_bloom_makes_zero_reads_near_free():
    db = load("garnering", 0.8, n=60_000, bits=10)
    _, blocks = zero_read_stats(db)
    assert blocks < 0.2  # Monkey: sum of FPRs << 1 block per lookup


def test_range_reads_touch_fewer_runs(dbs):
    def range_runs(db, n_ops=150):
        rng = np.random.default_rng(9)
        s0 = db.stats.snapshot()
        for k in rng.integers(0, 120_000 * 8, n_ops):
            db.scan(int(k), 10)
        d = db.stats.delta(s0)
        return d.runs_touched_range / n_ops
    assert range_runs(dbs["garnering5"]) <= range_runs(dbs["leveling"])


def test_write_amp_ordering(dbs):
    wa = {k: v.stats.write_amplification() for k, v in dbs.items()}
    assert wa["tiering"] < wa["leveling"]
    assert wa["garnering8"] < wa["leveling"] * 1.2  # not catastrophically worse


def test_delayed_compactions_happen(dbs):
    assert dbs["garnering8"].stats.delayed_last_level_compactions > 0
    assert dbs["leveling"].stats.delayed_last_level_compactions == 0


def test_eq6_prediction_tracks_reality(dbs):
    db = dbs["garnering8"]
    pred = db.policy.predicted_levels(db.total_entries * 66,
                                      db.config.base_level_bytes)
    assert abs(db.num_levels_in_use - pred) <= 2.5
