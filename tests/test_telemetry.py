"""Tests for the telemetry subsystem (DESIGN.md §14) and lossless stats.

* ``LatencyHistogram``: percentile vs a sorted-array nearest-rank oracle
  (bucket-exact — both land in the same log bucket by construction), the
  fieldwise merge algebra (concat-equivalence, associativity, identity),
  and ``record_many`` == scalar ``record`` loop.
* ``EventTrace``: ring-buffer wraparound, ``since(cursor)`` incremental
  consumption, timeline rendering.
* Disabled-mode no-op identity: a store with ``telemetry=None`` (the
  default) is bit-for-bit identical — tree, read results, IOStats — to
  seed behavior, and a telemetry-*on* store produces the identical tree
  (telemetry is an observer, never a behavior change).
* ``StatsHub``: the lost-update hammer — concurrent increments from many
  threads merge losslessly (the race this PR fixes), both raw and through
  a live engine with background workers churning.
* Engine wiring: op classes recorded, lifecycle events emitted, sharded
  aggregation through one shared Telemetry, ``IOStats.to_dict`` contract.
"""
import math
import threading

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (EventTrace, IOStats, LatencyHistogram, LSMConfig,
                        LSMStore, StatsHub, Telemetry, make_store)
from repro.core.run import levels_bit_equal
from repro.core.telemetry import N_BUCKETS, bucket_of


# ------------------------------------------------------------ histogram math
def _oracle_nearest_rank(vals, p):
    rank = max(1, math.ceil(len(vals) * p / 100.0))
    return int(np.sort(np.asarray(vals))[rank - 1])


@given(st.lists(st.integers(1, 10**9), min_size=1, max_size=400),
       st.sampled_from([0.0, 50.0, 90.0, 99.0, 99.9, 100.0]))
@settings(max_examples=60, deadline=None)
def test_histogram_percentile_matches_sorted_oracle(vals, p):
    h = LatencyHistogram()
    for v in vals:
        h.record(v)
    est = h.percentile(p)
    assert np.isfinite(est) and est >= 1.0
    # Nearest-rank oracle: the true sample and the histogram's estimate must
    # land in the same log bucket (exact, not tolerance-based: the estimate
    # is the geometric midpoint of the bucket holding the rank-th sample).
    true = _oracle_nearest_rank(vals, p)
    assert bucket_of(int(est)) == bucket_of(true), (p, est, true)


@given(st.lists(st.integers(1, 10**12), min_size=0, max_size=200),
       st.lists(st.integers(1, 10**12), min_size=0, max_size=200),
       st.lists(st.integers(1, 10**12), min_size=0, max_size=200))
@settings(max_examples=40, deadline=None)
def test_histogram_merge_algebra(a, b, c):
    def hist(vals):
        h = LatencyHistogram()
        for v in vals:
            h.record(v)
        return h

    ha, hb, hc = hist(a), hist(b), hist(c)
    # merge == concat
    concat = hist(a + b)
    merged = ha + hb
    assert np.array_equal(merged.counts, concat.counts)
    assert (merged.n, merged.sum_ns, merged.max_ns, merged.min_ns) == \
        (concat.n, concat.sum_ns, concat.max_ns, concat.min_ns)
    # associativity
    l = (ha + hb) + hc
    r = ha + (hb + hc)
    assert np.array_equal(l.counts, r.counts)
    assert (l.n, l.sum_ns, l.max_ns, l.min_ns) == (r.n, r.sum_ns, r.max_ns,
                                                   r.min_ns)
    # identity + sum() support (the IOStats algebra contract)
    ident = ha + LatencyHistogram()
    assert np.array_equal(ident.counts, ha.counts) and ident.n == ha.n
    s = sum([ha, hb, hc])
    assert s.n == len(a) + len(b) + len(c)
    assert s.n == LatencyHistogram.merge([ha, hb, hc]).n


@given(st.lists(st.integers(0, 10**13), min_size=1, max_size=300))
@settings(max_examples=40, deadline=None)
def test_record_many_matches_scalar_record(vals):
    h_scalar = LatencyHistogram()
    for v in vals:
        h_scalar.record(v)
    h_bulk = LatencyHistogram()
    h_bulk.record_many(np.asarray(vals, dtype=np.int64))
    assert np.array_equal(h_scalar.counts, h_bulk.counts)
    assert (h_scalar.n, h_scalar.sum_ns, h_scalar.max_ns, h_scalar.min_ns) \
        == (h_bulk.n, h_bulk.sum_ns, h_bulk.max_ns, h_bulk.min_ns)


def test_histogram_edge_cases():
    h = LatencyHistogram()
    assert math.isnan(h.percentile(50)) and math.isnan(h.mean())
    h.record(0)          # clamps to 1 ns
    h.record(1 << 50)    # clamps into the top bucket
    assert h.n == 2 and h.min_ns == 1
    assert int(h.counts[N_BUCKETS - 1]) == 1
    d = h.to_dict()
    assert list(d.keys()) == ["count", "p50_ns", "p99_ns", "p999_ns",
                              "max_ns", "min_ns", "mean_ns"]


# ------------------------------------------------------------- event trace
def test_event_trace_wraparound_and_since():
    tr = EventTrace(capacity=8)
    for i in range(20):
        tr.emit("ev", i=i)
    assert len(tr) == 8
    assert tr.dropped == 12
    evs = tr.dump()
    assert [e.seq for e in evs] == list(range(13, 21))      # oldest dropped
    assert [e.fields["i"] for e in evs] == list(range(12, 20))
    assert all(evs[i].ts_ns <= evs[i + 1].ts_ns for i in range(len(evs) - 1))
    # incremental consumption: cursor walks, wraparound past the cursor is
    # simply whatever is still buffered
    got, cur = tr.since(0)
    assert [e.seq for e in got] == list(range(13, 21)) and cur == 20
    got, cur = tr.since(cur)
    assert got == [] and cur == 20
    tr.emit("late", x=1)
    got, cur = tr.since(cur)
    assert len(got) == 1 and got[0].kind == "late" and cur == 21
    # interval reconstruction from end-event fields
    s = tr.emit("flush_end", t0=1000, dur_ns=50)
    ev = tr.dump()[-1]
    assert ev.seq == s and ev.interval() == (1000, 1050)
    assert tr.dump()[0].interval() is None
    text = tr.timeline(limit=4)
    assert "flush_end" in text and len(text.splitlines()) == 4


# -------------------------------------------------- disabled-mode identity
def _mixed_workload(db, n=3000):
    keys = np.random.default_rng(3).integers(0, n * 4, n, dtype=np.uint64)
    db.put_batch(keys[:n // 2].tolist(), b"x" * 40)
    for k in keys[n // 2:n // 2 + 200]:
        db.put(int(k), b"y" * 10)
    db.delete_batch(keys[:50].tolist())
    db.flush()
    reads = [db.get(int(k)) for k in keys[:300]]
    reads.append(db.multi_get(keys[:128]))
    reads.append(db.scan(0, 50))
    reads.append(db.seek(int(keys[0])))
    db.write_batch((int(k), b"z") for k in keys[200:400])
    db.flush()
    reads.append(db.scan(int(keys[5]), 30))
    return reads


@pytest.mark.parametrize("shards", [1, 2])
def test_disabled_mode_is_noop_identity(shards):
    """telemetry=None (seed behavior) vs telemetry=Telemetry(): identical
    tree bytes, identical read results, identical IOStats — telemetry is
    an observer, and the disabled path *is* the seed path."""
    def build(tel):
        cfg = LSMConfig(memtable_bytes=1 << 14, bits_per_key=8,
                        shards=shards, use_range_views=True, telemetry=tel)
        return make_store(cfg)

    db_off = build(None)
    db_on = build(Telemetry())
    r_off = _mixed_workload(db_off)
    r_on = _mixed_workload(db_on)
    assert r_off == r_on
    offs = db_off.shards if shards > 1 else [db_off]
    ons = db_on.shards if shards > 1 else [db_on]
    for a, b in zip(offs, ons):
        assert levels_bit_equal(a._levels, b._levels)
    # counters are deterministic; *_ns fields are wall-clock timers
    d_off = {k: v for k, v in db_off.stats.to_dict().items()
             if not k.endswith("_ns")}
    d_on = {k: v for k, v in db_on.stats.to_dict().items()
            if not k.endswith("_ns")}
    assert d_off == d_on
    # and the on-store actually observed the run
    tel = db_on.telemetry
    assert tel.histogram("get").n == 300
    assert tel.histogram("put").n >= 200
    assert any(e.kind == "flush_end" for e in tel.trace.dump())


# ------------------------------------------------------- lost-update hammer
def test_stats_hub_loses_no_increments():
    """The raw race this PR fixes: T threads x K read-modify-writes on the
    same counter.  Per-thread shards make the merged total exact (the old
    shared-IOStats ``+=`` dropped increments under contention)."""
    hub = StatsHub()
    T, K = 8, 20_000
    barrier = threading.Barrier(T)

    def worker():
        st = hub.local()
        barrier.wait()
        for _ in range(K):
            st.point_reads += 1
            st.stall_ns += 3
    threads = [threading.Thread(target=worker) for _ in range(T)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    merged = hub.merged()
    assert merged.point_reads == T * K
    assert merged.stall_ns == 3 * T * K
    # merged() is a fresh object; shards keep accumulating independently
    hub.local().point_reads += 1
    assert hub.merged().point_reads == T * K + 1
    assert merged.point_reads == T * K


@pytest.mark.slow
def test_engine_counters_exact_under_concurrent_readers():
    """End-to-end hammer: reader threads + the foreground writer + the
    background scheduler worker all charge counters concurrently; the
    merged totals are exact, not approximately right."""
    db = LSMStore(LSMConfig(memtable_bytes=1 << 14, bits_per_key=8,
                            async_compaction=True, compaction_workers=2,
                            slowdown_trigger=0, stall_trigger=0))
    n_keys = 6000
    db.put_batch(list(range(500)), b"seed")    # something to read
    R, M = 4, 1500
    barrier = threading.Barrier(R + 1)
    rng = np.random.default_rng(9)
    read_keys = rng.integers(0, n_keys, (R, M), dtype=np.uint64)

    def reader(r):
        barrier.wait()
        for k in read_keys[r]:
            db.get(int(k))
    threads = [threading.Thread(target=reader, args=(r,)) for r in range(R)]
    for t in threads:
        t.start()
    barrier.wait()
    # foreground writer churns (unique keys => every entry flushes once)
    for k in range(500, n_keys):
        db.put(k, b"v" * 20)
    for t in threads:
        t.join()
    db.flush()
    assert db.wait_for_quiesce(600)
    db.close()
    s = db.stats
    assert s.point_reads == R * M                      # readers, exactly
    assert s.wal_appends == n_keys                     # writer, exactly
    assert s.entries_flushed == n_keys                 # workers, exactly
    assert s.bg_flushes > 0                            # and it WAS concurrent


# ------------------------------------------------------------ engine wiring
def test_engine_records_op_classes_and_events():
    tel = Telemetry()
    db = LSMStore(LSMConfig(memtable_bytes=1 << 13, bits_per_key=8,
                            telemetry=tel))
    for i in range(4000):
        db.put(i, b"v" * 16)
    db.flush()
    db.get(1)
    db.multi_get([1, 2, 3])
    db.scan(0, 20)
    db.seek(7)
    db.delete(3)
    db.put_batch([10_000, 10_001], b"w")
    db.write_batch([(10_002, b"q"), (10_003, None)])
    s = tel.summary()
    for op in ("get", "multi_get", "put", "put_batch", "write_batch",
               "scan", "seek", "flush", "compaction", "wal_fsync"):
        assert op in s and s[op]["count"] > 0, op
        assert np.isfinite(s[op]["p99_ns"]) and s[op]["p99_ns"] > 0
    kinds = {e.kind for e in tel.trace.dump()}
    assert {"flush_start", "flush_end",
            "compaction_start", "compaction_end"} <= kinds
    ends = [e for e in tel.trace.dump() if e.kind == "compaction_end"]
    assert all(e.interval() is not None and "entries" in e.fields
               and "src" in e.fields and "dst" in e.fields for e in ends)
    assert "compaction" in tel.report()
    assert db.telemetry is tel


def test_slowdown_pressure_events_and_stall_histogram():
    tel = Telemetry()
    db = LSMStore(LSMConfig(memtable_bytes=1 << 12, telemetry=tel,
                            async_compaction=True, compaction_workers=1,
                            slowdown_trigger=1, stall_trigger=0))
    for i in range(4000):
        db.put(i, b"v" * 16)
    db.flush()
    assert db.wait_for_quiesce(600)
    db.close()
    assert db.stats.write_slowdowns > 0
    assert tel.histogram("stall").n == db.stats.write_slowdowns
    evs = [e for e in tel.trace.dump() if e.kind == "slowdown"]
    assert evs and all(e.interval() is not None and e.fields["depth"] >= 1
                       for e in evs)


def test_sharded_aggregates_one_telemetry():
    tel = Telemetry()
    db = make_store(LSMConfig(shards=3, memtable_bytes=1 << 14,
                              telemetry=tel))
    assert db.telemetry is tel
    assert all(s.telemetry is tel for s in db.shards)
    db.put_batch(list(range(3000)), b"x" * 30)
    db.flush()
    for k in (1, 1001, 2001, 2999):
        db.get(k)
    # every shard records into the same facade-level histograms
    assert tel.histogram("get").n >= 4
    assert tel.histogram("flush").n >= 3     # one flush per non-empty shard
    snap = db.get_snapshot()                  # exercised; retry event only
    db.release_snapshot(snap)                 # fires under real contention


# ------------------------------------------------------------------ to_dict
def test_iostats_to_dict_stable_order():
    import dataclasses
    s = IOStats(blocks_read=3, point_reads=7)
    d = s.to_dict()
    field_names = [f.name for f in dataclasses.fields(IOStats)]
    assert list(d.keys()) == field_names + ["write_amp"]
    assert d["blocks_read"] == 3 and d["point_reads"] == 7
    assert d["write_amp"] == s.write_amplification()
    # deltas dump through the same path
    s2 = IOStats(blocks_read=5, point_reads=7)
    assert s2.delta(s).to_dict()["blocks_read"] == 2
