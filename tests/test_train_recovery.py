"""Training substrate: convergence, bit-exact failure recovery, schedules,
gradient compression, data-pipeline determinism/seekability."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.data import DataConfig, SyntheticTokens
from repro.launch.train import SimulatedHostFailure, Trainer
from repro.train import OptConfig, schedule_lr
from repro.train.compress import (compress_with_feedback, dequantize,
                                  init_error_state, quantize)
from hypothesis import given, settings, strategies as st


def mk_trainer(steps=20, ckpt_every=5):
    cfg = get_smoke("smollm_135m")
    opt = OptConfig(peak_lr=1e-3, warmup_steps=2, total_steps=steps,
                    schedule="wsd")
    data = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4)
    return Trainer(cfg, opt, data, checkpoint_every=ckpt_every)


def test_loss_decreases():
    tr = mk_trainer(steps=30)
    tr.init(try_restore=False)
    hist = tr.run(30, log_every=30)
    assert hist[-1][1] < 6.0


def test_failure_recovery_bit_exact():
    """train(20) == train(12) + crash + restore(10) + train(10..20):
    deterministic data pipeline + exact state restore => identical params."""
    tr1 = mk_trainer(steps=20, ckpt_every=5)
    tr1.init(try_restore=False)
    tr1.run(20, log_every=100)
    ref_params = jax.tree.map(np.asarray, tr1.params)

    tr2 = mk_trainer(steps=20, ckpt_every=5)
    tr2.init(try_restore=False)
    with pytest.raises(SimulatedHostFailure):
        tr2.run(20, inject_failure_at=12, log_every=100)
    tr2.simulate_crash()
    resumed = tr2.init(try_restore=True)
    assert resumed == 10  # last durable checkpoint
    from repro.checkpoint import AsyncCheckpointer
    tr2.ckpt = AsyncCheckpointer(tr2.store)
    tr2.run(20, log_every=100)
    for a, b in zip(jax.tree.leaves(ref_params), jax.tree.leaves(tr2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_wsd_schedule_shape():
    cfg = OptConfig(peak_lr=1.0, warmup_steps=10, total_steps=100,
                    schedule="wsd", wsd_decay_frac=0.2, min_lr_frac=0.1)
    lrs = [float(schedule_lr(jnp.asarray(s), cfg)) for s in range(101)]
    assert lrs[5] < lrs[10]                     # warmup
    assert lrs[10] == pytest.approx(1.0)
    assert lrs[50] == pytest.approx(1.0)        # stable plateau
    assert lrs[100] == pytest.approx(0.1, rel=1e-3)  # decayed tail


def test_data_pipeline_deterministic_and_seekable():
    d1 = SyntheticTokens(DataConfig(vocab=100, seq_len=16, global_batch=4))
    d2 = SyntheticTokens(DataConfig(vocab=100, seq_len=16, global_batch=4))
    np.testing.assert_array_equal(d1.get_batch(7)["tokens"],
                                  d2.get_batch(7)["tokens"])
    # host partitioning is disjoint and covers the global batch
    g = SyntheticTokens(DataConfig(vocab=100, seq_len=16, global_batch=4))
    h0 = SyntheticTokens(DataConfig(vocab=100, seq_len=16, global_batch=4,
                                    num_hosts=2, host_id=0))
    h1 = SyntheticTokens(DataConfig(vocab=100, seq_len=16, global_batch=4,
                                    num_hosts=2, host_id=1))
    full = g.get_batch(3)["tokens"]
    np.testing.assert_array_equal(
        np.concatenate([h0.get_batch(3)["tokens"],
                        h1.get_batch(3)["tokens"]]), full)


def test_planted_bigram_learnable():
    """The synthetic stream's planted structure gives a learnable signal."""
    d = SyntheticTokens(DataConfig(vocab=50, seq_len=32, global_batch=8))
    b = d.get_batch(0)
    toks = b["tokens"]
    # odd positions are a deterministic function of the preceding token
    f = {}
    consistent = 0
    total = 0
    for row in toks:
        for i in range(1, len(row), 2):
            total += 1
            prev = row[i - 1]
            if prev in f:
                consistent += f[prev] == row[i]
            else:
                f[prev] = row[i]
                consistent += 1
    assert consistent / total > 0.95


@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=1,
                max_size=64))
@settings(max_examples=50, deadline=None)
def test_quantize_error_bounded(xs):
    x = jnp.asarray(np.asarray(xs, np.float32))
    q, scale = quantize(x)
    err = np.abs(np.asarray(dequantize(q, scale)) - np.asarray(x))
    assert err.max() <= float(scale) * 0.5 + 1e-6


def test_error_feedback_unbiased_over_time():
    """Sum of dequantized updates + final residual == sum of true grads."""
    rng = np.random.default_rng(0)
    err = jnp.zeros(32)
    total_sent = np.zeros(32)
    total_true = np.zeros(32)
    for step in range(50):
        g = jnp.asarray(rng.standard_normal(32), jnp.float32)
        q, scale, err = compress_with_feedback(g, err)
        total_sent += np.asarray(dequantize(q, scale))
        total_true += np.asarray(g)
    np.testing.assert_allclose(total_sent + np.asarray(err), total_true,
                               rtol=1e-4, atol=1e-4)


def test_compressed_allreduce_shard_map():
    """int8 gradient all-reduce under shard_map over the data axis."""
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.train.compress import compressed_grad_allreduce
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    g = {"w": jnp.arange(8, dtype=jnp.float32)}
    e = init_error_state(g)

    def f(g, e):
        return compressed_grad_allreduce(g, e, "data")

    out, new_e = jax.jit(shard_map(f, mesh=mesh,
                                   in_specs=(P(), P()),
                                   out_specs=(P(), P())))(g, e)
    np.testing.assert_allclose(np.asarray(out["w"]), np.arange(8),
                               atol=0.05)
