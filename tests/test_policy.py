"""Merge policy math: Eq. 4/5/6, delayed compaction, policy orderings."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Garnering, Leveling, LSMConfig, LSMStore, make_policy


def test_eq4_capacity_ratio():
    """C_i / C_{i-1} = T / c^{L-i} (Eq. 4), with C_0 = B."""
    g = Garnering(T=2.0, c=0.8)
    B, L = 1 << 20, 7
    prev = float(B)
    for i in range(1, L + 1):
        cap = g.capacity(i, L, B)
        assert cap / prev == pytest.approx(2.0 / 0.8 ** (L - i), rel=1e-9)
        prev = cap


def test_c_equals_one_is_leveling():
    """Paper §4.1: Garnering with c=1 has Leveling's capacity ratios."""
    g = Garnering(T=3.0, c=1.0)
    l = Leveling(T=3.0)
    for i in range(1, 8):
        assert g.capacity(i, 8, 1000) == pytest.approx(l.capacity(i, 8, 1000))


def test_capacities_grow_with_L():
    """Delayed last-level compaction is sound because every capacity grows
    when L grows (paper §3.1)."""
    g = Garnering(T=2.0, c=0.8)
    for i in range(1, 6):
        for L in range(i, 10):
            assert g.capacity(i, L + 1, 1000) > g.capacity(i, L, 1000)


def test_eq6_levels_sublogarithmic():
    g = Garnering(T=2.0, c=0.8)
    B = 1 << 20
    prev_L = 0.0
    ratios = []
    for k in range(4, 16):
        L = g.predicted_levels(B * 2 ** k, B)
        ratios.append(L / math.sqrt(k))
        assert L >= prev_L
        prev_L = L
    # L / sqrt(log N) is ~constant => predicted levels track Eq. 6
    assert max(ratios) / min(ratios) < 1.6


def test_delayed_compaction_counted():
    g = Garnering(T=2.0, c=0.8)
    B = 1000
    # last level (1) marginally overfull: plan grows L instead of compacting
    # (capacity(1, 2) = capacity(1, 1)/c covers the overflow — §3.1)
    levels = [[], [int(g.capacity(1, 1, B) * 1.1)]]
    new_L, task, delayed = g.plan(levels, 1, B)
    assert delayed >= 1 and new_L >= 2
    assert task is None or task.src_level == 0


def test_garnering_plan_prioritizes_lower_levels():
    g = Garnering(T=2.0, c=0.8, l0_trigger=4)
    B = 1000
    big = int(1e9)
    levels = [[], [big], [big]]
    new_L, task, _ = g.plan(levels, 3, B)
    assert task is not None and task.src_level == 1


# ---------------------------------------------------- Garnering invariants
@given(st.floats(min_value=1.1, max_value=8.0),
       st.integers(min_value=1, max_value=12),
       st.integers(min_value=10, max_value=10 ** 9))
@settings(max_examples=40, deadline=None)
def test_c1_capacities_equal_leveling_exactly(T, L, B):
    """Paper §4.1: Garnering with c=1 *is* Leveling — capacities are equal
    exactly (c^x == 1.0 in floating point), at every level and tree height."""
    g = Garnering(T=T, c=1.0)
    lv = Leveling(T=T)
    for i in range(1, L + 1):
        assert g.capacity(i, L, B) == lv.capacity(i, L, B)


@given(st.floats(min_value=1.1, max_value=8.0),
       st.floats(min_value=0.05, max_value=1.0),
       st.integers(min_value=1, max_value=12),
       st.integers(min_value=10, max_value=10 ** 9))
@settings(max_examples=60, deadline=None)
def test_capacities_monotone_in_level(T, c, L, B):
    """C_i is strictly increasing in i (Eq. 4: each ratio is T/c^{L-i} > 1),
    so deeper levels always hold more — the shape delayed compaction needs."""
    g = Garnering(T=T, c=c)
    caps = [g.capacity(i, L, B) for i in range(1, L + 1)]
    for lo, hi in zip(caps, caps[1:]):
        assert hi > lo


def test_predicted_levels_tracks_empirical_growth():
    """Eq. 6's prediction stays within a constant factor of the levels an
    actual Garnering tree grows as N scales up."""
    ratios = []
    for n in (2000, 6000, 18000):
        db = LSMStore(LSMConfig(policy="garnering", T=2.0, c=0.8,
                                memtable_bytes=1 << 12,
                                base_level_bytes=1 << 14))
        for k in range(n):
            db.put(k, b"x" * 40)
        db.flush()
        pred = db.policy.predicted_levels(n * 56, db.config.base_level_bytes)
        emp = db.num_levels_in_use
        assert emp >= 1 and pred > 0
        ratios.append(emp / pred)
    # constant-factor tracking: the ratio neither explodes nor collapses
    assert 0.3 < min(ratios) and max(ratios) < 3.5
    assert max(ratios) / min(ratios) < 2.0


@pytest.mark.parametrize("name", ["leveling", "tiering", "lazy-leveling",
                                  "qlsm-bush", "garnering"])
def test_plan_terminates(name):
    """Repeatedly applying plan+simulated-merge reaches a quiet state."""
    p = make_policy(name, T=2.0, c=0.8)
    B = 1000
    levels = [[B] * 6, [B], [2 * B], [4 * B]]
    L = 3
    for _ in range(100):
        L, task, _ = p.plan(levels, L, B)
        if task is None:
            break
        while len(levels) <= task.dst_level:
            levels.append([])
        moved = sum(levels[task.src_level])
        if task.include_dst:
            levels[task.dst_level] = [moved + sum(levels[task.dst_level])]
        else:
            levels[task.dst_level].append(moved)
        levels[task.src_level] = []
    else:
        pytest.fail(f"{name}: compaction loop did not quiesce")
