"""Merge policy math: Eq. 4/5/6, delayed compaction, policy orderings."""
import math

import pytest

from repro.core import Garnering, Leveling, make_policy


def test_eq4_capacity_ratio():
    """C_i / C_{i-1} = T / c^{L-i} (Eq. 4), with C_0 = B."""
    g = Garnering(T=2.0, c=0.8)
    B, L = 1 << 20, 7
    prev = float(B)
    for i in range(1, L + 1):
        cap = g.capacity(i, L, B)
        assert cap / prev == pytest.approx(2.0 / 0.8 ** (L - i), rel=1e-9)
        prev = cap


def test_c_equals_one_is_leveling():
    """Paper §4.1: Garnering with c=1 has Leveling's capacity ratios."""
    g = Garnering(T=3.0, c=1.0)
    l = Leveling(T=3.0)
    for i in range(1, 8):
        assert g.capacity(i, 8, 1000) == pytest.approx(l.capacity(i, 8, 1000))


def test_capacities_grow_with_L():
    """Delayed last-level compaction is sound because every capacity grows
    when L grows (paper §3.1)."""
    g = Garnering(T=2.0, c=0.8)
    for i in range(1, 6):
        for L in range(i, 10):
            assert g.capacity(i, L + 1, 1000) > g.capacity(i, L, 1000)


def test_eq6_levels_sublogarithmic():
    g = Garnering(T=2.0, c=0.8)
    B = 1 << 20
    prev_L = 0.0
    ratios = []
    for k in range(4, 16):
        L = g.predicted_levels(B * 2 ** k, B)
        ratios.append(L / math.sqrt(k))
        assert L >= prev_L
        prev_L = L
    # L / sqrt(log N) is ~constant => predicted levels track Eq. 6
    assert max(ratios) / min(ratios) < 1.6


def test_delayed_compaction_counted():
    g = Garnering(T=2.0, c=0.8)
    B = 1000
    # last level (1) marginally overfull: plan grows L instead of compacting
    # (capacity(1, 2) = capacity(1, 1)/c covers the overflow — §3.1)
    levels = [[], [int(g.capacity(1, 1, B) * 1.1)]]
    new_L, task, delayed = g.plan(levels, 1, B)
    assert delayed >= 1 and new_L >= 2
    assert task is None or task.src_level == 0


def test_garnering_plan_prioritizes_lower_levels():
    g = Garnering(T=2.0, c=0.8, l0_trigger=4)
    B = 1000
    big = int(1e9)
    levels = [[], [big], [big]]
    new_L, task, _ = g.plan(levels, 3, B)
    assert task is not None and task.src_level == 1


@pytest.mark.parametrize("name", ["leveling", "tiering", "lazy-leveling",
                                  "qlsm-bush", "garnering"])
def test_plan_terminates(name):
    """Repeatedly applying plan+simulated-merge reaches a quiet state."""
    p = make_policy(name, T=2.0, c=0.8)
    B = 1000
    levels = [[B] * 6, [B], [2 * B], [4 * B]]
    L = 3
    for _ in range(100):
        L, task, _ = p.plan(levels, L, B)
        if task is None:
            break
        while len(levels) <= task.dst_level:
            levels.append([])
        moved = sum(levels[task.src_level])
        if task.include_dst:
            levels[task.dst_level] = [moved + sum(levels[task.dst_level])]
        else:
            levels[task.dst_level].append(moved)
        levels[task.src_level] = []
    else:
        pytest.fail(f"{name}: compaction loop did not quiesce")
