"""Sharded keyspace facade (DESIGN.md §12): differential + concurrency.

The plain single store is the retained oracle: for any op sequence, a
``ShardedLSMStore`` must return byte-identical reads (``get``/``multi_get``/
``scan``/``seek``), because range partitioning routes each key's ops to one
shard in program order and shard ranges are disjoint and ordered.  On top:

  * ``shards=1`` is *bit-for-bit* the plain store (same flush boundaries,
    seqs, bloom bits) — the facade adds routing, not semantics;
  * batched ops split by one searchsorted: duplicates, in-batch overwrites,
    and cross-shard interleavings resolve exactly as the scalar loop;
  * crash mid-load + ``recover()`` restores every shard with no lost acked
    (fsynced) writes, no leaked version pins, no orphaned cache entries;
  * two shards compacting simultaneously under concurrent readers;
  * the shared BlockCache is namespaced: one shard's invalidation/repin can
    never evict a sibling's live blocks, and per-shard budgets scope
    eviction pressure to the owning namespace;
  * ``IOStats.merge``/``__add__`` aggregate every counter fieldwise.

All property tests run under both real hypothesis and the fixed-seed shim
(tests/_hypothesis_compat.py).
"""
import dataclasses
import threading

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (BlockCache, BlockCacheView, IOStats, LSMConfig,
                        LSMStore, ShardedLSMStore, make_store,
                        uniform_splitters)

KEY_SPACE = 400


def cfg(**kw):
    base = dict(policy="garnering", T=2.0, c=0.8, memtable_bytes=1 << 12,
                base_level_bytes=1 << 14, bits_per_key=8,
                bloom_allocation="monkey")
    base.update(kw)
    return LSMConfig(**base)


def sharded_cfg(shards, key_space=KEY_SPACE, **kw):
    return cfg(shards=shards,
               shard_splitters=uniform_splitters(shards, key_space),
               **kw)


def gen_ops(seed: int, n_ops: int, key_space: int = KEY_SPACE,
            del_frac: float = 0.2):
    rng = np.random.default_rng(seed)
    ops = []
    for i in range(n_ops):
        k = int(rng.integers(0, key_space))
        if rng.random() < del_frac:
            ops.append((k, None))
        else:
            ops.append((k, bytes([65 + i % 26]) * int(rng.integers(0, 80))))
    return ops


def close_quiet(db):
    if hasattr(db, "close"):
        db.close()


# ------------------------------------------------------- differential oracle
@given(st.integers(0, 10_000), st.sampled_from([1, 2, 4]))
@settings(max_examples=8, deadline=None)
def test_sharded_reads_identical_to_single_store(seed, shards):
    """Property: random interleaved put_batch/delete_batch/get/multi_get/
    scan waves — a sharded store (async, parallel schedulers) returns
    byte-identical reads to the single synchronous store at every wave
    boundary and after quiesce."""
    oracle = LSMStore(cfg())
    db = make_store(sharded_cfg(shards, async_compaction=True,
                                compaction_workers=2))
    rng = np.random.default_rng(seed)
    try:
        for wave in range(4):
            ops = gen_ops(seed + 31 * wave, 500)
            if wave % 2:
                # split the wave: puts through put_batch, deletes through
                # delete_batch (keeps per-key order within each sub-batch
                # only — apply to both stores identically)
                puts = [(k, v) for k, v in ops if v is not None]
                dels = [k for k, v in ops if v is None]
                for store in (oracle, db):
                    store.put_batch([k for k, _ in puts],
                                    [v for _, v in puts])
                    store.delete_batch(dels)
            else:
                oracle.write_batch(ops)
                db.write_batch(ops)
            # mid-churn reads (no quiesce): acked writes must be visible
            probes = rng.integers(0, KEY_SPACE, 32).tolist()
            assert db.multi_get(probes) == oracle.multi_get(probes)
            start = int(rng.integers(0, KEY_SPACE))
            assert db.scan(start, 40) == oracle.scan(start, 40)
        oracle.flush()
        db.flush()
        assert db.wait_for_quiesce(60)
        keys = list(range(KEY_SPACE))
        assert db.multi_get(keys) == oracle.multi_get(keys)
        assert [db.get(k) for k in range(0, KEY_SPACE, 7)] == \
            [oracle.get(k) for k in range(0, KEY_SPACE, 7)]
        assert db.scan(0, KEY_SPACE) == oracle.scan_scalar(0, KEY_SPACE)
        assert db.scan_scalar(0, KEY_SPACE) == \
            oracle.scan_scalar(0, KEY_SPACE)
        # seek's tombstone handling is a documented approximation (a
        # deleted key stops shadowing once its tombstone flushes, and
        # per-shard flush boundaries differ from the single store's), so
        # assert the cost-probe invariant, not oracle equality — the
        # delete-free test below asserts exact equality.
        for k in (0, KEY_SPACE // 3, KEY_SPACE - 1):
            got = db.seek(k)
            live = db.scan(k, 1)
            if live:
                assert got is not None and k <= got <= live[0][0]
            elif got is not None:
                assert got >= k     # flushed tombstone, per the seek contract
        assert db.total_live_entries() == oracle.total_live_entries()
    finally:
        close_quiet(db)


def test_shards1_facade_is_bit_for_bit_plain_store():
    """shards=1 keeps the single-store path bit-for-bit: same levels (every
    run's keys/seqs/vlens/vals/bloom bits), same memtable, same stats-
    relevant trajectory — the facade adds routing only."""
    from repro.core.run import levels_bit_equal

    ops = gen_ops(3, 2000)
    plain = LSMStore(cfg())
    facade = ShardedLSMStore(cfg(shards=1))
    plain.write_batch(ops)
    facade.write_batch(ops)
    plain.flush()
    facade.flush()
    assert levels_bit_equal(plain._levels, facade.shards[0]._levels)
    assert facade.shards[0].memtable._data == plain.memtable._data
    assert facade.shards[0]._seq == plain._seq


def test_cross_shard_scan_spans_boundaries():
    """A scan starting in one shard must continue seamlessly into the next
    (shard-ordered concatenation), including counts that exactly straddle a
    splitter."""
    db = make_store(sharded_cfg(4, key_space=100))
    oracle = LSMStore(cfg())
    for k in range(100):
        v = f"v{k}".encode()
        db.put(k, v)
        oracle.put(k, v)
    # start just below the shard-1 boundary (splitter at 25)
    for start, count in [(20, 10), (24, 2), (25, 1), (0, 100), (99, 5),
                        (23, 60)]:
        assert db.scan(start, count) == oracle.scan_scalar(start, count), \
            (start, count)
    assert db.seek(25) == 25
    assert db.seek(100) is None


def test_splitter_boundary_keys_route_consistently():
    """A key equal to a splitter belongs to the upper shard; writes and
    reads must agree (no key ever visible in two shards)."""
    db = ShardedLSMStore(sharded_cfg(4, key_space=100))
    for k in (0, 24, 25, 26, 49, 50, 74, 75, 99):
        db.put(k, b"x" * k)
    db.flush()
    present = [(si, k) for si, s in enumerate(db.shards)
               for k, _ in s.scan(0, 1000)]
    assert sorted(k for _, k in present) == [0, 24, 25, 26, 49, 50, 74, 75,
                                             99]
    by_key = {}
    for si, k in present:
        assert k not in by_key, f"key {k} in shards {by_key[k]} and {si}"
        by_key[k] = si
    assert by_key[24] == 0 and by_key[25] == 1  # boundary goes up
    for k in by_key:
        assert db.get(k) == b"x" * k


# ------------------------------------------------------------ crash safety
def test_crash_mid_load_recovers_all_shards():
    """Crash with background jobs in flight on several shards: recover()
    restores every acked (fsynced) write, pins return to baseline on every
    shard, and the shared cache holds only live namespaced blocks."""
    db = ShardedLSMStore(sharded_cfg(
        4, async_compaction=True, compaction_workers=2,
        wal_fsync_every_write=True, cache_bytes=1 << 18,
        pin_l0_bytes=1 << 16))
    oracle = {}
    for k, v in gen_ops(11, 3000):
        (db.delete(k) if v is None else db.put(k, v))
        if v is None:
            oracle.pop(k, None)
        else:
            oracle[k] = v
    db.crash()                            # likely mid-flight on some shard
    for s in db.shards:
        assert s._scheduler.pending() == 0
        assert s.manifest.total_pin_refs() == 0, "leaked version pins"
    db.recover()
    live = {(si, rid) for si, s in enumerate(db.shards)
            for rid in s.storage.ids()}
    cached = {k[0] for k in
              set(db.block_cache._entries) | set(db.block_cache._pinned)}
    assert cached <= live, f"orphaned cache entries: {cached - live}"
    for k in range(KEY_SPACE):            # every write was fsynced: all live
        assert db.get(k) == oracle.get(k), k
    # the facade keeps working after recovery (schedulers survived idle)
    db.put(10**6, b"post-recover")
    db.flush()
    assert db.wait_for_quiesce(60)
    assert db.get(10**6) == b"post-recover"
    db.close()


def test_sharded_double_crash_recover():
    db = ShardedLSMStore(sharded_cfg(2, async_compaction=True,
                                     wal_fsync_every_write=True))
    oracle = {}
    for k, v in gen_ops(23, 1500):
        (db.delete(k) if v is None else db.put(k, v))
        if v is None:
            oracle.pop(k, None)
        else:
            oracle[k] = v
    db.crash()
    db.recover()
    db.crash()
    db.recover()
    for k in range(KEY_SPACE):
        assert db.get(k) == oracle.get(k), k
    db.close()


# --------------------------------------------- concurrent compaction/readers
@given(st.integers(0, 10_000))
@settings(max_examples=3, deadline=None)
def test_concurrent_readers_with_parallel_shard_compaction(seed):
    """Reader threads on live paths + snapshot paths while BOTH shards'
    schedulers churn flush/compaction concurrently (worker budget 2):
    reads must stay internally consistent, snapshots frozen, and the final
    state must match the single-store oracle."""
    db = ShardedLSMStore(sharded_cfg(2, async_compaction=True,
                                     compaction_workers=2,
                                     cache_bytes=1 << 18, bits_per_key=6))
    oracle = LSMStore(cfg(bits_per_key=6))
    errors = []
    stop = threading.Event()

    def reader(tid):
        rng = np.random.default_rng(seed + tid)
        try:
            while not stop.is_set():
                keys = rng.integers(0, KEY_SPACE, 24).tolist()
                got = db.scan(int(rng.integers(0, KEY_SPACE)), 30)
                ks = [k for k, _ in got]
                assert ks == sorted(set(ks)), "scan not strictly sorted"
                db.multi_get(keys)
                snap = db.get_snapshot()
                try:
                    first = db.multi_get(keys, snapshot=snap)
                    assert db.multi_get(keys, snapshot=snap) == first, \
                        "snapshot view moved under a reader"
                finally:
                    db.release_snapshot(snap)
        except Exception as e:            # surface to the main thread
            errors.append(e)

    threads = [threading.Thread(target=reader, args=(t,)) for t in range(3)]
    for t in threads:
        t.start()
    try:
        for wave in range(5):
            ops = gen_ops(seed + wave, 700)
            db.write_batch(ops)
            oracle.write_batch(ops)
        db.flush()
        oracle.flush()
        assert db.wait_for_quiesce(60)
        # both shards really did background work in parallel pools
        assert all(s.stats.bg_flushes > 0 for s in db.shards)
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=30)
    assert not errors, errors
    keys = list(range(KEY_SPACE))
    assert db.multi_get(keys) == oracle.multi_get(keys)
    assert db.scan(0, KEY_SPACE) == oracle.scan(0, KEY_SPACE)
    db.close()


# ------------------------------------------------------- shared block cache
def test_shared_cache_retain_is_namespace_scoped():
    """The satellite fix: one shard's post-commit retain() must drop only
    its own dead runs' blocks — a sibling's live cached blocks survive."""
    cache = BlockCache(1 << 20, "lru")
    va = BlockCacheView(cache, 0, 1 << 19)
    vb = BlockCacheView(cache, 1, 1 << 19)
    stats = IOStats()
    va.read_block(101, 0, 4096, stats)     # shard 0, run 101
    vb.read_block(101, 0, 4096, stats)     # shard 1, its OWN run 101: no alias
    vb.read_block(202, 1, 4096, stats)
    assert len(cache._entries) == 3        # namespaced keys never collide
    # shard 0 compacted run 101 away; shard 1 still owns ITS run 101
    va.retain([999])
    assert (101, 0) not in va
    assert (101, 0) in vb and (202, 1) in vb, \
        "sibling's live blocks evicted by foreign retain"
    # namespace-scoped clear (a shard's crash) leaves the sibling alone
    va.read_block(303, 0, 4096, stats)
    va.clear()
    assert (101, 0) in vb and (303, 0) not in va


def test_shared_cache_pin_sets_are_namespace_scoped():
    cache = BlockCache(1 << 20, "clock")
    va = BlockCacheView(cache, 0, 1 << 19)
    vb = BlockCacheView(cache, 1, 1 << 19)
    va.set_pinned({(1, 0): 4096, (1, 1): 4096})
    vb.set_pinned({(7, 0): 2048})
    assert va.pinned_bytes == 8192 and vb.pinned_bytes == 2048
    assert cache.pinned_bytes == 8192 + 2048
    # repinning shard 0 wholesale must not wipe shard 1's resident set
    va.set_pinned({(2, 0): 4096})
    assert (7, 0) in vb
    assert cache.pinned_bytes == 4096 + 2048


def test_shared_cache_budget_evicts_within_namespace_only():
    """Admission pressure in one shard's namespace evicts that shard's cold
    entries, never a sibling's (per-shard charged-byte budgets)."""
    cache = BlockCache(4 * 4096, "lru")
    va = BlockCacheView(cache, 0, 2 * 4096)
    vb = BlockCacheView(cache, 1, 2 * 4096)
    stats = IOStats()
    vb.read_block(9, 0, 4096, stats)
    vb.read_block(9, 1, 4096, stats)
    for bid in range(4):                  # 4 blocks into a 2-block budget
        va.read_block(5, bid, 4096, stats)
    assert va.charged_bytes == 2 * 4096, "namespace budget not enforced"
    assert (9, 0) in vb and (9, 1) in vb, "sibling evicted by foreign pressure"
    assert (5, 2) in va and (5, 3) in va  # LRU within the namespace
    assert (5, 0) not in va and (5, 1) not in va
    assert cache.charged_bytes == 4 * 4096


def test_sharded_store_shares_one_cache_with_per_shard_budgets():
    db = ShardedLSMStore(sharded_cfg(2, cache_bytes=1 << 18,
                                     pin_l0_bytes=1 << 14))
    assert db.block_cache is not None
    assert all(s.block_cache.cache is db.block_cache for s in db.shards)
    budgets = [s.block_cache.budget_bytes for s in db.shards]
    assert budgets == [(1 << 18) // 2] * 2
    for k, v in gen_ops(7, 1500, del_frac=0.0):
        db.put(k, v)
    db.flush()
    rng = np.random.default_rng(2)
    for _ in range(3):
        db.multi_get(rng.integers(0, KEY_SPACE, 64).tolist())
    summ = db.cache_summary()
    assert summ["enabled"] and summ["hits"] > 0
    # global charged bytes = sum of the namespace slices
    assert summ["charged_bytes"] == sum(
        s.block_cache.charged_bytes for s in db.shards)
    # detach reverts every shard to raw block accounting
    db.configure_cache(0, 0)
    assert db.block_cache is None
    assert all(s.block_cache is None for s in db.shards)


# ----------------------------------------------------------- IOStats merge
def test_iostats_add_and_merge_cover_every_field():
    a, b = IOStats(), IOStats()
    for i, f in enumerate(dataclasses.fields(IOStats)):
        setattr(a, f.name, i + 1)
        setattr(b, f.name, 100 * (i + 1))
    tot = a + b
    for i, f in enumerate(dataclasses.fields(IOStats)):
        assert getattr(tot, f.name) == 101 * (i + 1), f.name
    # the PR 4 counters and cache fields are really in the dataclass (the
    # satellite contract: aggregation must include them)
    for name in ("stall_ns", "bg_flushes", "bg_compactions",
                 "cache_hit_blocks", "cache_miss_blocks"):
        assert hasattr(tot, name)
    assert getattr(IOStats.merge([a, b, IOStats()]), "blocks_read") == \
        tot.blocks_read
    # sum() works and inputs are untouched
    assert sum([a, b]).wal_appends == tot.wal_appends
    assert a.blocks_read == 1


def test_facade_stats_aggregate_per_shard_counters():
    db = ShardedLSMStore(sharded_cfg(4, async_compaction=True,
                                     compaction_workers=2))
    try:
        db.write_batch(gen_ops(5, 2000, del_frac=0.0))
        db.flush()
        assert db.wait_for_quiesce(60)
        keys = list(range(KEY_SPACE))
        s0 = db.stats.snapshot()
        db.multi_get(keys)
        d = db.stats.delta(s0)
        assert d.point_reads == len(keys)
        assert db.stats.bg_flushes == sum(s.stats.bg_flushes
                                          for s in db.shards)
        assert db.stats.entries_flushed == sum(s.stats.entries_flushed
                                               for s in db.shards)
    finally:
        db.close()


# ------------------------------------------------------------- construction
def test_make_store_factory_and_validation():
    assert isinstance(make_store(cfg()), LSMStore)
    assert isinstance(make_store(cfg(shards=1)), LSMStore)
    db = make_store(cfg(shards=3))
    assert isinstance(db, ShardedLSMStore) and len(db.shards) == 3
    assert len(db._splitters) == 2
    with pytest.raises(ValueError):
        ShardedLSMStore(cfg(shards=3, shard_splitters=(10,)))
    with pytest.raises(ValueError):
        ShardedLSMStore(cfg(shards=3, shard_splitters=(20, 10)))
    # runtime toggles on the facade's config reach every shard (live share)
    db.config.use_pallas_bloom = True
    assert all(s.config.use_pallas_bloom for s in db.shards)


# --------------------------------------------------- torn cross-shard snapshots
def test_snapshot_never_torn_by_racing_cross_shard_writer():
    """Regression (Issue 6 satellite): ``get_snapshot`` used to pin shard
    versions one by one with nothing excluding a concurrent cross-shard
    batch — a writer landing on shards 0 AND 1 between the two pins
    produced a snapshot holding generation i on one shard and i+1 on the
    other.  The facade write gate + pin-validate-retry must make every
    snapshot a point-in-time cut: both halves of every
    ``write_batch``+``flush`` generation are visible together or not at
    all.  The race window is widened deliberately by delaying shard 1's
    pin, which reliably tore snapshots under the old acquisition."""
    import time as _time

    db = ShardedLSMStore(cfg(shards=2, shard_splitters=(KEY_SPACE // 2,),
                             memtable_bytes=1 << 12))
    k0, k1 = KEY_SPACE // 4, 3 * KEY_SPACE // 4      # one key per shard
    inner = db.shards[1].get_snapshot

    def delayed():                                   # widen pin0 -> pin1 gap
        _time.sleep(0.0005)
        return inner()

    db.shards[1].get_snapshot = delayed
    torn = []
    stop = threading.Event()

    def snapshotter():
        while not stop.is_set():
            snap = db.get_snapshot()
            try:
                a = db.get(k0, snapshot=snap)
                b = db.get(k1, snapshot=snap)
                if a != b:
                    torn.append((a, b))
            finally:
                db.release_snapshot(snap)

    t = threading.Thread(target=snapshotter)
    t.start()
    try:
        for i in range(120):                # snapshot-visible generations:
            v = b"gen-%06d" % i             # batch + flush inside the gate
            db.write_batch([(k0, v), (k1, v)])
            db.flush()
    finally:
        stop.set()
        t.join(timeout=30)
        db.shards[1].get_snapshot = inner
    assert not torn, f"torn snapshots observed: {torn[:5]}"
    # and the pins all released cleanly
    for s in db.shards:
        assert s.manifest.pin_count(s.manifest.current().version_id) == 0


def test_snapshot_validate_retry_survives_background_installs():
    """Async mode: versions install from worker threads outside the write
    gate.  Acquisition must still return internally consistent pins (each
    pinned version is a shard's real committed version; no pin leaks), with
    the documented caveat that batch halves *enter* visibility on their
    shards' own flush schedules."""
    db = ShardedLSMStore(sharded_cfg(2, async_compaction=True,
                                     compaction_workers=2))
    try:
        for i in range(6):
            db.write_batch(gen_ops(90 + i, 400))
            for _ in range(20):
                snap = db.get_snapshot()
                assert len(snap.versions) == 2
                for s, v in zip(db.shards, snap.versions):
                    assert s.manifest.pin_count(v.version_id) >= 1
                db.release_snapshot(snap)
        db.flush()
        assert db.wait_for_quiesce(60)
        snap = db.get_snapshot()
        live = db.total_live_entries()
        got = db.scan(0, KEY_SPACE + 1, snapshot=snap)
        assert len(got) == live        # quiesced: snapshot sees everything
        db.release_snapshot(snap)
    finally:
        close_quiet(db)


# ------------------------------------- tombstones straddling a splitter bound
@pytest.mark.parametrize("shards", [1, 2, 4])
def test_scan_seek_tombstones_straddling_splitters(shards):
    """Differential: dense writes + delete bands centered on every splitter
    (and the keyspace edges) — facade ``scan`` must stay byte-identical to
    the single-store ``scan_scalar`` oracle from probes on, at, and beyond
    each boundary, with the tombstones in memtables AND after they flush
    into runs.  ``seek`` is exact while tombstones are memtable-resident
    (liveness-filtered identically); once flushed it is asserted against
    its documented cost-probe contract."""
    oracle = LSMStore(cfg())
    db = make_store(sharded_cfg(shards))
    splitters = list(uniform_splitters(shards, KEY_SPACE))
    try:
        for k in range(KEY_SPACE):
            v = b"s%d-%d" % (shards, k)
            oracle.put(k, v)
            db.put(k, v)
        oracle.flush()
        db.flush()
        bands = [range(max(0, s - 12), min(KEY_SPACE, s + 12))
                 for s in splitters]
        bands.append(range(0, 9))                    # keyspace edges too
        bands.append(range(KEY_SPACE - 9, KEY_SPACE))
        doomed = sorted({k for b in bands for k in b})
        for k in doomed:
            oracle.delete(k)
            db.delete(k)
        probes = sorted({p for s in splitters + [0, KEY_SPACE - 1]
                         for p in (s - 13, s - 12, s - 1, s, s + 1, s + 11,
                                   s + 12)
                         if 0 <= p < KEY_SPACE})
        # tombstones memtable-resident: scan AND seek exact vs oracle
        for p in probes:
            assert db.scan(p, 30) == oracle.scan_scalar(p, 30), p
            assert db.seek(p) == oracle.seek(p), p
        oracle.flush()
        db.flush()
        # tombstones flushed into runs (often *straddling* a splitter):
        # scan stays exact; seek keeps its cost-probe invariant
        for p in probes:
            got = db.scan(p, 30)
            assert got == oracle.scan_scalar(p, 30), p
            assert got == db.scan_scalar(p, 30), p
            sk = db.seek(p)
            if got:
                assert sk is not None and p <= sk <= got[0][0], (p, sk)
            elif sk is not None:
                assert sk >= p
        assert db.scan(0, KEY_SPACE) == oracle.scan_scalar(0, KEY_SPACE)
        assert db.total_live_entries() == oracle.total_live_entries()
    finally:
        close_quiet(db)
        close_quiet(oracle)
