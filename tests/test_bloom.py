"""Bloom filter + Monkey/Autumn allocation (paper Eq. 2, 7-10)."""
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (BloomFilter, allocate_fprs, bits_for_fpr,
                        garnering_theoretical_fprs, theoretical_fpr,
                        zero_result_read_cost)


def test_no_false_negatives():
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 2**63, 5000, dtype=np.uint64)
    bf = BloomFilter(keys, bits_per_key=10)
    assert bf.may_contain(keys).all()


def test_fpr_matches_eq2():
    rng = np.random.default_rng(1)
    keys = rng.integers(0, 2**62, 20_000, dtype=np.uint64)
    bf = BloomFilter(keys, bits_per_key=10)
    absent = rng.integers(2**62, 2**63, 50_000, dtype=np.uint64)
    fpr = float(np.mean(bf.may_contain(absent)))
    expected = theoretical_fpr(10)  # ~0.0082 (paper: 10 bits => ~1%)
    assert fpr < 3 * expected and fpr > expected / 5


def test_zero_bits_always_maybe():
    keys = np.arange(10, dtype=np.uint64)
    bf = BloomFilter(keys, bits_per_key=0)
    assert bf.may_contain(np.arange(100, dtype=np.uint64)).all()


@given(st.lists(st.integers(min_value=0, max_value=10**7), min_size=1,
                max_size=8),
       st.floats(min_value=1.0, max_value=16.0))
@settings(max_examples=60, deadline=None)
def test_monkey_allocation_budget_and_kkt(sizes, bits_per_key):
    """Water-filling invariants: (a) budget is respected, (b) interior FPRs
    are proportional to level sizes (KKT), (c) all FPRs in (0, 1]."""
    total = sum(sizes)
    if total == 0:
        return
    budget = bits_per_key * total
    fprs = allocate_fprs(sizes, budget)
    assert ((fprs > 0) & (fprs <= 1.0 + 1e-12)).all()
    spent = sum(-n * math.log(p) / math.log(2) ** 2
                for n, p in zip(sizes, fprs) if n > 0)
    assert spent <= budget * 1.001
    interior = [(n, p) for n, p in zip(sizes, fprs) if n > 0 and p < 0.999]
    for (n1, p1), (n2, p2) in zip(interior, interior[1:]):
        assert p1 * n2 == pytest.approx(p2 * n1, rel=1e-6)


def test_eq9_closed_form_matches_waterfilling():
    """Optimal FPRs on Garnering capacities reproduce Eq. 9's shape."""
    T, c, L, B = 2.0, 0.8, 6, 1000
    sizes = [int(B * T ** i / c ** ((2 * L - 1 - i) * i / 2))
             for i in range(1, L + 1)]
    fprs = allocate_fprs(sizes, 8.0 * sum(sizes))
    theory = garnering_theoretical_fprs(L, T, c, p_last=fprs[-1])
    interior = [i for i in range(L) if fprs[i] < 0.999]
    for i in interior:
        assert fprs[i] == pytest.approx(theory[i], rel=0.05)


def test_read_cost_converges_faster_than_geometric():
    """Paper §3.1: R = sum p_i converges to O(p_L) because numerators carry
    c^{i(i-1)/2}."""
    for L in (4, 8, 16):
        fprs = garnering_theoretical_fprs(L, T=2.0, c=0.8, p_last=0.01)
        r = zero_result_read_cost(fprs)
        geo = 0.01 * sum(0.5 ** i for i in range(L))
        assert r <= geo + 1e-12


def test_bits_for_fpr_roundtrip():
    for p in (0.5, 0.1, 0.01, 1.0):
        assert theoretical_fpr(bits_for_fpr(p)) == pytest.approx(p, rel=1e-9)
