"""Differential tests for the vectorized write & compaction subsystem
(DESIGN.md §10).

Randomized workloads drive the two new write paths against their scalar
oracles, asserting they are exact drop-ins:

  * ``LSMStore.write_batch``/``put_batch`` == the scalar put/delete loop —
    values, WAL bytes, tree structure, and IOStats field by field
    (identical flush boundaries), plus torn-tail crash recovery of a
    partially synced batch;
  * the vectorized ``merge_runs`` == the retained ``merge_runs_scalar``
    oracle — bit-for-bit keys/seqs/vlens/vals/bloom bits and identical
    compaction counters, with and without tombstone GC;
  * the Pallas merge-path lane (``use_pallas_merge``) and the Pallas bloom
    build route (``use_pallas_bloom``) produce bit-identical runs;
  * ``BlockCache.read_blocks``/``read_block_span`` == a per-block
    ``read_block`` loop on a twin cache;
  * ``LSMConfig.block_size``/``key_bytes`` reach every constructed run
    (flush and compaction), and ``total_live_entries`` /
    ``space_amplification`` match a brute-force oracle.
"""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import IOStats, LSMConfig, LSMStore, build_run
from repro.core.run import merge_runs, merge_runs_scalar
from repro.core.types import KEY_BYTES, TOMBSTONE_LEN


def small_cfg(**kw):
    base = dict(policy="garnering", T=2.0, c=0.8, memtable_bytes=1 << 12,
                base_level_bytes=1 << 14, bits_per_key=8,
                bloom_allocation="monkey")
    base.update(kw)
    return LSMConfig(**base)


def gen_ops(seed: int, n_ops: int, key_space: int = 300, del_frac: float = 0.2):
    rng = np.random.default_rng(seed)
    ops = []
    for i in range(n_ops):
        k = int(rng.integers(0, key_space))
        if rng.random() < del_frac:
            ops.append((k, None))
        else:
            ops.append((k, bytes([65 + i % 26]) * int(rng.integers(0, 120))))
    return ops


def assert_same_tree(db_a: LSMStore, db_b: LSMStore):
    assert len(db_a._levels) == len(db_b._levels)
    for la, lb in zip(db_a._levels, db_b._levels):
        assert len(la) == len(lb)
        for ra, rb in zip(la, lb):
            np.testing.assert_array_equal(ra.keys, rb.keys)
            np.testing.assert_array_equal(ra.seqs, rb.seqs)
            np.testing.assert_array_equal(ra.vlens, rb.vlens)
            np.testing.assert_array_equal(ra.vals, rb.vals)
            np.testing.assert_array_equal(ra.bloom.bits, rb.bloom.bits)


def assert_same_stats(a: IOStats, b: IOStats):
    for f in dataclasses.fields(IOStats):
        assert getattr(a, f.name) == getattr(b, f.name), f.name


# ----------------------------------------------------------- batched ingest
@given(st.integers(0, 10_000), st.integers(1, 600))
@settings(max_examples=12, deadline=None)
def test_write_batch_matches_scalar_loop(seed, wave):
    """Property: write_batch in arbitrary wave sizes is bit-for-bit the
    scalar loop — WAL bytes, IOStats (incl. write-amp counters), the run
    arrays of every level, and every readable value."""
    ops = gen_ops(seed, 1200)
    db_s, db_b = LSMStore(small_cfg()), LSMStore(small_cfg())
    for k, v in ops:
        (db_s.delete(k) if v is None else db_s.put(k, v))
    for i in range(0, len(ops), wave):
        db_b.write_batch(ops[i:i + wave])
    assert bytes(db_s.wal._buf) == bytes(db_b.wal._buf)
    assert_same_stats(db_s.stats, db_b.stats)
    assert db_s.stats.write_amplification() == \
        db_b.stats.write_amplification()
    assert_same_tree(db_s, db_b)
    for k in range(300):
        assert db_s.get(k) == db_b.get(k), k


def test_put_batch_values_and_duplicates():
    db = LSMStore(small_cfg(memtable_bytes=1 << 20))
    db.put_batch([1, 2, 3], [b"a", b"b", b"c"])
    db.put_batch([4, 5], b"bcast")           # broadcast single value
    db.write_batch([(2, None), (6, b"x"), (6, b"y"), (7, None)])
    assert db.multi_get([1, 2, 3, 4, 5, 6, 7, 8]) == \
        [b"a", None, b"c", b"bcast", b"bcast", b"y", None, None]
    db.write_batch([])                        # empty batch is a no-op
    assert db.total_live_entries() == 5


def test_put_batch_fsync_every_write_durability():
    """With wal_fsync_every_write the batch group-commits per chunk: a
    crash right after put_batch returns loses nothing."""
    db = LSMStore(small_cfg(wal_fsync_every_write=True,
                            memtable_bytes=1 << 20))
    db.put_batch(list(range(40)), b"durable")
    db.crash()
    db.recover()
    for k in range(40):
        assert db.get(k) == b"durable", k


def test_write_batch_fsync_coalescing_counts():
    """With wal_fsync_every_write=True a batch fsyncs once per *chunk*
    (group commit), not once per record — asserted by counting actual WAL
    fsync calls, not just the documented contract.

    A single-chunk batch (big memtable) costs exactly one WAL fsync for
    hundreds of records; the scalar twin pays one per record.  A multi-chunk
    batch (small memtable) costs one per chunk plus the flush-path fsyncs.
    """
    ops = [(k, b"x" * 10) for k in range(500)]
    # ---- single chunk: 500 records, exactly 1 WAL fsync ----
    db = LSMStore(small_cfg(wal_fsync_every_write=True,
                            memtable_bytes=1 << 20))
    fsyncs = []
    orig_fsync = db.wal.fsync
    db.wal.fsync = lambda stats: (fsyncs.append(1), orig_fsync(stats))[1]
    db.write_batch(ops)
    assert len(fsyncs) == 1
    # scalar twin: one fsync per record
    db_s = LSMStore(small_cfg(wal_fsync_every_write=True,
                              memtable_bytes=1 << 20))
    s0 = db_s.stats.snapshot()
    for k, v in ops:
        db_s.put(k, v)
    assert db_s.stats.delta(s0).wal_fsyncs == 500
    # ---- multi chunk: one fsync per chunk + one per flush, nothing more ----
    db_m = LSMStore(small_cfg(wal_fsync_every_write=True))  # 4 KiB memtable
    chunks, fsyncs_m, flushes = [], [], []
    orig_append = db_m.wal.append_batch_cols
    orig_fsync_m = db_m.wal.fsync
    orig_flush = db_m.flush
    db_m.wal.append_batch_cols = \
        lambda *a, **k: (chunks.append(1), orig_append(*a, **k))[1]
    db_m.wal.fsync = lambda stats: (fsyncs_m.append(1), orig_fsync_m(stats))[1]
    db_m.flush = lambda: (flushes.append(1), orig_flush())[1]
    db_m.write_batch(ops)
    assert len(chunks) > 1 and len(flushes) >= 1
    assert len(chunks) < len(ops), "chunking degenerated to per-record"
    assert len(fsyncs_m) == len(chunks) + len(flushes)


def test_torn_batch_tail_recovery():
    """A partially synced batch recovers exactly the records that fit the
    fsync watermark; the torn record and everything after are lost."""
    from repro.core.memtable import FRAME_OVERHEAD

    db = LSMStore(small_cfg(memtable_bytes=1 << 20))
    db.put_batch(list(range(50)), b"v" * 10)
    rec = FRAME_OVERHEAD + 10        # frame (crc+header) + payload per record
    db.wal._synced_upto = 7 * rec + 13   # cut mid-record 7
    db.crash()
    db.recover()
    for k in range(50):
        assert db.get(k) == (b"v" * 10 if k < 7 else None), k
    # same cut inside a *ragged* batch (deletes interleaved)
    db2 = LSMStore(small_cfg(memtable_bytes=1 << 20))
    db2.write_batch([(k, None) if k % 3 == 0 else (k, bytes(k))
                     for k in range(30)])
    db2.wal.fsync(db2.stats)
    db2.wal._synced_upto -= 5        # tear the last record
    db2.crash()
    db2.recover()
    for k in range(29):
        expect = None if k % 3 == 0 else bytes(k)
        assert db2.get(k) == expect, k
    assert db2.get(29) is None       # the torn record never replays


def test_wal_append_batch_bytes_match_scalar_appends():
    """The row-form WAL batch append (and the engine's column fast path
    behind it) writes byte-identical records to a scalar append loop."""
    from repro.core.memtable import WriteAheadLog

    items = [(5, b"abc"), (9, None), (2 ** 63, b""), (7, b"x" * 120),
             (1, None), (3, b"yz")]
    w_scalar, w_batch = WriteAheadLog(), WriteAheadLog()
    s1, s2 = IOStats(), IOStats()
    for i, (k, v) in enumerate(items):
        w_scalar.append(1 if v is None else 0, k, 10 + i, v or b"", s1)
    w_batch.append_batch(items, 10, s2)
    assert bytes(w_scalar._buf) == bytes(w_batch._buf)
    assert s1.wal_appends == s2.wal_appends == len(items)
    assert list(w_scalar.records()) == list(w_batch.records())
    # uniform-length batch exercises the 2-D interleave fast path
    uni = [(k, b"u" * 16) for k in range(40)]
    w_scalar2, w_batch2 = WriteAheadLog(), WriteAheadLog()
    for i, (k, v) in enumerate(uni):
        w_scalar2.append(0, k, 1 + i, v, s1)
    w_batch2.append_batch(uni, 1, s2)
    assert bytes(w_scalar2._buf) == bytes(w_batch2._buf)


def test_wal_outlier_length_batch_spans_stay_bounded_and_bit_exact():
    """A batch mixing many small records with a few huge values must not
    build one n*max padded CRC matrix: the pass splits into bounded spans
    (each under the scratch budget) and stays byte-identical to scalar
    appends — including replay through the same spanned verification."""
    from repro.core.memtable import (WriteAheadLog, _CRC_PAD_BUDGET, _HDR,
                                     _pad_spans)

    rng = np.random.default_rng(11)
    items = []
    for i in range(3000):
        if i % 500 == 250:               # scattered 4KB outliers
            items.append((i, bytes(rng.integers(0, 256, 4096, np.uint8))))
        elif i % 9 == 0:
            items.append((i, None))
        else:
            items.append((i, bytes(rng.integers(0, 256,
                                                int(rng.integers(0, 32)),
                                                np.uint8))))
    w_scalar, w_batch = WriteAheadLog(), WriteAheadLog()
    s = IOStats()
    for i, (k, v) in enumerate(items):
        w_scalar.append(1 if v is None else 0, k, 7 + i, v or b"", s)
    w_batch.append_batch(items, 7, s)
    assert bytes(w_scalar._buf) == bytes(w_batch._buf)
    assert list(w_scalar.records()) == list(w_batch.records())
    # the span generator's bound: rows * padded-width <= budget, except a
    # single row wider than the whole budget (the record itself, not padding)
    vlens = np.array([len(v) if v is not None else 0 for _, v in items],
                     np.int64)
    spans = list(_pad_spans(vlens, _HDR.size))
    assert len(spans) > 1                 # the outliers force a split
    assert spans[0][0] == 0 and spans[-1][1] == len(items)
    for (i, j), (i2, _) in zip(spans, spans[1:] + [(len(items), None)]):
        assert j == i2                    # contiguous, gap-free cover
        w = _HDR.size + int(vlens[i:j].max())
        assert (j - i) * w <= _CRC_PAD_BUDGET or j - i == 1


# ------------------------------------------------------- vectorized merges
def make_run(seed: int, n: int, key_space: int = 3000, vmax: int = 24,
             tomb: float = 0.15, seq0: int = 0):
    rng = np.random.default_rng(seed)
    keys = np.unique(rng.integers(0, key_space, n).astype(np.uint64))
    n = len(keys)
    seqs = seq0 + rng.permutation(n).astype(np.uint64)
    vlens = rng.integers(0, vmax + 1, n).astype(np.int32)
    vlens[rng.random(n) < tomb] = TOMBSTONE_LEN
    vals = np.zeros((n, vmax), dtype=np.uint8)
    for i in range(n):
        if vlens[i] > 0:
            vals[i, :vlens[i]] = rng.integers(1, 255, vlens[i])
    return build_run(keys, seqs, vlens, vals, assume_unique_sorted=True)


def assert_same_run(a, b):
    np.testing.assert_array_equal(a.keys, b.keys)
    np.testing.assert_array_equal(a.seqs, b.seqs)
    np.testing.assert_array_equal(a.vlens, b.vlens)
    np.testing.assert_array_equal(a.vals, b.vals)
    np.testing.assert_array_equal(a.bloom.bits, b.bloom.bits)
    assert a.n_blocks == b.n_blocks and a.data_bytes == b.data_bytes


@given(st.integers(0, 10_000), st.integers(1, 6), st.booleans())
@settings(max_examples=12, deadline=None)
def test_merge_matches_scalar_oracle(seed, n_runs, drop):
    """Property: the vectorized k-way merge is bit-for-bit the concat +
    lexsort oracle — keys/seqs/vlens/vals/bloom AND the write-amp counter
    algebra (blocks read/written, entries/bytes compacted)."""
    rng = np.random.default_rng(seed)
    # disjoint seq ranges per run, as engine flush/compaction produces
    runs = [make_run(seed * 13 + i, int(rng.integers(1, 900)),
                     seq0=i * 1_000_000) for i in range(n_runs)]
    s_ref, s_vec = IOStats(), IOStats()
    ref = merge_runs_scalar(runs, 6.0, s_ref, drop_tombstones=drop)
    out = merge_runs(runs, 6.0, s_vec, drop_tombstones=drop)
    assert_same_run(ref, out)
    assert_same_stats(s_ref, s_vec)


def test_merge_large_hits_vector_path():
    """Above the adaptive threshold the ladder (not the scalar fallback)
    runs; output must still be bit-for-bit."""
    runs = [make_run(i + 1, 9000, key_space=60_000, seq0=i * 1_000_000)
            for i in range(3)]
    assert sum(len(r) for r in runs) > 8192
    s_ref, s_vec = IOStats(), IOStats()
    ref = merge_runs_scalar(runs, 4.0, s_ref)
    out = merge_runs(runs, 4.0, s_vec)
    assert_same_run(ref, out)
    assert_same_stats(s_ref, s_vec)


def test_merge_tombstone_gc_at_deepest_level():
    """Engine-level: a full merge into the deepest level drops tombstones
    on the batched write path exactly as on the scalar one."""
    from repro.core import CompactionTask
    db = LSMStore(small_cfg())
    db.put_batch(list(range(400)), b"x" * 30)
    db.delete_batch(list(range(400)))
    db.flush()
    assert db.total_live_entries() == 0
    deepest = db._deepest_nonempty()
    for i in range(1, deepest):
        if db._levels[i]:
            db._apply(CompactionTask(i, deepest, True, "test-force"))
    if db._levels[0]:
        db._apply(CompactionTask(0, deepest, True, "test-force"))
    assert sum(len(r) for lvl in db._levels[1:] for r in lvl) == 0
    assert db.get(5) is None


def test_pallas_merge_lane_bit_for_bit():
    """use_pallas_merge routes compaction through the bitonic merge-path
    kernel (interpret mode) and must be a bit-for-bit drop-in."""
    jax = pytest.importorskip("jax")  # noqa: F841
    from repro.kernels.ops import merge_runs_tiled

    runs = [make_run(i + 1, 700, key_space=4000, seq0=i * 1_000_000)
            for i in range(3)]
    s_ref, s_vec = IOStats(), IOStats()
    ref = merge_runs_scalar(runs, 5.0, s_ref)
    out = merge_runs(runs, 5.0, s_vec, pair_merge=merge_runs_tiled)
    assert_same_run(ref, out)
    assert_same_stats(s_ref, s_vec)


def test_pallas_merge_handles_max_u64_key():
    """Regression: a real key equal to the uint64 maximum must survive the
    kernel's tile padding (pads tie-break behind real entries by payload)."""
    jax = pytest.importorskip("jax")  # noqa: F841
    from repro.kernels.ops import merge_runs_tiled

    top = np.iinfo(np.uint64).max
    ka = np.array([1, 5, top], dtype=np.uint64)
    kb = np.array([2, 5, 9], dtype=np.uint64)
    mk, mp = merge_runs_tiled(ka, kb, tile=64)
    np.testing.assert_array_equal(mk, np.sort(np.concatenate([ka, kb])))
    src_a = (mp >> 31) == 0
    np.testing.assert_array_equal(mk[src_a], ka[mp[src_a] & 0x7FFFFFFF])
    np.testing.assert_array_equal(mk[~src_a], kb[mp[~src_a] & 0x7FFFFFFF])
    # end to end: max-key entries merge bit-for-bit through the ladder
    ra = build_run(ka, np.array([1, 2, 3], np.uint64),
                   np.array([3, 3, 3], np.int32),
                   np.tile(np.array([7, 8, 9], np.uint8), (3, 1)))
    rb = build_run(kb, np.array([11, 12, 13], np.uint64),
                   np.array([3, 3, 3], np.int32),
                   np.tile(np.array([4, 5, 6], np.uint8), (3, 1)))
    s_ref, s_vec = IOStats(), IOStats()
    ref = merge_runs_scalar([ra, rb], 0.0, s_ref)
    out = merge_runs([ra, rb], 0.0, s_vec, pair_merge=merge_runs_tiled)
    assert_same_run(ref, out)


def test_pallas_merge_engine_route_matches_numpy():
    jax = pytest.importorskip("jax")  # noqa: F841
    ops = gen_ops(21, 900, key_space=200)
    db_n = LSMStore(small_cfg())
    db_p = LSMStore(small_cfg(use_pallas_merge=True))
    db_n.write_batch(ops)
    db_p.write_batch(ops)
    db_n.flush()
    db_p.flush()
    assert_same_tree(db_n, db_p)
    assert_same_stats(db_n.stats, db_p.stats)
    for k in range(200):
        assert db_n.get(k) == db_p.get(k), k


def test_pallas_bloom_build_route_matches_numpy():
    """use_pallas_bloom also reroutes the filter *build* hash pass; the
    constructed bitsets must be identical to the numpy family."""
    jax = pytest.importorskip("jax")  # noqa: F841
    from repro.core.bloom import BloomFilter
    from repro.kernels.ops import bloom_build_hashes

    rng = np.random.default_rng(7)
    keys = rng.integers(0, 2 ** 63, 1500, dtype=np.uint64)
    np.testing.assert_array_equal(
        BloomFilter(keys, 10).bits,
        BloomFilter(keys, 10, hash_fn=bloom_build_hashes).bits)
    # end to end through flush + compaction
    ops = gen_ops(33, 800, key_space=150)
    db_n = LSMStore(small_cfg())
    db_p = LSMStore(small_cfg(use_pallas_bloom=True))
    db_n.write_batch(ops)
    db_p.write_batch(ops)
    db_n.flush()
    db_p.flush()
    assert_same_tree(db_n, db_p)


# ------------------------------------------------ block-size threading bug
def test_config_block_size_and_key_bytes_reach_runs():
    """Regression: build_run/merge_runs/Memtable.to_run used to ignore
    LSMConfig.block_size/key_bytes and always built module-default runs."""
    cfg = small_cfg(block_size=512, key_bytes=8, bits_per_key=0)
    db = LSMStore(cfg)
    db.put_batch(list(range(2000)), b"v" * 40)
    db.flush()
    seen = 0
    for lvl in db._levels:
        for run in lvl:
            seen += 1
            assert run.block_size == 512
            expect_bytes = int(np.sum(8 + np.maximum(run.vlens, 0)))
            assert run.data_bytes == expect_bytes
            assert run.n_blocks == -(-expect_bytes // 512)
    assert seen >= 1
    assert db.stats.compactions > 0     # merge outputs were checked too
    assert db.stats.blocks_written > 0
    # same tree built with defaults packs far fewer, larger blocks
    db_def = LSMStore(small_cfg(bits_per_key=0))
    db_def.put_batch(list(range(2000)), b"v" * 40)
    db_def.flush()
    assert db.stats.blocks_written > db_def.stats.blocks_written


# ------------------------------------------- live-entry / space-amp algebra
@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_total_live_entries_and_space_amp_match_oracle(seed):
    ops = gen_ops(seed, 800, key_space=200)
    db = LSMStore(small_cfg())
    oracle = {}
    for k, v in ops:
        if v is None:
            db.delete(k)
            oracle[k] = None
        else:
            db.put(k, v)
            oracle[k] = v
    live = {k: v for k, v in oracle.items() if v is not None}
    assert db.total_live_entries() == len(live)
    phys = sum(r.data_bytes for lvl in db._levels for r in lvl) \
        + db.memtable.size_bytes
    logical = sum(KEY_BYTES + len(v) for v in live.values())
    if logical:
        assert db.space_amplification() == pytest.approx(phys / logical)
    else:
        assert db.space_amplification() == 1.0


def test_space_amp_shrinks_after_full_compaction():
    from repro.core import CompactionTask
    db = LSMStore(small_cfg(bits_per_key=0, memtable_bytes=1 << 15))
    for rep in range(3):                  # stack shadowed versions in L0
        db.put_batch(list(range(300)), bytes([rep + 1]) * 40)
        db.flush()                        # 3 L0 runs, below the L0 trigger
    amp_before = db.space_amplification()
    assert amp_before > 1.2               # duplicates inflate physical bytes
    deepest = db._deepest_nonempty()
    for i in range(1, deepest):
        if db._levels[i]:
            db._apply(CompactionTask(i, deepest, True, "test-force"))
    if db._levels[0]:
        db._apply(CompactionTask(0, deepest, True, "test-force"))
    amp_after = db.space_amplification()
    assert amp_after < amp_before
    assert amp_after == pytest.approx(1.0)   # one run, all live


# ---------------------------------------------------- cache span charging
def test_read_blocks_and_span_match_scalar_read_block():
    """The batched cache lanes are charge-for-charge identical to a
    per-block read_block loop on a twin cache."""
    from repro.core.cache import BlockCache

    rng = np.random.default_rng(3)
    for policy in ("lru", "clock"):
        a = BlockCache(8 * 512, policy)
        b = BlockCache(8 * 512, policy)
        sa, sb = IOStats(), IOStats()
        for _ in range(40):
            rid = int(rng.integers(0, 3))
            ids = rng.integers(0, 24, int(rng.integers(1, 9))).tolist()
            if rng.random() < 0.5:
                lo, hi = min(ids), max(ids)
                a.read_block_span(rid, lo, hi, lambda bid: 512, sa)
                for bid in range(lo, hi + 1):
                    b.read_block(rid, bid, 512, sb)
            else:
                a.read_blocks(rid, ids, lambda bid: 512, sa)
                for bid in ids:
                    b.read_block(rid, bid, 512, sb)
        assert (a.hits, a.misses, a.evictions) == (b.hits, b.misses,
                                                   b.evictions)
        assert list(a._entries) == list(b._entries)   # same eviction order
        assert_same_stats(sa, sb)


def test_batched_reads_cached_match_scalar_accounting():
    """End to end: with a cache attached, multi_get/scan accounting equals
    the scalar paths' on an identically built twin store."""
    ops = gen_ops(11, 1500, key_space=400)
    db_a = LSMStore(small_cfg(cache_bytes=64 << 10, pin_l0_bytes=8 << 10))
    db_b = LSMStore(small_cfg(cache_bytes=64 << 10, pin_l0_bytes=8 << 10))
    db_a.write_batch(ops)
    for k, v in ops:
        (db_b.delete(k) if v is None else db_b.put(k, v))
    queries = list(np.random.default_rng(5).integers(0, 500, 300))
    s_a = db_a.stats.snapshot()
    batched = db_a.multi_get(queries)
    scans_a = [db_a.scan(int(k), 20) for k in queries[:30]]
    d_a = db_a.stats.delta(s_a)
    s_b = db_b.stats.snapshot()
    scalar = [db_b.get(int(k)) for k in queries]
    scans_b = [db_b.scan(int(k), 20) for k in queries[:30]]
    d_b = db_b.stats.delta(s_b)
    assert batched == scalar and scans_a == scans_b
    assert_same_stats(d_a, d_b)
