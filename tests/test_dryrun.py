"""Dry-run machinery: HLO cost model validation + a mini multi-device cell.

Runs in a subprocess so XLA_FLAGS device-count forcing never leaks into the
rest of the test session (the assignment requires tests to see 1 device).
"""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


def run_sub(code: str) -> str:
    return subprocess.check_output(
        [sys.executable, "-c", textwrap.dedent(code)],
        env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/tmp",
             # the scrubbed env must still pin the platform: these tests
             # only ever want forced host (CPU) devices, and letting jax
             # probe an accelerator plugin hangs on TPU-less machines
             # (libtpu polls for a device forever under its lockfile)
             "JAX_PLATFORMS": "cpu"},
        stderr=subprocess.STDOUT, text=True, timeout=500)


@pytest.mark.slow
def test_hlo_cost_model_counts_scan_trips():
    out = run_sub("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, json
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.hlo_analysis import analyze
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        def f(ws, x):
            def body(c, w):
                return jnp.tanh(c @ w), ()
            return jax.lax.scan(body, x, ws)[0].sum()
        ws = jax.ShapeDtypeStruct((5, 256, 256), jnp.float32,
                                  sharding=NamedSharding(mesh, P(None, "data", "model")))
        x = jax.ShapeDtypeStruct((64, 256), jnp.float32,
                                 sharding=NamedSharding(mesh, P("data", None)))
        co = jax.jit(f).lower(ws, x).compile()
        c = analyze(co.as_text(), 8)
        print(json.dumps({"flops": c.flops,
                          "expected": 5 * 2 * 64 * 256 * 256 / 8}))
    """)
    data = json.loads(out.strip().splitlines()[-1])
    assert data["flops"] == pytest.approx(data["expected"], rel=0.02)


@pytest.mark.slow
def test_mini_dryrun_cell_compiles_and_is_sharded():
    """A smoke-config cell lowers+compiles on an 8-device host mesh, the
    memory analysis is populated, and the HLO contains collectives."""
    out = run_sub("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, json
        import repro.configs as C
        from repro.configs import ShapeSpec, get_smoke
        from repro.launch.specs import build_cell
        C.SHAPES["mini_train"] = ShapeSpec("mini_train", 64, 8, "train")
        C.SHAPES["mini_decode"] = ShapeSpec("mini_decode", 64, 8, "decode")
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        report = {}
        for shape in ("mini_train", "mini_decode"):
            cell = build_cell("qwen3_4b", shape, mesh,
                              cfg_override=get_smoke("qwen3_4b"))
            with mesh:
                co = jax.jit(cell.fn, donate_argnums=cell.donate
                             ).lower(*cell.args).compile()
            txt = co.as_text()
            report[shape] = {
                "temp": co.memory_analysis().temp_size_in_bytes,
                "colls": sum(txt.count(k) for k in
                             ("all-reduce(", "all-gather(",
                              "reduce-scatter(", "collective-permute(")),
            }
        print(json.dumps(report))
    """)
    data = json.loads(out.strip().splitlines()[-1])
    for shape, r in data.items():
        assert r["temp"] > 0
        assert r["colls"] > 0, f"{shape}: expected collectives in SPMD HLO"


def test_artifacts_when_present():
    """If the full dry-run has produced artifacts, sanity-check them all."""
    art = ROOT / "benchmarks" / "artifacts"
    files = list(art.glob("*.json"))
    if not files:
        pytest.skip("dry-run artifacts not generated yet")
    # mixtral-8x22b / llama-90B *training* exceeds v5e HBM on a single pod
    # (they fit the 2x16x16 multi-pod mesh, where FSDP spans 512 chips) —
    # documented in EXPERIMENTS.md §Dry-run; budget them at v5p-class HBM.
    big_single_pod = {"mixtral_8x22b__train_4k__pod16x16.json",
                      "llama32_vision_90b__train_4k__pod16x16.json"}
    n_ok = 0
    for f in files:
        a = json.loads(f.read_text())
        if a.get("tag"):
            continue  # hillclimb iteration artifacts have their own budgets
        assert a["status"] in ("ok", "skipped"), \
            f"{f.name}: {a.get('error', '')[:200]}"
        if a["status"] == "ok":
            n_ok += 1
            peak = a["memory_analysis"]["peak_estimate_bytes"]
            budget = (24 if f.name in big_single_pod else 16) * 2**30
            assert peak < budget, f"{f.name}: exceeds HBM budget ({peak})"
            assert a["hlo_cost"]["flops_per_device"] > 0
    assert n_ok >= 60  # 33 runnable cells x 2 meshes (minus any race)
