"""Test-suite bootstrap: make property tests runnable in bare environments.

If the real ``hypothesis`` package is importable we use it untouched.
Otherwise we install the fixed-seed shim from ``_hypothesis_compat`` so the
``from hypothesis import given, ...`` imports in the suite keep working.
"""
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))

try:  # pragma: no cover - depends on environment
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_compat

    _hypothesis_compat.install()
