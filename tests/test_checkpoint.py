"""Autumn checkpoint store: roundtrip, deltas, atomicity, async, recovery."""
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointer, CheckpointStore


def tree(seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return {"layer": {"w": rng.standard_normal((64, 32)).astype(np.float32)
                      * scale,
                      "b": rng.standard_normal(32).astype(np.float32)},
            "embed": rng.standard_normal((100, 16)).astype(np.float32)}


def assert_tree_equal(a, b):
    import jax
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_roundtrip_exact():
    st = CheckpointStore()
    t = tree(0)
    st.save(10, t)
    assert st.latest_step() == 10
    got = st.restore_tree(10, t)
    assert_tree_equal(t, got)


def test_multiple_steps_and_latest():
    """Delta semantics: chunk slots are overwritten, so the *latest* durable
    checkpoint is always exactly restorable (manifest written last =>
    crash-consistent); older manifests only share unchanged chunks."""
    st = CheckpointStore()
    for step in (10, 20, 30):
        st.save(step, tree(step))
    assert st.latest_step() == 30
    assert_tree_equal(tree(30), st.restore_tree(None, tree(0)))
    assert_tree_equal(tree(30), st.restore_tree(30, tree(0)))


def test_delta_checkpoints_skip_unchanged():
    st = CheckpointStore()
    t = tree(1)
    st.save(1, t)
    w0 = st.stats_chunks_written
    t2 = {"layer": {"w": t["layer"]["w"], "b": t["layer"]["b"] + 1.0},
          "embed": t["embed"]}
    st.save(2, t2)
    assert st.stats_deltas_skipped > 0
    assert st.stats_chunks_written - w0 < w0  # only 'b' chunks rewritten
    assert_tree_equal(t2, st.restore_tree(2, t))


def test_point_read_single_leaf():
    st = CheckpointStore()
    t = tree(3)
    st.save(5, t)
    import jax
    path = jax.tree_util.keystr(jax.tree_util.tree_flatten_with_path(t)[0][1][0])
    got = st.restore_leaf(5, path)
    assert got is not None


def test_crash_recovery_keeps_durable_checkpoints():
    st = CheckpointStore()
    t = tree(4)
    st.save(7, t)
    st.crash()
    assert st.latest_step() == 7
    assert_tree_equal(t, st.restore_tree(7, t))


def test_async_checkpointer():
    st = CheckpointStore()
    ck = AsyncCheckpointer(st)
    trees = {s: tree(s) for s in (1, 2, 3)}
    for s, t in trees.items():
        ck.submit(s, t)
    ck.close()
    assert st.latest_step() == 3
    assert_tree_equal(trees[3], st.restore_tree(3, trees[3]))


def test_garnering_restore_reads_few_runs():
    """The paper's claim in substrate form: after many delta saves, a restore
    (range read) touches O(sqrt(log N)) runs, and the store's level count is
    below an equivalent Leveling store's."""
    from repro.core import LSMConfig
    st = CheckpointStore(LSMConfig(policy="garnering", T=2.0, c=0.6,
                                   memtable_bytes=1 << 12,
                                   base_level_bytes=1 << 14,
                                   bits_per_key=10,
                                   bloom_allocation="monkey"))
    lv = CheckpointStore(LSMConfig(policy="leveling", T=2.0,
                                   memtable_bytes=1 << 12,
                                   base_level_bytes=1 << 14,
                                   bits_per_key=10,
                                   bloom_allocation="monkey"))
    for step in range(30):
        t = tree(step)
        st.save(step, t)
        lv.save(step, t)
    assert st.db.num_levels_in_use <= lv.db.num_levels_in_use
    got = st.restore_tree(29, tree(0))
    assert_tree_equal(tree(29), got)
