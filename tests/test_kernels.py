"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import (bloom_probe, flash_attention, merge_runs_tiled,
                           paged_attention)
from repro.kernels import ops, ref


@pytest.mark.parametrize("n,m_words,k", [(512, 128, 5), (2048, 1024, 7),
                                         (4096, 64, 3)])
def test_bloom_probe_sweep(n, m_words, k):
    rng = np.random.default_rng(n + k)
    keys = rng.integers(0, 2**63, n, dtype=np.uint64)
    lo, hi = ops.split_u64(keys)
    bits = ref.bloom_build_ref(np.asarray(lo), np.asarray(hi), m_words, k)
    got = np.asarray(bloom_probe(keys, jnp.asarray(bits), k))
    exp = np.asarray(ref.bloom_probe_ref(lo, hi, jnp.asarray(bits), k))
    assert (got == exp).all()
    assert got.all()  # no false negatives on members


def test_bloom_fpr_reasonable():
    rng = np.random.default_rng(9)
    keys = rng.integers(0, 2**62, 4096, dtype=np.uint64)
    lo, hi = ops.split_u64(keys)
    bits = ref.bloom_build_ref(np.asarray(lo), np.asarray(hi), 2048, 7)
    absent = rng.integers(2**62, 2**63, 8192, dtype=np.uint64)
    fpr = float(np.mean(np.asarray(bloom_probe(absent, jnp.asarray(bits), 7))))
    assert fpr < 0.05


@pytest.mark.parametrize("na,nb,tile", [(777, 1333, 256), (1, 5000, 128),
                                        (256, 256, 256), (0, 100, 64),
                                        (4096, 4096, 512)])
def test_merge_sweep(na, nb, tile):
    rng = np.random.default_rng(na + nb)
    a = np.sort(rng.integers(0, 1 << 31, na, dtype=np.uint32))
    b = np.sort(rng.integers(0, 1 << 31, nb, dtype=np.uint32))
    mk, mp = merge_runs_tiled(a, b, tile=tile)
    assert (mk == np.sort(np.concatenate([a, b]))).all()
    # payload integrity: every source index appears exactly once
    src_a = (mp >> 31) == 0
    assert (np.sort(mp[src_a] & 0x7FFFFFFF) == np.arange(na)).all()
    assert (np.sort(mp[~src_a] & 0x7FFFFFFF) == np.arange(nb)).all()
    # payload/key pairing: key at output equals source key
    back_a = mk[src_a]
    assert (back_a == a[(mp[src_a] & 0x7FFFFFFF)]).all()


@pytest.mark.parametrize("dt,lo,hi", [(np.int64, -2**60, 2**60),
                                      (np.int32, -2**31, 2**31 - 1),
                                      (np.uint64, 0, 2**63)])
def test_merge_signed_and_wide_dtypes(dt, lo, hi):
    """Regression: keys wider than 32 bits (and signed keys) must merge via
    the order-preserving u64 lane map, not a truncating u32 cast."""
    rng = np.random.default_rng(11)
    a = np.sort(rng.integers(lo, hi, 700).astype(dt))
    b = np.sort(rng.integers(lo, hi, 900).astype(dt))
    mk, mp = merge_runs_tiled(a, b, tile=128)
    assert mk.dtype == dt
    assert (mk == np.sort(np.concatenate([a, b]))).all()
    src_a = (mp >> 31) == 0
    assert (mk[src_a] == a[mp[src_a] & 0x7FFFFFFF]).all()
    assert (mk[~src_a] == b[mp[~src_a] & 0x7FFFFFFF]).all()


def test_merge_matches_engine_merge():
    """Ties the TPU kernel to the engine's compaction semantics."""
    from repro.core import IOStats, build_run, merge_runs
    rng = np.random.default_rng(3)
    ka = np.sort(rng.choice(1 << 20, 900, replace=False)).astype(np.uint64)
    kb = np.sort(rng.choice(1 << 20, 500, replace=False)).astype(np.uint64)
    mk, _ = merge_runs_tiled(ka.astype(np.uint32), kb.astype(np.uint32))
    ra = build_run(ka, np.arange(900, dtype=np.uint64),
                   np.zeros(900, np.int32), np.zeros((900, 0), np.uint8))
    rb = build_run(kb, np.arange(1000, 1500, dtype=np.uint64),
                   np.zeros(500, np.int32), np.zeros((500, 0), np.uint8))
    merged = merge_runs([ra, rb], 0.0, IOStats())
    # engine dedups duplicate keys; kernel keeps both — compare on uniques
    assert (np.unique(mk) == merged.keys.astype(np.uint32)).all()


@pytest.mark.parametrize("B,H,KH,dh,page,P", [
    (2, 4, 4, 16, 8, 3),     # MHA
    (3, 8, 2, 32, 16, 4),    # GQA
    (1, 16, 1, 64, 32, 2),   # MQA
])
def test_paged_attention_sweep(B, H, KH, dh, page, P):
    rng = np.random.default_rng(B * H)
    nphys = P * B + 2
    q = jnp.asarray(rng.standard_normal((B, H, dh)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((nphys, page, KH, dh)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((nphys, page, KH, dh)), jnp.float32)
    bt = jnp.asarray(rng.integers(0, nphys, (B, P)), jnp.int32)
    ln = jnp.asarray(rng.integers(1, P * page + 1, B), jnp.int32)
    got = paged_attention(q, kp, vp, bt, ln)
    exp = ref.paged_attention_ref(q, kp, vp, bt, ln)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
def test_flash_attention_sweep(dtype, causal, window):
    rng = np.random.default_rng(42)
    q = jnp.asarray(rng.standard_normal((2, 256, 4, 32)), dtype)
    k = jnp.asarray(rng.standard_normal((2, 256, 2, 32)), dtype)
    v = jnp.asarray(rng.standard_normal((2, 256, 2, 32)), dtype)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          bq=64, bk=64)
    exp = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=tol, atol=tol)


def test_flash_matches_model_attention():
    """Kernel vs the model's XLA-fallback gqa_attention."""
    from repro.models.layers import gqa_attention
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.standard_normal((2, 128, 8, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 128, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 128, 2, 16)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(128)[None], (2, 128))
    xla = gqa_attention(q, k, v, q_positions=pos, k_positions=pos,
                        causal=True, window=None)
    pallas = flash_attention(q, k, v, causal=True, bq=64, bk=64)
    np.testing.assert_allclose(np.asarray(xla), np.asarray(pallas),
                               rtol=2e-5, atol=2e-5)
