"""Differential tests for the batched read subsystem (DESIGN.md §3).

Randomized workloads (puts / deletes / overwrites / flushes / snapshots)
drive every merge policy, asserting that the two new read paths are exact
drop-ins for the scalar ones:

  * ``LSMStore.multi_get(keys) == [get(k) for k in keys]`` — results AND
    aggregate IOStats accounting;
  * ``MergingIterator`` / ``LSMStore.scan`` == a brute-force sorted-dict
    oracle == the reference ``scan_scalar`` path;
  * the numpy bloom probe and the Pallas kernel probe agree bit-for-bit.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import LSMConfig, LSMStore

# all five policies; c only shapes Garnering (c=1 == Leveling, paper §4.1)
POLICY_C = [
    ("leveling", 1.0),
    ("tiering", 1.0),
    ("lazy-leveling", 1.0),
    ("qlsm-bush", 1.0),
    ("garnering", 1.0),
    ("garnering", 0.8),
    ("garnering", 0.4),
]
IDS = [f"{p}-c{c}" for p, c in POLICY_C]


def make_db(policy: str, c: float, **kw) -> LSMStore:
    base = dict(policy=policy, c=c, T=2.0, memtable_bytes=1 << 11,
                base_level_bytes=1 << 13, bits_per_key=8,
                bloom_allocation="monkey")
    base.update(kw)
    return LSMStore(LSMConfig(**base))


def run_workload(db: LSMStore, seed: int, n_ops: int = 1500,
                 key_space: int = 400):
    """Random puts/deletes/flushes; returns (oracle, snapshot, snap_oracle).

    The snapshot is taken right after a flush mid-workload, so the snapshot
    oracle is exactly the durable state at that point.
    """
    rng = np.random.default_rng(seed)
    oracle = {}
    snap = None
    snap_oracle = None
    for i in range(n_ops):
        k = int(rng.integers(0, key_space))
        u = rng.random()
        if u < 0.2:
            db.delete(k)
            oracle.pop(k, None)
        else:
            v = f"s{seed}i{i}".encode()
            db.put(k, v)
            oracle[k] = v
        if i == n_ops // 2:
            db.flush()
            snap = db.get_snapshot()
            snap_oracle = dict(oracle)
        elif u > 0.995:
            db.flush()
    return oracle, snap, snap_oracle


@pytest.mark.parametrize("policy,c", POLICY_C, ids=IDS)
def test_multi_get_matches_scalar_get(policy, c):
    db = make_db(policy, c)
    oracle, snap, snap_oracle = run_workload(db, seed=hash(policy) % 97 + 1)
    rng = np.random.default_rng(5)
    # present, absent, and duplicate keys in one batch
    queries = list(rng.integers(0, 500, 300)) + [7, 7, 7]
    s0 = db.stats.snapshot()
    scalar = [db.get(int(k)) for k in queries]
    d_scalar = db.stats.delta(s0)
    s1 = db.stats.snapshot()
    batch = db.multi_get(queries)
    d_batch = db.stats.delta(s1)
    assert batch == scalar
    assert scalar == [oracle.get(int(k)) for k in queries]
    # reads don't mutate the tree: accounting must match field by field
    for f in dataclasses.fields(d_scalar):
        assert getattr(d_scalar, f.name) == getattr(d_batch, f.name), f.name
    # snapshot reads
    assert db.multi_get(queries, snapshot=snap) == \
        [snap_oracle.get(int(k)) for k in queries]


@pytest.mark.parametrize("policy,c", POLICY_C, ids=IDS)
def test_scan_matches_oracle_and_scalar(policy, c):
    db = make_db(policy, c)
    oracle, snap, snap_oracle = run_workload(db, seed=hash(policy) % 89 + 2)
    exp = sorted(oracle.items())
    assert db.scan(0, len(exp) + 10) == exp
    rng = np.random.default_rng(6)
    for start in rng.integers(0, 450, 12):
        for count in (1, 5, 37):
            got = db.scan(int(start), count)
            assert got == db.scan_scalar(int(start), count), (start, count)
            assert got == [e for e in exp if e[0] >= start][:count]
    # snapshot scans see the frozen state only
    snap_exp = sorted(snap_oracle.items())
    assert db.scan(0, len(snap_exp) + 10, snapshot=snap) == snap_exp
    assert db.scan_scalar(0, len(snap_exp) + 10, snapshot=snap) == snap_exp


def test_iterator_streaming_api():
    db = make_db("garnering", 0.8)
    oracle, _, _ = run_workload(db, seed=13)
    exp = sorted(oracle.items())
    it = db.iterator()
    it.seek(0)
    assert [e for e in it] == exp
    # re-seek mid-stream, stream via next()
    it.seek(200)
    got = []
    while True:
        e = it.next()
        if e is None:
            break
        got.append(e)
    assert got == [e for e in exp if e[0] >= 200]
    # keys come out strictly increasing
    keys = [k for k, _ in exp]
    assert keys == sorted(set(keys))


def test_multi_get_empty_and_memtable_only():
    db = make_db("garnering", 0.8)
    assert db.multi_get([]) == []
    db.put(1, b"a")
    db.delete(2)
    # memtable-resolved: value, tombstone, miss
    assert db.multi_get([1, 2, 3]) == [b"a", None, None]


def test_scan_interleaves_memtable_and_runs():
    db = make_db("garnering", 0.8, memtable_bytes=1 << 14)
    for k in range(0, 100, 2):
        db.put(k, b"run")
    db.flush()
    for k in range(1, 100, 2):
        db.put(k, b"mem")           # stays in the memtable
    db.delete(4)
    got = db.scan(0, 8)
    assert got == [(0, b"run"), (1, b"mem"), (2, b"run"), (3, b"mem"),
                   (5, b"mem"), (6, b"run"), (7, b"mem"), (8, b"run")]


def test_snapshot_pinned_across_many_compactions():
    """get_snapshot pins the version: its runs survive manifest GC no matter
    how many commits follow, until release_snapshot."""
    db = make_db("garnering", 0.8)
    for k in range(100):
        db.put(k, b"old")
    db.flush()
    snap = db.get_snapshot()
    for rep in range(30):            # >> the manifest's 8-version tail
        for k in range(100):
            db.put(k, f"r{rep}".encode())
        db.flush()
    assert db.get(5, snapshot=snap) == b"old"
    assert db.multi_get([5, 6, 7], snapshot=snap) == [b"old"] * 3
    assert db.scan(5, 3, snapshot=snap) == [(5, b"old"), (6, b"old"),
                                            (7, b"old")]
    db.release_snapshot(snap)
    assert db.get(5) == b"r29"


def test_bloom_numpy_and_pallas_probe_agree():
    """The core filter and the Pallas kernel share one hash family."""
    jax = pytest.importorskip("jax")  # noqa: F841
    from repro.core.bloom import BloomFilter
    from repro.kernels.ops import bloom_probe_filter

    rng = np.random.default_rng(3)
    keys = rng.integers(0, 2 ** 63, 900, dtype=np.uint64)
    bf = BloomFilter(keys, bits_per_key=10)
    for nq in (1, 64, 512, 700):   # below / at / above the kernel block
        q = rng.integers(0, 2 ** 63, nq, dtype=np.uint64)
        np.testing.assert_array_equal(bloom_probe_filter(bf, q),
                                      bf.may_contain(q))
    assert bloom_probe_filter(bf, keys).all()   # no false negatives


def test_multi_get_pallas_route_matches_numpy():
    jax = pytest.importorskip("jax")  # noqa: F841
    db = make_db("garnering", 0.8)
    oracle, _, _ = run_workload(db, seed=21, n_ops=600)
    db.flush()
    queries = list(np.random.default_rng(9).integers(0, 500, 200))
    expected = db.multi_get(queries)
    db.config.use_pallas_bloom = True   # toggling on a live store takes effect
    assert db.multi_get(queries) == expected
    assert expected == [oracle.get(int(k)) for k in queries]


def test_pallas_bloom_differential_bit_for_bit_same_batches():
    """``use_pallas_bloom=True`` (interpret mode) is a bit-for-bit drop-in:
    on the same key batches the engine returns identical values AND identical
    filter decisions — every probe/negative/false-positive/block counter in
    the IOStats delta matches the numpy route exactly."""
    jax = pytest.importorskip("jax")  # noqa: F841
    db = make_db("garnering", 0.8, bits_per_key=10)
    oracle, _, _ = run_workload(db, seed=33, n_ops=1200)
    db.flush()
    rng = np.random.default_rng(17)
    batches = [list(rng.integers(0, 600, sz)) for sz in (1, 63, 64, 257, 500)]
    s0 = db.stats.snapshot()
    numpy_results = [db.multi_get(b) for b in batches]
    d_numpy = db.stats.delta(s0)
    db.config.use_pallas_bloom = True
    s1 = db.stats.snapshot()
    pallas_results = [db.multi_get(b) for b in batches]
    d_pallas = db.stats.delta(s1)
    assert pallas_results == numpy_results
    assert numpy_results == [[oracle.get(int(k)) for k in b] for b in batches]
    # identical filter decisions => identical accounting, field by field
    for f in dataclasses.fields(d_numpy):
        assert getattr(d_numpy, f.name) == getattr(d_pallas, f.name), f.name


# ------------------------------------------- tombstone-dense range scans (§3)
def test_tombstone_dense_scan_refill_count_is_logarithmic():
    """Regression (Issue 6 satellite): tombstone winners occupy demand
    slots, so a scan across a heavily-deleted range used to pay
    O(deleted / window) refills of mostly-dead winners before reaching the
    live tail.  The tombstone carry must grow the demand (and the window,
    past the ``_MAX_WINDOW`` cap) geometrically with the dead prefix:
    ~120k contiguous tombstones must be crossed in O(log deleted) refills
    — the un-fixed iterator needs >200 at the default chunk — and the
    result must stay byte-identical to ``scan_scalar``."""
    db = make_db("garnering", 0.8, memtable_bytes=1 << 16,
                 base_level_bytes=1 << 18, bits_per_key=0)
    n, live_tail, wave = 120_000, 1_000, 8_192
    for i in range(0, n, wave):
        ks = list(range(i, min(i + wave, n)))
        db.put_batch(ks, [b"v%d" % k for k in ks])
    for i in range(0, n - live_tail, wave):
        db.delete_batch(list(range(i, min(i + wave, n - live_tail))))
    db.flush()
    it = db.iterator()
    refills = [0]
    orig = it._refill

    def counting():
        refills[0] += 1
        return orig()

    it._refill = counting
    got = it.scan(0, 100)
    assert got == db.scan_scalar(0, 100)
    assert [k for k, _ in got] == list(range(n - live_tail,
                                             n - live_tail + 100))
    assert refills[0] <= 14, \
        f"{refills[0]} refills to cross {n - live_tail} tombstones"
    # the carry must reset between seeks: a fresh scan over live keys
    # starts from the base ramp again (no leftover giant windows)
    it2 = db.iterator()
    assert it2.scan(n - live_tail, 5) == db.scan_scalar(n - live_tail, 5)
    db.close()


def test_deleted_range_scan_differential_mid_range_probes():
    """Scans *starting inside* a tombstone-dense band (and exactly at its
    edges) must match the scalar oracle — the carry-boosted windows may
    overshoot the band's end, and unconsumed entries must re-window
    correctly on the next refill."""
    db = make_db("garnering", 0.8, memtable_bytes=1 << 13,
                 base_level_bytes=1 << 15)
    n = 6_000
    db.put_batch(list(range(n)), [b"x%d" % k for k in range(n)])
    db.flush()
    db.delete_batch(list(range(1_000, 5_000)))
    db.flush()
    for start in (0, 999, 1_000, 1_001, 2_500, 4_999, 5_000, 5_001, n - 10):
        assert db.scan(start, 64) == db.scan_scalar(start, 64), start
    # interleave fresh writes INTO the dead band (memtable + runs merge)
    db.put_batch(list(range(2_000, 2_050)), [b"new%d" % k
                                             for k in range(2_000, 2_050)])
    for start in (1_500, 1_999, 2_000, 2_025, 2_050, 3_000):
        assert db.scan(start, 64) == db.scan_scalar(start, 64), start
    db.close()
