"""Online tuner (DESIGN.md §17): tuning never changes data, knobs stay
bounded, actuation lands only at boundaries.

The headline property is the differential one: a store under *active*
tuning (knobs genuinely moving mid-stream) must stay bit-for-bit
read-identical to an untuned twin fed the same ops — the controller may
reshape the tree (levels can differ), never the data.  Runs under both
real hypothesis and the fixed-seed shim (tests/_hypothesis_compat.py).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (KNOB_BOUNDS, LSMConfig, LSMStore, OnlineTuner,
                        Telemetry, make_store)
from repro.core.scheduler import WorkerBudget


def tuned_cfg(**kw):
    """Tiny store with an aggressive tuner: ticks every 8 writes, decides
    on any non-empty window, so knobs actually move inside small tests."""
    base = dict(policy="garnering", T=2.0, c=1.0, memtable_bytes=1 << 9,
                base_level_bytes=1 << 11, bits_per_key=10,
                bloom_allocation="monkey", cache_bytes=1 << 14,
                pin_l0_bytes=1 << 13, telemetry=Telemetry(),
                tuner=OnlineTuner(interval_ops=8, min_window_ops=1,
                                  tolerance=0.0))
    base.update(kw)
    return LSMConfig(**base)


def plain_cfg(**kw):
    base = dict(policy="garnering", T=2.0, c=1.0, memtable_bytes=1 << 9,
                base_level_bytes=1 << 11, bits_per_key=10,
                bloom_allocation="monkey")
    base.update(kw)
    return LSMConfig(**base)


def assert_reads_identical(db, twin, universe):
    """get / multi_get / scan / scan_scalar bit-for-bit across the twins."""
    for k in universe:
        assert db.get(k) == twin.get(k), k
    keys = np.asarray(list(universe), np.uint64)
    assert db.multi_get(keys) == twin.multi_get(keys)
    n = len(universe) + 4
    assert db.scan(0, n) == twin.scan(0, n)
    assert db.scan_scalar(0, n) == twin.scan_scalar(0, n)


# ------------------------------------------------------- differential twin
@given(st.lists(st.tuples(st.sampled_from(["put", "del", "get"]),
                          st.integers(0, 80)), min_size=20, max_size=300))
@settings(max_examples=25, deadline=None)
def test_tuned_store_reads_bit_identical(ops):
    db = LSMStore(tuned_cfg())
    twin = LSMStore(plain_cfg())
    tun = db.config.tuner
    for i, (op, k) in enumerate(ops):
        if op == "put":
            v = f"{i}".encode()
            db.put(k, v)
            twin.put(k, v)
        elif op == "del":
            db.delete(k)
            twin.delete(k)
        else:
            assert db.get(k) == twin.get(k), k
    db.flush()
    twin.flush()
    db.apply_tuning()
    assert_reads_identical(db, twin, range(81))
    # the tuner must have actually driven knobs for this to mean anything
    if len(ops) >= 60:
        assert tun.ticks > 0
    for s in tun.steps:
        for k, v in s.knobs.items():
            lo, hi = KNOB_BOUNDS[k]
            assert lo - 1e-9 <= v <= hi + 1e-9, (k, v)


def test_tuned_sharded_matches_single_oracle():
    tel = Telemetry()
    cfg = tuned_cfg(shards=2, async_compaction=True, compaction_workers=2,
                    telemetry=tel,
                    tuner=OnlineTuner(interval_ops=64, min_window_ops=1,
                                      tolerance=0.0))
    db = make_store(cfg)
    twin = LSMStore(plain_cfg())
    rng = np.random.default_rng(3)
    keys = rng.integers(0, 1 << 40, 3_000, dtype=np.uint64)
    for wave in range(6):
        lo, hi = wave * 500, (wave + 1) * 500
        for k in keys[lo:hi]:
            v = f"w{wave}k{int(k)}".encode()
            db.put(int(k), v)
            twin.put(int(k), v)
        for k in keys[max(0, lo - 200):lo:7]:
            assert db.get(int(k)) == twin.get(int(k))
        assert db.wait_for_quiesce(60)
        db.apply_tuning()
    probe = keys[::5]
    assert db.multi_get(probe) == twin.multi_get(probe)
    start = int(keys.min())
    assert db.scan(start, 200) == twin.scan(start, 200)
    assert db.scan_scalar(start, 200) == twin.scan_scalar(start, 200)
    assert db.config.tuner.ticks > 0
    db.close()
    twin.close()


# ------------------------------------------------------------- knob bounds
def test_knob_bounds_hold_under_long_drive():
    db = LSMStore(tuned_cfg())
    tun = db.config.tuner
    rng = np.random.default_rng(11)
    ks = rng.integers(0, 400, 4_000, dtype=np.uint64)
    for i, k in enumerate(ks):
        db.put(int(k), b"x" * 24)
        if i % 3 == 0:
            db.get(int(ks[i // 2]))
    assert len(tun.steps) >= 10
    seen = set()
    for s in tun.steps:
        seen.add(s.knob)
        for k, v in s.knobs.items():
            lo, hi = KNOB_BOUNDS[k]
            assert lo - 1e-9 <= v <= hi + 1e-9, (k, v)
    # round-robin visits every knob the store exposes (c/T/pin_frac here)
    assert {"c", "T", "pin_frac"} <= seen
    # ...and the policy object actually tracks the tuned knobs
    assert db.policy.c == pytest.approx(tun.last_knobs()["c"])
    assert db.policy.T == pytest.approx(tun.last_knobs()["T"])
    db.close()


def test_bounds_are_policy_family_safe():
    """Every (c, T) inside KNOB_BOUNDS constructs a valid Garnering policy
    (the MergePolicy ctor asserts T > 1, 0 < c <= 1)."""
    from repro.core import make_policy
    for c in np.linspace(*KNOB_BOUNDS["c"], 5):
        for T in np.linspace(*KNOB_BOUNDS["T"], 5):
            p = make_policy("garnering", T=float(T), c=float(c))
            assert type(p.retuned(c=float(c))) is type(p)


# ------------------------------------------------- boundary-only actuation
def test_apply_only_at_boundary():
    db = LSMStore(tuned_cfg(async_compaction=True,
                            memtable_bytes=1 << 9, stall_trigger=10_000,
                            slowdown_trigger=0))
    tun = db.config.tuner
    db._scheduler.pause()
    for k in range(200):                 # rotations pile up queued jobs
        db.put(k, b"y" * 40)
    assert not db._scheduler.idle()
    before = len(tun.steps)
    assert db.apply_tuning() is None     # not a boundary: refuse, no step
    assert len(tun.steps) == before
    db._scheduler.resume()
    assert db.wait_for_quiesce(60)
    for k in range(50):
        db.put(k, b"z" * 24)
        db.get(k)
    assert db.wait_for_quiesce(60)
    st1 = db.apply_tuning()              # baseline tick at worst
    for k in range(50):
        db.get(k)
    st2 = db.apply_tuning()
    assert st1 is not None or st2 is not None
    assert len(tun.steps) > before
    db.close()


def test_second_store_cannot_drive_anothers_tuner():
    tun = OnlineTuner(interval_ops=8, min_window_ops=1)
    db = LSMStore(tuned_cfg(tuner=tun))
    other = LSMStore(tuned_cfg(tuner=tun))   # same tuner: binder loses
    assert tun.owner is db
    assert tun.tick(other) is None
    db.close()
    other.close()


def test_disabled_path_stays_inert():
    db = LSMStore(plain_cfg())
    assert db.config.tuner is None and db._tuner is None
    for k in range(300):
        db.put(k, b"q" * 16)
    assert db.apply_tuning() is None
    db.close()


# ------------------------------------------------------------ worker budget
def test_worker_budget_resize_semantics():
    b = WorkerBudget(2)
    assert b.size == 2
    assert b.resize(4) and b.size == 4
    assert b.resize(1) and b.size == 1
    b.acquire()                          # permit in flight: shrink refuses
    assert b.resize(2) and b.size == 2   # grow is always fine
    b.acquire()
    assert not b.resize(1) and b.size == 2
    b.release()
    b.release()
    assert b.resize(1) and b.size == 1
    with b:                              # context-manager protocol survives
        assert not b._sem.acquire(blocking=False)


# ------------------------------------------------- maintenance reshape (§17)
def test_compact_to_shape_preserves_reads_and_folds_levels():
    """Retune to a wider capacity schedule, then fold: the maintenance
    compaction must consolidate the old deep shape down to the new
    policy's predicted level count with reads staying bit-for-bit."""
    db = LSMStore(plain_cfg())          # T=2, c=1: deepest possible shape
    twin = LSMStore(plain_cfg())
    for i in range(600):
        v = f"v{i}".encode()
        db.put(i % 200, v)
        twin.put(i % 200, v)
    db.flush(); twin.flush()
    deep_before = len([l for l in db._levels if l])
    db.retune_policy(T=6.0, c=0.4)      # widen: nothing is over-cap now
    merges = db.compact_to_shape()
    total = sum(r.data_bytes for lvl in db._levels for r in lvl)
    import math as _m
    target = max(1, _m.ceil(db.policy.predicted_levels(
        total, db.config.base_level_bytes)))
    deep_after = len([l for i, l in enumerate(db._levels) if l and i >= 1])
    if deep_before > target + 1:        # there was something to fold
        assert merges >= 1
    assert deep_after <= max(target, 1)
    assert_reads_identical(db, twin, range(200))
    # idempotent: an in-shape tree is a no-op
    assert db.compact_to_shape() == 0
    db.close(); twin.close()


def test_facade_compact_to_shape_matches_oracle():
    tel = Telemetry()
    tun = OnlineTuner(interval_ops=8, min_window_ops=1, tolerance=0.0)
    db = make_store(tuned_cfg(telemetry=tel, tuner=tun, shards=2,
                              async_compaction=True))
    twin = LSMStore(plain_cfg())
    for i in range(400):
        v = f"w{i}".encode()
        db.put(i % 150, v)
        twin.put(i % 150, v)
    assert db.wait_for_quiesce(120)
    db.retune_policy(T=6.0, c=0.5)
    db.compact_to_shape()
    twin.flush()
    assert_reads_identical(db, twin, range(150))
    db.close(); twin.close()


def test_restore_best_settles_incumbent_within_bounds():
    db = LSMStore(tuned_cfg())
    tun = db.config.tuner
    for i in range(400):
        db.put(i % 64, f"r{i}".encode())
        if i % 40 == 39:
            db.flush()
            db.apply_tuning()
    assert len(tun.steps) >= 3
    # best_knobs pairs vector k with window k+1's objective (reporting API)
    best = tun.best_knobs()
    objs = [s.objective for s in tun.steps[1:]]
    k_best = int(np.argmin(objs))
    assert best == dict(tun.steps[k_best].knobs)
    # restore_best reverts the unjudged trailing trial and settles on the
    # incumbent, clamped to bounds
    pending = tun._pending
    restored = tun.restore_best(db)
    assert tun._pending is None
    if pending is not None and pending[0] in restored:
        assert restored[pending[0]] == pytest.approx(pending[1])
    for k, v in restored.items():
        lo, hi = KNOB_BOUNDS[k]
        assert lo - 1e-9 <= v <= hi + 1e-9, (k, v)
    assert db.policy.c == pytest.approx(restored["c"])
    assert db.policy.T == pytest.approx(restored["T"])
    # non-owners can't restore
    other = LSMStore(plain_cfg())
    assert tun.restore_best(other) == {}
    db.close(); other.close()
