"""Per-assigned-architecture smoke tests (REDUCED same-family configs):
one train step (finite loss, shapes) + prefill/decode path equivalence.
The FULL configs are exercised only via the dry-run, per the assignment.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke
from repro.data import stub_frontend_inputs
from repro.models import model as M
from repro.models.params import count_params, init_params
from repro.train import OptConfig, init_opt_state, make_train_step


def _batch(cfg, B, S, seed=0):
    rng = np.random.default_rng(seed)
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    b["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    b.update({k: jnp.asarray(v)
              for k, v in stub_frontend_inputs(cfg, B).items()})
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_smoke(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, OptConfig(peak_lr=1e-3,
                                                  warmup_steps=1,
                                                  total_steps=10)))
    batch = _batch(cfg, B=2, S=16)
    new_params, new_opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_opt["step"]) == 1
    # params actually moved
    moved = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), params, new_params))
    assert max(moved) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    """decode(prefill(S-1)) logits == prefill(S) logits — serve path exact.

    Run in float32 compute so the two paths (batched matmuls vs single-token
    matmuls) agree to numerical precision; S=17 so the S-1=16 prefix divides
    the SSD chunk."""
    import dataclasses
    cfg = dataclasses.replace(get_smoke(arch), compute_dtype="float32")
    if cfg.moe is not None:
        # dropless capacity: prefill routes tokens against sequence-wide
        # competition while decode routes alone — with capacity drops the two
        # paths legitimately differ, so remove drops for the equality check.
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(
                cfg.moe, capacity_factor=float(cfg.moe.num_experts)))
    params = init_params(cfg, jax.random.PRNGKey(1))
    B, S = 2, 17
    batch = _batch(cfg, B, S, seed=2)
    full_logits, _ = jax.jit(
        lambda p, b: M.prefill(p, b, cfg, s_max=S + 4))(params, batch)
    short = dict(batch, tokens=batch["tokens"][:, :S - 1])
    _, cache = jax.jit(
        lambda p, b: M.prefill(p, b, cfg, s_max=S + 4))(params, short)
    step_logits, _ = jax.jit(
        lambda p, t, c: M.decode_step(p, t, c, cfg))(
        params, batch["tokens"][:, S - 1:S], cache)
    got = np.asarray(step_logits, np.float32)[:, :cfg.vocab]
    exp = np.asarray(full_logits, np.float32)[:, :cfg.vocab]
    np.testing.assert_allclose(got, exp, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_counts_match_family(arch):
    """Full config param counts are in the family's published ballpark."""
    from repro.configs import get_config
    expected = {
        "whisper_medium": (0.7e9, 1.2e9),
        "mamba2_130m": (0.11e9, 0.16e9),
        "minicpm_2b": (2.0e9, 3.3e9),
        "smollm_135m": (0.12e9, 0.16e9),
        "qwen3_4b": (3.3e9, 4.8e9),
        "gemma3_1b": (0.8e9, 1.3e9),
        "granite_moe_1b_a400m": (1.0e9, 1.7e9),
        "mixtral_8x22b": (1.3e11, 1.5e11),
        "recurrentgemma_2b": (2.2e9, 3.3e9),
        "llama32_vision_90b": (0.8e11, 1.0e11),
    }
    lo, hi = expected[arch]
    n = count_params(get_config(arch))
    assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]"


def test_sliding_window_restricts_attention():
    """A token beyond the window cannot influence a local-attention output.
    (Dense FFN config: MoE capacity routing would legitimately couple distant
    tokens through expert-slot displacement.)"""
    from repro.models.config import ModelConfig
    cfg = ModelConfig("win", n_layers=2, d_model=32, n_q=4, n_kv=2, d_ff=64,
                      vocab=64, d_head=8, layer_pattern=("lattn", "lattn"),
                      window=8, compute_dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(3))
    B, S = 1, 16
    b1 = _batch(cfg, B, S, seed=4)
    toks = np.asarray(b1["tokens"]).copy()
    toks2 = toks.copy()
    toks2[0, 0] = (toks2[0, 0] + 1) % cfg.vocab  # perturb far-away token
    out1, _ = jax.jit(lambda p, b: M.prefill(p, b, cfg, s_max=S))(
        params, dict(b1, tokens=jnp.asarray(toks)))
    out2, _ = jax.jit(lambda p, b: M.prefill(p, b, cfg, s_max=S))(
        params, dict(b1, tokens=jnp.asarray(toks2)))
    # position 15 attends to (7..15] only => logits unchanged
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-5, atol=1e-5)


def test_ring_buffer_matches_full_cache():
    """lattn ring cache (window-sized) == attn full cache restricted by mask."""
    from repro.models.config import ModelConfig
    base = dict(n_layers=2, d_model=32, n_q=4, n_kv=2, d_ff=64, vocab=64,
                d_head=8, window=8, compute_dtype="float32")
    cfg_l = ModelConfig("ring", layer_pattern=("lattn", "lattn"), **base)
    params = init_params(cfg_l, jax.random.PRNGKey(0))
    B, S, gen = 1, 12, 6
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, 64, (B, S + gen)), jnp.int32)
    # path A: direct prefill over longer prompt
    fullA, _ = jax.jit(lambda p, b: M.prefill(p, b, cfg_l, s_max=S + gen))(
        params, {"tokens": toks})
    # path B: prefill prefix, decode the rest through the ring buffer
    _, cache = jax.jit(lambda p, b: M.prefill(p, b, cfg_l, s_max=S + gen))(
        params, {"tokens": toks[:, :S]})
    logits = None
    dec = jax.jit(lambda p, t, c: M.decode_step(p, t, c, cfg_l))
    for i in range(S, S + gen):
        logits, cache = dec(params, toks[:, i:i + 1], cache)
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(fullA, np.float32),
                               rtol=3e-2, atol=3e-2)
