"""Fallback property-testing shim used when ``hypothesis`` is not installed.

The seed test suite property-tests several invariants with hypothesis, but the
bare container does not ship the package (and we may not pip install).  This
module provides just enough of the hypothesis API surface the suite uses —
``given``, ``settings`` and the ``strategies`` combinators below — backed by
fixed-seed random example generators, so the same test bodies run everywhere:

  * with hypothesis installed, ``conftest.py`` leaves the real package alone
    (full shrinking / adaptive search);
  * without it, ``install()`` registers this module as ``sys.modules
    ["hypothesis"]`` and each ``@given`` test runs ``max_examples``
    deterministic examples (example 0 is the minimal draw of every strategy,
    the rest are seeded off the test name so failures reproduce).

Only the strategies the repo uses are implemented: integers, floats, lists,
tuples, sampled_from, booleans, just.
"""
from __future__ import annotations

import random
import sys
import types
from typing import Any, Callable, List, Sequence

_DEFAULT_MAX_EXAMPLES = 25


class Strategy:
    """Base: a strategy draws one example from a ``random.Random``."""

    def example(self, rng: random.Random) -> Any:
        raise NotImplementedError

    def minimal(self) -> Any:
        raise NotImplementedError


class _Integers(Strategy):
    def __init__(self, min_value=None, max_value=None):
        self.lo = -(2 ** 20) if min_value is None else int(min_value)
        self.hi = 2 ** 20 if max_value is None else int(max_value)

    def example(self, rng):
        return rng.randint(self.lo, self.hi)

    def minimal(self):
        # hypothesis shrinks toward 0 when in range, else the bound nearest 0
        return min(max(self.lo, 0), self.hi)


class _Floats(Strategy):
    def __init__(self, min_value=None, max_value=None, allow_nan=None,
                 allow_infinity=None, width=64):
        self.lo = -1e9 if min_value is None else float(min_value)
        self.hi = 1e9 if max_value is None else float(max_value)

    def example(self, rng):
        # occasionally pin to an endpoint: boundary values find more bugs
        u = rng.random()
        if u < 0.05:
            return self.lo
        if u < 0.10:
            return self.hi
        return rng.uniform(self.lo, self.hi)

    def minimal(self):
        return min(max(self.lo, 0.0), self.hi)


class _Lists(Strategy):
    def __init__(self, elements: Strategy, min_size=0, max_size=None,
                 unique=False):
        self.elements = elements
        self.min_size = int(min_size)
        self.max_size = self.min_size + 20 if max_size is None else int(max_size)
        self.unique = unique

    def example(self, rng):
        n = rng.randint(self.min_size, self.max_size)
        out: List[Any] = []
        tries = 0
        while len(out) < n and tries < 50 * (n + 1):
            x = self.elements.example(rng)
            tries += 1
            if self.unique and x in out:
                continue
            out.append(x)
        return out

    def minimal(self):
        return [self.elements.minimal() for _ in range(self.min_size)]


class _Tuples(Strategy):
    def __init__(self, *parts: Strategy):
        self.parts = parts

    def example(self, rng):
        return tuple(p.example(rng) for p in self.parts)

    def minimal(self):
        return tuple(p.minimal() for p in self.parts)


class _SampledFrom(Strategy):
    def __init__(self, options: Sequence[Any]):
        self.options = list(options)

    def example(self, rng):
        return rng.choice(self.options)

    def minimal(self):
        return self.options[0]


class _Just(Strategy):
    def __init__(self, value):
        self.value = value

    def example(self, rng):
        return self.value

    def minimal(self):
        return self.value


def integers(min_value=None, max_value=None) -> Strategy:
    return _Integers(min_value, max_value)


def floats(min_value=None, max_value=None, **kw) -> Strategy:
    return _Floats(min_value, max_value, **kw)


def lists(elements, min_size=0, max_size=None, unique=False) -> Strategy:
    return _Lists(elements, min_size, max_size, unique)


def tuples(*parts) -> Strategy:
    return _Tuples(*parts)


def sampled_from(options) -> Strategy:
    return _SampledFrom(options)


def booleans() -> Strategy:
    return _SampledFrom([False, True])


def just(value) -> Strategy:
    return _Just(value)


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **kw):
    """Decorator recording run options; works above or below ``@given``."""

    def deco(fn):
        fn._shim_settings = dict(max_examples=max_examples)
        return fn

    return deco


def given(*strategies: Strategy, **kw_strategies: Strategy) -> Callable:
    """Run the test body on ``max_examples`` deterministically drawn examples.

    Example 0 is every strategy's minimal draw; examples 1.. are seeded from
    the test name and the example index, so reported failures replay exactly.
    """

    def deco(fn):
        def runner():
            conf = getattr(runner, "_shim_settings", None) or \
                getattr(fn, "_shim_settings", {})
            n = int(conf.get("max_examples", _DEFAULT_MAX_EXAMPLES))
            for i in range(n):
                if i == 0:
                    args = [s.minimal() for s in strategies]
                    kwargs = {k: s.minimal() for k, s in kw_strategies.items()}
                else:
                    rng = random.Random(f"{fn.__module__}.{fn.__name__}#{i}")
                    args = [s.example(rng) for s in strategies]
                    kwargs = {k: s.example(rng)
                              for k, s in kw_strategies.items()}
                try:
                    fn(*args, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example #{i} for {fn.__name__}: "
                        f"args={args!r} kwargs={kwargs!r}") from e

        # pytest must see a zero-arg test function (no fixture params); avoid
        # functools.wraps so inspect.signature doesn't follow __wrapped__.
        runner.__name__ = fn.__name__
        runner.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        runner._hypothesis_shim = True
        return runner

    return deco


def install() -> None:
    """Register this module as a stand-in ``hypothesis`` package."""
    if "hypothesis" in sys.modules:
        return
    hyp = types.ModuleType("hypothesis")
    strat = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "lists", "tuples", "sampled_from",
                 "booleans", "just"):
        setattr(strat, name, globals()[name])
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = strat
    hyp.HealthCheck = types.SimpleNamespace(too_slow="too_slow",
                                            filter_too_much="filter_too_much")
    hyp.__shim__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = strat
