"""REMIX-style cross-run range views (DESIGN.md §13): differential + churn.

The ``MergingIterator`` scan is the retained oracle (and ``scan_scalar``
behind it): with ``use_range_views`` on, every ``scan``/``seek`` result must
be byte-identical — the view changes the cost, never the answer.  On top:

  * property test: random put/delete/overwrite/flush workloads, probed
    after every flush boundary and with live memtable overlays;
  * async churn: scans racing background flush/compaction must return
    correct results whether they hit a fresh view or fall back to the
    merging iterator (``view_fallbacks``), and rebuilds must be charged to
    the scheduler workers (``bg_view_rebuilds``), never the write path;
  * incremental rebuild: per-level column cache reuse across rebuilds,
    cache pruning (no dead-run rooting), COW identity invalidation;
  * accounting: ``view_rebuilds``/``view_entries_built``/``view_scans``
    counters and block charging on the materialization path.

All property tests run under both real hypothesis and the fixed-seed shim
(tests/_hypothesis_compat.py).
"""
import threading

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (LSMConfig, LSMStore, RangeView, build_range_view,
                        make_store)

KEY_SPACE = 500


def cfg(**kw):
    base = dict(policy="garnering", T=2.0, c=0.8, memtable_bytes=1 << 12,
                base_level_bytes=1 << 14, bits_per_key=8,
                bloom_allocation="monkey", use_range_views=True)
    base.update(kw)
    return LSMConfig(**base)


# ------------------------------------------------------- differential oracle
@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_view_scan_matches_scalar_oracle_property(seed):
    """Property: random interleaved puts/deletes/overwrites/flushes — the
    view-backed ``scan`` must equal ``scan_scalar`` (and a plain store's
    scan) at every probe, including probes with a live memtable overlay on
    top of the viewed runs."""
    rng = np.random.default_rng(seed)
    db = LSMStore(cfg())
    plain = LSMStore(cfg(use_range_views=False))
    for i in range(900):
        k = int(rng.integers(0, KEY_SPACE))
        if rng.random() < 0.25:
            db.delete(k)
            plain.delete(k)
        else:
            v = b"s%d-%d" % (seed % 97, i)
            db.put(k, v)
            plain.put(k, v)
        if rng.random() > 0.99:
            db.flush()
            plain.flush()
        if i % 150 == 149:        # probe mid-workload: memtable overlay live
            start = int(rng.integers(0, KEY_SPACE))
            n = int(rng.integers(1, 80))
            got = db.scan(start, n)
            assert got == db.scan_scalar(start, n)
            assert got == plain.scan(start, n)
            assert db.seek(start) == plain.seek(start)
    db.flush()
    plain.flush()
    assert db.scan(0, KEY_SPACE) == plain.scan_scalar(0, KEY_SPACE)
    assert db.stats.view_scans > 0
    db.close()
    plain.close()


def test_view_seek_matches_iterator_seek():
    """``seek`` through the view must equal the run-walk seek on the same
    tree (both share the approximate-liveness contract for run entries and
    exact liveness for memtable entries)."""
    db = LSMStore(cfg())
    plain = LSMStore(cfg(use_range_views=False))
    for k in range(0, 300, 3):
        db.put(k, b"v%d" % k)
        plain.put(k, b"v%d" % k)
    db.flush()
    plain.flush()
    for k in range(60, 120, 3):   # memtable tombstones (filtered by both)
        db.delete(k)
        plain.delete(k)
    for p in (0, 1, 59, 60, 61, 118, 119, 120, 297, 298, 299, 300):
        assert db.seek(p) == plain.seek(p), p
    assert db.stats.view_scans > 0
    db.close()
    plain.close()


# ---------------------------------------------------------- async churn
@given(st.integers(0, 10_000))
@settings(max_examples=4, deadline=None)
def test_view_scans_under_async_churn_match_sync_oracle(seed):
    """Scans racing background flush/compaction (view going stale and
    being rebuilt mid-workload) must stay internally consistent and the
    final quiesced state must match the synchronous oracle byte-for-byte;
    every view rebuild must be charged to a scheduler worker."""
    rng = np.random.default_rng(seed)
    db = LSMStore(cfg(async_compaction=True, compaction_workers=2))
    oracle = LSMStore(cfg(use_range_views=False))
    errors = []
    stop = threading.Event()

    def scanner():
        srng = np.random.default_rng(seed + 1)
        try:
            while not stop.is_set():
                start = int(srng.integers(0, KEY_SPACE))
                got = db.scan(start, 40)
                ks = [k for k, _ in got]
                assert ks == sorted(set(ks)), "view scan not sorted/unique"
                assert all(k >= start for k in ks)
        except Exception as e:
            errors.append(e)

    t = threading.Thread(target=scanner)
    t.start()
    try:
        for wave in range(6):
            ops = []
            for i in range(400):
                k = int(rng.integers(0, KEY_SPACE))
                v = None if rng.random() < 0.2 else b"w%d-%d" % (wave, i)
                ops.append((k, v))
            db.write_batch(ops)
            oracle.write_batch(ops)
        db.flush()
        oracle.flush()
        assert db.wait_for_quiesce(60)
    finally:
        stop.set()
        t.join(timeout=30)
    assert not errors, errors
    # quiesced: a fresh-view scan must be byte-identical to the sync oracle
    assert db.scan(0, KEY_SPACE) == oracle.scan_scalar(0, KEY_SPACE)
    assert db.stats.bg_view_rebuilds > 0
    # in async mode every rebuild runs on a worker — none on the write path
    assert db.stats.view_rebuilds == db.stats.bg_view_rebuilds
    db.close()
    oracle.close()


def test_stale_view_falls_back_to_merging_iterator():
    """The stale window is the gap between a background install and the
    chain-end view refresh.  Reproduced deterministically by suppressing
    the refresh hook around one flush: scans in the window must fall back
    to the merging iterator (counted, never a rebuild on the read path in
    async mode) and still return exact results; once the hook runs again
    the next chain refreshes the view."""
    db = LSMStore(cfg(async_compaction=True, compaction_workers=1))
    try:
        for k in range(0, 200, 2):
            db.put(k, b"a%d" % k)
        db.flush()
        assert db.wait_for_quiesce(60)
        db.scan(0, 5)                   # served fresh (chain-end rebuild)
        fresh_scans = db.stats.view_scans
        orig = db._bg_refresh_view
        db._bg_refresh_view = lambda: None     # freeze mid-chain staleness
        try:
            for k in range(1, 41, 2):
                db.put(k, b"b%d" % k)
            db.flush()
            assert db.wait_for_quiesce(60)     # installed; view left stale
            assert db._view_fresh() is None
            before = db.stats.view_fallbacks
            rebuilds = db.stats.view_rebuilds
            got = db.scan(0, 30)
            assert got == db.scan_scalar(0, 30)
            assert db.stats.view_fallbacks == before + 1
            assert db.stats.view_scans == fresh_scans   # not view-served
            assert db.stats.view_rebuilds == rebuilds   # async: reads never
        finally:                                        # rebuild in-line
            db._bg_refresh_view = orig
        db.put(999, b"tail")
        db.flush()
        assert db.wait_for_quiesce(60)  # chain end refreshes the view again
        assert db._view_fresh() is not None
        assert db.scan(0, 30) == got
        assert db.stats.view_scans == fresh_scans + 1
    finally:
        db.close()


# ------------------------------------------------- incremental rebuild/cache
def test_view_rebuild_reuses_unchanged_level_columns():
    """The per-level column cache must hand back identical column objects
    for levels whose run membership didn't change between rebuilds, and
    must drop entries for retired run sets (no dead-run rooting)."""
    db = LSMStore(cfg(use_range_views=False))   # drive rebuilds by hand
    for k in range(0, 400, 2):
        db.put(k, b"v%d" % k)
    db.flush()
    cache = {}
    v1 = build_range_view(db._levels, cache)
    keys1 = set(cache.keys())
    assert keys1
    v2 = build_range_view(db._levels, cache)
    assert v2.keys is v1.keys or np.array_equal(v2.keys, v1.keys)
    assert set(cache.keys()) == keys1           # nothing invalidated
    # change the tree: new L0 run -> L0 columns recompute, deep levels reuse
    for k in range(1, 101, 2):
        db.put(k, b"w%d" % k)
    db.flush()
    v3 = build_range_view(db._levels, cache)
    assert len(v3) == len(v1) + 50
    for stale in keys1 - set(cache.keys()):     # pruned sets really retired
        pass
    live_ids = {tuple(r.run_id for r in reversed(lvl))
                for lvl in db._levels if any(len(r) for r in lvl)}
    assert set(cache.keys()) <= live_ids | keys1
    for ck in cache:                            # every cached set is live
        assert any(set(ck) <= {r.run_id for r in lvl}
                   for lvl in db._levels)
    db.close()


def test_view_freshness_is_cow_identity():
    """A view is fresh iff it indexes the exact published ``_levels`` list
    object; any install (flush, compaction) swaps that object and the view
    must read as stale with no further bookkeeping."""
    db = LSMStore(cfg())
    for k in range(100):
        db.put(k, b"x%d" % k)
    db.flush()
    db.scan(0, 1)                               # lazy rebuild (sync mode)
    view = db._view_fresh()
    assert view is not None and view.levels_ref is db._levels
    db.put(1000, b"y")
    db.flush()                                  # install -> new list object
    assert db._view_fresh() is None
    assert db.refresh_range_view() is not db._range_view or \
        db._range_view.levels_ref is db._levels
    db.close()


def test_view_holds_runs_alive_across_compaction():
    """A scan through a view captured before a compaction must stay safe:
    the view roots its runs, so the result is still exact for the state it
    indexed even after the tree moved on."""
    db = LSMStore(cfg())
    for k in range(0, 300, 3):
        db.put(k, b"v%d" % k)
    db.flush()
    db.scan(0, 1)
    old_view = db._range_view
    before = old_view.scan(0, 50, (), None, None)
    for k in range(0, 300, 3):                  # overwrite + force churn
        db.put(k, b"w%d" % k)
    db.flush()
    # the retired view still answers for its frozen state
    assert old_view.scan(0, 50, (), None, None) == before
    # and the live store serves the new values through a fresh view
    assert db.scan(0, 3)[0][1] == b"w0"
    db.close()


# ------------------------------------------------------------- accounting
def test_view_counters_and_block_charging():
    """``view_rebuilds``/``view_entries_built`` charge per rebuild,
    ``view_scans`` per view-served read, and materialization charges
    ``blocks_read`` like any other read path (through the cache when one
    is attached)."""
    db = LSMStore(cfg())
    n = 600
    db.put_batch(list(range(n)), [b"val%05d" % k for k in range(n)])
    db.flush()
    assert db.stats.view_rebuilds == 0          # write path never rebuilds
    s0 = db.stats.snapshot()
    got = db.scan(0, 64)
    assert len(got) == 64
    d = db.stats.delta(s0)
    assert d.view_rebuilds == 1                 # lazy, on first read
    assert d.bg_view_rebuilds == 0              # sync mode: foreground read
    assert d.view_entries_built == db.total_live_entries()
    assert d.view_scans == 1 and d.view_fallbacks == 0
    assert d.blocks_read > 0                    # materialization was charged
    s1 = db.stats.snapshot()
    db.scan(0, 64)
    d2 = db.stats.delta(s1)
    assert d2.view_rebuilds == 0                # fresh view: no rebuild
    assert d2.view_scans == 1
    # snapshot reads never take the view path (views index the live tree)
    snap = db.get_snapshot()
    s2 = db.stats.snapshot()
    db.scan(0, 10, snapshot=snap)
    assert db.stats.delta(s2).view_scans == 0
    db.release_snapshot(snap)
    db.close()


def test_view_counters_aggregate_across_shards():
    """The sharded facade's summed IOStats must include the §13 counters
    (fieldwise-declared aggregation), and per-shard lazy rebuilds happen
    independently."""
    db = make_store(cfg(shards=2, shard_splitters=(KEY_SPACE // 2,)))
    try:
        for k in range(0, KEY_SPACE, 2):
            db.put(k, b"v%d" % k)
        db.flush()
        got = db.scan(0, KEY_SPACE)             # spans both shards
        assert [k for k, _ in got] == list(range(0, KEY_SPACE, 2))
        assert db.scan(0, KEY_SPACE) == db.scan_scalar(0, KEY_SPACE)
        assert db.stats.view_rebuilds == 2      # one lazy rebuild per shard
        assert db.stats.view_scans >= 2
        assert all(s.stats.view_rebuilds == 1 for s in db.shards)
    finally:
        db.close()


def test_view_scan_with_tombstone_dense_prefix():
    """The view sweep must cross a huge dead prefix in geometrically
    growing windows (no O(deleted) scans) and return exactly the live
    tail, matching ``scan_scalar``."""
    db = LSMStore(cfg(memtable_bytes=1 << 16, base_level_bytes=1 << 18,
                      bits_per_key=0))
    n, tail = 40_000, 500
    wave = 8_192
    for i in range(0, n, wave):
        ks = list(range(i, min(i + wave, n)))
        db.put_batch(ks, [b"v%d" % k for k in ks])
    for i in range(0, n - tail, wave):
        db.delete_batch(list(range(i, min(i + wave, n - tail))))
    db.flush()
    got = db.scan(0, 100)
    assert got == db.scan_scalar(0, 100)
    assert [k for k, _ in got] == list(range(n - tail, n - tail + 100))
    assert db.stats.view_scans > 0
    db.close()


def test_empty_store_and_edge_probes():
    db = LSMStore(cfg())
    assert db.scan(0, 10) == []
    assert db.seek(0) is None
    db.put(5, b"five")
    db.flush()
    assert db.scan(0, 10) == [(5, b"five")]
    assert db.scan(6, 10) == []
    assert db.seek(6) is None
    assert db.scan(5, 0) == []
    view = db._view_fresh() or db.refresh_range_view()
    assert isinstance(view, RangeView) and len(view) == 1
    db.close()
