"""Memory subsystem tests: BlockCache, pinned L0, and cache accounting.

Property tests (shim-compatible, see ``_hypothesis_compat``) pin down:

  * LRU eviction order against a reference model, CLOCK invariants
    (capacity, second chance, byte accounting);
  * pinned-L0 residency across flushes and compactions, including the
    invalidation protocol (no cached block may outlive its run);
  * ``IOStats`` hit/miss accounting: on a read-only window,
    ``blocks_read == cache_miss_blocks`` and ``hits + misses`` equals the
    block charge of an identically built cache-less store;
  * the ISSUE acceptance criterion: with ``pin_l0_bytes`` sized to hold L0,
    a compacted store answers point/range reads with ``cache_hit_blocks > 0``
    and strictly fewer charged ``blocks_read`` than the cache-disabled
    configuration, returning identical values (differential vs scalar
    ``get`` / ``scan_scalar``).
"""
import dataclasses
from collections import OrderedDict

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import BlockCache, LSMConfig, LSMStore
from repro.core.types import IOStats


def make_db(cache_bytes=0, pin_l0_bytes=0, policy="clock", **kw):
    base = dict(policy="garnering", c=0.8, T=2.0, memtable_bytes=1 << 11,
                base_level_bytes=1 << 13, bits_per_key=8,
                bloom_allocation="monkey", cache_bytes=cache_bytes,
                pin_l0_bytes=pin_l0_bytes, cache_policy=policy)
    base.update(kw)
    return LSMStore(LSMConfig(**base))


def fill(db, seed, n_ops=1200, key_space=300):
    rng = np.random.default_rng(seed)
    oracle = {}
    for i in range(n_ops):
        k = int(rng.integers(0, key_space))
        if rng.random() < 0.15:
            db.delete(k)
            oracle.pop(k, None)
        else:
            v = f"s{seed}i{i}".encode()
            db.put(k, v)
            oracle[k] = v
    db.flush()
    return oracle


# --------------------------------------------------------------- BlockCache
BLOCK_NBYTES = 512


@settings(max_examples=40)
@given(st.lists(st.tuples(st.integers(0, 4), st.integers(0, 15)),
                min_size=0, max_size=120),
       st.integers(1, 12))
def test_lru_eviction_order_matches_reference_model(accesses, cap_blocks):
    """LRU contents after any access sequence == an OrderedDict LRU model."""
    cache = BlockCache(cap_blocks * BLOCK_NBYTES, policy="lru")
    model = OrderedDict()
    stats = IOStats()
    for rid, bid in accesses:
        hit = cache.read_block(rid, bid, BLOCK_NBYTES, stats)
        assert hit == ((rid, bid) in model)
        if (rid, bid) in model:
            model.move_to_end((rid, bid))
        else:
            while len(model) >= cap_blocks:
                model.popitem(last=False)
            model[(rid, bid)] = True
    assert set(cache._entries) == set(model)
    assert list(cache._entries) == list(model)  # exact recency order
    assert cache.charged_bytes == len(model) * BLOCK_NBYTES
    assert stats.cache_hit_blocks == cache.hits
    assert stats.cache_miss_blocks == cache.misses == stats.blocks_read


@settings(max_examples=40)
@given(st.lists(st.integers(0, 25), min_size=0, max_size=150),
       st.integers(1, 10),
       st.sampled_from(["clock", "lru"]))
def test_cache_capacity_and_accounting_invariants(blocks, cap_blocks, policy):
    """Any policy: bytes bound respected, hits+misses == accesses, and the
    charged byte count always equals the sum of resident entry sizes."""
    cache = BlockCache(cap_blocks * BLOCK_NBYTES, policy=policy)
    stats = IOStats()
    for bid in blocks:
        cache.read_block(0, bid, BLOCK_NBYTES, stats)
        assert cache.charged_bytes <= cache.capacity_bytes
        assert cache.charged_bytes == sum(
            e[0] for e in cache._entries.values())
    assert cache.hits + cache.misses == len(blocks)
    assert cache.misses == stats.blocks_read
    assert cache.misses - cache.evictions == len(cache._entries)


def test_clock_gives_hot_entry_a_second_chance():
    """A re-referenced block survives a full eviction sweep; under plain FIFO
    (no ref bit) it would have been the first to go."""
    cache = BlockCache(4 * BLOCK_NBYTES, policy="clock")
    stats = IOStats()
    for bid in range(4):
        cache.read_block(0, bid, BLOCK_NBYTES, stats)   # fill: 0 oldest
    cache.read_block(0, 0, BLOCK_NBYTES, stats)         # set 0's ref bit
    for bid in range(4, 7):
        cache.read_block(0, bid, BLOCK_NBYTES, stats)   # force 3 evictions
    assert (0, 0) in cache                              # second chance
    assert (0, 1) not in cache and (0, 2) not in cache  # cold ones evicted


def test_pinned_blocks_never_evicted_by_pressure():
    cache = BlockCache(2 * BLOCK_NBYTES, policy="clock")
    stats = IOStats()
    cache.set_pinned({(99, 0): BLOCK_NBYTES, (99, 1): BLOCK_NBYTES})
    for bid in range(20):
        cache.read_block(0, bid, BLOCK_NBYTES, stats)
    assert (99, 0) in cache and (99, 1) in cache
    assert cache.pinned_bytes == 2 * BLOCK_NBYTES
    assert cache.charged_bytes <= cache.capacity_bytes
    # pinned reads are hits and charge no block I/O
    s = IOStats()
    assert cache.read_block(99, 0, BLOCK_NBYTES, s)
    assert s.cache_hit_blocks == 1 and s.blocks_read == 0


# ------------------------------------------------------- pinned L0 residency
@settings(max_examples=8)
@given(st.integers(1, 5), st.sampled_from(["clock", "lru"]))
def test_pinned_l0_residency_across_flush_and_compaction(seed, policy):
    """After every flush/compaction, exactly the L0 runs that fit the pin
    budget are resident, and no cached block references a dead run."""
    db = make_db(cache_bytes=1 << 16, pin_l0_bytes=1 << 20, policy=policy)
    rng = np.random.default_rng(seed)
    for i in range(900):
        db.put(int(rng.integers(0, 200)), f"x{i}".encode())
        if i % 90 == 89:
            db.flush()
            l0_ids = [r.run_id for r in db._levels[0]]
            assert sorted(db.pinned_l0.pinned_run_ids) == sorted(l0_ids)
            live = set(db.storage.ids())
            for rid, _ in list(db.block_cache._entries) + \
                    list(db.block_cache._pinned):
                assert rid in live
            # every L0 block answers from DRAM: hit, no I/O charge
            for run in db._levels[0]:
                s = IOStats()
                assert db.block_cache.read_block(
                    run.run_id, 0, run.block_bytes(0), s)
                assert s.blocks_read == 0
    # pinned bytes never exceed the budget
    assert db.block_cache.pinned_bytes <= 1 << 20


def test_pin_budget_prefers_newest_runs():
    """When L0 outgrows pin_l0_bytes, newest runs win the budget."""
    db = make_db(cache_bytes=1 << 16, pin_l0_bytes=1 << 12,
                 l0_compaction_trigger=64, l0_stop_writes_trigger=128,
                 base_level_bytes=1 << 22)
    for wave in range(6):
        for k in range(40):
            db.put(k + 1000 * wave, bytes(40))
        db.flush()
    l0 = db._levels[0]
    assert len(l0) >= 2
    pinned = set(db.pinned_l0.pinned_run_ids)
    assert pinned and db.block_cache.pinned_bytes <= 1 << 12
    # the newest run always gets the first claim on the budget
    assert l0[-1].run_id in pinned
    # newest-first greedy: every pinned run fit the budget remaining after
    # all newer pinned runs were admitted
    budget = 1 << 12
    for r in reversed(l0):
        if r.run_id in pinned:
            assert r.data_bytes <= budget
            budget -= r.data_bytes


# -------------------------------------------------- IOStats hit/miss algebra
@settings(max_examples=6)
@given(st.integers(1, 4), st.sampled_from(["clock", "lru"]))
def test_hit_miss_accounting_vs_uncached_twin(seed, policy):
    """Identically built stores: on a read-only window the cached store's
    ``hits + misses`` equals the uncached store's ``blocks_read``, and its
    charged ``blocks_read`` equals its misses exactly."""
    db_u = make_db()
    db_c = make_db(cache_bytes=1 << 22, pin_l0_bytes=1 << 20, policy=policy)
    oracle = fill(db_u, seed)
    assert fill(db_c, seed) == oracle
    queries = list(np.random.default_rng(seed).integers(0, 350, 250))
    s_u = db_u.stats.snapshot()
    s_c = db_c.stats.snapshot()
    got_u = [db_u.get(int(k)) for k in queries]
    got_c = [db_c.get(int(k)) for k in queries]
    assert got_u == got_c == [oracle.get(int(k)) for k in queries]
    d_u = db_u.stats.delta(s_u)
    d_c = db_c.stats.delta(s_c)
    assert d_c.blocks_read == d_c.cache_miss_blocks
    assert d_c.cache_hit_blocks + d_c.cache_miss_blocks == d_u.blocks_read
    # CPU-side counters are cache-independent
    for f in ("bloom_probes", "bloom_negatives", "runs_touched_point",
              "point_reads"):
        assert getattr(d_c, f) == getattr(d_u, f), f
    # scans: same equality on the iterator path
    s_u = db_u.stats.snapshot()
    s_c = db_c.stats.snapshot()
    assert db_u.scan(0, 100) == db_c.scan(0, 100)
    d_u = db_u.stats.delta(s_u)
    d_c = db_c.stats.delta(s_c)
    assert d_c.blocks_read == d_c.cache_miss_blocks
    assert d_c.cache_hit_blocks + d_c.cache_miss_blocks == d_u.blocks_read


def test_multi_get_cached_matches_scalar_results():
    """multi_get through the cache returns scalar-get results; its block
    *touches* (hits+misses) match the scalar pass touch-for-touch when the
    cache is large enough that no eviction interleaves."""
    db = make_db(cache_bytes=1 << 22, pin_l0_bytes=1 << 20)
    oracle = fill(db, seed=9)
    queries = list(np.random.default_rng(2).integers(0, 350, 300)) + [5, 5]
    scalar = [db.get(int(k)) for k in queries]
    s0 = db.stats.snapshot()
    batch = db.multi_get(queries)
    d = db.stats.delta(s0)
    assert batch == scalar == [oracle.get(int(k)) for k in queries]
    # warmed cache + ample capacity: the batched pass re-touches the same
    # blocks, all hits
    assert d.cache_miss_blocks == 0 and d.blocks_read == 0
    assert d.cache_hit_blocks > 0


# ------------------------------------------------------ acceptance criterion
@pytest.mark.parametrize("policy", ["clock", "lru"])
def test_cached_reads_cheaper_identical_results(policy):
    """ISSUE acceptance: pin_l0_bytes sized to hold L0 => point/range reads
    over a compacted store report cache_hit_blocks > 0 and strictly fewer
    charged blocks_read than the cache-disabled config, identical values."""
    db_off = make_db()
    db_on = make_db(cache_bytes=1 << 21, pin_l0_bytes=1 << 21, policy=policy)
    oracle = fill(db_off, seed=3, n_ops=2500)
    assert fill(db_on, seed=3, n_ops=2500) == oracle
    assert db_on.stats.compactions > 0        # compacted store
    queries = list(np.random.default_rng(4).integers(0, 400, 500))
    expect = [oracle.get(int(k)) for k in queries]
    # oracle passes first, OUTSIDE the measured windows, so the two windows
    # below contain exactly the same operations on both stores
    wants = {start: db_off.scan_scalar(start, 60) for start in (0, 100, 333)}
    s_off = db_off.stats.snapshot()
    s_on = db_on.stats.snapshot()
    assert [db_off.get(int(k)) for k in queries] == expect
    assert [db_on.get(int(k)) for k in queries] == expect
    for start, want in wants.items():
        assert db_off.scan(start, 60) == want
        assert db_on.scan(start, 60) == want
    d_off = db_off.stats.delta(s_off)
    d_on = db_on.stats.delta(s_on)
    assert d_on.cache_hit_blocks > 0
    assert d_on.blocks_read < d_off.blocks_read


def test_configure_cache_on_live_store_and_detach():
    db = make_db()
    oracle = fill(db, seed=7)
    base = [oracle.get(k) for k in range(50)]
    assert [db.get(k) for k in range(50)] == base
    db.configure_cache(1 << 20, 1 << 20)
    assert [db.get(k) for k in range(50)] == base
    assert db.stats.cache_hit_blocks + db.stats.cache_miss_blocks > 0
    assert db.cache_summary()["enabled"]
    db.configure_cache(0, 0)                  # detach: raw accounting again
    s0 = db.stats.snapshot()
    assert [db.get(k) for k in range(50)] == base
    d = db.stats.delta(s0)
    assert d.cache_hit_blocks == 0 and d.cache_miss_blocks == 0
    assert d.blocks_read > 0


def test_cache_invalidation_on_compaction_and_recover():
    db = make_db(cache_bytes=1 << 20, pin_l0_bytes=1 << 20)
    fill(db, seed=11, n_ops=2000)
    [db.get(k) for k in range(100)]           # populate cache
    for rid, _ in list(db.block_cache._entries) + list(db.block_cache._pinned):
        assert rid in set(db.storage.ids())
    # crash+recover: DRAM is volatile, pin set rebuilt from recovered L0 —
    # and reloading the resident blocks is charged as real device reads
    s0 = db.stats.snapshot()
    db.crash()
    db.recover()
    d = db.stats.delta(s0)
    n_pinned = len(db.block_cache._pinned)
    assert d.cache_miss_blocks == d.blocks_read == n_pinned
    assert db.block_cache.charged_bytes == 0
    assert sorted(db.pinned_l0.pinned_run_ids) == \
        sorted(r.run_id for r in db._levels[0] if len(r))
    s0 = db.stats.snapshot()
    db.get(0)
    assert db.stats.delta(s0).point_reads == 1


# ------------------------------------------------------ snapshot refcounting
def test_snapshot_refcounting_shared_version():
    """Two readers pinning one version: the first release must not unpin."""
    db = make_db()
    for k in range(60):
        db.put(k, b"old")
    db.flush()
    s1 = db.get_snapshot()
    s2 = db.get_snapshot()
    assert s1.version_id == s2.version_id
    assert db.manifest.pin_count(s1.version_id) == 2
    for rep in range(20):                     # churn past the manifest tail
        for k in range(60):
            db.put(k, f"r{rep}".encode())
        db.flush()
    db.release_snapshot(s1)
    # second reader still holds the version: reads stay valid
    assert db.manifest.pin_count(s2.version_id) == 1
    assert db.get(5, snapshot=s2) == b"old"
    assert db.scan(5, 2, snapshot=s2) == [(5, b"old"), (6, b"old")]
    db.release_snapshot(s2)
    assert db.manifest.pin_count(s2.version_id) == 0
    assert db.get(5) == b"r19"
    # over-release is harmless (refcount floors at zero)
    db.release_snapshot(s2)
    assert db.manifest.pin_count(s2.version_id) == 0


def test_snapshot_reads_with_cache_enabled_survive_churn():
    """Snapshot-pinned runs keep their cached blocks across compactions."""
    db = make_db(cache_bytes=1 << 20, pin_l0_bytes=1 << 16)
    for k in range(80):
        db.put(k, b"snap")
    db.flush()
    snap = db.get_snapshot()
    for rep in range(15):
        for k in range(80):
            db.put(k, f"n{rep}".encode())
        db.flush()
    assert db.multi_get([1, 2, 3], snapshot=snap) == [b"snap"] * 3
    live = set(db.storage.ids())
    for rid, _ in list(db.block_cache._entries):
        assert rid in live
    db.release_snapshot(snap)
    live = set(db.storage.ids())
    for rid, _ in list(db.block_cache._entries):
        assert rid in live
